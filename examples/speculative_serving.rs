//! Speculative serving: prompt-lookup drafts verified as chunked
//! attention steps — the new-workload demo.
//!
//! A repetition-heavy workload (small-vocab reference model whose greedy
//! decode settles into short cycles — the regime self-drafting exists
//! for) runs twice through the full coordinator stack:
//!
//! * **decode-only** — the non-speculative pipeline: every generated
//!   token costs one engine tick;
//! * **speculative** — each decoding slot's prompt-lookup draft rides the
//!   tick as a verification chunk (`StepRunner::verify_chunk`), so one
//!   prefill-shaped step can emit up to `max_draft + 1` tokens.
//!
//! The run asserts the claims that matter: **bit-identical outputs** and
//! **≥ 1.5x fewer engine steps**, and prints per-tick plan summaries plus
//! the acceptance histogram so mixed decode+prefill+verify ticks are
//! inspectable.
//!
//!     cargo run --release --example speculative_serving
//!     cargo run --release --example speculative_serving -- --max-draft 8

use flashmla_etap::coordinator::{Engine, EngineConfig, EngineReport, GenerationRequest};
use flashmla_etap::runtime::ReferenceModelConfig;
use flashmla_etap::spec::SpecConfig;
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::rng::Rng;

const BLOCK_SIZE: usize = 8;
const VOCAB: usize = 16;

fn model() -> ReferenceModelConfig {
    ReferenceModelConfig {
        vocab: VOCAB,
        n_layers: 2,
        latent_dim: 8,
        // Seed chosen so greedy decode reliably enters short cycles —
        // the repetitive regime prompt lookup drafts for.
        seed: 21,
        batch_buckets: vec![1, 2, 4],
        kv_buckets: vec![32, 64, 128],
    }
}

fn run(
    work: &[(Vec<i32>, usize)],
    slots: usize,
    spec: SpecConfig,
    show_plans: usize,
) -> anyhow::Result<EngineReport> {
    let mut engine = Engine::reference(
        model(),
        EngineConfig {
            max_slots: slots,
            kv_blocks: 256,
            block_size: BLOCK_SIZE,
            spec,
            ..EngineConfig::default()
        },
    )?;
    for (p, b) in work {
        engine.submit(GenerationRequest::new(p.clone(), *b));
    }
    // Drive ticks manually so the first few plans can be shown (the
    // planner's `plan_summary` — d=decode, p=prefill, v=verify slots).
    let mut tick = 0usize;
    while engine.has_work() {
        engine.step()?;
        tick += 1;
        if tick <= show_plans {
            println!("    tick {tick:>3}: {}", engine.last_plan_summary());
        }
    }
    Ok(engine.into_report())
}

fn main() -> anyhow::Result<()> {
    let p = ArgParser::new(
        "speculative_serving",
        "speculative decoding demo: decode-only vs prompt-lookup + verify chunks",
    )
    .opt("requests", Some("4"), "number of requests")
    .opt("prompt-len", Some("24"), "prompt length in tokens")
    .opt("max-new", Some("48"), "generated tokens per request")
    .opt("max-draft", Some("4"), "draft tokens verified per tick (k)")
    .opt("lookback", Some("64"), "drafter history window")
    .opt("slots", Some("4"), "batch slots")
    .opt("show-plans", Some("8"), "print the first N tick plans")
    .opt("seed", Some("42"), "workload rng seed");
    let a = p.parse_or_exit();
    let quick = std::env::var("FLASHMLA_BENCH_QUICK").is_ok();
    let n = a.get_usize("requests").unwrap();
    let prompt_len = a.get_usize("prompt-len").unwrap();
    let mut max_new = a.get_usize("max-new").unwrap();
    if quick {
        max_new = max_new.min(32);
    }
    let slots = a.get_usize("slots").unwrap();
    let max_draft = a.get_usize("max-draft").unwrap();
    let lookback = a.get_usize("lookback").unwrap();
    let show_plans = a.get_usize("show-plans").unwrap();

    let mut rng = Rng::new(a.get_u64("seed").unwrap());
    let work: Vec<(Vec<i32>, usize)> = (0..n)
        .map(|_| {
            let p: Vec<i32> = (0..prompt_len)
                .map(|_| rng.range(1, VOCAB as u64) as i32)
                .collect();
            (p, max_new)
        })
        .collect();

    println!(
        "{n} requests × {prompt_len}-token prompts, {max_new} new tokens each, \
         {slots} slots, draft k={max_draft}, lookback {lookback}\n"
    );

    println!("[decode-only]");
    let base = run(&work, slots, SpecConfig::default(), show_plans)?;
    println!("    {}\n", base.metrics.report());

    println!("[speculative]");
    let spec = SpecConfig {
        enabled: true,
        lookback,
        max_draft,
        ..SpecConfig::default()
    };
    let fast = run(&work, slots, spec, show_plans)?;
    println!("    {}", fast.metrics.report());
    println!(
        "    acceptance histogram (accepted×count): {}\n",
        fast.metrics.accept_hist_summary()
    );

    // 1. Speculation is a pure optimization: outputs bit-identical.
    anyhow::ensure!(
        base.outputs == fast.outputs,
        "speculative decoding changed generated tokens!"
    );
    println!("✓ all {n} output sequences bit-identical to decode-only");

    // 2. The acceptance bar: ≥ 1.5x fewer engine steps on this workload.
    anyhow::ensure!(
        fast.steps * 3 <= base.steps * 2,
        "expected ≥ 1.5x fewer engine steps, got {} → {}",
        base.steps,
        fast.steps
    );
    println!(
        "✓ engine steps {} → {} ({:.2}x fewer): {} drafts accepted of {} \
         ({:.0}%), {} decode steps saved over {} verifications",
        base.steps,
        fast.steps,
        base.steps as f64 / fast.steps as f64,
        fast.metrics.spec_accepted,
        fast.metrics.spec_drafted,
        fast.metrics.acceptance_rate() * 100.0,
        fast.metrics.spec_steps_saved(),
        fast.metrics.spec_verify_chunks,
    );

    // 3. Same tokens, fewer ticks — the whole point.
    anyhow::ensure!(
        base.metrics.tokens_generated == fast.metrics.tokens_generated,
        "token accounting diverged"
    );
    println!(
        "✓ same {} tokens generated in {:.1} vs {:.1} tokens/step",
        fast.metrics.tokens_generated,
        fast.metrics.tokens_generated as f64 / fast.steps as f64,
        base.metrics.tokens_generated as f64 / base.steps as f64,
    );
    Ok(())
}
