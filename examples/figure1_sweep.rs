//! Reproduce Figure 1(a) and 1(b): decode-attention throughput (TFLOPS/s)
//! for FlashMLA-ETAP / FlashMLA / FlashAttention-3 / FlashInfer across
//! sequence lengths 512…64K at batch 16 and 32, on the H20 performance
//! model (we have no H20 — see DESIGN.md §2).
//!
//!     cargo run --release --example figure1_sweep [--csv]

use flashmla_etap::hardware::GpuSpec;
use flashmla_etap::sim::figures;

fn main() {
    let csv = std::env::args().any(|a| a == "--csv");
    let gpu = GpuSpec::h20();
    for batch in [16usize, 32] {
        let t = figures::figure1_table(batch, &gpu);
        if csv {
            print!("{}", t.csv());
            continue;
        }
        t.print();
        let r = figures::headline_ratios(batch, &gpu);
        let fidelity = figures::model_fidelity(batch, &gpu);
        println!(
            "headline @batch {batch}: ETAP/FlashMLA {:.2}x @64K, {:.2}x @512 | \
             ETAP/FA-3 {:.2}x | ETAP/FlashInfer {:.2}x",
            r.speedup_vs_flashmla_64k,
            r.speedup_vs_flashmla_512,
            r.speedup_vs_fa3_64k,
            r.speedup_vs_flashinfer_64k
        );
        println!(
            "paper     @batch 16: 2.78x @64K, 1.44x @512 | 5.24x | 4.94x ; \
             mean |model-paper|/paper over the {} bars: {:.0}%\n",
            8 * 4,
            fidelity * 100.0
        );
    }
    println!(
        "who-wins / shape checks: ETAP leads everywhere; its margin over FlashMLA \
         grows monotonically with context (padding amortization), FA-3/FlashInfer \
         stay flat (uncompressed-KV memory bound + 4x padding) — matching §4.2."
    );
}
