//! Chunked-prefill serving: the token-budget pipeline's headline demo.
//!
//! N long prompts run twice through the full coordinator stack on the
//! deterministic reference backend:
//!
//! * **per-token** — the old prefill-as-decode pipeline: every prompt
//!   token costs one engine step;
//! * **chunked** — the token-budget planner packs multi-token prefill
//!   chunks (and decode singles) into each step, executed through the
//!   backend's multi-token `prefill_chunk` operation.
//!
//! The run asserts the claims that matter: ≥ 4x fewer prefill engine
//! steps at chunk budget 8, bit-identical generated tokens, and (with
//! `--shared-prefix`) clean composition with the prefix cache — adopted
//! prefixes are never re-chunked.
//!
//!     cargo run --release --example chunked_prefill_serving
//!     cargo run --release --example chunked_prefill_serving -- --shared-prefix 24

use flashmla_etap::coordinator::{Engine, EngineConfig, EngineReport, GenerationRequest};
use flashmla_etap::prefill::{FairnessPolicy, PrefillConfig};
use flashmla_etap::runtime::ReferenceModelConfig;
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::rng::Rng;

const BLOCK_SIZE: usize = 8;

struct Workload {
    prompts: Vec<Vec<i32>>,
    budgets: Vec<usize>,
}

fn synth_workload(n: usize, prompt_len: usize, shared: usize, seed: u64, vocab: usize) -> Workload {
    let mut rng = Rng::new(seed);
    let system: Vec<i32> = (0..shared)
        .map(|_| rng.range(1, vocab as u64) as i32)
        .collect();
    let mut prompts = Vec::new();
    let mut budgets = Vec::new();
    for _ in 0..n {
        let mut p = system.clone();
        while p.len() < prompt_len {
            p.push(rng.range(1, vocab as u64) as i32);
        }
        prompts.push(p);
        budgets.push(rng.range(3, 8) as usize);
    }
    Workload { prompts, budgets }
}

fn run(
    w: &Workload,
    slots: usize,
    prefix_cache: bool,
    prefill: PrefillConfig,
) -> anyhow::Result<EngineReport> {
    let model = ReferenceModelConfig {
        kv_buckets: vec![32, 64, 128],
        ..ReferenceModelConfig::default()
    };
    let mut engine = Engine::reference(
        model,
        EngineConfig {
            max_slots: slots,
            kv_blocks: 256,
            block_size: BLOCK_SIZE,
            prefix_cache,
            prefill,
            ..EngineConfig::default()
        },
    )?;
    for (p, &b) in w.prompts.iter().zip(&w.budgets) {
        engine.submit(GenerationRequest::new(p.clone(), b));
    }
    engine.run_to_completion()
}

fn main() -> anyhow::Result<()> {
    let p = ArgParser::new(
        "chunked_prefill_serving",
        "chunked-prefill demo: per-token vs token-budget pipeline",
    )
    .opt("requests", Some("8"), "number of requests")
    .opt("prompt-len", Some("32"), "prompt length in tokens")
    .opt("shared-prefix", Some("0"), "tokens of shared system prefix (0 = unique prompts)")
    .opt("chunk-tokens", Some("8"), "max prefill tokens per request per step")
    .opt("budget", Some("32"), "per-step token budget across all slots")
    .opt("slots", Some("4"), "batch slots")
    .opt("fairness", Some("fair"), "surplus policy: fair|fifo")
    .opt("seed", Some("42"), "rng seed");
    let a = p.parse_or_exit();
    // CI quick mode (same switch as the bench harness): cap the workload
    // so the demo's assertions run in milliseconds.
    let quick = std::env::var("FLASHMLA_BENCH_QUICK").is_ok();
    let mut n = a.get_usize("requests").unwrap();
    let mut prompt_len = a.get_usize("prompt-len").unwrap();
    let mut shared = a.get_usize("shared-prefix").unwrap();
    if quick {
        n = n.min(6);
        prompt_len = prompt_len.min(24);
        // Keep a user-supplied prefix consistent with the capped prompt.
        shared = shared.min(prompt_len.saturating_sub(BLOCK_SIZE));
    }
    let slots = a.get_usize("slots").unwrap();
    let chunk_tokens = a.get_usize("chunk-tokens").unwrap();
    let budget = a.get_usize("budget").unwrap();
    let fairness = match a.get("fairness").unwrap_or("fair") {
        "fifo" => FairnessPolicy::Fifo,
        _ => FairnessPolicy::Fair,
    };
    anyhow::ensure!(shared < prompt_len, "--shared-prefix must be < --prompt-len");

    let w = synth_workload(n, prompt_len, shared, a.get_u64("seed").unwrap(), 512);
    let prefix_cache = shared > 0;
    println!(
        "{n} requests × {prompt_len}-token prompts ({} shared), {slots} slots, \
         chunk {chunk_tokens}, budget {budget}, prefix cache {}\n",
        shared,
        if prefix_cache { "on" } else { "off" },
    );

    let base = run(&w, slots, prefix_cache, PrefillConfig::per_token())?;
    println!("[per-token] {}", base.metrics.report());
    let chunked_cfg = PrefillConfig {
        step_token_budget: budget,
        chunk_tokens,
        fairness,
        ..PrefillConfig::default()
    };
    let fast = run(&w, slots, prefix_cache, chunked_cfg)?;
    println!("[chunked]   {}", fast.metrics.report());
    println!(
        "            chunk histogram: {}\n",
        fast.metrics.chunk_hist_summary()
    );

    // 1. Chunking is a pure optimization: generated tokens bit-identical.
    anyhow::ensure!(
        base.outputs == fast.outputs,
        "chunked prefill changed generated tokens!"
    );
    println!("✓ all {n} output sequences bit-identical to the per-token run");

    // 2. Prefill engine steps collapse by ≥ 4x (the acceptance bar at
    // chunk budget 8; higher chunk settings do better).
    let (b_steps, f_steps) = (base.metrics.prefill_steps, fast.metrics.prefill_steps);
    anyhow::ensure!(
        f_steps > 0 && f_steps * 4 <= b_steps,
        "expected ≥ 4x fewer prefill steps, got {b_steps} → {f_steps}"
    );
    println!(
        "✓ prefill engine steps {b_steps} → {f_steps} ({:.1}x fewer), \
         {:.1} prefill tokens/step (was {:.1})",
        b_steps as f64 / f_steps as f64,
        fast.metrics.prefill_tokens_per_step(),
        base.metrics.prefill_tokens_per_step(),
    );
    anyhow::ensure!(fast.steps < base.steps, "total engine steps should drop");
    println!(
        "✓ total engine steps {} → {}, ttft proxy {:.1} → {:.1} steps",
        base.steps,
        fast.steps,
        base.metrics.ttft_steps.mean(),
        fast.metrics.ttft_steps.mean(),
    );

    // 3. With a shared prefix, the cache and the chunker compose.
    if prefix_cache {
        anyhow::ensure!(
            fast.metrics.prefix.hits > 0,
            "expected prefix hits with --shared-prefix"
        );
        anyhow::ensure!(
            fast.metrics.prefill_tokens < n as u64 * prompt_len as u64,
            "adopted prefixes must not be re-chunked"
        );
        println!(
            "✓ prefix cache composed: {} hits, {} prompt tokens skipped, \
             only unshared suffixes chunked",
            fast.metrics.prefix.hits,
            fast.metrics.prefix.hit_tokens,
        );
    }
    Ok(())
}
