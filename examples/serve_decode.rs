//! End-to-end serving driver (the prompt-mandated workload): serve a batch
//! of synthetic requests through the full coordinator stack — router →
//! continuous batcher → decode engine → paged latent KV store — and report
//! latency/throughput.
//!
//! With AOT artifacts present (`make artifacts`), the workload runs on the
//! PJRT backend under both attention modes to demonstrate that the
//! computation mode changes performance bookkeeping but not a single
//! output token (paper §3.1 equivalence).  Without artifacts it falls back
//! to the deterministic pure-Rust reference backend, comparing prefix
//! sharing on/off instead.
//!
//! `--shared-prefix <len>` prepends a common `len`-token system prefix to
//! every synthetic prompt, so the prefix-cache hit path is exercised
//! directly from this example.
//!
//!     cargo run --release --example serve_decode -- --shared-prefix 32
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::path::PathBuf;
use std::time::Instant;

use flashmla_etap::coordinator::{Engine, EngineConfig, EngineReport, GenerationRequest, Router};
use flashmla_etap::runtime::ReferenceModelConfig;
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::rng::Rng;

struct Workload {
    prompts: Vec<Vec<i32>>,
    budgets: Vec<usize>,
}

fn synth_workload(n: usize, shared_prefix: usize, seed: u64, vocab: usize) -> Workload {
    let mut rng = Rng::new(seed);
    let prefix: Vec<i32> = (0..shared_prefix)
        .map(|_| rng.range(1, vocab as u64) as i32)
        .collect();
    let mut prompts = Vec::new();
    let mut budgets = Vec::new();
    for _ in 0..n {
        let plen = rng.range(2, 16) as usize;
        let mut p = prefix.clone();
        p.extend((0..plen).map(|_| rng.range(1, vocab as u64) as i32));
        prompts.push(p);
        budgets.push(rng.range(4, 24) as usize);
    }
    Workload { prompts, budgets }
}

enum Backend<'a> {
    Pjrt { dir: &'a PathBuf, kernel: String },
    Reference { prefix_cache: bool },
}

fn run(backend: Backend, w: &Workload) -> anyhow::Result<(Vec<Vec<i32>>, f64, String)> {
    let mut engine = match backend {
        Backend::Pjrt { dir, kernel } => Engine::new(
            dir,
            EngineConfig {
                kernel,
                max_slots: 8,
                kv_blocks: 512,
                block_size: 16,
                ..EngineConfig::default()
            },
        )?,
        Backend::Reference { prefix_cache } => Engine::reference(
            ReferenceModelConfig::default(),
            EngineConfig {
                max_slots: 8,
                kv_blocks: 512,
                block_size: 16,
                prefix_cache,
                ..EngineConfig::default()
            },
        )?,
    };
    // Admission through the router (validation + ids).
    let mut router = Router::new(engine.max_context(), 512, 1024);
    let mut ids = Vec::new();
    for (prompt, &budget) in w.prompts.iter().zip(&w.budgets) {
        let req = router
            .admit(prompt.clone(), budget, 0)
            .map_err(|e| anyhow::anyhow!("admission: {e}"))?;
        ids.push(engine.submit(GenerationRequest::new(req.prompt, req.max_new_tokens)).id());
    }
    let t0 = Instant::now();
    let report: EngineReport = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let outs = ids.iter().map(|id| report.outputs[id].clone()).collect();
    Ok((outs, wall, report.metrics.report()))
}

fn main() -> anyhow::Result<()> {
    let p = ArgParser::new(
        "serve_decode",
        "serve synthetic requests end-to-end through the coordinator stack",
    )
    .opt("artifacts", Some("artifacts"), "artifacts directory")
    .opt("requests", Some("16"), "number of synthetic requests")
    .opt(
        "shared-prefix",
        Some("0"),
        "tokens of common system prefix prepended to every prompt",
    )
    .opt("seed", Some("42"), "rng seed");
    let a = p.parse_or_exit();
    let n_req = a.get_usize("requests").unwrap();
    let shared_prefix = a.get_usize("shared-prefix").unwrap();
    let w = synth_workload(n_req, shared_prefix, a.get_u64("seed").unwrap(), 512);
    let total_budget: usize = w.budgets.iter().sum();
    println!(
        "serving {n_req} requests ({total_budget} tokens budgeted, \
         {shared_prefix}-token shared prefix)\n"
    );

    let dir = PathBuf::from(a.get("artifacts").unwrap());
    if dir.join("manifest.json").exists() {
        // PJRT path: the paper's equivalence claim, verified end to end.
        let (out_etap, wall_etap, metrics_etap) = run(
            Backend::Pjrt {
                dir: &dir,
                kernel: "etap".into(),
            },
            &w,
        )?;
        println!("[etap]     {wall_etap:.2}s wall\n  {metrics_etap}\n");
        let (out_base, wall_base, metrics_base) = run(
            Backend::Pjrt {
                dir: &dir,
                kernel: "flashmla".into(),
            },
            &w,
        )?;
        println!("[flashmla] {wall_base:.2}s wall\n  {metrics_base}\n");
        anyhow::ensure!(
            out_etap == out_base,
            "computation modes produced different tokens!"
        );
        println!(
            "✓ all {} output sequences identical across ETAP and query-major modes",
            out_etap.len()
        );
        let toks: usize = out_etap.iter().map(|o| o.len()).sum();
        println!(
            "✓ generated {toks} tokens end-to-end through router → batcher → \
             PJRT engine → paged KV"
        );
    } else {
        // Reference fallback: prefix sharing must be a pure optimization.
        println!("(artifacts/ not built — using the reference decode backend)\n");
        let (out_off, wall_off, metrics_off) = run(Backend::Reference { prefix_cache: false }, &w)?;
        println!("[prefix off] {wall_off:.2}s wall\n  {metrics_off}\n");
        let (out_on, wall_on, metrics_on) = run(Backend::Reference { prefix_cache: true }, &w)?;
        println!("[prefix on]  {wall_on:.2}s wall\n  {metrics_on}\n");
        anyhow::ensure!(
            out_off == out_on,
            "prefix sharing changed decode outputs!"
        );
        println!(
            "✓ all {} output sequences identical with and without prefix sharing",
            out_on.len()
        );
        if shared_prefix >= 32 {
            println!("✓ hit path exercised (see `prefix hits` in the metrics line)");
        } else {
            println!("  (pass --shared-prefix 32 to exercise the hit path)");
        }
    }
    Ok(())
}
