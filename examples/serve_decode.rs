//! End-to-end serving driver (the prompt-mandated workload): load the tiny
//! MLA transformer artifacts, serve a batch of synthetic requests through
//! the full coordinator stack — router → continuous batcher → PJRT decode
//! engine → paged latent KV store — and report latency/throughput.
//!
//! Also runs the same workload under the query-major FlashMLA artifacts to
//! demonstrate that the computation mode changes performance bookkeeping
//! but not a single output token (paper §3.1 equivalence).
//!
//!     make artifacts && cargo run --release --example serve_decode
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::path::PathBuf;
use std::time::Instant;

use flashmla_etap::coordinator::{Engine, EngineConfig, Router};
use flashmla_etap::util::rng::Rng;

struct Workload {
    prompts: Vec<Vec<i32>>,
    budgets: Vec<usize>,
}

fn synth_workload(n: usize, seed: u64, vocab: usize) -> Workload {
    let mut rng = Rng::new(seed);
    let mut prompts = Vec::new();
    let mut budgets = Vec::new();
    for _ in 0..n {
        let plen = rng.range(2, 16) as usize;
        prompts.push((0..plen).map(|_| rng.range(1, vocab as u64) as i32).collect());
        budgets.push(rng.range(4, 24) as usize);
    }
    Workload { prompts, budgets }
}

fn run(kernel: &str, w: &Workload, dir: &PathBuf) -> anyhow::Result<(Vec<Vec<i32>>, f64, String)> {
    let mut engine = Engine::new(
        dir,
        EngineConfig {
            kernel: kernel.into(),
            max_slots: 8,
            kv_blocks: 512,
            block_size: 16,
            eos_token: None,
        },
    )?;
    // Admission through the router (validation + ids).
    let mut router = Router::new(engine.max_context(), 512, 1024);
    let mut ids = Vec::new();
    for (prompt, &budget) in w.prompts.iter().zip(&w.budgets) {
        let req = router
            .admit(prompt.clone(), budget, 0)
            .map_err(|e| anyhow::anyhow!("admission: {e}"))?;
        ids.push(engine.submit(req.prompt, req.max_new_tokens));
    }
    let t0 = Instant::now();
    let report = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let outs = ids.iter().map(|id| report.outputs[id].clone()).collect();
    Ok((outs, wall, report.metrics.report()))
}

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    let n_req = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16usize);
    let w = synth_workload(n_req, 42, 512);
    let total_budget: usize = w.budgets.iter().sum();
    println!("serving {n_req} requests ({total_budget} tokens budgeted) on the tiny MLA model\n");

    let (out_etap, wall_etap, metrics_etap) = run("etap", &w, &dir)?;
    println!("[etap]     {wall_etap:.2}s wall\n  {metrics_etap}\n");

    let (out_base, wall_base, metrics_base) = run("flashmla", &w, &dir)?;
    println!("[flashmla] {wall_base:.2}s wall\n  {metrics_base}\n");

    // The paper's equivalence claim, verified end to end.
    anyhow::ensure!(
        out_etap == out_base,
        "computation modes produced different tokens!"
    );
    println!(
        "✓ all {} output sequences identical across ETAP and query-major modes",
        out_etap.len()
    );
    let toks: usize = out_etap.iter().map(|o| o.len()).sum();
    println!(
        "✓ generated {toks} tokens end-to-end through router → batcher → PJRT engine → paged KV"
    );
    Ok(())
}
