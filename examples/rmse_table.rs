//! Reproduce Table 1: FP16 RMSE against an FP64 reference for the
//! FA-3-style pipeline (FP16 rescale-chain accumulation) vs FlashMLA-ETAP
//! (FP32 on-chip accumulator, single epilogue rounding).
//!
//!     cargo run --release --example rmse_table [kv_len]

use flashmla_etap::attention::precision::table1_experiment;
use flashmla_etap::attention::AttnShape;
use flashmla_etap::bench::Table;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);
    // Paper geometry (16 heads, d 576, dv 512); scale over qk_head_dim 192.
    let shape = AttnShape {
        h: 16,
        d: 576,
        dv: 512,
        n,
    };
    let scale = 1.0 / (192.0f32).sqrt();
    eprintln!("running Table 1 experiment at n={n} (a minute or two in f32 emulation)...");
    let results = table1_experiment(&shape, scale, 64, 2, 42);

    let mut t = Table::new(
        &format!("Table 1 — RMSE, FP16 vs FP64 reference (n={n})"),
        &["Framework", "RMSE (model)", "RMSE (paper)"],
    );
    let paper = [1.9e-4, 1.25e-5];
    for (r, p) in results.iter().zip(paper) {
        t.row(&[
            r.framework.to_string(),
            format!("{:.3e}", r.rmse),
            format!("{p:.3e}"),
        ]);
    }
    t.print();
    let ratio = results[0].rmse / results[1].rmse;
    println!("ETAP is {ratio:.1}x more accurate (paper: 15.2x)");
    println!(
        "mechanism: the FA-3-style pipeline rounds its output accumulator to FP16 \
         after every KV block (rescale chain), ETAP keeps O^T in FP32 and rounds once \
         in the epilogue (Algorithm 1 line 30) — see DESIGN.md §2."
    );
}
