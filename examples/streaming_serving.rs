//! Streaming serving: request handles, per-request sampling, token
//! events, and mid-flight cancellation — the serving-API demo.
//!
//! Two requests stream interleaved through the event-driven engine loop
//! (`Engine::poll_events`); one is cancelled mid-decode.  The run asserts
//! the claims that matter (`docs/serving-api.md`):
//!
//! * **greedy-path bit-identity** — the surviving request's streamed
//!   tokens equal the batch-mode `run_to_completion` output exactly, and
//!   the cancelled request's partial stream is a prefix of its
//!   uncancelled output;
//! * **no KV leak** — after the drain every block is back in the pool;
//! * **sampling determinism** — a temperature-sampled rerun with the same
//!   seed reproduces itself bit-for-bit;
//! * **flight-recorder replay** — the streaming engine runs with the
//!   recorder on (proving observability leaves the greedy path
//!   bit-identical), and the dumped JSON ring replays the exact per-tick
//!   plan summaries the engine reported live.  The recorder dump and a
//!   Prometheus metrics snapshot are written next to the bench JSONs and
//!   re-validated by a tiny parser check (`docs/observability.md`).
//!
//!     cargo run --release --example streaming_serving
//!     cargo run --release --example streaming_serving -- --cancel-at 12

use std::collections::HashMap;
use std::path::PathBuf;

use flashmla_etap::coordinator::{
    Engine, EngineConfig, FinishReason, GenerationRequest, SamplingParams, StepEvent,
};
use flashmla_etap::runtime::ReferenceModelConfig;
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::json;
use flashmla_etap::util::rng::Rng;

const BLOCK_SIZE: usize = 8;
const KV_BLOCKS: usize = 64;
const VOCAB: usize = 64;

fn model() -> ReferenceModelConfig {
    ReferenceModelConfig {
        vocab: VOCAB,
        n_layers: 2,
        latent_dim: 8,
        seed: 23,
        batch_buckets: vec![1, 2, 4],
        kv_buckets: vec![32, 64, 128],
    }
}

const RECORDER_TICKS: usize = 256;

fn engine_with(flight_recorder_ticks: usize) -> anyhow::Result<Engine> {
    Engine::reference(
        model(),
        EngineConfig {
            max_slots: 2,
            kv_blocks: KV_BLOCKS,
            block_size: BLOCK_SIZE,
            prefix_cache: false, // exact pool accounting for the leak check
            flight_recorder_ticks,
            ..EngineConfig::default()
        },
    )
}

fn engine() -> anyhow::Result<Engine> {
    engine_with(0)
}

fn main() -> anyhow::Result<()> {
    let p = ArgParser::new(
        "streaming_serving",
        "streaming serving demo: token events, cancellation, sampling determinism",
    )
    .opt("prompt-len", Some("10"), "prompt length in tokens")
    .opt("max-new", Some("32"), "generated tokens per request")
    .opt("cancel-at", Some("8"), "engine step at which request B is cancelled")
    .opt("seed", Some("42"), "workload rng seed");
    let a = p.parse_or_exit();
    let quick = std::env::var("FLASHMLA_BENCH_QUICK").is_ok();
    let prompt_len = a.get_usize("prompt-len").unwrap();
    let mut max_new = a.get_usize("max-new").unwrap();
    if quick {
        max_new = max_new.min(20);
    }
    let cancel_at = a.get_u64("cancel-at").unwrap();

    let mut rng = Rng::new(a.get_u64("seed").unwrap());
    let mut prompt = || -> Vec<i32> {
        (0..prompt_len)
            .map(|_| rng.range(1, VOCAB as u64 - 1) as i32)
            .collect()
    };
    let (pa, pb) = (prompt(), prompt());

    // Batch-mode oracle: both requests run to completion.
    let (want_a, want_b) = {
        let mut e = engine()?;
        let ha = e.submit(GenerationRequest::new(pa.clone(), max_new));
        let hb = e.submit(GenerationRequest::new(pb.clone(), max_new));
        let r = e.run_to_completion()?;
        (r.outputs[&ha.id()].clone(), r.outputs[&hb.id()].clone())
    };

    // Streaming run: drive steps manually, drain events, cancel B mid-way.
    // The flight recorder is on for this engine only — the bit-identity
    // check against the recorder-less oracle above doubles as the proof
    // that observability never perturbs the token stream.
    println!("[streaming] two interleaved requests, cancelling B at step {cancel_at}\n");
    let mut e = engine_with(RECORDER_TICKS)?;
    let ha = e.submit(GenerationRequest::new(pa.clone(), max_new));
    let hb = e.submit(GenerationRequest::new(pb.clone(), max_new));
    let name = |id: u64| if id == ha.id() { "A" } else { "B" };
    let mut streamed: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut reasons: HashMap<u64, FinishReason> = HashMap::new();
    let mut live_plans: Vec<String> = Vec::new();
    let mut tick = 0u64;
    while e.has_work() {
        if tick == cancel_at {
            anyhow::ensure!(e.cancel(hb.id()), "cancel must land mid-decode");
            println!("  -- cancel(B) issued at step {tick}");
        }
        if e.step()? {
            live_plans.push(e.last_plan_summary());
        }
        tick += 1;
        let mut line: Vec<String> = Vec::new();
        for ev in e.poll_events() {
            match ev {
                StepEvent::Admitted { id } => line.push(format!("{}+", name(id))),
                StepEvent::Token { id, token } => {
                    streamed.entry(id).or_default().push(token);
                    line.push(format!("{}:{token}", name(id)));
                }
                StepEvent::Finished { id, reason } => {
                    reasons.insert(id, reason);
                    line.push(format!("{}✓{reason:?}", name(id)));
                }
                StepEvent::Rejected { id, reason } => {
                    line.push(format!("{}✗{reason}", name(id)));
                }
            }
        }
        if tick <= 6 || line.iter().any(|s| s.contains('✓')) {
            println!("  step {tick:>3}: {}", line.join(" "));
        }
    }
    println!("\n  {}", e.metrics().report());

    // 1. Greedy-path bit-identity for the survivor.
    let got_a = &streamed[&ha.id()];
    anyhow::ensure!(
        got_a == &want_a,
        "streamed tokens for A diverge from run_to_completion"
    );
    println!("\n✓ A streamed {} tokens, bit-identical to batch mode", got_a.len());

    // 2. The cancelled stream is a strict prefix of its uncancelled run.
    let got_b = &streamed[&hb.id()];
    anyhow::ensure!(
        reasons[&hb.id()] == FinishReason::Cancelled,
        "B must finish as Cancelled, got {:?}",
        reasons[&hb.id()]
    );
    anyhow::ensure!(
        !got_b.is_empty() && got_b.len() < want_b.len(),
        "B must be cancelled mid-decode ({} of {} tokens)",
        got_b.len(),
        want_b.len()
    );
    anyhow::ensure!(
        got_b[..] == want_b[..got_b.len()],
        "B's partial stream must be a prefix of its uncancelled output"
    );
    println!(
        "✓ B cancelled after {} of {} tokens; partial stream is an exact prefix",
        got_b.len(),
        want_b.len()
    );

    // 3. No KV leak: every block back in the pool.
    anyhow::ensure!(
        e.free_kv_blocks() == KV_BLOCKS,
        "leaked KV blocks: {} of {} free",
        e.free_kv_blocks(),
        KV_BLOCKS
    );
    anyhow::ensure!(e.metrics().requests_cancelled == 1);
    println!("✓ all {KV_BLOCKS} KV blocks returned to the pool");

    // 4. Sampling determinism: same seed, same stream.
    let sampled = |seed: u64| -> anyhow::Result<Vec<i32>> {
        let mut e = engine()?;
        let h = e.submit(
            GenerationRequest::new(pa.clone(), max_new.min(16))
                .sampling(SamplingParams::sampled(1.0, seed).with_top_k(32)),
        );
        Ok(e.run_to_completion()?.outputs[&h.id()].clone())
    };
    let s1 = sampled(7)?;
    let s2 = sampled(7)?;
    let s3 = sampled(8)?;
    anyhow::ensure!(s1 == s2, "same-seed sampled reruns must be bit-identical");
    anyhow::ensure!(s1 != s3, "different seeds must diverge");
    anyhow::ensure!(s1 != want_a[..s1.len()], "temperature 1 must leave the greedy path");
    println!("✓ sampled run (temp 1.0, top-k 32) reproducible by seed, distinct across seeds");

    // 5. Flight recorder replay + export dump.  The ring holds one record
    // per *executed* tick, and each record's plan summary must equal what
    // `last_plan_summary` reported live right after that step.
    let rec = e.flight_recorder().expect("recorder enabled for the streaming engine");
    anyhow::ensure!(rec.dropped() == 0, "ring sized to hold the whole run");
    anyhow::ensure!(
        rec.len() == live_plans.len(),
        "recorder holds {} ticks, live run reported {}",
        rec.len(),
        live_plans.len()
    );
    for (r, plan) in rec.records().zip(live_plans.iter()) {
        anyhow::ensure!(
            &r.plan == plan,
            "tick {}: recorded plan `{}` != live `{plan}`",
            r.tick,
            r.plan
        );
    }

    // Per-request timelines survive termination.
    let tl = e.timeline(ha).expect("timeline kept after finish");
    anyhow::ensure!(tl.finished_step.is_some() && tl.outcome.is_some());
    anyhow::ensure!(tl.ttft_steps().is_some(), "A produced a first token");
    let tb = e.timeline(hb).expect("timeline for the cancelled request");
    anyhow::ensure!(tb.outcome.as_deref() == Some("Cancelled"));

    // Dump both exporters and re-validate them with a tiny checker, the
    // same one CI's quick mode runs (reuses `util::json`).
    let dir = PathBuf::from(std::env::var("FLASHMLA_BENCH_OUT").unwrap_or_else(|_| ".".into()));
    let fr_path = dir.join("flight_recorder.json");
    e.dump_flight_recorder(&fr_path)?;
    let prom_path = dir.join("metrics.prom");
    std::fs::write(&prom_path, e.metrics().to_prometheus())?;

    let doc = json::parse_file(&fr_path)?;
    anyhow::ensure!(doc.get("capacity").as_usize() == Some(RECORDER_TICKS));
    let ticks = doc.get("ticks").as_arr().expect("ticks array");
    anyhow::ensure!(ticks.len() == rec.len(), "dump holds every record");
    let mut prev = 0u64;
    for t in ticks {
        let n = t.get("tick").as_usize().expect("tick number") as u64;
        anyhow::ensure!(n > prev, "tick numbers strictly increase");
        prev = n;
        anyhow::ensure!(t.get("plan").as_str().is_some(), "plan is a string");
        anyhow::ensure!(t.get("kv_free_blocks").as_usize().is_some());
    }
    let prom = std::fs::read_to_string(&prom_path)?;
    let mut samples = 0usize;
    for line in prom.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let mut it = line.split_whitespace();
        let metric = it.next().expect("metric name");
        anyhow::ensure!(
            metric.starts_with("flashmla_"),
            "unexpected metric name `{metric}`"
        );
        let val = it.next().expect("metric value");
        anyhow::ensure!(
            val.parse::<f64>().is_ok(),
            "sample value `{val}` is not a number"
        );
        anyhow::ensure!(it.next().is_none(), "exactly `name value` per sample line");
        samples += 1;
    }
    anyhow::ensure!(samples > 0, "exporter produced no samples");
    println!(
        "✓ flight recorder replayed {} ticks exactly; dumps validated \
         ({} + {}, {samples} Prometheus samples)",
        rec.len(),
        fr_path.display(),
        prom_path.display()
    );
    Ok(())
}
