//! Shared-prefix serving: the prefix cache's headline demo.
//!
//! N requests share M system prompts (the classic serving shape: a few
//! fixed system/few-shot templates, per-user suffixes).  The same workload
//! runs twice through the full coordinator stack on the deterministic
//! reference backend:
//!
//! * **baseline** — prefix cache disabled: every request prefills its full
//!   prompt, one engine step per token;
//! * **shared** — prefix cache enabled: completed prefills feed the radix
//!   tree, later requests adopt the cached blocks copy-on-write and skip
//!   those prefill steps entirely.
//!
//! The run asserts the three claims that matter: hit rate > 0, strictly
//! fewer prefill steps, and decode outputs bit-identical to the unshared
//! run (sharing is a pure optimization).
//!
//!     cargo run --release --example shared_prefix_serving

use flashmla_etap::coordinator::{Engine, EngineConfig, EngineReport, GenerationRequest};
use flashmla_etap::runtime::ReferenceModelConfig;
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::rng::Rng;

const BLOCK_SIZE: usize = 8;

struct Workload {
    prompts: Vec<Vec<i32>>,
    budgets: Vec<usize>,
}

/// `n` requests round-robining over `m` system prompts of `sys_len` tokens,
/// each with a unique user suffix.
fn synth_workload(n: usize, m: usize, sys_len: usize, seed: u64, vocab: usize) -> Workload {
    let mut rng = Rng::new(seed);
    let systems: Vec<Vec<i32>> = (0..m)
        .map(|_| {
            (0..sys_len)
                .map(|_| rng.range(1, vocab as u64) as i32)
                .collect()
        })
        .collect();
    let mut prompts = Vec::new();
    let mut budgets = Vec::new();
    for i in 0..n {
        let mut p = systems[i % m].clone();
        let suffix = rng.range(3, 9) as usize;
        p.extend((0..suffix).map(|_| rng.range(1, vocab as u64) as i32));
        prompts.push(p);
        budgets.push(rng.range(6, 14) as usize);
    }
    Workload { prompts, budgets }
}

fn run(w: &Workload, slots: usize, prefix_cache: bool) -> anyhow::Result<EngineReport> {
    let model = ReferenceModelConfig {
        kv_buckets: vec![32, 64, 128],
        ..ReferenceModelConfig::default()
    };
    let mut engine = Engine::reference(
        model,
        EngineConfig {
            max_slots: slots,
            kv_blocks: 128,
            block_size: BLOCK_SIZE,
            prefix_cache,
            ..EngineConfig::default()
        },
    )?;
    for (p, &b) in w.prompts.iter().zip(&w.budgets) {
        engine.submit(GenerationRequest::new(p.clone(), b));
    }
    engine.run_to_completion()
}

fn main() -> anyhow::Result<()> {
    let p = ArgParser::new(
        "shared_prefix_serving",
        "prefix-cache demo: N requests over M shared system prompts",
    )
    .opt("requests", Some("12"), "number of requests (≥ 8 for the demo)")
    .opt("system-prompts", Some("2"), "distinct shared system prompts")
    .opt("system-len", Some("24"), "system prompt length in tokens")
    .opt("slots", Some("4"), "batch slots")
    .opt("seed", Some("42"), "rng seed");
    let a = p.parse_or_exit();
    let n = a.get_usize("requests").unwrap();
    let m = a.get_usize("system-prompts").unwrap();
    let sys_len = a.get_usize("system-len").unwrap();
    let slots = a.get_usize("slots").unwrap();
    anyhow::ensure!(
        sys_len / BLOCK_SIZE >= 2,
        "system prompt must span ≥ 2 blocks of {BLOCK_SIZE}"
    );

    let w = synth_workload(n, m, sys_len, a.get_u64("seed").unwrap(), 512);
    println!(
        "{n} requests over {m} system prompts of {sys_len} tokens \
         ({} blocks of {BLOCK_SIZE}), {slots} slots\n",
        sys_len / BLOCK_SIZE
    );

    let base = run(&w, slots, false)?;
    println!("[no sharing]   {}", base.metrics.report());
    let shared = run(&w, slots, true)?;
    println!("[prefix cache] {}", shared.metrics.report());
    println!();

    // 1. Sharing is a pure optimization: outputs are bit-identical.
    anyhow::ensure!(
        base.outputs == shared.outputs,
        "prefix sharing changed decode outputs!"
    );
    println!("✓ all {} output sequences bit-identical to the unshared run", n);

    // 2. The tree actually served prefixes.
    let hit_rate = shared.metrics.prefix_hit_rate();
    anyhow::ensure!(hit_rate > 0.0, "expected a prefix hit rate > 0");
    println!(
        "✓ prefix hit rate {:.0}% ({} of {} lookups, {} blocks reused)",
        hit_rate * 100.0,
        shared.metrics.prefix.hits,
        shared.metrics.prefix.lookups,
        shared.metrics.prefix.hit_blocks
    );

    // 3. Hits translate into skipped prefill work.
    anyhow::ensure!(
        shared.metrics.prefill_tokens < base.metrics.prefill_tokens,
        "sharing did not reduce prefill steps ({} vs {})",
        shared.metrics.prefill_tokens,
        base.metrics.prefill_tokens
    );
    anyhow::ensure!(shared.steps < base.steps, "total steps should drop too");
    println!(
        "✓ prefill steps {} → {} ({} saved), total engine steps {} → {}",
        base.metrics.prefill_tokens,
        shared.metrics.prefill_tokens,
        base.metrics.prefill_tokens - shared.metrics.prefill_tokens,
        base.steps,
        shared.steps
    );
    Ok(())
}
