//! Quickstart: load an AOT attention artifact, run one ETAP decode-attention
//! call from Rust, and cross-check it against the pure-Rust reference.
//!
//!     make artifacts && cargo run --release --example quickstart

use flashmla_etap::attention::{etap_f32, AttnShape};
use flashmla_etap::runtime::{AttentionRunner, Runtime};
use flashmla_etap::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );

    // 1. Bring up the PJRT CPU runtime over the artifact manifest.
    let rt = Runtime::cpu(&dir)?;

    // 2. Pick the smallest ETAP attention bucket that fits one request
    //    with a 200-token context (paper geometry: 16 heads, d=576).
    let attn = AttentionRunner::best(&rt, "etap", 1, 200)?;
    println!(
        "loaded {} (bucket: batch {}, kv {})",
        attn.name(),
        attn.batch,
        attn.kv_bucket
    );

    // 3. Random decode query + latent cache.
    let shape = AttnShape::paper(attn.kv_bucket);
    let mut rng = Rng::new(0);
    let q = rng.normal_vec(shape.q_len());
    let mut cache = rng.normal_vec(shape.cache_len());
    // Zero the padding beyond the real 200-token context.
    for x in &mut cache[200 * shape.d..] {
        *x = 0.0;
    }

    // 4. Execute the transposed-attention kernel (ETAP, Algorithm 1).
    let (out, lse) = attn.run(&q, &cache, &[200])?;
    println!(
        "out[0..4] = {:?} …  lse[0..4] = {:?} …",
        &out[..4],
        &lse[..4]
    );

    // 5. Cross-check against the pure-Rust ETAP reference.
    let scale = 1.0 / (192.0f32).sqrt();
    let mut shape200 = shape;
    shape200.n = 200;
    let want = etap_f32(&shape200, &q, &cache[..200 * shape.d], scale, 64);
    let max_err = out
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |artifact − rust reference| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "numerics mismatch");
    println!("quickstart OK");
    Ok(())
}
