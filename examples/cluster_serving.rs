//! Paper-scale serving scenario: DeepSeek-R1 decode on a simulated 8×H20
//! server under a bursty trace, comparing all four kernel models at the
//! system level (throughput, TPOT, queueing).
//!
//!     cargo run --release --example cluster_serving

use flashmla_etap::bench::Table;
use flashmla_etap::coordinator::{ClusterConfig, ClusterSim, TraceRequest};
use flashmla_etap::hardware::GpuSpec;
use flashmla_etap::util::rng::Rng;

fn trace(n: usize, rate_per_s: f64, seed: u64) -> Vec<TraceRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate_per_s) * 1e6;
            // Long-context decode instance: 8K–32K contexts, 32–128 new
            // tokens (the regime Fig. 1 targets).
            let context = *rng.choose(&[8192usize, 16384, 32768]);
            let gen = rng.range(32, 129) as usize;
            TraceRequest {
                arrival_us: t,
                context_len: context,
                gen_len: gen,
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let tr = trace(96, 6.0, 7);
    let total_tokens: usize = tr.iter().map(|r| r.gen_len).sum();
    println!(
        "trace: {} requests, {} decode tokens, contexts 8K–32K, Poisson 6 req/s\n",
        tr.len(),
        total_tokens
    );

    let mut t = Table::new(
        "Cluster serving (8×H20, DeepSeek-R1 geometry, max batch 16)",
        &["kernel", "tok/s", "TPOT p50 ms", "TPOT p99 ms", "mean wait ms", "mean batch"],
    );
    let mut baseline_tps = 0.0;
    for kernel in ["flashmla", "etap", "fa3", "flashinfer"] {
        let sim = ClusterSim::new(
            ClusterConfig {
                kernel: kernel.into(),
                ..Default::default()
            },
            GpuSpec::h20(),
        )?;
        let rep = sim.serve_trace(&tr, 16);
        if kernel == "flashmla" {
            baseline_tps = rep.tokens_per_s;
        }
        t.row(&[
            kernel.to_string(),
            format!("{:.1}", rep.tokens_per_s),
            format!("{:.1}", rep.tpot_p50_ms),
            format!("{:.1}", rep.tpot_p99_ms),
            format!("{:.1}", rep.mean_wait_ms),
            format!("{:.1}", rep.mean_batch),
        ]);
        if kernel == "etap" {
            println!(
                "ETAP end-to-end gain over FlashMLA: {:.2}x tokens/s (kernel-level \
                 gain is larger; MLA is ~30% of the step — Amdahl, see Ablation 4)",
                rep.tokens_per_s / baseline_tps
            );
        }
    }
    println!();
    t.print();
    Ok(())
}
