//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. padding factor vs heads-per-GPU (why the 8-way head split hurts);
//! 2. ETAP's §3.2 integration hypotheticals (ETAP-in-FA3/FlashInfer);
//! 3. block size Bc sweep (SMEM staging vs fill);
//! 4. cluster-level Amdahl: kernel speedup vs end-to-end step speedup;
//! 5. GPU sweep: the same kernels on H20 / H100 / A100 atoms.
//!
//!     cargo run --release --example ablation_padding

use flashmla_etap::bench::Table;
use flashmla_etap::coordinator::{ClusterConfig, ClusterSim};
use flashmla_etap::hardware::{padding_factor, GpuSpec};
use flashmla_etap::sim::kernels::{all_models_extended, model_by_name};
use flashmla_etap::sim::pipeline;
use flashmla_etap::sim::DecodeWorkload;

fn main() -> anyhow::Result<()> {
    let gpu = GpuSpec::h20();

    // 1. Padding vs head count (the §3.1 argument).
    let mut t = Table::new(
        "Ablation 1 — WGMMA padding vs heads/GPU (query-major mode)",
        &["heads/GPU", "GPUs for 128 heads", "padding", "util ceiling"],
    );
    for gpus in [1usize, 2, 4, 8, 16] {
        let heads = 128 / gpus;
        let f = padding_factor(heads, &gpu.atom);
        t.row(&[
            heads.to_string(),
            gpus.to_string(),
            format!("{f:.1}x"),
            format!("{:.0}%", 100.0 / f),
        ]);
    }
    t.print();

    // 2. §3.2 integration hypotheticals.
    let mut t = Table::new(
        "Ablation 2 — ETAP integrated into other frameworks (§3.2), 32K/BS16",
        &["framework", "TFLOPS/s", "with ETAP", "gain"],
    );
    let w = DecodeWorkload::paper(16, 32768);
    for (base, etap) in [("fa3", "etap-fa3"), ("flashinfer", "etap-flashinfer")] {
        let b = model_by_name(base).unwrap().estimate(&w, &gpu).tflops_per_s;
        let e = model_by_name(etap).unwrap().estimate(&w, &gpu).tflops_per_s;
        t.row(&[
            base.to_string(),
            format!("{b:.1}"),
            format!("{e:.1}"),
            format!("{:.2}x", e / b),
        ]);
    }
    t.print();

    // 3. Block-size sweep: SMEM stages vs pipeline fill.
    let mut t = Table::new(
        "Ablation 3 — KV block size Bc on H20 (228 KiB SMEM)",
        &["Bc", "stage KiB", "stages fit", "fill eff @512", "fill eff @64K"],
    );
    for bc in [32usize, 64, 128, 256] {
        let stage = pipeline::stage_bytes(bc, 576, 2);
        let stages = pipeline::max_stages(228 * 1024, stage, 64 * 1024);
        let f512 = pipeline::fill_efficiency(pipeline::kv_blocks(512, bc), 16.0);
        let f64k = pipeline::fill_efficiency(pipeline::kv_blocks(65536, bc), 16.0);
        t.row(&[
            bc.to_string(),
            format!("{}", stage / 1024),
            stages.to_string(),
            format!("{f512:.2}"),
            format!("{f64k:.2}"),
        ]);
    }
    t.print();
    println!(
        "Bc=64 is the sweet spot: ≥2 SMEM stages (double buffering, Algorithm 1's \
         circular buffer) while keeping fill losses acceptable.\n"
    );

    // 4. Amdahl at the cluster level: MLA is ~30% of the forward pass.
    let mut t = Table::new(
        "Ablation 4 — kernel speedup vs end-to-end decode step (8×H20, BS16)",
        &["context", "kernel speedup", "step speedup", "MLA share (base)"],
    );
    for ctx in [4096usize, 16384, 65536] {
        let base = ClusterSim::new(
            ClusterConfig {
                kernel: "flashmla".into(),
                ..Default::default()
            },
            gpu.clone(),
        )?;
        let etap = ClusterSim::new(
            ClusterConfig {
                kernel: "etap".into(),
                ..Default::default()
            },
            gpu.clone(),
        )?;
        let kv = vec![ctx; 16];
        let sb = base.step_time(&kv);
        let se = etap.step_time(&kv);
        let w = DecodeWorkload::paper(16, ctx);
        let k = model_by_name("flashmla").unwrap().estimate(&w, &gpu).total_us
            / model_by_name("etap").unwrap().estimate(&w, &gpu).total_us;
        t.row(&[
            ctx.to_string(),
            format!("{k:.2}x"),
            format!("{:.2}x", sb.total_us() / se.total_us()),
            format!("{:.0}%", sb.attention_fraction() * 100.0),
        ]);
    }
    t.print();

    // 5. GPU sweep: where does ETAP matter?
    let mut t = Table::new(
        "Ablation 5 — ETAP gain by GPU (64K, BS16)",
        &["gpu", "atom min-M", "FlashMLA", "ETAP", "gain"],
    );
    for g in [GpuSpec::h20(), GpuSpec::h100(), GpuSpec::a100()] {
        let w = DecodeWorkload::paper(16, 65536);
        let b = model_by_name("flashmla").unwrap().estimate(&w, &g).tflops_per_s;
        let e = model_by_name("etap").unwrap().estimate(&w, &g).tflops_per_s;
        t.row(&[
            g.name.to_string(),
            g.atom.min_m.to_string(),
            format!("{b:.1}"),
            format!("{e:.1}"),
            format!("{:.2}x", e / b),
        ]);
    }
    t.print();
    println!(
        "A100's m16 atom doesn't pad 16 heads — the pathology (and ETAP's gain) is \
         Hopper-specific, as the paper's WGMMA framing implies.  On H100 the larger \
         compute roof mutes the padding penalty at the same bandwidth."
    );

    // Keep the extended model list exercised.
    assert_eq!(all_models_extended().len(), 6);
    Ok(())
}
