//! Fleet serving: the multi-engine executor's headline demo.
//!
//! N requests over M hot system prompts run through a 4-engine
//! [`FleetExecutor`] twice — replication off, then on — plus a solo
//! oracle and an overload burst.  Four claims are asserted end to end:
//!
//! * **Bit-identity** — every fleet-served token stream equals the same
//!   request served alone on a solo engine (fleet = pure placement).
//! * **Replication adopts** — hot prefixes get copied to non-donor
//!   engines (≥ 1 replication pass lands).
//! * **Replication pays** — the replicated run spends strictly fewer
//!   prefill tokens than the affinity-only run: spilled requests hit
//!   replicas instead of re-prefilling the shared head.
//! * **Overload sheds** — a burst past the queue bound surfaces as
//!   `Rejected{Backpressure}` events, and the survivors still serve.
//!
//!     cargo run --release --example fleet_serving
//!
//! `FLASHMLA_BENCH_QUICK=1` caps the workload for CI smoke runs.

use std::collections::BTreeMap;

use flashmla_etap::coordinator::{
    Engine, EngineConfig, GenerationRequest, RejectReason, StepEvent,
};
use flashmla_etap::fleet::{FleetConfig, FleetExecutor};
use flashmla_etap::runtime::ReferenceModelConfig;
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::rng::Rng;

const BLOCK: usize = 8;
const SYS_LEN: usize = 24; // 3 blocks
const ENGINES: usize = 4;

fn model() -> ReferenceModelConfig {
    ReferenceModelConfig {
        kv_buckets: vec![32, 64, 128],
        ..ReferenceModelConfig::default()
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        max_slots: 4,
        kv_blocks: 128,
        block_size: BLOCK,
        prefix_cache: true,
        ..EngineConfig::default()
    }
}

fn fleet_cfg(replication: bool) -> FleetConfig {
    FleetConfig {
        engines: ENGINES,
        engine: engine_cfg(),
        replication,
        replicate_hot_after: 2,
        max_queue_per_engine: 64,
        spill_threshold: Some(2),
        ..FleetConfig::default()
    }
}

struct Workload {
    prompts: Vec<Vec<i32>>,
    budgets: Vec<usize>,
    tenants: Vec<&'static str>,
}

/// `n` requests round-robining over `m` hot system prompts, each with a
/// unique user suffix and a tenant label.
fn synth_workload(n: usize, m: usize, seed: u64, vocab: usize) -> Workload {
    let mut rng = Rng::new(seed);
    let systems: Vec<Vec<i32>> = (0..m)
        .map(|_| {
            (0..SYS_LEN)
                .map(|_| rng.range(1, vocab as u64) as i32)
                .collect()
        })
        .collect();
    let tenant_names = ["acme", "globex", "initech"];
    let mut w = Workload {
        prompts: Vec::new(),
        budgets: Vec::new(),
        tenants: Vec::new(),
    };
    for i in 0..n {
        let mut p = systems[i % m].clone();
        let suffix = rng.range(3, 9) as usize;
        p.extend((0..suffix).map(|_| rng.range(1, vocab as u64) as i32));
        w.prompts.push(p);
        w.budgets.push(rng.range(6, 12) as usize);
        w.tenants.push(tenant_names[i % tenant_names.len()]);
    }
    w
}

/// Solo oracle: the token stream of one request served alone.
fn solo_stream(prompt: &[i32], budget: usize) -> anyhow::Result<Vec<i32>> {
    let mut e = Engine::reference(model(), engine_cfg())?;
    let h = e.submit(GenerationRequest::new(prompt.to_vec(), budget));
    let mut out = Vec::new();
    while e.has_work() {
        e.step()?;
        for ev in e.poll_events() {
            if let StepEvent::Token { id, token } = ev {
                if id == h.id() {
                    out.push(token);
                }
            }
        }
    }
    Ok(out)
}

struct FleetRun {
    /// Request index (submission order) → token stream.
    streams: Vec<Vec<i32>>,
    prefill_tokens: u64,
    prefix_hit_tokens: u64,
    replications: u64,
    replication_hits: u64,
    ticks: u64,
}

/// Serve the workload on a fleet: two warm-up waves (one request per
/// template each — the second marks every template hot), then the rest
/// as one burst so affinity spills engage.
fn run_fleet(w: &Workload, replication: bool) -> anyhow::Result<FleetRun> {
    let mut fleet = FleetExecutor::reference(model(), fleet_cfg(replication))?;
    let m = w
        .prompts
        .iter()
        .map(|p| &p[..SYS_LEN])
        .collect::<std::collections::BTreeSet<_>>()
        .len();
    let mut id2idx: BTreeMap<u64, usize> = BTreeMap::new();
    let mut streams: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut ticks = 0u64;

    let drive = |fleet: &mut FleetExecutor,
                 streams: &mut BTreeMap<u64, Vec<i32>>,
                 ticks: &mut u64|
     -> anyhow::Result<()> {
        while fleet.has_work() {
            fleet.step()?;
            *ticks += 1;
            for ev in fleet.poll_events() {
                if let StepEvent::Token { id, token } = ev.event {
                    streams.entry(id).or_default().push(token);
                }
            }
            anyhow::ensure!(*ticks < 1_000_000, "fleet did not drain");
        }
        Ok(())
    };

    // Waves 1 and 2: requests 0..m and m..2m, each drained to idle so
    // the donors' chains land and the hot count crosses the threshold.
    let waves = (2 * m).min(w.prompts.len());
    for wave in 0..2 {
        for i in (wave * m..(wave + 1) * m).take_while(|&i| i < w.prompts.len()) {
            let h = fleet.submit_for(
                w.tenants[i],
                GenerationRequest::new(w.prompts[i].clone(), w.budgets[i]),
            )?;
            id2idx.insert(h.id(), i);
        }
        drive(&mut fleet, &mut streams, &mut ticks)?;
    }
    // The burst: everything else at once.
    for i in waves..w.prompts.len() {
        let h = fleet.submit_for(
            w.tenants[i],
            GenerationRequest::new(w.prompts[i].clone(), w.budgets[i]),
        )?;
        id2idx.insert(h.id(), i);
    }
    drive(&mut fleet, &mut streams, &mut ticks)?;
    anyhow::ensure!(fleet.shed() == 0, "headroom config must not shed");

    let mut by_idx = vec![Vec::new(); w.prompts.len()];
    for (id, s) in streams {
        by_idx[id2idx[&id]] = s;
    }
    let metrics = fleet.merged_metrics();
    Ok(FleetRun {
        streams: by_idx,
        prefill_tokens: metrics.prefill_tokens,
        prefix_hit_tokens: metrics.prefix.hit_tokens,
        replications: fleet.replications(),
        replication_hits: fleet.replication_hits(),
        ticks,
    })
}

fn main() -> anyhow::Result<()> {
    let p = ArgParser::new(
        "fleet_serving",
        "multi-engine fleet demo: affinity routing, hot-prefix replication, QoS backpressure",
    )
    .opt("requests", Some("48"), "number of requests (≥ 8)")
    .opt("system-prompts", Some("2"), "distinct hot system prompts")
    .opt("seed", Some("42"), "rng seed");
    let a = p.parse_or_exit();
    let quick = std::env::var("FLASHMLA_BENCH_QUICK").is_ok();
    let mut n = a.get_usize("requests").unwrap();
    if quick {
        n = n.min(16);
    }
    let m = a.get_usize("system-prompts").unwrap();
    anyhow::ensure!(n >= 4 * m, "need at least two waves plus a burst");

    let w = synth_workload(n, m, a.get_u64("seed").unwrap(), 512);
    println!(
        "{n} requests over {m} hot system prompts of {SYS_LEN} tokens \
         ({} blocks of {BLOCK}), fleet of {ENGINES} engines\n",
        SYS_LEN / BLOCK
    );

    let off = run_fleet(&w, false)?;
    println!(
        "[affinity only] prefill {} tok, prefix hits {} tok, {} ticks",
        off.prefill_tokens, off.prefix_hit_tokens, off.ticks
    );
    let on = run_fleet(&w, true)?;
    println!(
        "[+replication]  prefill {} tok, prefix hits {} tok, {} ticks, \
         {} replication passes, {} replica hits",
        on.prefill_tokens, on.prefix_hit_tokens, on.ticks, on.replications, on.replication_hits
    );
    println!();

    // 1. Fleet = pure placement: streams bit-identical to the solo
    //    oracle, replication on or off.
    for i in 0..n {
        let want = solo_stream(&w.prompts[i], w.budgets[i])?;
        anyhow::ensure!(
            off.streams[i] == want && on.streams[i] == want,
            "request {i}: fleet stream diverged from the solo oracle"
        );
    }
    println!("✓ all {n} token streams bit-identical to the solo oracle (both runs)");

    // 2. Hot prefixes replicated across engines.
    anyhow::ensure!(
        on.replications >= 1,
        "expected at least one replication pass to adopt blocks"
    );
    println!(
        "✓ {} replication passes adopted blocks on non-donor engines",
        on.replications
    );

    // 3. Replication pays: spilled requests hit replicas instead of
    //    re-prefilling the shared head.
    anyhow::ensure!(
        on.prefill_tokens < off.prefill_tokens,
        "replication did not reduce prefill work ({} vs {})",
        on.prefill_tokens,
        off.prefill_tokens
    );
    println!(
        "✓ prefill tokens {} → {} ({} saved by replicas)",
        off.prefill_tokens,
        on.prefill_tokens,
        off.prefill_tokens - on.prefill_tokens
    );

    // 4. Overload sheds with Backpressure, survivors still serve.
    let mut tight = fleet_cfg(false);
    tight.max_queue_per_engine = 1;
    let mut fleet = FleetExecutor::reference(model(), tight)?;
    for i in 0..3 * ENGINES {
        let idx = i % n;
        fleet.submit_for(
            w.tenants[idx],
            GenerationRequest::new(w.prompts[idx].clone(), w.budgets[idx]),
        )?;
    }
    let shed = fleet.shed();
    anyhow::ensure!(shed >= 1, "burst past the queue bound must shed");
    let backpressure = fleet
        .poll_events()
        .iter()
        .filter(|e| {
            matches!(
                e.event,
                StepEvent::Rejected {
                    reason: RejectReason::Backpressure,
                    ..
                }
            )
        })
        .count() as u64;
    anyhow::ensure!(backpressure == shed, "every shed surfaces as Backpressure");
    fleet.run_until_idle()?;
    let served = fleet
        .take_finished()
        .iter()
        .filter(|f| !f.tokens.is_empty())
        .count() as u64;
    anyhow::ensure!(served == 3 * ENGINES as u64 - shed, "survivors all serve");
    println!(
        "✓ overload burst: {shed} of {} submissions shed with Backpressure, {served} served",
        3 * ENGINES
    );
    Ok(())
}
