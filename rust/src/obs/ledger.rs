//! Per-tick compute ledger: attributes every modeled FLOP and byte the
//! engine hot path issues to a waste category.
//!
//! ETAP's contribution is eliminating *redundant* computation, so the
//! serving engine must be able to say, per tick, how much of its compute
//! was useful.  The ledger models each dispatched token at the **paper
//! shape** (16 query heads, d_qk 576, d_v 512 — the H20 MLA decode kernel
//! of §4.1), driven by the engine's *real* scheduling shapes: which slots
//! were fed, how many KV rows were real, which bucket was dispatched.
//! The reference backend's tiny scalar model is deliberately *not* what is
//! costed — the ledger answers "what would this schedule cost on the
//! paper's kernel", which is the number ROADMAP item 3 must improve.
//!
//! ## Category taxonomy
//!
//! Every issued FLOP lands in exactly one bucket:
//!
//! * `useful` — attention GEMM work over real KV rows of live tokens.
//! * `bucket_pad` — KV-bucket rows past the token's real context
//!   (`kv_len`), plus whole scratch dispatches for empty slots.
//! * `chunk_refeed` — fallback wavefront re-feeds of slots whose chunk
//!   is shorter than the tick's longest chunk (only non-native-chunking
//!   backends pay this; see [`crate::runtime::StepRunner::native_chunking`]).
//! * `spec_rejected` — draft positions that were verified and rejected;
//!   recorded as `useful` at dispatch time and reclassified by the engine
//!   once verification outcomes are known ([`reclassify_rejected`]).
//! * `mask_pad` — M-dimension WGMMA tile padding of every dispatch,
//!   computed with the *same atom math* as `sim/gemm.rs`
//!   ([`GemmDims::issued_flops`] minus [`GemmDims::useful_flops`]), so the
//!   live ledger equals the sim prediction exactly on identical shapes.
//!
//! Bytes follow the same attribution, except `mask_pad` moves no bytes:
//! M-padding is register/tile fill, not HBM traffic.
//!
//! ## Determinism and exactness
//!
//! All per-token quantities are integer-valued `f64`s (products of small
//! integers, far below 2^53), so sums are exact and order-independent:
//! two pipelines that consume the same token positions report
//! **bit-identical** `useful` FLOPs regardless of scheduling, and
//! reclassification subtracts exactly what dispatch added.
//!
//! ## Gate
//!
//! Recording is off by default and costs one relaxed atomic load
//! (`rust/tests/obs_overhead.rs` re-asserts zero allocations).  A live
//! [`LedgerGuard`] holds a refcount on the shared `obs` gate; the tally
//! itself is a thread-local `Cell` of a `Copy` struct, so recording
//! allocates nothing even when enabled.

use std::cell::Cell;

use crate::hardware::gpu::MatmulAtom;
use crate::sim::gemm::{query_major_gemms, GemmDims};

use super::trace;

/// Query heads of the modeled kernel (paper §4.1 MLA decode shape).
pub const MODEL_HEADS: usize = 16;
/// Per-head Q/K dimension of the modeled kernel.
pub const MODEL_D_QK: usize = 576;
/// Per-head V dimension of the modeled kernel.
pub const MODEL_D_V: usize = 512;
/// Bytes per element (FP16/BF16).
pub const MODEL_ELEM_BYTES: usize = 2;

/// The two attention GEMMs of one modeled token over `kv_rows` KV rows,
/// in the paper's query-major (pre-ETAP) layout: heads on the padded M
/// dimension — exactly [`query_major_gemms`] at the paper shape.
pub fn model_gemms(kv_rows: usize) -> [GemmDims; 2] {
    query_major_gemms(MODEL_HEADS, kv_rows, MODEL_D_QK, MODEL_D_V)
}

/// Mathematically necessary FLOPs for one token over `kv_rows` rows
/// (`Σ 2·m·n·k`).  Linear in `kv_rows`, which is what makes partial
/// attribution and post-hoc reclassification exact.
pub fn logical_flops(kv_rows: usize) -> f64 {
    if kv_rows == 0 {
        return 0.0;
    }
    model_gemms(kv_rows).iter().map(GemmDims::useful_flops).sum()
}

/// FLOPs the WGMMA pipeline actually issues for one token over `kv_rows`
/// rows, with M padded to the atom granule — the same arithmetic as
/// `sim/gemm.rs`, so live ledger ≡ sim prediction by construction.
pub fn issued_flops(kv_rows: usize) -> f64 {
    if kv_rows == 0 {
        return 0.0;
    }
    let atom = MatmulAtom::wgmma();
    model_gemms(kv_rows)
        .iter()
        .map(|g| g.issued_flops(&atom))
        .sum()
}

/// Modeled GFLOP/s for one attention call over `kv_rows` rows that took
/// `mean_us` microseconds of wall clock: [`logical_flops`] divided by
/// the measured time.  `benches/attention_cpu.rs` uses this to put the
/// *measured* CPU kernel throughput on the same axis as the ledger's
/// modeled numbers, which is what `bench_compare`'s roofline section
/// cross-reports.
pub fn modeled_gflops_at(kv_rows: usize, mean_us: f64) -> f64 {
    if mean_us <= 0.0 {
        return 0.0;
    }
    logical_flops(kv_rows) / (mean_us * 1e3)
}

/// HBM bytes to stream `kv_rows` KV latent rows for one token.
pub fn kv_bytes(kv_rows: usize) -> f64 {
    (kv_rows * MODEL_D_QK * MODEL_ELEM_BYTES) as f64
}

/// HBM bytes for one token's query read and output write.
pub fn qo_bytes() -> f64 {
    (MODEL_HEADS * (MODEL_D_QK + MODEL_D_V) * MODEL_ELEM_BYTES) as f64
}

/// A tally of attributed FLOPs and bytes — one engine tick's worth
/// ([`take_tick`]) or a run's accumulated totals
/// (`ServingMetrics::compute`).  `Copy` so the hot path is a `Cell`
/// read-modify-write with no allocation.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ComputeTally {
    /// FLOPs over real KV rows of live tokens.
    pub useful_flops: f64,
    /// FLOPs over bucket rows past `kv_len`, plus scratch dispatches.
    pub bucket_pad_flops: f64,
    /// FLOPs of fallback wavefront re-feeds.
    pub chunk_refeed_flops: f64,
    /// FLOPs of verified-but-rejected draft positions.
    pub spec_rejected_flops: f64,
    /// M-dimension WGMMA tile-padding FLOPs (issued − logical).
    pub mask_pad_flops: f64,
    /// Bytes moved for useful work (KV rows up to `kv_len` + Q/O).
    pub useful_bytes: f64,
    /// Bytes moved for bucket padding rows and scratch dispatches.
    pub bucket_pad_bytes: f64,
    /// Bytes moved by fallback re-feeds.
    pub chunk_refeed_bytes: f64,
    /// Bytes moved for rejected draft positions.
    pub spec_rejected_bytes: f64,
}

impl ComputeTally {
    /// All-zero tally; `const` so it can seed a `thread_local!` `Cell`.
    pub const ZERO: ComputeTally = ComputeTally {
        useful_flops: 0.0,
        bucket_pad_flops: 0.0,
        chunk_refeed_flops: 0.0,
        spec_rejected_flops: 0.0,
        mask_pad_flops: 0.0,
        useful_bytes: 0.0,
        bucket_pad_bytes: 0.0,
        chunk_refeed_bytes: 0.0,
        spec_rejected_bytes: 0.0,
    };

    /// Total FLOPs issued: the five categories partition it.
    pub fn issued_flops(&self) -> f64 {
        self.useful_flops
            + self.bucket_pad_flops
            + self.chunk_refeed_flops
            + self.spec_rejected_flops
            + self.mask_pad_flops
    }

    /// Issued FLOPs that were not useful.
    pub fn waste_flops(&self) -> f64 {
        self.issued_flops() - self.useful_flops
    }

    /// Wasted share of issued FLOPs, in `[0, 1)` — `0` for an empty
    /// tally, and strictly below `1` otherwise because any dispatch
    /// contributes a nonzero `useful` (or is pure waste over a nonzero
    /// logical base, in which case `useful` from other tokens still
    /// anchors it; a tally that is *all* waste reports `< 1` only
    /// asymptotically, and real ticks always carry useful tokens).
    pub fn waste_fraction(&self) -> f64 {
        let issued = self.issued_flops();
        if issued <= 0.0 {
            0.0
        } else {
            (self.waste_flops() / issued).min(1.0 - f64::EPSILON)
        }
    }

    /// Total modeled HBM bytes moved.
    pub fn total_bytes(&self) -> f64 {
        self.useful_bytes
            + self.bucket_pad_bytes
            + self.chunk_refeed_bytes
            + self.spec_rejected_bytes
    }

    /// Accumulate another tally (tick → run totals, run → merged totals).
    pub fn add(&mut self, other: &ComputeTally) {
        self.useful_flops += other.useful_flops;
        self.bucket_pad_flops += other.bucket_pad_flops;
        self.chunk_refeed_flops += other.chunk_refeed_flops;
        self.spec_rejected_flops += other.spec_rejected_flops;
        self.mask_pad_flops += other.mask_pad_flops;
        self.useful_bytes += other.useful_bytes;
        self.bucket_pad_bytes += other.bucket_pad_bytes;
        self.chunk_refeed_bytes += other.chunk_refeed_bytes;
        self.spec_rejected_bytes += other.spec_rejected_bytes;
    }
}

/// Is any ledger guard live?  One relaxed atomic load when off.
#[inline]
pub fn enabled() -> bool {
    trace::ledger_on()
}

/// RAII enable handle: recording is live while at least one guard exists
/// anywhere in the process.  Refcounted (not a toggle) so overlapping
/// runs in parallel test threads can't disable each other mid-run.
pub struct LedgerGuard(());

impl LedgerGuard {
    pub fn new() -> Self {
        trace::ledger_add();
        LedgerGuard(())
    }
}

impl Default for LedgerGuard {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for LedgerGuard {
    fn drop(&mut self) {
        trace::ledger_sub();
    }
}

thread_local! {
    /// The current tick's tally.  Thread-local like the trace collector:
    /// the engine runs on its caller's thread, so parallel tests never
    /// race on a shared accumulator.
    static TICK_TALLY: Cell<ComputeTally> = const { Cell::new(ComputeTally::ZERO) };
}

/// Zero this thread's tick tally.  The engine calls this at the top of
/// each tick's execute phase.
pub fn begin_tick() {
    TICK_TALLY.with(|t| t.set(ComputeTally::ZERO));
}

/// Take and reset this thread's tick tally.  Returns zeros when recording
/// is disabled (nothing was tallied).
pub fn take_tick() -> ComputeTally {
    TICK_TALLY.with(|t| t.replace(ComputeTally::ZERO))
}

/// Why a dispatched token exists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenKind {
    /// A real token of a live request.
    Useful,
    /// A fallback wavefront re-feed of an already-finished slot.
    Refeed,
    /// A scratch dispatch for an empty (padded) batch slot.
    Scratch,
}

/// Record one dispatched token: a query of `real_rows` real KV rows,
/// dispatched over a `kv_bucket`-row KV bucket.  No-op unless a
/// [`LedgerGuard`] is live; allocates nothing either way.
///
/// Attribution: the dispatch logically covers `kv_bucket` rows.  Its
/// M-padding (`issued − logical`, the `sim/gemm.rs` atom math) is always
/// `mask_pad` — the dispatch's tile shape doesn't depend on why the
/// dispatch exists.  The logical part splits by `kind`: `Useful` tokens
/// put `real_rows` worth in `useful` and the rest in `bucket_pad`;
/// `Refeed`/`Scratch` dispatches are pure waste.
pub fn record_token(kind: TokenKind, real_rows: usize, kv_bucket: usize) {
    if !enabled() || kv_bucket == 0 {
        return;
    }
    let rows = real_rows.min(kv_bucket);
    let logical = logical_flops(kv_bucket);
    let mask = issued_flops(kv_bucket) - logical;
    let kv_all = kv_bytes(kv_bucket);
    let qo = qo_bytes();

    let mut delta = ComputeTally::ZERO;
    delta.mask_pad_flops = mask;
    match kind {
        TokenKind::Useful => {
            let useful = logical_flops(rows);
            delta.useful_flops = useful;
            delta.bucket_pad_flops = logical - useful;
            let useful_kv = kv_bytes(rows);
            delta.useful_bytes = useful_kv + qo;
            delta.bucket_pad_bytes = kv_all - useful_kv;
        }
        TokenKind::Refeed => {
            delta.chunk_refeed_flops = logical;
            delta.chunk_refeed_bytes = kv_all + qo;
        }
        TokenKind::Scratch => {
            delta.bucket_pad_flops = logical;
            delta.bucket_pad_bytes = kv_all + qo;
        }
    }

    TICK_TALLY.with(|t| {
        let mut cur = t.get();
        cur.add(&delta);
        t.set(cur);
    });
}

/// Record one batch slot of a chunked dispatch (`prefill_chunk` /
/// `verify_chunk`): `chunk_len` tokens starting at context position
/// `start`, in a tick whose longest chunk is `max_k` tokens, over a
/// `kv_bucket`-row bucket.  `native` mirrors
/// [`crate::runtime::StepRunner::native_chunking`]: native backends
/// process each slot's tokens once (one scratch dispatch per empty
/// slot), while fallback backends run `max_k` wavefronts — short slots
/// re-feed their last token and empty slots burn scratch every wave.
pub fn record_slot(chunk_len: usize, start: usize, max_k: usize, kv_bucket: usize, native: bool) {
    if !enabled() || kv_bucket == 0 {
        return;
    }
    if chunk_len == 0 {
        let waves = if native { 1 } else { max_k.max(1) };
        for _ in 0..waves {
            record_token(TokenKind::Scratch, 1, kv_bucket);
        }
        return;
    }
    // Token t of the chunk sits at context position start+t and attends
    // rows 0..=start+t — the engine-wide exact-kv_len convention.
    for t in 0..chunk_len {
        record_token(TokenKind::Useful, start + t + 1, kv_bucket);
    }
    if !native {
        // Fallback wavefronts past this chunk's length re-feed the last
        // token at its (clamped) final position.
        for _ in chunk_len..max_k {
            record_token(TokenKind::Refeed, start + chunk_len, kv_bucket);
        }
    }
}

/// Move one previously-`Useful` token (of `real_rows` real KV rows over
/// `kv_bucket`) into `spec_rejected`.  The engine calls this once per
/// rejected draft position after verification outcomes are known —
/// dispatch-time recording can't see acceptance.  Exact: per-token
/// quantities are integer-valued `f64`s, so the subtraction restores
/// `useful` to precisely its pre-dispatch value; the token's `bucket_pad`
/// and `mask_pad` shares stay where they are (those FLOPs were issued
/// regardless of the verdict).
pub fn reclassify_rejected(real_rows: usize, kv_bucket: usize) {
    if !enabled() || kv_bucket == 0 {
        return;
    }
    let rows = real_rows.min(kv_bucket);
    let flops = logical_flops(rows);
    let bytes = kv_bytes(rows) + qo_bytes();
    TICK_TALLY.with(|t| {
        let mut cur = t.get();
        cur.useful_flops -= flops;
        cur.spec_rejected_flops += flops;
        cur.useful_bytes -= bytes;
        cur.spec_rejected_bytes += bytes;
        t.set(cur);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Guard-using tests share the process-global gate; serialize them so
    /// the "disabled" test can't observe another test's open guard from
    /// this module (other modules in this binary never hold one).
    static LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn modeled_gflops_at_inverts_logical_flops() {
        // 1e9 logical FLOPs in 1000 us = 1000 GFLOP/s, by definition.
        let n = 4096;
        let flops = logical_flops(n);
        let us = flops / 1e9 * 1e3;
        assert!((modeled_gflops_at(n, us) - 1000.0).abs() < 1e-6);
        assert_eq!(modeled_gflops_at(n, 0.0), 0.0, "degenerate time");
        assert_eq!(modeled_gflops_at(0, 5.0), 0.0, "no rows, no flops");
    }

    #[test]
    fn live_ledger_matches_sim_on_identical_shapes() {
        let _l = lock();
        let _g = LedgerGuard::new();
        let atom = MatmulAtom::wgmma();
        let bucket = 64;
        let batch = 4;

        begin_tick();
        for _ in 0..batch {
            record_token(TokenKind::Useful, bucket, bucket);
        }
        let t = take_tick();

        // The sim's prediction for the same fixed batch shape.
        let gemms = query_major_gemms(MODEL_HEADS, bucket, MODEL_D_QK, MODEL_D_V);
        let sim_useful: f64 =
            batch as f64 * gemms.iter().map(GemmDims::useful_flops).sum::<f64>();
        let sim_issued: f64 =
            batch as f64 * gemms.iter().map(|g| g.issued_flops(&atom)).sum::<f64>();

        // Exact equality is the parity contract: same atom math, not
        // merely close.
        assert_eq!(t.useful_flops, sim_useful);
        assert_eq!(t.issued_flops(), sim_issued);
        assert_eq!(t.mask_pad_flops, sim_issued - sim_useful);
        assert_eq!(t.bucket_pad_flops, 0.0);
        assert_eq!(t.chunk_refeed_flops, 0.0);
        assert_eq!(t.spec_rejected_flops, 0.0);
        // Paper shape: 16 heads under a 64-row WGMMA granule ⇒ 4× issue.
        assert_eq!(sim_issued, 4.0 * sim_useful);
    }

    #[test]
    fn partial_rows_split_between_useful_and_bucket_pad() {
        let _l = lock();
        let _g = LedgerGuard::new();
        begin_tick();
        record_token(TokenKind::Useful, 13, 64);
        let t = take_tick();
        assert_eq!(t.useful_flops, logical_flops(13));
        assert_eq!(t.bucket_pad_flops, logical_flops(64) - logical_flops(13));
        // Linearity in rows (exact: integer-valued f64s).
        assert_eq!(logical_flops(13), 13.0 * logical_flops(1));
        assert_eq!(t.useful_bytes, kv_bytes(13) + qo_bytes());
        assert_eq!(t.bucket_pad_bytes, kv_bytes(64) - kv_bytes(13));
        // M-padding is register fill, not HBM traffic.
        assert_eq!(t.total_bytes(), kv_bytes(64) + qo_bytes());
    }

    #[test]
    fn slot_walk_models_fallback_wavefronts_and_native_chunking() {
        let _l = lock();
        let _g = LedgerGuard::new();

        // Fallback: 2-token chunk at start 5 in a 4-wave tick ⇒ 2 useful
        // tokens (rows 6, 7) + 2 re-feeds of the last token (rows 7).
        begin_tick();
        record_slot(2, 5, 4, 64, false);
        let t = take_tick();
        assert_eq!(t.useful_flops, logical_flops(6) + logical_flops(7));
        assert_eq!(t.chunk_refeed_flops, 2.0 * logical_flops(64));
        assert_eq!(t.chunk_refeed_bytes, 2.0 * (kv_bytes(64) + qo_bytes()));

        // Native: same slot, no wavefront re-feeds.
        begin_tick();
        record_slot(2, 5, 4, 64, true);
        let t = take_tick();
        assert_eq!(t.useful_flops, logical_flops(6) + logical_flops(7));
        assert_eq!(t.chunk_refeed_flops, 0.0);

        // Empty slot: scratch per wave on fallback, once on native.
        begin_tick();
        record_slot(0, 0, 3, 64, false);
        let fallback = take_tick();
        begin_tick();
        record_slot(0, 0, 3, 64, true);
        let native = take_tick();
        assert_eq!(fallback.bucket_pad_flops, 3.0 * logical_flops(64));
        assert_eq!(native.bucket_pad_flops, logical_flops(64));
        assert_eq!(fallback.useful_flops, 0.0);
    }

    #[test]
    fn reclassify_rejected_moves_exactly_the_dispatched_amount() {
        let _l = lock();
        let _g = LedgerGuard::new();
        begin_tick();
        record_token(TokenKind::Useful, 7, 64);
        record_token(TokenKind::Useful, 8, 64);
        reclassify_rejected(8, 64);
        let t = take_tick();
        // Token at rows=8 moved wholesale; token at rows=7 untouched.
        assert_eq!(t.useful_flops, logical_flops(7));
        assert_eq!(t.spec_rejected_flops, logical_flops(8));
        assert_eq!(t.useful_bytes, kv_bytes(7) + qo_bytes());
        assert_eq!(t.spec_rejected_bytes, kv_bytes(8) + qo_bytes());
        // bucket_pad / mask_pad stay: those FLOPs were issued regardless.
        assert_eq!(
            t.bucket_pad_flops,
            2.0 * logical_flops(64) - logical_flops(7) - logical_flops(8)
        );
    }

    #[test]
    fn waste_fraction_stays_in_unit_interval() {
        let zero = ComputeTally::ZERO;
        assert_eq!(zero.waste_fraction(), 0.0);

        let _l = lock();
        let _g = LedgerGuard::new();
        begin_tick();
        record_token(TokenKind::Useful, 64, 64);
        record_token(TokenKind::Scratch, 1, 64);
        let t = take_tick();
        assert!(t.waste_fraction() > 0.0);
        assert!(t.waste_fraction() < 1.0);
        // Pure waste still reports < 1 (clamped).
        begin_tick();
        record_token(TokenKind::Scratch, 1, 64);
        let t = take_tick();
        assert!(t.waste_fraction() < 1.0);
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _l = lock();
        if enabled() {
            // A parallel test elsewhere in this binary (e.g. a workload
            // run) holds the gate open; only assert the disabled path
            // when the gate is actually closed — same tolerance as
            // `trace::tests::event_with_is_lazy_when_disabled`.
            return;
        }
        begin_tick();
        record_token(TokenKind::Useful, 64, 64);
        record_slot(3, 0, 4, 64, false);
        reclassify_rejected(4, 64);
        let t = take_tick();
        assert_eq!(t, ComputeTally::ZERO);
    }

    #[test]
    fn guard_refcount_nests() {
        let _l = lock();
        let externally_open = enabled();
        let a = LedgerGuard::new();
        let b = LedgerGuard::new();
        assert!(enabled());
        drop(a);
        assert!(enabled(), "second guard still holds the gate");
        drop(b);
        if !externally_open {
            assert!(!enabled(), "gate closed once our guards are gone");
        }
    }

    #[test]
    fn tally_accumulates_and_totals() {
        let mut a = ComputeTally::ZERO;
        let b = ComputeTally {
            useful_flops: 10.0,
            bucket_pad_flops: 4.0,
            chunk_refeed_flops: 3.0,
            spec_rejected_flops: 2.0,
            mask_pad_flops: 1.0,
            useful_bytes: 100.0,
            bucket_pad_bytes: 40.0,
            chunk_refeed_bytes: 30.0,
            spec_rejected_bytes: 20.0,
        };
        a.add(&b);
        a.add(&b);
        assert_eq!(a.issued_flops(), 40.0);
        assert_eq!(a.waste_flops(), 20.0);
        assert_eq!(a.waste_fraction(), 0.5);
        assert_eq!(a.total_bytes(), 380.0);
    }
}
