//! Span-duration profiler: aggregate span wall times per `target.name`.
//!
//! The tracing layer already stamps every span `Exit` with its duration
//! ([`super::trace::TraceRecord::wall_us`]); this module folds those
//! durations into a process-global per-span aggregate (count, total,
//! mean, approximate p99 via [`LatencyHistogram`], exact min/max) so a
//! bench or a long-running server can export a hot-path profile without
//! keeping — or even installing — a record collector.
//!
//! Cost model, matching the rest of `obs`:
//!
//! * **Disabled** (default): nothing.  [`enable`] sets a bit in the same
//!   gate `span`/`event` already consult, so the disabled path stays one
//!   relaxed atomic load and zero allocation
//!   (`rust/tests/obs_overhead.rs` asserts this with a counting
//!   allocator, including after an enable → disable round trip).
//! * **Enabled**: each span exit takes a mutex and updates one
//!   `BTreeMap` entry keyed by the `'static` target/name pair — no
//!   per-record allocation after a span's first observation.
//!
//! Export: [`export_into`] writes one `flashmla_span_<target>_<name>_us`
//! summary per observed span into a [`MetricsRegistry`];
//! `ServingMetrics::registry` calls it, so every `BENCH_*.json` snapshot
//! and `metrics.prom` dump automatically carries the profile when
//! profiling was on.  The aggregate is process-global (spans from every
//! engine in the process fold together), which is exactly what a bench
//! run wants and what `docs/benchmarking.md` documents.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::registry::{MetricsRegistry, Summary};
use super::trace;
use crate::util::stats::LatencyHistogram;

struct SpanAgg {
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
    hist: LatencyHistogram,
}

static PROFILE: Mutex<BTreeMap<(&'static str, &'static str), SpanAgg>> =
    Mutex::new(BTreeMap::new());

/// Start profiling span durations (idempotent).  Opens the tracing gate,
/// so spans on every thread begin reporting their exit durations here.
pub fn enable() {
    trace::set_profiling(true);
}

/// Stop profiling (idempotent).  Accumulated aggregates survive until
/// [`reset`] so they can still be exported after the measured region.
pub fn disable() {
    trace::set_profiling(false);
}

/// Is the profiler currently recording?
pub fn enabled() -> bool {
    trace::profiling()
}

/// Drop all accumulated aggregates (typically paired with [`enable`] at
/// the start of a measured region).
pub fn reset() {
    PROFILE.lock().unwrap().clear();
}

/// Fold one span exit into the aggregate.  Called by the trace layer
/// only while the profiler bit is set.
pub(crate) fn record(target: &'static str, name: &'static str, dur_us: f64) {
    let mut map = PROFILE.lock().unwrap();
    let agg = map.entry((target, name)).or_insert_with(|| SpanAgg {
        count: 0,
        sum_us: 0.0,
        min_us: f64::INFINITY,
        max_us: 0.0,
        hist: LatencyHistogram::new(),
    });
    agg.count += 1;
    agg.sum_us += dur_us;
    agg.min_us = agg.min_us.min(dur_us);
    agg.max_us = agg.max_us.max(dur_us);
    agg.hist.record_us(dur_us);
}

/// One span's aggregated profile.
#[derive(Clone, Debug)]
pub struct SpanProfile {
    pub target: &'static str,
    pub name: &'static str,
    pub count: u64,
    pub total_us: f64,
    pub mean_us: f64,
    /// Approximate (log-bucketed histogram, ≤ ~4 % relative error).
    pub p50_us: f64,
    /// Approximate (log-bucketed histogram, ≤ ~4 % relative error).
    pub p99_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

/// Snapshot of every span observed so far, ordered by `target.name`.
pub fn snapshot() -> Vec<SpanProfile> {
    let map = PROFILE.lock().unwrap();
    map.iter()
        .map(|(&(target, name), agg)| SpanProfile {
            target,
            name,
            count: agg.count,
            total_us: agg.sum_us,
            mean_us: if agg.count == 0 {
                0.0
            } else {
                agg.sum_us / agg.count as f64
            },
            p50_us: agg.hist.percentile_us(50.0),
            p99_us: agg.hist.percentile_us(99.0),
            min_us: if agg.count == 0 { 0.0 } else { agg.min_us },
            max_us: agg.max_us,
        })
        .collect()
}

/// Metric-name-safe rendering of a span component (`kv_sync` stays,
/// anything exotic maps to `_`).
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Export every aggregated span as a
/// `flashmla_span_<target>_<name>_us` summary.  No-op when nothing was
/// profiled, so registries built with profiling off are unchanged.
pub fn export_into(r: &mut MetricsRegistry) {
    for p in snapshot() {
        r.summary(
            &format!(
                "flashmla_span_{}_{}_us",
                sanitize(p.target),
                sanitize(p.name)
            ),
            &format!("Wall time of `{}.{}` spans (µs).", p.target, p.name),
            Summary {
                count: p.count,
                sum: p.total_us,
                mean: p.mean_us,
                p50: Some(p.p50_us),
                p99: Some(p.p99_us),
                min: p.min_us,
                max: p.max_us,
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test fn only: the profiler state and gate are process-global,
    // and Rust runs tests in this module on separate threads.
    #[test]
    fn profiler_round_trip() {
        reset();
        // Disabled: spans leave no aggregate.
        disable();
        {
            let _s = trace::span("profiler_test", "cold");
        }
        assert!(
            snapshot()
                .iter()
                .all(|p| !(p.target == "profiler_test" && p.name == "cold")),
            "disabled profiler must not record"
        );

        enable();
        assert!(enabled());
        for _ in 0..3 {
            let _s = trace::span("profiler_test", "hot");
        }
        disable();
        assert!(!enabled());
        {
            let _s = trace::span("profiler_test", "late");
        }

        let snap = snapshot();
        let hot = snap
            .iter()
            .find(|p| p.target == "profiler_test" && p.name == "hot")
            .expect("profiled span present");
        assert_eq!(hot.count, 3);
        assert!(hot.total_us >= hot.max_us);
        assert!(hot.min_us <= hot.mean_us && hot.mean_us <= hot.max_us + 1e-9);
        assert!(
            !snap
                .iter()
                .any(|p| p.target == "profiler_test" && p.name == "late"),
            "spans after disable must not record"
        );

        // Export shape: sanitized summary name with count/sum/p99.
        let mut r = MetricsRegistry::new();
        export_into(&mut r);
        match r.get("flashmla_span_profiler_test_hot_us") {
            Some(crate::obs::registry::MetricValue::Summary(s)) => {
                assert_eq!(s.count, 3);
                assert!(s.p99.is_some());
            }
            other => panic!("expected summary, got {other:?}"),
        }

        reset();
        assert!(snapshot()
            .iter()
            .all(|p| p.target != "profiler_test"));
    }
}
