//! Observability: structured tracing, the engine flight recorder, the
//! metrics registry, and per-request timelines.
//!
//! Zero external dependencies — the JSON exporters ride on
//! [`crate::util::json`], the narrative rides on [`crate::util::logging`].
//! Four pieces, layered from cheapest to richest:
//!
//! * [`trace`] — span/event API stamped with both the wall clock and the
//!   deterministic engine tick clock.  Disabled cost is one relaxed atomic
//!   load; tests install a per-thread [`TraceCollector`] and assert the
//!   trace shape bit-for-bit via [`TraceRecord::key`].
//! * [`recorder`] — the flight recorder: a fixed-capacity ring of
//!   per-tick [`TickRecord`]s (plan summary, batch composition, budget,
//!   KV pressure, spec + prefix activity), dumpable as JSON on demand or
//!   when the debug KV ledger trips.
//! * [`profiler`] — opt-in span-duration aggregation per `target.name`
//!   (count/total/mean/p99), exported as `flashmla_span_*` summaries so
//!   bench JSON and Prometheus dumps carry a hot-path profile.
//! * [`registry`] — the named metric registry `ServingMetrics` exports
//!   into, with Prometheus-text and JSON snapshot exporters.
//! * [`timeline`] — per-request tick-stamped lifecycle records,
//!   queryable through `RequestHandle`.
//! * [`ledger`] — the per-tick compute ledger: attributes every modeled
//!   FLOP/byte of the engine hot path to useful vs. waste categories
//!   with the same atom math as `sim/gemm.rs`, gated by the shared
//!   one-atomic-load `obs` gate.
//!
//! The tick-clock/wall-clock contract, span taxonomy, and exporter
//! schemas are documented in `docs/observability.md`.

pub mod ledger;
pub mod profiler;
pub mod recorder;
pub mod registry;
pub mod timeline;
pub mod trace;

pub use ledger::{ComputeTally, LedgerGuard};
pub use profiler::SpanProfile;
pub use recorder::{FlightRecorder, TickRecord};
pub use registry::{MetricEntry, MetricValue, MetricsRegistry, Summary};
pub use timeline::RequestTimeline;
pub use trace::{
    active, collect, current_tick, event, event_with, set_narrative, set_tick, span, SpanGuard,
    TraceCollector, TraceKind, TraceRecord,
};
