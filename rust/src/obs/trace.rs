//! Structured tracing: spans and events stamped with the engine tick clock.
//!
//! The design centers on two clocks and one gate:
//!
//! * **Tick clock** — the engine publishes its deterministic step counter
//!   via [`set_tick`] before each tick; every record carries it, so two
//!   runs of the same workload produce bit-identical record *keys*
//!   ([`TraceRecord::key`]) and tests can assert on trace shape exactly.
//! * **Wall clock** — every record also carries a wall-time stamp
//!   (`wall_us`: µs since process start for `Enter`/`Event`, span duration
//!   for `Exit`).  Wall fields are explicitly non-deterministic and are
//!   excluded from [`TraceRecord::key`].
//! * **The gate** — a single process-global atomic ([`active`]).  When no
//!   collector is installed and trace-level logging is off, [`span`] and
//!   [`event`] cost exactly one relaxed atomic load and **allocate
//!   nothing** (`rust/tests/obs_overhead.rs` asserts this with a counting
//!   allocator).  Detail strings are built lazily via [`event_with`]'s
//!   closure, so disabled call sites never pay for formatting either.
//!
//! Sinks are **thread-local**: the engine runs on its caller's thread, so
//! a [`collect`]-ed test observes only its own engine and parallel tests
//! never race on a shared buffer.  With no collector but `FLASHMLA_LOG=
//! trace`, records are narrated through the stderr logger instead, giving
//! the interleaved `engine`/`batcher`/`planner`/`spec`/`prefix` story.

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Instant;

use crate::util::logging::{self, Level};

/// Bit 0 of the gate: narrate records through the stderr logger.
const NARRATIVE: u32 = 1;
/// Bit 1 of the gate: feed span `Exit` durations to the span profiler
/// ([`crate::obs::profiler`]).
const PROFILER: u32 = 2;
/// Each installed collector adds this to the gate (any thread's collector
/// flips every thread onto the slow path; threads without a sink then
/// no-op after the thread-local check).
const COLLECTOR_UNIT: u32 = 4;
/// Each live compute-ledger guard ([`crate::obs::ledger::LedgerGuard`])
/// adds this to the gate.  A refcount (not a bit) so concurrent runs in
/// parallel test threads can each hold the ledger open without one run's
/// drop disabling recording mid-run in another — that would make per-run
/// compute totals nondeterministic.  Sitting at bit 16, the collector
/// refcount below would need >16384 simultaneous collectors to collide.
const LEDGER_UNIT: u32 = 1 << 16;
/// Sentinel: the gate has not consulted `FLASHMLA_LOG` yet.
const UNINIT: u32 = u32::MAX;

static ACTIVE: AtomicU32 = AtomicU32::new(UNINIT);

#[cold]
fn init_active() -> u32 {
    let base = if logging::enabled(Level::Trace) {
        NARRATIVE
    } else {
        0
    };
    // First writer wins; a racing `collect()` may already have bumped the
    // counter past UNINIT, in which case its value stands.
    match ACTIVE.compare_exchange(UNINIT, base, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => base,
        Err(cur) => cur,
    }
}

/// Is any tracing consumer (collector or trace-level narrative) live?
/// This is the whole disabled-path cost: one relaxed atomic load.
///
/// Masks off the compute-ledger refcount (bits ≥ 16): a live
/// [`crate::obs::ledger::LedgerGuard`] must not open the span/event slow
/// path — the ledger consumes shapes at the runtime boundary, never
/// trace records, and ledger-on runs keep the zero-alloc tracing fast
/// path (`rust/tests/obs_overhead.rs` asserts this too).
#[inline]
pub fn active() -> bool {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v == UNINIT {
        return init_active() != 0;
    }
    v & (LEDGER_UNIT - 1) != 0
}

/// Force the stderr narrative on or off programmatically (tests, CLI
/// `--verbose`), overriding what `FLASHMLA_LOG` implied.  Narration still
/// goes through the logger, so the level must admit `Trace` for lines to
/// actually print ([`logging::set_level`]).
pub fn set_narrative(on: bool) {
    active(); // force init so the bit ops see a real value
    if on {
        ACTIVE.fetch_or(NARRATIVE, Ordering::Relaxed);
    } else {
        ACTIVE.fetch_and(!NARRATIVE, Ordering::Relaxed);
    }
}

/// Flip the span-profiler bit of the gate (see
/// [`crate::obs::profiler::enable`], the public entry point).  While set,
/// every span `Exit` also lands in the profiler's per-`target.name`
/// aggregate; the disabled path is untouched — still the one relaxed load
/// in [`active`].
pub(crate) fn set_profiling(on: bool) {
    active(); // force init so the bit ops see a real value
    if on {
        ACTIVE.fetch_or(PROFILER, Ordering::Relaxed);
    } else {
        ACTIVE.fetch_and(!PROFILER, Ordering::Relaxed);
    }
}

/// Is the span-profiler bit set?  (Callers are already past the [`active`]
/// gate, so the load here never races initialization.)
pub(crate) fn profiling() -> bool {
    let v = ACTIVE.load(Ordering::Relaxed);
    v != UNINIT && v & PROFILER != 0
}

/// Take a compute-ledger reference on the gate (see
/// [`crate::obs::ledger::LedgerGuard`], the public entry point).
pub(crate) fn ledger_add() {
    active(); // force init so the arithmetic sees a real value
    ACTIVE.fetch_add(LEDGER_UNIT, Ordering::Relaxed);
}

/// Release a compute-ledger reference taken by [`ledger_add`].
pub(crate) fn ledger_sub() {
    ACTIVE.fetch_sub(LEDGER_UNIT, Ordering::Relaxed);
}

/// Is at least one compute-ledger guard live?  One relaxed atomic load —
/// the whole disabled-path cost, mirroring [`active`].  Everything below
/// `LEDGER_UNIT` is narrative/profiler bits plus the collector refcount,
/// so `v >= LEDGER_UNIT` means "ledger refcount nonzero".
#[inline]
pub(crate) fn ledger_on() -> bool {
    let v = ACTIVE.load(Ordering::Relaxed);
    v != UNINIT && v >= LEDGER_UNIT
}

thread_local! {
    /// The engine's deterministic step clock, stamped into every record.
    static TICK: Cell<u64> = const { Cell::new(0) };
    /// At most one collector per thread (see [`collect`]).
    static COLLECTOR: RefCell<Option<Rc<RefCell<Vec<TraceRecord>>>>> =
        const { RefCell::new(None) };
}

/// Publish the current engine tick for this thread; subsequent records are
/// stamped with it.  The engine calls this at the top of every `step`.
pub fn set_tick(tick: u64) {
    TICK.with(|t| t.set(tick));
}

/// The tick most recently published via [`set_tick`] on this thread.
pub fn current_tick() -> u64 {
    TICK.with(|t| t.get())
}

fn t0() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

fn wall_us() -> f64 {
    t0().elapsed().as_secs_f64() * 1e6
}

/// What a record marks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Span opened.
    Enter,
    /// Span closed (`wall_us` holds the span duration, not a timestamp).
    Exit,
    /// Point event.
    Event,
}

/// One trace record.  Everything except `wall_us` is deterministic for a
/// deterministic workload.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    /// Engine tick clock at emission ([`set_tick`]).
    pub tick: u64,
    /// Subsystem target (`engine`, `batcher`, `planner`, `spec`,
    /// `prefix`, `runtime`).
    pub target: &'static str,
    /// Span or event name within the target.
    pub name: &'static str,
    pub kind: TraceKind,
    /// Lazily built detail string (empty for plain spans/events).
    pub detail: String,
    /// Wall stamp: µs since process start, or span duration for `Exit`.
    /// The one non-deterministic field; excluded from [`key`](Self::key).
    pub wall_us: f64,
}

impl TraceRecord {
    /// Deterministic rendering for bit-for-bit test assertions: every
    /// field except the wall clock.
    pub fn key(&self) -> String {
        let sigil = match self.kind {
            TraceKind::Enter => " >",
            TraceKind::Exit => " <",
            TraceKind::Event => "",
        };
        if self.detail.is_empty() {
            format!("[t{}] {}.{}{}", self.tick, self.target, self.name, sigil)
        } else {
            format!(
                "[t{}] {}.{}{} {}",
                self.tick, self.target, self.name, sigil, self.detail
            )
        }
    }
}

fn emit(kind: TraceKind, target: &'static str, name: &'static str, detail: String, wall: f64) {
    if kind == TraceKind::Exit && profiling() {
        // `wall` is the span duration for Exit records.
        crate::obs::profiler::record(target, name, wall);
    }
    let rec = TraceRecord {
        tick: current_tick(),
        target,
        name,
        kind,
        detail,
        wall_us: wall,
    };
    if ACTIVE.load(Ordering::Relaxed) & NARRATIVE != 0 {
        let sigil = match rec.kind {
            TraceKind::Enter => " >",
            TraceKind::Exit => " <",
            TraceKind::Event => "",
        };
        logging::log(
            Level::Trace,
            rec.target,
            format_args!("[t{}] {}{} {}", rec.tick, rec.name, sigil, rec.detail),
        );
    }
    COLLECTOR.with(|c| {
        if let Some(sink) = c.borrow().as_ref() {
            sink.borrow_mut().push(rec);
        }
    });
}

struct SpanInner {
    target: &'static str,
    name: &'static str,
    t0: Instant,
}

/// RAII span guard: records `Enter` at creation, `Exit` (with duration)
/// on drop.  When tracing is disabled the guard is inert and allocates
/// nothing.
pub struct SpanGuard {
    inner: Option<SpanInner>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(s) = self.inner.take() {
            emit(
                TraceKind::Exit,
                s.target,
                s.name,
                String::new(),
                s.t0.elapsed().as_secs_f64() * 1e6,
            );
        }
    }
}

/// Open a span.  `target` and `name` must be `'static` literals so the
/// disabled path moves nothing to the heap.
#[inline]
pub fn span(target: &'static str, name: &'static str) -> SpanGuard {
    if !active() {
        return SpanGuard { inner: None };
    }
    span_slow(target, name)
}

#[cold]
fn span_slow(target: &'static str, name: &'static str) -> SpanGuard {
    emit(TraceKind::Enter, target, name, String::new(), wall_us());
    SpanGuard {
        inner: Some(SpanInner {
            target,
            name,
            t0: Instant::now(),
        }),
    }
}

/// Record a point event with no detail.
#[inline]
pub fn event(target: &'static str, name: &'static str) {
    if active() {
        emit(TraceKind::Event, target, name, String::new(), wall_us());
    }
}

/// Record a point event whose detail string is built only when tracing is
/// live — disabled call sites never pay for the formatting.
#[inline]
pub fn event_with(target: &'static str, name: &'static str, detail: impl FnOnce() -> String) {
    if active() {
        emit(TraceKind::Event, target, name, detail(), wall_us());
    }
}

/// Handle over an installed per-thread record sink.  Records emitted on
/// this thread while the handle lives are appended to its buffer; dropping
/// the handle uninstalls the sink and decrements the global gate.
pub struct TraceCollector {
    sink: Rc<RefCell<Vec<TraceRecord>>>,
}

/// Install a collector on the current thread (at most one per thread;
/// panics on a double install so tests fail loudly instead of splitting
/// their records).
pub fn collect() -> TraceCollector {
    active(); // force gate init before arithmetic on it
    let sink = Rc::new(RefCell::new(Vec::new()));
    COLLECTOR.with(|c| {
        let mut cur = c.borrow_mut();
        assert!(
            cur.is_none(),
            "a trace collector is already installed on this thread"
        );
        *cur = Some(sink.clone());
    });
    ACTIVE.fetch_add(COLLECTOR_UNIT, Ordering::Relaxed);
    TraceCollector { sink }
}

impl TraceCollector {
    /// Snapshot of the records collected so far.
    pub fn records(&self) -> Vec<TraceRecord> {
        self.sink.borrow().clone()
    }

    /// Drain the collected records.
    pub fn take(&self) -> Vec<TraceRecord> {
        std::mem::take(&mut *self.sink.borrow_mut())
    }

    /// Deterministic keys of the records collected so far
    /// ([`TraceRecord::key`]): the bit-for-bit assertable trace shape.
    pub fn keys(&self) -> Vec<String> {
        self.sink.borrow().iter().map(|r| r.key()).collect()
    }
}

impl Drop for TraceCollector {
    fn drop(&mut self) {
        COLLECTOR.with(|c| {
            *c.borrow_mut() = None;
        });
        ACTIVE.fetch_sub(COLLECTOR_UNIT, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_captures_spans_events_and_ticks() {
        let c = collect();
        set_tick(7);
        {
            let _s = span("engine", "step");
            event_with("engine", "submit", || "id=1 prompt=4".to_string());
            set_tick(8);
            event("batcher", "reap");
        }
        let keys = c.keys();
        assert_eq!(
            keys,
            vec![
                "[t7] engine.step >",
                "[t7] engine.submit id=1 prompt=4",
                "[t8] batcher.reap",
                "[t8] engine.step <",
            ]
        );
        // Wall stamps exist but are excluded from the deterministic key.
        for r in c.records() {
            assert!(r.wall_us >= 0.0);
            assert!(!r.key().contains("wall"), "key leaks wall time: {}", r.key());
        }
        set_tick(0);
    }

    #[test]
    fn exit_carries_span_duration() {
        let c = collect();
        {
            let _s = span("runtime", "prefill_chunk");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let recs = c.take();
        let exit = recs
            .iter()
            .find(|r| r.kind == TraceKind::Exit)
            .expect("exit record");
        assert!(exit.wall_us >= 1000.0, "duration {} µs", exit.wall_us);
    }

    #[test]
    fn collector_drop_uninstalls() {
        {
            let c = collect();
            event("engine", "alive");
            assert_eq!(c.records().len(), 1);
        }
        // No collector on this thread anymore: events land nowhere, and a
        // fresh collector starts empty.
        event("engine", "lost");
        let c = collect();
        assert!(c.records().is_empty());
    }

    #[test]
    #[should_panic(expected = "already installed")]
    fn double_install_panics() {
        let _a = collect();
        let _b = collect();
    }

    #[test]
    fn event_with_is_lazy_when_disabled() {
        // No collector on this thread, narrative forced off: the detail
        // closure must never run.
        set_narrative(false);
        if active() {
            // Another test's collector (other thread) holds the gate open;
            // the thread-local check still keeps our closure… running.
            // Only assert laziness when the gate is actually closed.
            return;
        }
        let mut ran = false;
        event_with("engine", "noop", || {
            ran = true;
            String::new()
        });
        assert!(!ran, "detail closure ran while tracing was disabled");
    }
}
