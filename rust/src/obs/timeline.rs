//! Per-request lifecycle timelines.
//!
//! One [`RequestTimeline`] per request, keyed by the request id and
//! maintained by the engine as ticks execute: when the request was
//! submitted, when it was admitted into the live batch, when its first
//! token landed, when (and how) it finished, and what the pipelines did
//! for it along the way — prefill chunks consumed, prefix-cache tokens
//! adopted, speculative tokens drafted and accepted.
//!
//! All stamps are **engine ticks** (the deterministic step clock), not
//! wall time, so timelines are bit-reproducible for a deterministic
//! workload and queryable through `RequestHandle` after the run.

use crate::util::json::Json;

/// Tick-stamped lifecycle record for one request.
#[derive(Clone, Debug)]
pub struct RequestTimeline {
    /// Raw request id (`RequestId`'s integer value).
    pub id: u64,
    /// `ServingMetrics::steps` at submit time.
    pub submitted_step: u64,
    /// Step count when the request entered the live batch.
    pub admitted_step: Option<u64>,
    /// Step count after the tick that produced the first output token.
    pub first_token_step: Option<u64>,
    /// Step count when the request left the engine.
    pub finished_step: Option<u64>,
    /// Terminal outcome (`FinishReason` debug form), once finished.
    pub outcome: Option<String>,
    /// Output tokens produced.
    pub tokens: usize,
    /// Prefill chunks executed for this request.
    pub prefill_chunks: usize,
    /// Prompt tokens skipped via prefix-cache adoption.
    pub adopted_prefix_tokens: usize,
    /// Speculative draft tokens fed to verification / accepted.
    pub spec_drafted: usize,
    pub spec_accepted: usize,
}

impl RequestTimeline {
    pub fn new(id: u64, submitted_step: u64) -> Self {
        RequestTimeline {
            id,
            submitted_step,
            admitted_step: None,
            first_token_step: None,
            finished_step: None,
            outcome: None,
            tokens: 0,
            prefill_chunks: 0,
            adopted_prefix_tokens: 0,
            spec_drafted: 0,
            spec_accepted: 0,
        }
    }

    /// Ticks spent queued before admission (once admitted).
    pub fn queue_steps(&self) -> Option<u64> {
        self.admitted_step.map(|a| a - self.submitted_step)
    }

    /// Ticks from submit to first token (once produced).
    pub fn ttft_steps(&self) -> Option<u64> {
        self.first_token_step.map(|f| f - self.submitted_step)
    }

    /// Ticks from submit to completion (once finished).
    pub fn e2e_steps(&self) -> Option<u64> {
        self.finished_step.map(|f| f - self.submitted_step)
    }

    fn opt_step(v: Option<u64>) -> Json {
        v.map(|s| Json::num(s as f64)).unwrap_or(Json::Null)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("submitted_step", Json::num(self.submitted_step as f64)),
            ("admitted_step", Self::opt_step(self.admitted_step)),
            ("first_token_step", Self::opt_step(self.first_token_step)),
            ("finished_step", Self::opt_step(self.finished_step)),
            (
                "outcome",
                self.outcome
                    .as_ref()
                    .map(|o| Json::str(o.clone()))
                    .unwrap_or(Json::Null),
            ),
            ("tokens", Json::num(self.tokens as f64)),
            ("prefill_chunks", Json::num(self.prefill_chunks as f64)),
            (
                "adopted_prefix_tokens",
                Json::num(self.adopted_prefix_tokens as f64),
            ),
            ("spec_drafted", Json::num(self.spec_drafted as f64)),
            ("spec_accepted", Json::num(self.spec_accepted as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_intervals() {
        let mut t = RequestTimeline::new(7, 4);
        assert_eq!(t.queue_steps(), None);
        t.admitted_step = Some(5);
        t.first_token_step = Some(9);
        t.finished_step = Some(14);
        assert_eq!(t.queue_steps(), Some(1));
        assert_eq!(t.ttft_steps(), Some(5));
        assert_eq!(t.e2e_steps(), Some(10));
    }

    #[test]
    fn json_shape() {
        let mut t = RequestTimeline::new(3, 0);
        t.admitted_step = Some(1);
        t.tokens = 6;
        t.outcome = Some("Eos".to_string());
        let doc = crate::util::json::parse(&t.to_json().dump()).unwrap();
        assert_eq!(doc.get("id").as_usize(), Some(3));
        assert_eq!(doc.get("admitted_step").as_usize(), Some(1));
        assert_eq!(doc.get("first_token_step"), &Json::Null);
        assert_eq!(doc.get("outcome").as_str(), Some("Eos"));
        assert_eq!(doc.get("tokens").as_usize(), Some(6));
    }
}
