//! The engine flight recorder: a fixed-capacity ring of per-tick records.
//!
//! Every completed engine tick appends one [`TickRecord`] — the plan
//! summary the engine reported live, batch composition, token budget use,
//! KV pool pressure, speculation and prefix-cache activity.  When a
//! scheduling pathology happens (a starved cold prompt, a spec-suppressed
//! tick storm, pressure evictions) the recorder answers *which ticks* did
//! it and *why*, without a debugger attached.
//!
//! The ring is bounded ([`FlightRecorder::capacity`]): old ticks fall off
//! the front and are counted in [`dropped`](FlightRecorder::dropped), so a
//! long-running server pays fixed memory.  Records are deterministic for a
//! deterministic workload **modulo the `wall_us` field** — the dump-
//! determinism test strips exactly that key and asserts bit-equality.
//!
//! Dumps go through [`crate::util::json`]: on demand
//! (`Engine::dump_flight_recorder`), and automatically when the
//! debug-build KV-occupancy ledger trips (the crash dump that makes the
//! assertion message actionable).

use std::collections::VecDeque;
use std::path::Path;

use crate::util::json::Json;

/// One engine tick, as the recorder saw it.
#[derive(Clone, Debug)]
pub struct TickRecord {
    /// 1-based engine step count after this tick (`ServingMetrics::steps`).
    pub tick: u64,
    /// Tick wall duration in µs — the only non-deterministic field.
    pub wall_us: f64,
    /// The plan summary the engine reported live
    /// (`Engine::last_plan_summary`).
    pub plan: String,
    /// Active requests after the tick.
    pub active: usize,
    /// Requests still queued after the tick.
    pub queued: usize,
    /// Batch composition: slots that consumed exactly one decode token…
    pub decode_slots: usize,
    /// …slots that consumed a prefill chunk…
    pub prefill_slots: usize,
    /// …and slots that ran a speculative verification chunk.
    pub verify_slots: usize,
    /// Executed (batch, kv) bucket shape.
    pub batch_bucket: usize,
    pub kv_bucket: usize,
    /// Tokens the plan consumed vs. the effective per-tick budget.
    pub budget_used: usize,
    pub budget: usize,
    /// Tokens appended to outputs this tick (decode + accepted drafts +
    /// prefill-completion firsts).
    pub new_tokens: usize,
    /// Prompt tokens consumed by prefill chunks this tick.
    pub prefill_tokens: usize,
    /// KV pool pressure after the tick.
    pub kv_free_blocks: usize,
    pub kv_total_blocks: usize,
    /// Cumulative prefix-cache counters after the tick.
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    /// Speculation this tick: draft tokens fed / accepted, and whether a
    /// sampled co-resident suppressed drafting batch-wide.
    pub spec_drafted: usize,
    pub spec_accepted: usize,
    pub spec_suppressed: bool,
    /// Did this tick rebuild the live batch (sync + regather)?
    pub recomposed: bool,
    /// Step events emitted this tick.
    pub events: usize,
    /// Compute-ledger attribution for this tick ([`crate::obs::ledger`]):
    /// modeled FLOPs by category and total modeled HBM bytes.  All zero
    /// when no `LedgerGuard` was live.
    pub useful_flops: f64,
    pub bucket_pad_flops: f64,
    pub chunk_refeed_flops: f64,
    pub spec_rejected_flops: f64,
    pub mask_pad_flops: f64,
    pub bytes_moved: f64,
}

impl TickRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tick", Json::num(self.tick as f64)),
            ("wall_us", Json::num(self.wall_us)),
            ("plan", Json::str(self.plan.clone())),
            ("active", Json::num(self.active as f64)),
            ("queued", Json::num(self.queued as f64)),
            ("decode_slots", Json::num(self.decode_slots as f64)),
            ("prefill_slots", Json::num(self.prefill_slots as f64)),
            ("verify_slots", Json::num(self.verify_slots as f64)),
            ("batch_bucket", Json::num(self.batch_bucket as f64)),
            ("kv_bucket", Json::num(self.kv_bucket as f64)),
            ("budget_used", Json::num(self.budget_used as f64)),
            ("budget", Json::num(self.budget as f64)),
            ("new_tokens", Json::num(self.new_tokens as f64)),
            ("prefill_tokens", Json::num(self.prefill_tokens as f64)),
            ("kv_free_blocks", Json::num(self.kv_free_blocks as f64)),
            ("kv_total_blocks", Json::num(self.kv_total_blocks as f64)),
            ("prefix_hits", Json::num(self.prefix_hits as f64)),
            ("prefix_lookups", Json::num(self.prefix_lookups as f64)),
            ("spec_drafted", Json::num(self.spec_drafted as f64)),
            ("spec_accepted", Json::num(self.spec_accepted as f64)),
            ("spec_suppressed", Json::Bool(self.spec_suppressed)),
            ("recomposed", Json::Bool(self.recomposed)),
            ("events", Json::num(self.events as f64)),
            ("useful_flops", Json::num(self.useful_flops)),
            ("bucket_pad_flops", Json::num(self.bucket_pad_flops)),
            ("chunk_refeed_flops", Json::num(self.chunk_refeed_flops)),
            ("spec_rejected_flops", Json::num(self.spec_rejected_flops)),
            ("mask_pad_flops", Json::num(self.mask_pad_flops)),
            ("bytes_moved", Json::num(self.bytes_moved)),
        ])
    }
}

/// Fixed-capacity ring buffer of [`TickRecord`]s.
pub struct FlightRecorder {
    capacity: usize,
    ring: VecDeque<TickRecord>,
    dropped: u64,
}

impl FlightRecorder {
    /// `capacity` must be ≥ 1 (the engine maps capacity 0 to "no
    /// recorder" before construction).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "flight recorder needs capacity ≥ 1");
        FlightRecorder {
            capacity,
            ring: VecDeque::with_capacity(capacity),
            dropped: 0,
        }
    }

    /// Append one tick, evicting the oldest when full.
    pub fn record(&mut self, rec: TickRecord) {
        if self.ring.len() == self.capacity {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(rec);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Ticks that fell off the front of the ring.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retained records, oldest first.
    pub fn records(&self) -> impl Iterator<Item = &TickRecord> {
        self.ring.iter()
    }

    /// Whole-recorder JSON document: `{"capacity", "dropped", "ticks"}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("capacity", Json::num(self.capacity as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            (
                "ticks",
                Json::Arr(self.ring.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Write the JSON document to `path`.
    pub fn dump(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().dump())
            .map_err(|e| anyhow::anyhow!("flight recorder dump {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(tick: u64) -> TickRecord {
        TickRecord {
            tick,
            wall_us: 123.4,
            plan: format!("plan[used 1/8] s0=d1 ({tick})"),
            active: 1,
            queued: 0,
            decode_slots: 1,
            prefill_slots: 0,
            verify_slots: 0,
            batch_bucket: 1,
            kv_bucket: 32,
            budget_used: 1,
            budget: 8,
            new_tokens: 1,
            prefill_tokens: 0,
            kv_free_blocks: 60,
            kv_total_blocks: 64,
            prefix_hits: 0,
            prefix_lookups: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_suppressed: false,
            recomposed: tick == 1,
            events: 1,
            useful_flops: 1_114_112.0,
            bucket_pad_flops: 2_228_224.0,
            chunk_refeed_flops: 0.0,
            spec_rejected_flops: 0.0,
            mask_pad_flops: 3_342_336.0,
            bytes_moved: 73_728.0,
        }
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut fr = FlightRecorder::new(3);
        for t in 1..=7 {
            fr.record(rec(t));
        }
        assert_eq!(fr.len(), 3);
        assert_eq!(fr.dropped(), 4);
        let ticks: Vec<u64> = fr.records().map(|r| r.tick).collect();
        assert_eq!(ticks, vec![5, 6, 7], "oldest evicted first");
    }

    #[test]
    fn json_round_trips_through_parser() {
        let mut fr = FlightRecorder::new(8);
        fr.record(rec(1));
        fr.record(rec(2));
        let doc = crate::util::json::parse(&fr.to_json().dump()).unwrap();
        assert_eq!(doc.get("capacity").as_usize(), Some(8));
        assert_eq!(doc.get("dropped").as_usize(), Some(0));
        let ticks = doc.get("ticks").as_arr().unwrap();
        assert_eq!(ticks.len(), 2);
        assert_eq!(ticks[1].get("tick").as_usize(), Some(2));
        assert!(ticks[0].get("plan").as_str().unwrap().starts_with("plan["));
        assert_eq!(ticks[0].get("recomposed").as_bool(), Some(true));
        assert_eq!(ticks[1].get("recomposed").as_bool(), Some(false));
        assert_eq!(ticks[0].get("kv_total_blocks").as_usize(), Some(64));
        assert_eq!(ticks[0].get("useful_flops").as_f64(), Some(1_114_112.0));
        assert_eq!(ticks[0].get("bytes_moved").as_f64(), Some(73_728.0));
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        FlightRecorder::new(0);
    }
}
