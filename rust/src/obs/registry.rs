//! Named metrics registry with Prometheus-text and JSON exporters.
//!
//! `ServingMetrics` stays the engine's hot-path accumulator (plain struct
//! fields, no name lookups per tick); this registry is the *export* shape
//! it enumerates into on demand (`ServingMetrics::registry`).  Four value
//! kinds cover everything the engine counts:
//!
//! * **Counter** — monotone total (`…_total` names).  Merging two engines'
//!   metrics sums these, which is what makes the merge-parity test below
//!   checkable mechanically.
//! * **Gauge** — instantaneous or derived value (rates recompute from the
//!   merged totals, never average).
//! * **Summary** — count/sum/mean plus min/max, with approximate p50/p99
//!   when the source is a latency histogram (a Welford source has exact
//!   moments but no quantiles).
//! * **Series** — a labeled counter family (chunk-size and acceptance
//!   histograms: one sample count per integer label).
//!
//! Exporters: [`MetricsRegistry::to_prometheus`] renders the standard
//! text exposition format; [`MetricsRegistry::to_json`] renders the
//! snapshot schema the bench harness embeds in every `BENCH_*.json`
//! (`{"counters", "gauges", "summaries", "series"}`).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Summary statistics of a distribution-valued metric.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub mean: f64,
    /// Approximate quantiles — histogram-backed sources only.
    pub p50: Option<f64>,
    pub p99: Option<f64>,
    pub min: f64,
    pub max: f64,
}

/// A metric's exported value.
#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(f64),
    Gauge(f64),
    Summary(Summary),
    /// Labeled counter family: (label value, count) pairs, ascending.
    Series {
        label: &'static str,
        points: Vec<(u64, u64)>,
    },
}

/// One named, documented metric.
#[derive(Clone, Debug)]
pub struct MetricEntry {
    pub name: String,
    pub help: String,
    pub value: MetricValue,
}

/// An ordered collection of uniquely named metrics.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    entries: Vec<MetricEntry>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, name: &str, help: &str, value: MetricValue) {
        assert!(
            self.entries.iter().all(|e| e.name != name),
            "duplicate metric name `{name}`"
        );
        self.entries.push(MetricEntry {
            name: name.to_string(),
            help: help.to_string(),
            value,
        });
    }

    pub fn counter(&mut self, name: &str, help: &str, v: u64) {
        self.push(name, help, MetricValue::Counter(v as f64));
    }

    /// Counter with a fractional total (e.g. busy-time in µs).
    pub fn counter_f64(&mut self, name: &str, help: &str, v: f64) {
        self.push(name, help, MetricValue::Counter(v));
    }

    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.push(name, help, MetricValue::Gauge(v));
    }

    pub fn summary(&mut self, name: &str, help: &str, s: Summary) {
        self.push(name, help, MetricValue::Summary(s));
    }

    pub fn series(
        &mut self,
        name: &str,
        help: &str,
        label: &'static str,
        points: &BTreeMap<usize, u64>,
    ) {
        self.push(
            name,
            help,
            MetricValue::Series {
                label,
                points: points.iter().map(|(&k, &n)| (k as u64, n)).collect(),
            },
        );
    }

    pub fn entries(&self) -> &[MetricEntry] {
        &self.entries
    }

    /// Look a metric up by name (tests, checkers).
    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| &e.value)
    }

    /// Render the Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            match &e.value {
                MetricValue::Counter(v) => {
                    out.push_str(&format!("# TYPE {} counter\n", e.name));
                    out.push_str(&format!("{} {}\n", e.name, fmt_num(*v)));
                }
                MetricValue::Gauge(v) => {
                    out.push_str(&format!("# TYPE {} gauge\n", e.name));
                    out.push_str(&format!("{} {}\n", e.name, fmt_num(*v)));
                }
                MetricValue::Summary(s) => {
                    out.push_str(&format!("# TYPE {} summary\n", e.name));
                    if let Some(p50) = s.p50 {
                        out.push_str(&format!(
                            "{}{{quantile=\"0.5\"}} {}\n",
                            e.name,
                            fmt_num(p50)
                        ));
                    }
                    if let Some(p99) = s.p99 {
                        out.push_str(&format!(
                            "{}{{quantile=\"0.99\"}} {}\n",
                            e.name,
                            fmt_num(p99)
                        ));
                    }
                    out.push_str(&format!("{}_sum {}\n", e.name, fmt_num(s.sum)));
                    out.push_str(&format!("{}_count {}\n", e.name, s.count));
                }
                MetricValue::Series { label, points } => {
                    out.push_str(&format!("# TYPE {} counter\n", e.name));
                    for (k, n) in points {
                        out.push_str(&format!("{}{{{label}=\"{k}\"}} {n}\n", e.name));
                    }
                }
            }
        }
        out
    }

    /// Render the JSON snapshot schema (the one the bench harness embeds
    /// under `serving_metrics` in `BENCH_*.json`).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut summaries = BTreeMap::new();
        let mut series = BTreeMap::new();
        for e in &self.entries {
            match &e.value {
                MetricValue::Counter(v) => {
                    counters.insert(e.name.clone(), Json::num(*v));
                }
                MetricValue::Gauge(v) => {
                    gauges.insert(e.name.clone(), Json::num(*v));
                }
                MetricValue::Summary(s) => {
                    let mut o = vec![
                        ("count", Json::num(s.count as f64)),
                        ("sum", Json::num(s.sum)),
                        ("mean", Json::num(s.mean)),
                        ("min", Json::num(s.min)),
                        ("max", Json::num(s.max)),
                    ];
                    if let Some(p50) = s.p50 {
                        o.push(("p50", Json::num(p50)));
                    }
                    if let Some(p99) = s.p99 {
                        o.push(("p99", Json::num(p99)));
                    }
                    summaries.insert(e.name.clone(), Json::obj(o));
                }
                MetricValue::Series { points, .. } => {
                    series.insert(
                        e.name.clone(),
                        Json::Obj(
                            points
                                .iter()
                                .map(|(k, n)| (k.to_string(), Json::num(*n as f64)))
                                .collect(),
                        ),
                    );
                }
            }
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("summaries", Json::Obj(summaries)),
            ("series", Json::Obj(series)),
        ])
    }
}

/// Compact number formatting: integers without a trailing `.0`, everything
/// else as shortest-round-trip f64 (matches `util::json`'s convention).
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.counter("flashmla_requests_finished_total", "Requests finished.", 3);
        r.gauge("flashmla_occupancy_mean", "Mean batch occupancy.", 0.875);
        r.summary(
            "flashmla_ttft_us",
            "Time to first token (µs).",
            Summary {
                count: 2,
                sum: 300.0,
                mean: 150.0,
                p50: Some(140.0),
                p99: Some(260.0),
                min: 100.0,
                max: 200.0,
            },
        );
        let mut hist = BTreeMap::new();
        hist.insert(3usize, 1u64);
        hist.insert(8usize, 2u64);
        r.series(
            "flashmla_prefill_chunk_tokens",
            "Prefill chunk sizes.",
            "tokens",
            &hist,
        );
        r
    }

    #[test]
    fn prometheus_text_format() {
        let text = sample().to_prometheus();
        assert!(text.contains("# TYPE flashmla_requests_finished_total counter\n"));
        assert!(text.contains("flashmla_requests_finished_total 3\n"));
        assert!(text.contains("# TYPE flashmla_occupancy_mean gauge\n"));
        assert!(text.contains("flashmla_occupancy_mean 0.875\n"));
        assert!(text.contains("flashmla_ttft_us{quantile=\"0.5\"} 140\n"));
        assert!(text.contains("flashmla_ttft_us_sum 300\n"));
        assert!(text.contains("flashmla_ttft_us_count 2\n"));
        assert!(text.contains("flashmla_prefill_chunk_tokens{tokens=\"8\"} 2\n"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, value) = line.rsplit_once(' ').expect("name value");
            value.parse::<f64>().unwrap_or_else(|_| {
                panic!("non-numeric sample value in line: {line}")
            });
        }
    }

    #[test]
    fn json_snapshot_schema() {
        let doc =
            crate::util::json::parse(&sample().to_json().dump()).expect("snapshot parses");
        assert_eq!(
            doc.get("counters")
                .get("flashmla_requests_finished_total")
                .as_usize(),
            Some(3)
        );
        assert_eq!(
            doc.get("gauges").get("flashmla_occupancy_mean").as_f64(),
            Some(0.875)
        );
        let ttft = doc.get("summaries").get("flashmla_ttft_us");
        assert_eq!(ttft.get("count").as_usize(), Some(2));
        assert_eq!(ttft.get("p99").as_f64(), Some(260.0));
        assert_eq!(
            doc.get("series")
                .get("flashmla_prefill_chunk_tokens")
                .get("8")
                .as_usize(),
            Some(2)
        );
    }

    #[test]
    fn get_finds_by_name() {
        let r = sample();
        assert!(matches!(
            r.get("flashmla_requests_finished_total"),
            Some(MetricValue::Counter(v)) if *v == 3.0
        ));
        assert!(r.get("nope").is_none());
    }

    #[test]
    #[should_panic(expected = "duplicate metric name")]
    fn duplicate_names_rejected() {
        let mut r = MetricsRegistry::new();
        r.counter("x", "one", 1);
        r.counter("x", "two", 2);
    }
}
