//! Simulated 8×H20 cluster: the paper's single-instance deployment
//! (DeepSeek-R1's 128 heads split 16-per-GPU, §1) driven by the `sim`
//! kernel models — this is how the repo exercises paper-scale contexts
//! (16K–64K) that the CPU-PJRT path cannot execute.
//!
//! Lives in `sim/` next to `gemm.rs`/`roofline.rs` because it *is* the
//! analytical step-time model: each simulated decode step costs every
//! GPU's head shard with the selected kernel model, takes the max
//! (tensor-parallel barrier), adds the allreduce and the non-attention
//! layer time, and advances the simulated clock.  Serving behaviour
//! (continuous batching over a decode trace) then yields
//! throughput/latency at paper scale.  The *real* multi-engine executor
//! is `fleet::FleetExecutor`; this module is its modeled counterpart,
//! kept single-sourced here so the step-time math cannot drift.

use crate::hardware::GpuSpec;
use crate::sim::kernels::{model_by_name, KernelModel};
use crate::sim::DecodeWorkload;
use crate::util::stats::{percentile, Welford};

/// Cluster topology + calibration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// GPUs in the tensor-parallel group (paper: 8).
    pub gpus: usize,
    /// Total attention heads (DeepSeek-R1: 128).
    pub total_heads: usize,
    /// Transformer layers (DeepSeek-R1: 61).
    pub n_layers: usize,
    /// Kernel model name ("etap", "flashmla", "fa3", "flashinfer").
    pub kernel: String,
    /// Per-layer allreduce cost: latency + bytes/bandwidth (µs).
    pub allreduce_base_us: f64,
    pub allreduce_us_per_mb: f64,
    /// d_model for allreduce sizing (DeepSeek-R1: 7168).
    pub d_model: usize,
    /// Non-attention time per layer, batch-constant part (µs): at decode
    /// batch sizes the MoE/dense GEMMs are weight-streaming bound, so this
    /// dominates.  Calibrated so MLA is ~30 % of a BS=16/16K FlashMLA
    /// forward pass (paper §3.1).
    pub other_base_us_per_layer: f64,
    /// Non-attention time per layer per request (µs): the small
    /// activation-proportional part.
    pub other_us_per_req_layer: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            gpus: 8,
            total_heads: 128,
            n_layers: 61,
            kernel: "etap".into(),
            allreduce_base_us: 5.0,
            allreduce_us_per_mb: 5.0,
            d_model: 7168,
            other_base_us_per_layer: 690.0,
            other_us_per_req_layer: 1.0,
        }
    }
}

impl ClusterConfig {
    pub fn heads_per_gpu(&self) -> usize {
        self.total_heads / self.gpus
    }
}

/// Per-step time breakdown (µs).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepBreakdown {
    pub attention_us: f64,
    pub allreduce_us: f64,
    pub other_us: f64,
}

impl StepBreakdown {
    pub fn total_us(&self) -> f64 {
        self.attention_us + self.allreduce_us + self.other_us
    }

    /// MLA share of the forward pass (the paper's ~30 % figure).
    pub fn attention_fraction(&self) -> f64 {
        self.attention_us / self.total_us()
    }
}

/// One request in a decode trace: arrives with `context_len` tokens of KV
/// already present (decode-instance scenario, as in the paper's setup) and
/// generates `gen_len` tokens.
#[derive(Clone, Copy, Debug)]
pub struct TraceRequest {
    pub arrival_us: f64,
    pub context_len: usize,
    pub gen_len: usize,
}

/// Serving results in simulated time.
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub simulated_s: f64,
    pub tokens: u64,
    pub tokens_per_s: f64,
    pub mean_batch: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p99_ms: f64,
    pub mean_wait_ms: f64,
}

/// The simulated cluster.
pub struct ClusterSim {
    cfg: ClusterConfig,
    gpu: GpuSpec,
    model: Box<dyn KernelModel>,
}

impl ClusterSim {
    pub fn new(cfg: ClusterConfig, gpu: GpuSpec) -> anyhow::Result<Self> {
        anyhow::ensure!(
            cfg.total_heads % cfg.gpus == 0,
            "heads {} not divisible by {} GPUs",
            cfg.total_heads,
            cfg.gpus
        );
        let model = model_by_name(&cfg.kernel)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel model `{}`", cfg.kernel))?;
        Ok(ClusterSim { cfg, gpu, model })
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Time for one decode step with the given per-request KV lengths.
    ///
    /// All requests share the batch; each GPU holds `heads_per_gpu` heads
    /// of every request, so each worker's workload is (batch, heads/gpu,
    /// max kv).  Workers run concurrently; the barrier takes the max.
    pub fn step_time(&self, kv_lens: &[usize]) -> StepBreakdown {
        assert!(!kv_lens.is_empty());
        let batch = kv_lens.len();
        // Conservative single-bucket model: the kernel pads to the longest
        // context in the batch (what a fixed-shape decode kernel does).
        let kv = *kv_lens.iter().max().unwrap();
        let w = DecodeWorkload {
            batch,
            heads: self.cfg.heads_per_gpu(),
            d_qk: 576,
            d_v: 512,
            kv_len: kv,
            dtype_bytes: 2,
        };
        // One estimate per GPU (identical shards — heterogeneous shards
        // would differ; the barrier takes the max regardless).
        let estimates: Vec<f64> = (0..self.cfg.gpus)
            .map(|_| self.model.estimate(&w, &self.gpu).total_us)
            .collect();
        let attn_per_layer = estimates.iter().cloned().fold(0.0, f64::max);

        let allreduce_mb =
            (batch * self.cfg.d_model * 2) as f64 / 1e6; // bf16 activations
        let allreduce_per_layer =
            self.cfg.allreduce_base_us + self.cfg.allreduce_us_per_mb * allreduce_mb;
        let other_per_layer =
            self.cfg.other_base_us_per_layer + self.cfg.other_us_per_req_layer * batch as f64;

        let layers = self.cfg.n_layers as f64;
        StepBreakdown {
            attention_us: attn_per_layer * layers,
            allreduce_us: 2.0 * allreduce_per_layer * layers, // attn + mlp
            other_us: other_per_layer * layers,
        }
    }

    /// Serve a decode trace with continuous batching (simulated clock).
    pub fn serve_trace(&self, trace: &[TraceRequest], max_batch: usize) -> TraceReport {
        #[derive(Clone)]
        struct Live {
            kv: usize,
            remaining: usize,
            step_times: Vec<f64>,
            waited_us: f64,
        }
        let mut pending: Vec<TraceRequest> = trace.to_vec();
        pending.sort_by(|a, b| a.arrival_us.partial_cmp(&b.arrival_us).unwrap());
        let mut pending = std::collections::VecDeque::from(pending);
        let mut live: Vec<Live> = Vec::new();
        let mut clock_us = 0.0f64;
        let mut tokens = 0u64;
        let mut batch_stat = Welford::new();
        let mut tpots: Vec<f64> = Vec::new();
        let mut waits: Vec<f64> = Vec::new();

        while !pending.is_empty() || !live.is_empty() {
            // Admit arrivals.
            while live.len() < max_batch {
                match pending.front() {
                    Some(r) if r.arrival_us <= clock_us => {
                        let r = pending.pop_front().unwrap();
                        waits.push((clock_us - r.arrival_us) / 1e3);
                        live.push(Live {
                            kv: r.context_len,
                            remaining: r.gen_len,
                            step_times: Vec::new(),
                            waited_us: clock_us - r.arrival_us,
                        });
                    }
                    _ => break,
                }
            }
            if live.is_empty() {
                // Jump to next arrival.
                clock_us = pending.front().unwrap().arrival_us;
                continue;
            }
            // One decode step for the whole batch.
            let kv_lens: Vec<usize> = live.iter().map(|l| l.kv).collect();
            let dt = self.step_time(&kv_lens).total_us();
            clock_us += dt;
            batch_stat.push(live.len() as f64);
            for l in &mut live {
                l.kv += 1;
                l.remaining -= 1;
                l.step_times.push(dt);
                tokens += 1;
            }
            live.retain(|l| {
                if l.remaining == 0 {
                    let _ = l.waited_us;
                    for &t in &l.step_times {
                        tpots.push(t / 1e3);
                    }
                    false
                } else {
                    true
                }
            });
        }

        TraceReport {
            simulated_s: clock_us / 1e6,
            tokens,
            tokens_per_s: tokens as f64 / (clock_us / 1e6).max(1e-9),
            mean_batch: batch_stat.mean(),
            tpot_p50_ms: percentile(&tpots, 50.0),
            tpot_p99_ms: percentile(&tpots, 99.0),
            mean_wait_ms: if waits.is_empty() {
                0.0
            } else {
                waits.iter().sum::<f64>() / waits.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(kernel: &str) -> ClusterSim {
        ClusterSim::new(
            ClusterConfig {
                kernel: kernel.into(),
                ..Default::default()
            },
            GpuSpec::h20(),
        )
        .unwrap()
    }

    #[test]
    fn heads_split_matches_paper() {
        let c = ClusterConfig::default();
        assert_eq!(c.heads_per_gpu(), 16); // 128 / 8 (paper §1)
    }

    #[test]
    fn mla_fraction_near_30_percent_for_flashmla() {
        // Paper §3.1: "MLA accounting for approximately 30 % of a decoding
        // forward pass … (e.g. BS=16, ContextLength=16K)".
        let s = sim("flashmla");
        let b = s.step_time(&vec![16384; 16]);
        let f = b.attention_fraction();
        assert!((f - 0.30).abs() < 0.06, "attention fraction {f}");
    }

    #[test]
    fn etap_cuts_step_time_at_long_context() {
        let kv = vec![32768usize; 16];
        let base = sim("flashmla").step_time(&kv).total_us();
        let etap = sim("etap").step_time(&kv).total_us();
        assert!(
            etap < base * 0.75,
            "cluster-level speedup missing: {etap} vs {base}"
        );
    }

    #[test]
    fn serve_trace_decode_only() {
        let s = sim("etap");
        let trace: Vec<TraceRequest> = (0..32)
            .map(|i| TraceRequest {
                arrival_us: i as f64 * 1000.0,
                context_len: 4096,
                gen_len: 32,
            })
            .collect();
        let rep = s.serve_trace(&trace, 16);
        assert_eq!(rep.tokens, 32 * 32);
        assert!(rep.tokens_per_s > 0.0);
        assert!(rep.mean_batch > 1.0, "batching should occur");
        assert!(rep.tpot_p99_ms >= rep.tpot_p50_ms);
    }

    #[test]
    fn throughput_improves_with_batching() {
        let s = sim("etap");
        let mk = |n: usize| -> Vec<TraceRequest> {
            (0..n)
                .map(|_| TraceRequest {
                    arrival_us: 0.0,
                    context_len: 8192,
                    gen_len: 16,
                })
                .collect()
        };
        let solo = s.serve_trace(&mk(16), 1).tokens_per_s;
        let batched = s.serve_trace(&mk(16), 16).tokens_per_s;
        assert!(
            batched > 4.0 * solo,
            "batched {batched} should dwarf solo {solo}"
        );
    }
}
