//! Producer/consumer pipeline fill model.
//!
//! FlashMLA-style kernels stream KV blocks through an s-stage circular SMEM
//! buffer (Algorithm 1 line 1): the warpgroup pipeline reaches steady state
//! only after a prologue of loads, and drains at the end.  With `T_c`
//! blocks and an effective fill cost of `fill_blocks` block-times, the
//! fraction of time in steady state is `T_c / (T_c + fill)` — the standard
//! throughput expression for a linear pipeline.
//!
//! Wave quantization: a grid of `ctas` CTAs on `sm_count` SMs runs in
//! `ceil(ctas/sm)` waves but only fills `ctas/sm` of them.

/// Steady-state fraction of a block pipeline.
pub fn fill_efficiency(t_c: usize, fill_blocks: f64) -> f64 {
    assert!(t_c >= 1);
    assert!(fill_blocks >= 0.0);
    t_c as f64 / (t_c as f64 + fill_blocks)
}

/// Occupancy of the last (partial) wave amortized over the grid.
pub fn wave_efficiency(ctas: usize, sm_count: usize) -> f64 {
    assert!(ctas >= 1 && sm_count >= 1);
    let waves = ctas.div_ceil(sm_count) as f64;
    ctas as f64 / (waves * sm_count as f64).max(ctas as f64)
}

/// Number of KV blocks for a context length.
pub fn kv_blocks(kv_len: usize, block_kv: usize) -> usize {
    assert!(block_kv >= 1);
    kv_len.div_ceil(block_kv).max(1)
}

/// SMEM footprint (bytes) of one pipeline stage holding a K/V block of
/// `block_kv × d` halfs — used to check how many stages fit.
pub fn stage_bytes(block_kv: usize, d: usize, dtype_bytes: usize) -> usize {
    block_kv * d * dtype_bytes
}

/// Maximum circular-buffer stages that fit in SMEM after reserving
/// `reserved` bytes for Q, accumulators and barriers.
pub fn max_stages(smem_bytes: usize, stage: usize, reserved: usize) -> usize {
    if smem_bytes <= reserved || stage == 0 {
        return 0;
    }
    (smem_bytes - reserved) / stage
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_efficiency_limits() {
        assert!((fill_efficiency(1, 0.0) - 1.0).abs() < 1e-12);
        // Long contexts approach 1.
        assert!(fill_efficiency(1024, 16.0) > 0.98);
        // Short contexts pay heavily.
        assert!(fill_efficiency(8, 16.0) < 0.34);
    }

    #[test]
    fn fill_efficiency_monotone_in_t_c() {
        let mut prev = 0.0;
        for t in [1, 2, 4, 8, 64, 1024] {
            let e = fill_efficiency(t, 8.0);
            assert!(e > prev);
            prev = e;
        }
    }

    #[test]
    fn wave_efficiency_exact_fit() {
        assert_eq!(wave_efficiency(78, 78), 1.0);
        assert_eq!(wave_efficiency(156, 78), 1.0);
        // 79 CTAs on 78 SMs: second wave nearly empty.
        let e = wave_efficiency(79, 78);
        assert!(e > 0.5 && e < 0.51);
    }

    #[test]
    fn kv_blocks_rounding() {
        assert_eq!(kv_blocks(512, 64), 8);
        assert_eq!(kv_blocks(513, 64), 9);
        assert_eq!(kv_blocks(1, 64), 1);
    }

    #[test]
    fn smem_budget_h20() {
        // Paper kernel: Bc=64, d=576 f16 → 72 KiB per stage; H20 has
        // 228 KiB → 2 stages fit with ~64 KiB reserved (double buffering,
        // matching Algorithm 1's s-stage circular buffer with s=2).
        let stage = stage_bytes(64, 576, 2);
        assert_eq!(stage, 73_728);
        let stages = max_stages(228 * 1024, stage, 64 * 1024);
        assert_eq!(stages, 2);
    }
}
