//! FlashInfer kernel model (paper §2, §4).
//!
//! Algorithm-derived structure: like FA-3 it runs the generic
//! `S = Q·K^T / softmax / P·V` pattern on decompressed K/V (no latent
//! absorption), query-major → 4× padding.  Differences from FA-3 in the
//! model: FlashInfer's paged layout and fused decode kernels are tuned for
//! serving, so it sustains a bit more bandwidth (`mem_eff 0.85`) and a
//! slightly better decode pipeline (`pipe_eff 0.49`) at the cost of a
//! larger launch path through its plan/run split (`launch 16 µs`).
//!
//! Calibrated against Fig. 1's FlashInfer bars (~8→18 TFLOPS/s at BS=16,
//! up to 23 at BS=32).

use crate::hardware::GpuSpec;
use crate::sim::engine::{estimate, Estimate, PipelineParams};
use crate::sim::gemm::query_major_gemms;
use crate::sim::memory::split_kv_traffic;
use crate::sim::workload::DecodeWorkload;

use super::KernelModel;

pub struct FlashInfer {
    params: PipelineParams,
}

impl FlashInfer {
    pub fn new() -> Self {
        FlashInfer {
            params: PipelineParams {
                name: "FlashInfer",
                block_kv: 64,
                pipe_eff: 0.53,
                fill_blocks: 4.0,
                mem_eff: 0.85,
                launch_us: 16.0,
                persistent: false, // plan/run split grid
                ctas: |w| w.batch * w.heads.div_ceil(64).max(1) * 8,
            },
        }
    }
}

impl Default for FlashInfer {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelModel for FlashInfer {
    fn name(&self) -> &'static str {
        "FlashInfer"
    }

    fn estimate(&self, w: &DecodeWorkload, gpu: &GpuSpec) -> Estimate {
        let gemms = query_major_gemms(w.heads, self.params.block_kv, w.d_qk, w.d_v);
        let traffic = split_kv_traffic(w, 1, 0.0);
        estimate(&self.params, &gemms, &traffic, w, gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernels::FlashAttention3;

    #[test]
    fn near_paper_value_at_64k() {
        // Paper: 18 TFLOPS/s at 64K BS=16.
        let e = FlashInfer::new()
            .estimate(&DecodeWorkload::paper(16, 65536), &GpuSpec::h20());
        assert!(
            (e.tflops_per_s - 18.0).abs() / 18.0 < 0.2,
            "model {} vs paper 18",
            e.tflops_per_s
        );
    }

    #[test]
    fn slightly_ahead_of_fa3_at_long_context() {
        // Fig. 1: FlashInfer edges out FA-3 at 64K (18 vs 17; 23 vs 21).
        let gpu = GpuSpec::h20();
        let w = DecodeWorkload::paper(16, 65536);
        let fi = FlashInfer::new().estimate(&w, &gpu).tflops_per_s;
        let fa = FlashAttention3::new().estimate(&w, &gpu).tflops_per_s;
        assert!(fi > fa, "FlashInfer {fi} should beat FA-3 {fa} at 64K");
    }
}
