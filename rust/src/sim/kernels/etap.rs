//! FlashMLA-ETAP kernel model — the paper's contribution (§3.1–3.2), plus
//! the two hypothetical integrations of §3.2 (ETAP-in-FA3 and
//! ETAP-in-FlashInfer) used by the ablation bench.
//!
//! Algorithm-derived structure (Algorithm 1):
//! * GEMM orientation: `S^T = K·Q^T` puts the KV block on M (no padding);
//!   `O^T = V^T·P^T` puts d_v = 512 on M (no padding).  Heads land on N
//!   where n-granularity is 8 → 16 heads are exactly representable.
//! * Traffic: identical latent sharing to FlashMLA, plus one extra staging
//!   pass for the epilogue transpose `O = (O^T)^T` (eq. 4) — B·H·d_v
//!   elements written once more through SMEM, negligible but counted.
//! * Grid: CTAs partition the KV dimension (that is now M), so occupancy
//!   *grows* with context — the opposite of query-major decode.
//!
//! Calibrated constants (Fig. 1 ETAP bars, 13→89 TFLOPS/s):
//! `pipe_eff 0.80` — slightly below FlashMLA's 0.87: the column-softmax
//! (per-column max/sum along M) serializes against the MMA pipeline more
//! than row-softmax does, and the R_i broadcast through SMEM (Algorithm 1
//! line 13) adds sync.  `fill 16` blocks — the transposed pipeline has a
//! longer prologue (K must land before Q^T reuse begins, and the split
//! accumulator halves double the drain).  `launch 15 µs`, `mem_eff 0.78`.
//!
//! At 64K the model is *memory-bound* (intensity ≈ 30 F/B < ridge 37):
//! ETAP's ~89 TFLOPS/s ceiling in Fig. 1 is the HBM roof, not the MXU/WGMMA
//! roof — reproducing the paper's "plateau beyond 32K" observation (§4.4).

use crate::hardware::GpuSpec;
use crate::sim::engine::{estimate, Estimate, PipelineParams};
use crate::sim::gemm::etap_gemms;
use crate::sim::memory::{latent_traffic, split_kv_traffic};
use crate::sim::workload::DecodeWorkload;

use super::KernelModel;

/// Extra HBM bytes for the epilogue transpose staging (eq. 4).
fn transpose_extra(w: &DecodeWorkload) -> f64 {
    (w.batch * w.heads * w.d_v * w.dtype_bytes) as f64
}

pub struct FlashMlaEtap {
    params: PipelineParams,
}

impl FlashMlaEtap {
    pub fn new() -> Self {
        FlashMlaEtap {
            params: PipelineParams {
                name: "FlashMLA-ETAP",
                block_kv: 64,
                pipe_eff: 0.80,
                fill_blocks: 16.0,
                mem_eff: 0.78,
                launch_us: 15.0,
                persistent: true, // inherits FlashMLA's persistent scheduler
                // KV-major grid: CTAs tile the context; cap at a per-batch
                // partition count that keeps the combine cheap.
                ctas: |w| w.batch * (w.kv_len / 4096).clamp(1, 16),
            },
        }
    }
}

impl Default for FlashMlaEtap {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelModel for FlashMlaEtap {
    fn name(&self) -> &'static str {
        "FlashMLA-ETAP"
    }

    fn estimate(&self, w: &DecodeWorkload, gpu: &GpuSpec) -> Estimate {
        let gemms = etap_gemms(w.heads, self.params.block_kv, w.d_qk, w.d_v);
        let traffic = latent_traffic(w, transpose_extra(w));
        estimate(&self.params, &gemms, &traffic, w, gpu)
    }
}

/// Hypothetical "ETAP integrated into FlashAttention-3" (§3.2): FA-3's
/// pipeline constants and decompressed-KV traffic, but the transposed GEMM
/// orientation removes the 4× padding.
pub struct EtapFa3 {
    params: PipelineParams,
}

impl EtapFa3 {
    pub fn new() -> Self {
        EtapFa3 {
            params: PipelineParams {
                name: "ETAP-FA3",
                block_kv: 64,
                pipe_eff: 0.60, // FA-3 scheduling, minus padding stalls
                fill_blocks: 8.0,
                mem_eff: 0.80,
                launch_us: 12.0,
                persistent: false,
                ctas: |w| w.batch * (w.kv_len / 4096).clamp(1, 16) * 4,
            },
        }
    }
}

impl Default for EtapFa3 {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelModel for EtapFa3 {
    fn name(&self) -> &'static str {
        "ETAP-FA3"
    }

    fn estimate(&self, w: &DecodeWorkload, gpu: &GpuSpec) -> Estimate {
        let gemms = etap_gemms(w.heads, self.params.block_kv, w.d_qk, w.d_v);
        let traffic = split_kv_traffic(w, 1, transpose_extra(w));
        estimate(&self.params, &gemms, &traffic, w, gpu)
    }
}

/// Hypothetical "ETAP integrated into FlashInfer" (§3.2).
pub struct EtapFlashInfer {
    params: PipelineParams,
}

impl EtapFlashInfer {
    pub fn new() -> Self {
        EtapFlashInfer {
            params: PipelineParams {
                name: "ETAP-FlashInfer",
                block_kv: 64,
                pipe_eff: 0.62,
                fill_blocks: 8.0,
                mem_eff: 0.85,
                launch_us: 16.0,
                persistent: false,
                ctas: |w| w.batch * (w.kv_len / 4096).clamp(1, 16) * 4,
            },
        }
    }
}

impl Default for EtapFlashInfer {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelModel for EtapFlashInfer {
    fn name(&self) -> &'static str {
        "ETAP-FlashInfer"
    }

    fn estimate(&self, w: &DecodeWorkload, gpu: &GpuSpec) -> Estimate {
        let gemms = etap_gemms(w.heads, self.params.block_kv, w.d_qk, w.d_v);
        let traffic = split_kv_traffic(w, 1, transpose_extra(w));
        estimate(&self.params, &gemms, &traffic, w, gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernels::FlashMla;

    #[test]
    fn near_paper_values() {
        let m = FlashMlaEtap::new();
        let gpu = GpuSpec::h20();
        // Paper Fig. 1(a): 13 @512, 89 @64K (BS=16).
        let short = m.estimate(&DecodeWorkload::paper(16, 512), &gpu);
        let long = m.estimate(&DecodeWorkload::paper(16, 65536), &gpu);
        assert!(
            (short.tflops_per_s - 13.0).abs() / 13.0 < 0.25,
            "512: {}",
            short.tflops_per_s
        );
        assert!(
            (long.tflops_per_s - 89.0).abs() / 89.0 < 0.15,
            "64K: {}",
            long.tflops_per_s
        );
    }

    #[test]
    fn memory_bound_at_long_context() {
        // §4.4's "plateau beyond 32K … compute saturation" — in the model
        // the plateau is the HBM roof (DESIGN.md discusses the difference).
        let m = FlashMlaEtap::new();
        let e = m.estimate(&DecodeWorkload::paper(16, 65536), &GpuSpec::h20());
        assert!(e.memory_bound);
        assert_eq!(e.waste_factor, 1.0);
    }

    #[test]
    fn speedup_grows_with_context() {
        let etap = FlashMlaEtap::new();
        let base = FlashMla::new();
        let gpu = GpuSpec::h20();
        let mut prev = 0.0;
        for &n in DecodeWorkload::paper_seq_lens() {
            let w = DecodeWorkload::paper(16, n);
            let s = etap.estimate(&w, &gpu).tflops_per_s
                / base.estimate(&w, &gpu).tflops_per_s;
            assert!(s >= prev * 0.98, "speedup not growing at N={n}: {s} < {prev}");
            prev = s;
        }
        assert!(prev > 2.4, "64K speedup {prev} (paper: 2.78×)");
    }

    #[test]
    fn integration_variants_beat_their_hosts() {
        // §3.2's claim, quantified: adding ETAP to FA-3/FlashInfer should
        // recover most of the padding loss.
        use crate::sim::kernels::{FlashAttention3, FlashInfer};
        let gpu = GpuSpec::h20();
        let w = DecodeWorkload::paper(16, 32768);
        assert!(
            EtapFa3::new().estimate(&w, &gpu).tflops_per_s
                > 2.0 * FlashAttention3::new().estimate(&w, &gpu).tflops_per_s
        );
        assert!(
            EtapFlashInfer::new().estimate(&w, &gpu).tflops_per_s
                > 2.0 * FlashInfer::new().estimate(&w, &gpu).tflops_per_s
        );
    }
}
