//! FlashMLA (DeepSeek's baseline) kernel model — query-major computation
//! mode (paper §3.1 "Original MLA Computation Mode").
//!
//! Algorithm-derived structure:
//! * GEMM orientation: heads on M in both GEMMs → 4× WGMMA padding at 16
//!   heads (`sim::gemm::query_major_gemms`).
//! * Traffic: MLA-aware — the 576-dim latent is read once per token and
//!   shared across all heads (`sim::memory::latent_traffic`).
//! * Grid: one CTA per (batch, head-group); decode grids also split KV for
//!   occupancy, folded into the wave term.
//!
//! Calibrated constants (against Fig. 1's FlashMLA bars, 9→32 TFLOPS/s):
//! `pipe_eff 0.87` — FlashMLA is a mature, well-scheduled kernel; its
//! *issued*-FLOP efficiency is high even though 75 % of them are padding.
//! `fill 4` blocks, `launch 15 µs`, `mem_eff 0.85`.

use crate::hardware::GpuSpec;
use crate::sim::engine::{estimate, Estimate, PipelineParams};
use crate::sim::gemm::query_major_gemms;
use crate::sim::memory::latent_traffic;
use crate::sim::workload::DecodeWorkload;

use super::KernelModel;

pub struct FlashMla {
    params: PipelineParams,
}

impl FlashMla {
    pub fn new() -> Self {
        FlashMla {
            params: PipelineParams {
                name: "FlashMLA",
                block_kv: 64,
                pipe_eff: 0.87,
                fill_blocks: 4.0,
                mem_eff: 0.85,
                launch_us: 15.0,
                persistent: true, // FlashMLA uses a persistent-CTA scheduler
                ctas: |w| w.batch * w.heads.div_ceil(64).max(1) * 8, // split-KV ×8
            },
        }
    }
}

impl Default for FlashMla {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelModel for FlashMla {
    fn name(&self) -> &'static str {
        "FlashMLA"
    }

    fn estimate(&self, w: &DecodeWorkload, gpu: &GpuSpec) -> Estimate {
        let gemms = query_major_gemms(w.heads, self.params.block_kv, w.d_qk, w.d_v);
        let traffic = latent_traffic(w, 0.0);
        estimate(&self.params, &gemms, &traffic, w, gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_below_25_percent() {
        // The paper's motivating observation (§1): padded query-major MLA
        // decode runs under 25 % of the H20's 148 TFLOPS.
        let m = FlashMla::new();
        let gpu = GpuSpec::h20();
        for &n in DecodeWorkload::paper_seq_lens() {
            let e = m.estimate(&DecodeWorkload::paper(16, n), &gpu);
            assert!(e.utilization < 0.25, "util {} at N={n}", e.utilization);
        }
    }

    #[test]
    fn compute_bound_at_long_context() {
        let m = FlashMla::new();
        let e = m.estimate(&DecodeWorkload::paper(16, 65536), &GpuSpec::h20());
        assert!(!e.memory_bound);
        assert_eq!(e.waste_factor, 4.0);
    }

    #[test]
    fn near_paper_value_at_64k() {
        // Paper: 32 TFLOPS/s at 64K (both batch sizes).
        let m = FlashMla::new();
        let e = m.estimate(&DecodeWorkload::paper(16, 65536), &GpuSpec::h20());
        assert!(
            (e.tflops_per_s - 32.0).abs() / 32.0 < 0.15,
            "model {} vs paper 32",
            e.tflops_per_s
        );
    }
}
