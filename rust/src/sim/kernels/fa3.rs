//! FlashAttention-3 kernel model — the "optimized for high-end GPUs"
//! baseline (paper §2, §4).
//!
//! Algorithm-derived structure:
//! * Not MLA-aware: no weight absorption, no latent sharing.  The best
//!   available deployment on an MLA model is an MQA-style layout (one KV
//!   head) over decompressed K [N, 576] and V [N, 512] — distinct tensors,
//!   1.89× the latent traffic (`sim::memory::split_kv_traffic`).
//! * Query-major tiling: Br×Bc blocks with the (single-token × 16-head)
//!   query on M → the same 4× WGMMA padding as FlashMLA.
//!
//! Calibrated constants (Fig. 1 FA-3 bars, ~10→17 TFLOPS/s at BS=16):
//! `pipe_eff 0.47` — FA-3's warp specialization and pingpong scheduling
//! are tuned for *prefill-shaped* tiles on H100-class SMs; on a decode
//! workload on the H20 its issued-FLOP efficiency is roughly half of
//! FlashMLA's decode-specialized pipeline (this is the paper's "flatter
//! profile" observation).  `fill 2`, `launch 12 µs`, `mem_eff 0.80`.

use crate::hardware::GpuSpec;
use crate::sim::engine::{estimate, Estimate, PipelineParams};
use crate::sim::gemm::query_major_gemms;
use crate::sim::memory::split_kv_traffic;
use crate::sim::workload::DecodeWorkload;

use super::KernelModel;

pub struct FlashAttention3 {
    params: PipelineParams,
}

impl FlashAttention3 {
    pub fn new() -> Self {
        FlashAttention3 {
            params: PipelineParams {
                name: "FlashAttention-3",
                block_kv: 64,
                pipe_eff: 0.47,
                fill_blocks: 2.0,
                mem_eff: 0.80,
                launch_us: 12.0,
                persistent: false, // per-(batch, split) grid
                ctas: |w| w.batch * w.heads.div_ceil(64).max(1) * 8,
            },
        }
    }
}

impl Default for FlashAttention3 {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelModel for FlashAttention3 {
    fn name(&self) -> &'static str {
        "FlashAttention-3"
    }

    fn estimate(&self, w: &DecodeWorkload, gpu: &GpuSpec) -> Estimate {
        let gemms = query_major_gemms(w.heads, self.params.block_kv, w.d_qk, w.d_v);
        let traffic = split_kv_traffic(w, 1, 0.0);
        estimate(&self.params, &gemms, &traffic, w, gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_paper_value_at_64k() {
        // Paper: 17 TFLOPS/s at 64K BS=16.
        let e = FlashAttention3::new()
            .estimate(&DecodeWorkload::paper(16, 65536), &GpuSpec::h20());
        assert!(
            (e.tflops_per_s - 17.0).abs() / 17.0 < 0.2,
            "model {} vs paper 17",
            e.tflops_per_s
        );
    }

    #[test]
    fn flat_profile() {
        // The paper notes FA-3's curve is flat (10–17); check the dynamic
        // range over the sweep is small compared to ETAP's ~7×.
        let m = FlashAttention3::new();
        let gpu = GpuSpec::h20();
        let vals: Vec<f64> = DecodeWorkload::paper_seq_lens()
            .iter()
            .map(|&n| m.estimate(&DecodeWorkload::paper(16, n), &gpu).tflops_per_s)
            .collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 3.0, "FA-3 range {min}–{max} should be flat-ish");
    }

    #[test]
    fn pays_decompression_traffic() {
        let m = FlashAttention3::new();
        let e = m.estimate(&DecodeWorkload::paper(16, 65536), &GpuSpec::h20());
        // Memory time exceeds the latent-sharing frameworks' by ~1.9×,
        // though FA-3 is still compute-bound from padding.
        assert!(e.waste_factor == 4.0);
    }
}
