//! Per-framework kernel models for the paper's four evaluated systems,
//! plus the two hypothetical "ETAP integrated into X" variants the paper's
//! §3.2 theoretical analysis predicts.
//!
//! Each model derives its GEMM orientation and HBM traffic from the
//! framework's documented algorithm; four scalar constants per framework
//! (`pipe_eff`, `fill_blocks`, `mem_eff`, `launch_us`) are calibrated
//! against the paper's Fig. 1 bar heights.  EXPERIMENTS.md tabulates
//! paper-vs-model for every bar; `rust/tests/paper_calibration.rs` asserts
//! the headline ratios.

mod etap;
mod fa3;
mod flashinfer;
mod flashmla;

pub use etap::{EtapFa3, EtapFlashInfer, FlashMlaEtap};
pub use fa3::FlashAttention3;
pub use flashinfer::FlashInfer;
pub use flashmla::FlashMla;

use crate::hardware::GpuSpec;

use super::engine::Estimate;
use super::workload::DecodeWorkload;

/// A simulated decode-attention kernel.
pub trait KernelModel: Send + Sync {
    /// Framework name as it appears in Fig. 1.
    fn name(&self) -> &'static str;

    /// Estimate one decode-attention forward pass.
    fn estimate(&self, w: &DecodeWorkload, gpu: &GpuSpec) -> Estimate;
}

/// The four frameworks of Fig. 1, in the paper's legend order.
pub fn all_models() -> Vec<Box<dyn KernelModel>> {
    vec![
        Box::new(FlashMlaEtap::new()),
        Box::new(FlashMla::new()),
        Box::new(FlashAttention3::new()),
        Box::new(FlashInfer::new()),
    ]
}

/// All models including the §3.2 integration hypotheticals.
pub fn all_models_extended() -> Vec<Box<dyn KernelModel>> {
    let mut v = all_models();
    v.push(Box::new(EtapFa3::new()));
    v.push(Box::new(EtapFlashInfer::new()));
    v
}

/// Look up a model by CLI name.
pub fn model_by_name(name: &str) -> Option<Box<dyn KernelModel>> {
    match name.to_ascii_lowercase().as_str() {
        "flashmla-etap" | "etap" => Some(Box::new(FlashMlaEtap::new())),
        "flashmla" => Some(Box::new(FlashMla::new())),
        "flashattention-3" | "fa3" => Some(Box::new(FlashAttention3::new())),
        "flashinfer" => Some(Box::new(FlashInfer::new())),
        "etap-fa3" => Some(Box::new(EtapFa3::new())),
        "etap-flashinfer" => Some(Box::new(EtapFlashInfer::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legend_order_matches_paper() {
        let names: Vec<_> = all_models().iter().map(|m| m.name()).collect();
        assert_eq!(
            names,
            vec!["FlashMLA-ETAP", "FlashMLA", "FlashAttention-3", "FlashInfer"]
        );
    }

    #[test]
    fn lookup_aliases() {
        assert!(model_by_name("etap").is_some());
        assert!(model_by_name("FA3").is_some());
        assert!(model_by_name("nope").is_none());
    }

    #[test]
    fn every_model_produces_finite_estimates() {
        let gpu = GpuSpec::h20();
        for m in all_models_extended() {
            for &n in DecodeWorkload::paper_seq_lens() {
                for b in [16, 32] {
                    let e = m.estimate(&DecodeWorkload::paper(b, n), &gpu);
                    assert!(e.total_us.is_finite() && e.total_us > 0.0);
                    assert!(e.tflops_per_s > 0.0 && e.tflops_per_s < gpu.fp16_tflops);
                }
            }
        }
    }
}
