//! Paper-figure generation: the exact series of Fig. 1(a)/(b) and the
//! published reference values, shared by the CLI, examples, and benches.

use crate::bench::Table;
use crate::hardware::GpuSpec;

use super::kernels::all_models;
use super::workload::DecodeWorkload;

/// Published bar heights digitized from Fig. 1 and the §4.2 text.  Values
/// the text states exactly are exact (512/16K/64K rows, Table footnotes);
/// the rest are interpolated from the bar chart and marked approximate in
/// EXPERIMENTS.md.
pub fn paper_reference(batch: usize) -> &'static [(usize, [f64; 4])] {
    // Columns: [FlashMLA-ETAP, FlashMLA, FlashAttention-3, FlashInfer].
    match batch {
        16 => &[
            (512, [13.0, 9.0, 10.0, 8.0]),
            (1024, [17.0, 12.0, 10.5, 9.0]),
            (2048, [24.0, 16.0, 11.0, 10.0]),
            (4096, [34.0, 20.0, 12.0, 12.0]),
            (8192, [47.0, 24.0, 14.0, 14.0]),
            (16384, [61.0, 27.0, 15.0, 16.0]),
            (32768, [78.0, 30.0, 16.0, 17.0]),
            (65536, [89.0, 32.0, 17.0, 18.0]),
        ],
        32 => &[
            (512, [16.0, 11.0, 12.0, 10.0]),
            (1024, [22.0, 14.0, 13.0, 12.0]),
            (2048, [30.0, 18.0, 14.0, 14.0]),
            (4096, [42.0, 22.0, 16.0, 16.0]),
            (8192, [58.0, 26.0, 18.0, 19.0]),
            (16384, [73.0, 29.0, 19.0, 21.0]),
            (32768, [87.0, 31.0, 20.0, 22.0]),
            (65536, [87.0, 32.0, 21.0, 23.0]),
        ],
        _ => panic!("paper only reports batch 16 and 32"),
    }
}

/// One generated figure row.
#[derive(Clone, Debug)]
pub struct FigureRow {
    pub seq_len: usize,
    /// (framework name, model TFLOPS/s, paper TFLOPS/s).
    pub cells: Vec<(&'static str, f64, f64)>,
}

/// Generate the Fig. 1 series for a batch size on a GPU.
pub fn figure1(batch: usize, gpu: &GpuSpec) -> Vec<FigureRow> {
    let models = all_models();
    let reference = paper_reference(batch);
    reference
        .iter()
        .map(|&(n, paper_vals)| {
            let w = DecodeWorkload::paper(batch, n);
            let cells = models
                .iter()
                .zip(paper_vals.iter())
                .map(|(m, &paper)| (m.name(), m.estimate(&w, gpu).tflops_per_s, paper))
                .collect();
            FigureRow { seq_len: n, cells }
        })
        .collect()
}

/// Render a figure as a table (model vs paper per framework).
pub fn figure1_table(batch: usize, gpu: &GpuSpec) -> Table {
    let rows = figure1(batch, gpu);
    let mut header: Vec<String> = vec!["seqlen".into()];
    for (name, _, _) in &rows[0].cells {
        header.push(format!("{name} (model)"));
        header.push("(paper)".into());
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Figure 1({}) — TFLOPS/s on {}, batch {batch}",
                 if batch == 16 { "a" } else { "b" }, gpu.name),
        &header_refs,
    );
    for row in &rows {
        let mut cells: Vec<String> = vec![row.seq_len.to_string()];
        for (_, model, paper) in &row.cells {
            cells.push(format!("{model:.1}"));
            cells.push(format!("{paper:.1}"));
        }
        t.row(&cells);
    }
    t
}

/// The §4.2 headline ratios, computed from the model.
#[derive(Clone, Debug)]
pub struct HeadlineRatios {
    pub speedup_vs_flashmla_64k: f64,
    pub speedup_vs_flashmla_512: f64,
    pub speedup_vs_fa3_64k: f64,
    pub speedup_vs_flashinfer_64k: f64,
}

/// Compute headline ratios for a batch size.
pub fn headline_ratios(batch: usize, gpu: &GpuSpec) -> HeadlineRatios {
    let models = all_models();
    let tflops = |idx: usize, n: usize| {
        models[idx]
            .estimate(&DecodeWorkload::paper(batch, n), gpu)
            .tflops_per_s
    };
    HeadlineRatios {
        speedup_vs_flashmla_64k: tflops(0, 65536) / tflops(1, 65536),
        speedup_vs_flashmla_512: tflops(0, 512) / tflops(1, 512),
        speedup_vs_fa3_64k: tflops(0, 65536) / tflops(2, 65536),
        speedup_vs_flashinfer_64k: tflops(0, 65536) / tflops(3, 65536),
    }
}

/// Mean absolute relative error of the model against the paper bars.
pub fn model_fidelity(batch: usize, gpu: &GpuSpec) -> f64 {
    let rows = figure1(batch, gpu);
    let mut total = 0.0;
    let mut count = 0usize;
    for r in rows {
        for (_, model, paper) in r.cells {
            total += (model - paper).abs() / paper;
            count += 1;
        }
    }
    total / count as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_matches_stated_text_values() {
        // §4.2 states these exactly.
        let bs16 = paper_reference(16);
        assert_eq!(bs16.last().unwrap().1, [89.0, 32.0, 17.0, 18.0]);
        assert_eq!(bs16[0].1[0], 13.0);
        assert_eq!(bs16[0].1[1], 9.0);
        let bs32 = paper_reference(32);
        assert_eq!(bs32.last().unwrap().1[0], 87.0);
        assert_eq!(bs32.last().unwrap().1[2], 21.0);
        assert_eq!(bs32.last().unwrap().1[3], 23.0);
    }

    #[test]
    fn figure_has_all_rows_and_frameworks() {
        let rows = figure1(16, &GpuSpec::h20());
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0].cells.len(), 4);
        assert_eq!(rows[0].cells[0].0, "FlashMLA-ETAP");
    }

    #[test]
    fn fidelity_within_tolerance() {
        // Mean |model−paper|/paper across all 64 bars ≤ 25 %: the shape
        // claim of DESIGN.md §4 (absolute numbers are not the target).
        let gpu = GpuSpec::h20();
        let f16 = model_fidelity(16, &gpu);
        let f32b = model_fidelity(32, &gpu);
        assert!(f16 < 0.25, "BS16 fidelity {f16}");
        assert!(f32b < 0.25, "BS32 fidelity {f32b}");
    }

    #[test]
    fn table_renders() {
        let t = figure1_table(16, &GpuSpec::h20());
        let s = t.render();
        assert!(s.contains("65536"));
        assert!(s.contains("FlashMLA-ETAP"));
    }
}
