//! H20 performance simulator — the testbed substitute (DESIGN.md §2).
//!
//! The paper's evaluation ran CUDA kernels on a physical H20; we have CPUs.
//! This module reproduces Fig. 1 / the §4 analysis from first principles:
//! WGMMA tile algebra (`gemm`), producer/consumer pipeline fill
//! (`pipeline`), HBM traffic (`memory`), and the roofline composition
//! (`engine`).  Each evaluated framework is a `KernelModel` whose
//! parameters are derived from its documented algorithm; a small set of
//! efficiency constants is calibrated against the paper's published bar
//! heights (see `kernels/` and EXPERIMENTS.md for paper-vs-model tables).

pub mod cluster;
pub mod engine;
pub mod figures;
pub mod gemm;
pub mod kernels;
pub mod memory;
pub mod pipeline;
pub mod roofline;
pub mod workload;

pub use engine::{Estimate, PipelineParams};
pub use kernels::{all_models, model_by_name, KernelModel};
pub use workload::DecodeWorkload;
