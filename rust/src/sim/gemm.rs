//! GEMM tile algebra: maps the two attention GEMMs of each computation
//! mode onto the hardware matmul atom and counts issued vs useful FLOPs.

use crate::hardware::gpu::MatmulAtom;
use crate::hardware::wgmma;

/// Logical dimensions of one GEMM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GemmDims {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmDims {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        GemmDims { m, n, k }
    }

    /// Useful FLOPs (2·M·N·K).
    pub fn useful_flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// FLOPs actually issued once M/N are padded to the atom.
    pub fn issued_flops(&self, atom: &MatmulAtom) -> f64 {
        let m = wgmma::padded_rows(self.m, atom) as f64;
        let n = wgmma::padded_cols(self.n, atom) as f64;
        2.0 * m * n * self.k as f64
    }

    /// Issued / useful ≥ 1.
    pub fn waste_factor(&self, atom: &MatmulAtom) -> f64 {
        self.issued_flops(atom) / self.useful_flops()
    }
}

/// The two GEMMs of one KV block in *query-major* (original FlashMLA) mode:
/// `S = Q·K^T` is (H × Bc × d_qk); `O += P·V` is (H × d_v × Bc).
/// Heads sit on M in both — the padded dimension.
pub fn query_major_gemms(heads: usize, block_kv: usize, d_qk: usize, d_v: usize) -> [GemmDims; 2] {
    [
        GemmDims::new(heads, block_kv, d_qk),
        GemmDims::new(heads, d_v, block_kv),
    ]
}

/// The two GEMMs of one KV block in *ETAP (KV-major)* mode (paper eq. 1–3):
/// `S^T = K·Q^T` is (Bc × H × d_qk); `O^T += V^T·P^T` is (d_v × H × Bc).
/// M is the KV block (64-aligned) resp. d_v (512) — no padding.
pub fn etap_gemms(heads: usize, block_kv: usize, d_qk: usize, d_v: usize) -> [GemmDims; 2] {
    [
        GemmDims::new(block_kv, heads, d_qk),
        GemmDims::new(d_v, heads, block_kv),
    ]
}

/// Aggregate waste factor over a full decode pass (all KV blocks have the
/// same shape, so the per-block factor is the pass factor).
pub fn mode_waste_factor(gemms: &[GemmDims; 2], atom: &MatmulAtom) -> f64 {
    let useful: f64 = gemms.iter().map(|g| g.useful_flops()).sum();
    let issued: f64 = gemms.iter().map(|g| g.issued_flops(atom)).sum();
    issued / useful
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::gpu::MatmulAtom;

    const WGMMA: MatmulAtom = MatmulAtom::wgmma();

    #[test]
    fn query_major_waste_is_4x_at_16_heads() {
        // The paper's central claim: both GEMMs pad 16 → 64 on M.
        let g = query_major_gemms(16, 64, 576, 512);
        assert!((mode_waste_factor(&g, &WGMMA) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn etap_waste_is_1x() {
        let g = etap_gemms(16, 64, 576, 512);
        // N = 16 heads pads to 16 (n_step 8) — exactly representable.
        assert!((mode_waste_factor(&g, &WGMMA) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn etap_advantage_shrinks_with_more_heads() {
        // With 64 heads per GPU (no head split) query-major wouldn't pad:
        // the paper's pathology is specific to the sharded deployment.
        let q64 = query_major_gemms(64, 64, 576, 512);
        assert!((mode_waste_factor(&q64, &WGMMA) - 1.0).abs() < 1e-12);
        let q8 = query_major_gemms(8, 64, 576, 512);
        assert!((mode_waste_factor(&q8, &WGMMA) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn issued_flops_counts_padding() {
        let g = GemmDims::new(16, 64, 576);
        assert_eq!(g.useful_flops(), 2.0 * 16.0 * 64.0 * 576.0);
        assert_eq!(g.issued_flops(&WGMMA), 2.0 * 64.0 * 64.0 * 576.0);
        assert_eq!(g.waste_factor(&WGMMA), 4.0);
    }

    #[test]
    fn n_padding_counted_too() {
        // N=12 pads to 16 under n_step 8 → ×(16/12) on that axis.
        let g = GemmDims::new(64, 12, 64);
        assert!((g.waste_factor(&WGMMA) - 16.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn waste_factor_on_mxu_analogue() {
        // TPU adaptation numbers used in DESIGN.md §8.
        let mxu = MatmulAtom::mxu();
        let g = query_major_gemms(16, 128, 576, 512);
        let w = mode_waste_factor(&g, &mxu);
        assert!(w >= 8.0, "MXU underfill should be ≥8×, got {w}");
        let e = etap_gemms(16, 128, 576, 512);
        // ETAP on MXU still pads N=16→128 on the *narrow* axis, but M is
        // full: overall waste far below query-major.
        assert!(mode_waste_factor(&e, &mxu) < w);
    }
}
