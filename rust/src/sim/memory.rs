//! HBM traffic accounting for one decode-attention forward pass.

use super::workload::DecodeWorkload;

/// Byte-level traffic breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    /// KV cache reads (the dominant term at long context).
    pub kv_bytes: f64,
    /// Query reads + output/LSE writes.
    pub qo_bytes: f64,
    /// Extra passes (e.g. ETAP's final transpose staging, split-KV
    /// partial-result combines).
    pub extra_bytes: f64,
}

impl Traffic {
    pub fn total(&self) -> f64 {
        self.kv_bytes + self.qo_bytes + self.extra_bytes
    }

    /// Time in µs at `bytes_per_us` sustained bandwidth.
    pub fn time_us(&self, bytes_per_us: f64, mem_eff: f64) -> f64 {
        assert!(mem_eff > 0.0 && mem_eff <= 1.0);
        self.total() / (bytes_per_us * mem_eff)
    }
}

/// Traffic for a framework that shares the MLA latent across heads
/// (FlashMLA, FlashMLA-ETAP): each token's 576-dim latent is read once.
pub fn latent_traffic(w: &DecodeWorkload, extra_bytes: f64) -> Traffic {
    Traffic {
        kv_bytes: w.batch as f64 * w.kv_len as f64 * w.latent_bytes_per_token(),
        qo_bytes: w.qo_bytes(),
        extra_bytes,
    }
}

/// Traffic for a framework on decompressed K/V (FA-3, FlashInfer run the
/// generic attention pattern: K and V are distinct tensors).  `kv_heads`
/// is the number of distinct KV heads materialized (1 = MQA-style layout,
/// which is the best case for these baselines on MLA models).
pub fn split_kv_traffic(w: &DecodeWorkload, kv_heads: usize, extra_bytes: f64) -> Traffic {
    Traffic {
        kv_bytes: w.batch as f64
            * w.kv_len as f64
            * kv_heads as f64
            * w.split_kv_bytes_per_token(),
        qo_bytes: w.qo_bytes(),
        extra_bytes,
    }
}

/// Compute intensity (useful FLOPs per byte moved).
pub fn intensity(w: &DecodeWorkload, t: &Traffic) -> f64 {
    w.useful_flops() / t.total()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latent_traffic_dominated_by_kv() {
        let w = DecodeWorkload::paper(16, 65536);
        let t = latent_traffic(&w, 0.0);
        // 16·65536·1152 B ≈ 1.208 GB.
        assert!((t.kv_bytes - 1.2079e9).abs() / 1.2079e9 < 1e-3);
        assert!(t.qo_bytes / t.kv_bytes < 1e-3);
    }

    #[test]
    fn split_kv_costs_more() {
        let w = DecodeWorkload::paper(16, 16384);
        let lat = latent_traffic(&w, 0.0);
        let split = split_kv_traffic(&w, 1, 0.0);
        let amp = split.kv_bytes / lat.kv_bytes;
        assert!((amp - 1088.0 / 576.0).abs() < 1e-9);
    }

    #[test]
    fn mla_is_memory_bound_on_h20_even_without_padding() {
        use crate::hardware::GpuSpec;
        // Intensity of latent MLA decode: 2·H·(dqk+dv) / (dqk·2) ≈ 30
        // FLOPs/B < H20 ridge 37 → ETAP ends up bandwidth-limited, which is
        // exactly why its curve saturates near 90 rather than 148 TFLOPS/s.
        let w = DecodeWorkload::paper(16, 65536);
        let t = latent_traffic(&w, 0.0);
        let i = intensity(&w, &t);
        assert!(i > 29.0 && i < 31.0, "intensity {i}");
        assert!(i < GpuSpec::h20().ridge_flops_per_byte());
    }

    #[test]
    fn time_scales_with_efficiency() {
        let w = DecodeWorkload::paper(16, 4096);
        let t = latent_traffic(&w, 0.0);
        let fast = t.time_us(4e6, 1.0);
        let slow = t.time_us(4e6, 0.5);
        assert!((slow / fast - 2.0).abs() < 1e-9);
    }
}
