//! Decode-attention workload description (the paper's §4.1 setup).

/// One MLA decode-attention forward pass: every request contributes one
/// query token against its KV context.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeWorkload {
    /// Requests in the batch (paper: 16 and 32).
    pub batch: usize,
    /// Attention heads on this GPU (paper: 128/8 = 16).
    pub heads: usize,
    /// Query/key dim per head — for MLA this is the latent dim 512 + 64
    /// rope = 576 (paper §4.1 "head dimension 576").
    pub d_qk: usize,
    /// Value dim (first 512 latent dims).
    pub d_v: usize,
    /// KV context length (paper sweeps 512 … 64K).
    pub kv_len: usize,
    /// Bytes per stored element (FP16/BF16 = 2).
    pub dtype_bytes: usize,
}

impl DecodeWorkload {
    /// Paper-standard workload at a given (batch, kv_len).
    pub fn paper(batch: usize, kv_len: usize) -> Self {
        DecodeWorkload {
            batch,
            heads: 16,
            d_qk: 576,
            d_v: 512,
            kv_len,
            dtype_bytes: 2,
        }
    }

    /// Useful (algorithmic) FLOPs: 2·B·H·N·(d_qk + d_v) — one MAC each for
    /// the S and PV contractions per (head, kv position).
    pub fn useful_flops(&self) -> f64 {
        2.0 * self.batch as f64
            * self.heads as f64
            * self.kv_len as f64
            * (self.d_qk + self.d_v) as f64
    }

    /// Bytes of latent KV cache per token (shared across heads under MLA).
    pub fn latent_bytes_per_token(&self) -> f64 {
        (self.d_qk * self.dtype_bytes) as f64
    }

    /// Bytes of K + V per token for a framework that does NOT share the
    /// latent (FA-3 / FlashInfer operating on decompressed K and V).
    pub fn split_kv_bytes_per_token(&self) -> f64 {
        ((self.d_qk + self.d_v) * self.dtype_bytes) as f64
    }

    /// Query + output traffic (read q, write out + lse); small next to KV.
    pub fn qo_bytes(&self) -> f64 {
        (self.batch * self.heads * (self.d_qk + self.d_v + 1) * self.dtype_bytes) as f64
    }

    /// The paper's sequence-length sweep.
    pub fn paper_seq_lens() -> &'static [usize] {
        &[512, 1024, 2048, 4096, 8192, 16384, 32768, 65536]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn useful_flops_match_hand_count() {
        // BS=16, 64K: 2·16·16·65536·1088 = 36.507 GFLOP.
        let w = DecodeWorkload::paper(16, 65536);
        assert!((w.useful_flops() - 36.507e9).abs() / 36.507e9 < 1e-3);
    }

    #[test]
    fn latent_vs_split_amplification() {
        let w = DecodeWorkload::paper(16, 4096);
        // Split K/V costs (576+512)/576 ≈ 1.89× the latent bytes.
        let amp = w.split_kv_bytes_per_token() / w.latent_bytes_per_token();
        assert!((amp - 1088.0 / 576.0).abs() < 1e-12);
    }

    #[test]
    fn flops_linear_in_batch_and_len() {
        let a = DecodeWorkload::paper(16, 1024).useful_flops();
        let b = DecodeWorkload::paper(32, 1024).useful_flops();
        let c = DecodeWorkload::paper(16, 2048).useful_flops();
        assert!((b / a - 2.0).abs() < 1e-12);
        assert!((c / a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn paper_sweep_is_512_to_64k() {
        let lens = DecodeWorkload::paper_seq_lens();
        assert_eq!(lens.first(), Some(&512));
        assert_eq!(lens.last(), Some(&65536));
        assert_eq!(lens.len(), 8);
    }
}
