//! The simulator engine: composes tile algebra, pipeline fill, and HBM
//! traffic into a per-kernel time estimate.
//!
//! Model (per decode-attention forward pass):
//!
//! ```text
//! issued    = useful_flops × waste_factor(mode, atom)
//! compute   = issued / (peak × pipe_eff × fill_eff(T_c) × wave_eff)
//! memory    = traffic / (bw × mem_eff)
//! total     = max(compute, memory) + launch_overhead
//! TFLOPS/s  = useful_flops / total            (the paper's reported metric)
//! ```
//!
//! `pipe_eff`, `fill_blocks`, `mem_eff`, `launch_us` are per-framework
//! constants; everything else is derived from the algorithm's GEMM shapes.
//! Compute and memory overlap fully (TMA/double-buffering) — `max`, not
//! sum — which all four evaluated kernels implement.

use crate::hardware::GpuSpec;

use super::gemm::{self, GemmDims};
use super::memory::Traffic;
use super::pipeline;
use super::workload::DecodeWorkload;

/// Per-framework pipeline parameters (derivations in `sim::kernels::*`).
#[derive(Clone, Debug)]
pub struct PipelineParams {
    /// Human-readable framework name.
    pub name: &'static str,
    /// KV block size Bc streamed through SMEM.
    pub block_kv: usize,
    /// Asymptotic fraction of peak the matmul pipeline sustains once full
    /// (instruction mix, issue limits, softmax interleave).
    pub pipe_eff: f64,
    /// Pipeline fill/drain cost in KV-block units.
    pub fill_blocks: f64,
    /// Sustained fraction of peak HBM bandwidth.
    pub mem_eff: f64,
    /// Kernel launch + host-side fixed overhead per forward (µs).
    pub launch_us: f64,
    /// Persistent-grid kernel (one CTA per SM, software scheduling) — no
    /// wave quantization.  FlashMLA and FlashMLA-ETAP schedule this way.
    pub persistent: bool,
    /// CTAs per forward for non-persistent grids (wave quantization);
    /// usually B × head-groups or B × split-KV partitions.
    pub ctas: fn(&DecodeWorkload) -> usize,
}

/// Simulation output for one (framework, workload) point.
#[derive(Clone, Debug)]
pub struct Estimate {
    pub name: &'static str,
    pub useful_flops: f64,
    pub issued_flops: f64,
    pub waste_factor: f64,
    pub compute_us: f64,
    pub memory_us: f64,
    pub launch_us: f64,
    pub total_us: f64,
    /// The paper's metric: useful FLOPs / wall time.
    pub tflops_per_s: f64,
    /// Fraction of peak compute (the "<25 %" utilization the paper cites).
    pub utilization: f64,
    pub memory_bound: bool,
}

/// Run the model for one workload.
pub fn estimate(
    params: &PipelineParams,
    gemms: &[GemmDims; 2],
    traffic: &Traffic,
    w: &DecodeWorkload,
    gpu: &GpuSpec,
) -> Estimate {
    let useful = w.useful_flops();
    let waste = gemm::mode_waste_factor(gemms, &gpu.atom);
    let issued = useful * waste;

    let t_c = pipeline::kv_blocks(w.kv_len, params.block_kv);
    let fill = pipeline::fill_efficiency(t_c, params.fill_blocks);
    let wave = if params.persistent {
        1.0
    } else {
        pipeline::wave_efficiency((params.ctas)(w), gpu.sm_count)
    };

    let compute_us = issued / (gpu.flops_per_us() * params.pipe_eff * fill * wave);
    let memory_us = traffic.time_us(gpu.bytes_per_us(), params.mem_eff);
    let total_us = compute_us.max(memory_us) + params.launch_us;

    Estimate {
        name: params.name,
        useful_flops: useful,
        issued_flops: issued,
        waste_factor: waste,
        compute_us,
        memory_us,
        launch_us: params.launch_us,
        total_us,
        tflops_per_s: useful / total_us / 1e6,
        utilization: useful / total_us / 1e6 / gpu.fp16_tflops,
        memory_bound: memory_us > compute_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gemm::{etap_gemms, query_major_gemms};
    use crate::sim::memory::latent_traffic;

    fn params(name: &'static str, fill: f64) -> PipelineParams {
        PipelineParams {
            name,
            block_kv: 64,
            pipe_eff: 0.8,
            fill_blocks: fill,
            mem_eff: 0.8,
            launch_us: 15.0,
            persistent: true,
            ctas: |w| w.batch * w.heads,
        }
    }

    #[test]
    fn padding_shows_up_in_estimate() {
        let gpu = GpuSpec::h20();
        let w = DecodeWorkload::paper(16, 65536);
        let t = latent_traffic(&w, 0.0);
        let qm = estimate(
            &params("qm", 4.0),
            &query_major_gemms(w.heads, 64, w.d_qk, w.d_v),
            &t,
            &w,
            &gpu,
        );
        let et = estimate(
            &params("etap", 4.0),
            &etap_gemms(w.heads, 64, w.d_qk, w.d_v),
            &t,
            &w,
            &gpu,
        );
        assert_eq!(qm.waste_factor, 4.0);
        assert_eq!(et.waste_factor, 1.0);
        assert!(et.tflops_per_s > 2.0 * qm.tflops_per_s);
        // Query-major is compute-bound (padded), ETAP memory-bound.
        assert!(!qm.memory_bound);
        assert!(et.memory_bound);
    }

    #[test]
    fn tflops_equals_useful_over_time() {
        let gpu = GpuSpec::h20();
        let w = DecodeWorkload::paper(16, 4096);
        let t = latent_traffic(&w, 0.0);
        let e = estimate(
            &params("x", 8.0),
            &etap_gemms(w.heads, 64, w.d_qk, w.d_v),
            &t,
            &w,
            &gpu,
        );
        let recomputed = e.useful_flops / e.total_us / 1e6;
        assert!((e.tflops_per_s - recomputed).abs() < 1e-9);
        assert!(e.utilization < 1.0);
    }

    #[test]
    fn overhead_dominates_short_context() {
        let gpu = GpuSpec::h20();
        let short = DecodeWorkload::paper(16, 512);
        let t = latent_traffic(&short, 0.0);
        let e = estimate(
            &params("x", 8.0),
            &etap_gemms(short.heads, 64, short.d_qk, short.d_v),
            &t,
            &short,
            &gpu,
        );
        assert!(e.launch_us / e.total_us > 0.3, "launch should dominate");
    }
}
