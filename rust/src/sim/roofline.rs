//! Roofline composition: attainable throughput given compute intensity.

use crate::hardware::GpuSpec;

/// A point on the roofline.
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    /// FLOPs per byte of HBM traffic.
    pub intensity: f64,
    /// Attainable TFLOPS/s at that intensity (min of the two roofs).
    pub attainable_tflops: f64,
    /// True if limited by bandwidth rather than compute.
    pub memory_bound: bool,
}

/// Evaluate the roofline for a given intensity and efficiency derates.
pub fn attainable(
    gpu: &GpuSpec,
    intensity: f64,
    compute_eff: f64,
    mem_eff: f64,
) -> RooflinePoint {
    assert!(intensity > 0.0);
    let compute_roof = gpu.fp16_tflops * compute_eff;
    let memory_roof = gpu.hbm_tbps * mem_eff * intensity; // TB/s · F/B = TF/s
    let memory_bound = memory_roof < compute_roof;
    RooflinePoint {
        intensity,
        attainable_tflops: compute_roof.min(memory_roof),
        memory_bound,
    }
}

/// Efficiency ratio: achieved / attainable — the metric the L1 performance
/// target in DESIGN.md §7 is phrased in.
pub fn efficiency_ratio(achieved_tflops: f64, point: &RooflinePoint) -> f64 {
    achieved_tflops / point.attainable_tflops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridge_point_switches_regime() {
        let gpu = GpuSpec::h20();
        let below = attainable(&gpu, 10.0, 1.0, 1.0);
        let above = attainable(&gpu, 100.0, 1.0, 1.0);
        assert!(below.memory_bound);
        assert!(!above.memory_bound);
        assert!((above.attainable_tflops - 148.0).abs() < 1e-9);
        assert!((below.attainable_tflops - 40.0).abs() < 1e-9);
    }

    #[test]
    fn paper_numbers_sit_under_the_mla_roof() {
        // MLA latent decode intensity ≈ 30.2 F/B → roof ≈ 121 TFLOPS/s at
        // ideal bandwidth.  The paper's best bar (89) is ~74 % of it —
        // consistent with a well-tuned memory-bound kernel, which is the
        // shape argument EXPERIMENTS.md makes.
        let gpu = GpuSpec::h20();
        let p = attainable(&gpu, 30.2, 1.0, 1.0);
        assert!(p.memory_bound);
        let r = efficiency_ratio(89.0, &p);
        assert!(r > 0.6 && r < 0.85, "ratio {r}");
    }

    #[test]
    fn padded_compute_roof_quarter() {
        // Query-major FlashMLA burns 4×: its compute roof is 37 TFLOPS/s,
        // below the memory roof at MLA intensity → compute-bound at 25 %.
        let gpu = GpuSpec::h20();
        let p = attainable(&gpu, 30.2, 0.25, 1.0);
        assert!(!p.memory_bound);
        assert!((p.attainable_tflops - 37.0).abs() < 1e-9);
    }
}
