//! Multi-engine serving fleet: N [`Engine`] instances behind one
//! prefix-affinity router, driven concurrently and fronted by a
//! `submit -> FleetHandle` / `poll_events` API that is a drop-in superset
//! of the solo serving API (`docs/fleet-serving.md`).
//!
//! The hardware-centric MLA analysis (arXiv:2506.02523) shows MLA decode
//! is memory-bound per instance, so fleet-level wins come from
//! *placement*, not FLOPs: route each request to the engine that already
//! holds its prefix blocks, and when a prefix is hot enough that affinity
//! would hotspot one engine, **replicate** its chain to the others
//! (`PrefixTree` + [`crate::prefixcache::replicate_chain`]) so the
//! affinity constraint dissolves instead of serializing the fleet.
//!
//! Three ideas, one executor:
//!
//! * **Routing** — [`PrefixAffinityRouter`]: block-granularity prefix
//!   fingerprints, least-loaded tiebreak, and a load-imbalance spill
//!   threshold so a hot template spreads once its home engine saturates.
//! * **Replication** — a prefix observed [`FleetConfig::replicate_hot_after`]
//!   times is exported from whichever engine caches it
//!   ([`Engine::export_prefix_latents`]) and adopted, best-effort, by
//!   every other engine ([`Engine::adopt_replicated_prefix`]).  Block ids
//!   are store-local, so replication ships latent *data*; each tree ends
//!   up owning an independent refcounted chain and donor-side eviction
//!   never invalidates a replica.
//! * **QoS admission** — one shared [`validate_request`] path with the
//!   solo front door, then prefix-aware charging (a hit-heavy request is
//!   charged only its unshared suffix plus its budget), a per-tenant
//!   in-flight token budget, and a bounded per-engine queue.  Overload
//!   surfaces as [`RejectReason::Backpressure`] events at submit time —
//!   never as unbounded queue growth.
//!
//! Determinism contract: with a fixed seed and engine count, routing and
//! outputs are reproducible, and every request's token stream is
//! bit-identical to the same request served by a solo engine with the
//! same config.  Engines step concurrently on the panic-propagating
//! [`ThreadPool`], but [`ThreadPool::map`] preserves input order and the
//! executor drains events engine-by-engine in index order on the
//! coordinator thread, so concurrency never reorders the observable
//! stream.  The fleet-vs-solo oracle is pinned by `tests/fleet_e2e.rs`.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::mem;

use crate::coordinator::{
    validate_request, AdmitError, Engine, EngineConfig, FinishReason, FinishedRequest, FleetEvent,
    GenerationRequest, PrefixAffinityRouter, RejectReason, RequestId, ServingMetrics, StepEvent,
};
use crate::obs::MetricsRegistry;
use crate::runtime::ReferenceModelConfig;
use crate::util::threadpool::ThreadPool;

/// Fleet topology + policy knobs.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Engine instances (≥ 1; 1 degenerates to a solo engine behind the
    /// fleet API, which the bit-identity oracle exploits).
    pub engines: usize,
    /// Per-engine configuration, applied identically to every instance —
    /// identical configs are what make cross-engine bit-identity hold.
    pub engine: EngineConfig,
    /// Worker threads for the concurrent tick drive (0 = one per engine).
    pub threads: usize,
    /// Queued requests an engine may hold before submissions targeting it
    /// shed with `Rejected{Backpressure}`.
    pub max_queue_per_engine: usize,
    /// Enable cross-engine replication of hot prefixes.
    pub replication: bool,
    /// Submissions sharing a first-block prefix before that prefix counts
    /// as hot and replication kicks in.
    pub replicate_hot_after: u64,
    /// Per-tenant in-flight charged-token budget (`None` = no limit).
    /// Charged tokens = unshared prompt suffix + generation budget, so a
    /// tenant riding a replicated prefix fits more requests in the same
    /// budget — prefix-aware fairness, not raw token counting.
    pub tenant_token_budget: Option<u64>,
    /// Prefix fingerprints the router retains per engine.
    pub max_tracked_prefixes: usize,
    /// Router load-imbalance spill threshold (`None` = pure affinity).
    pub spill_threshold: Option<usize>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            engines: 2,
            engine: EngineConfig::default(),
            threads: 0,
            max_queue_per_engine: 64,
            replication: true,
            replicate_hot_after: 2,
            tenant_token_budget: None,
            max_tracked_prefixes: 256,
            spill_threshold: Some(4),
        }
    }
}

/// Handle for a fleet-submitted request: the fleet-level id (what every
/// [`FleetEvent`] carries) plus the engine the router placed it on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FleetHandle {
    id: RequestId,
    engine: usize,
}

impl FleetHandle {
    pub fn id(self) -> RequestId {
        self.id
    }

    /// Engine index the request was routed to (for a shed request: the
    /// engine it *would* have landed on).
    pub fn engine(self) -> usize {
        self.engine
    }
}

/// Heat tracking for one first-block prefix key.
#[derive(Debug)]
struct HotPrefix {
    /// Submissions observed with this key.
    count: u64,
    /// Longest common block-aligned prefix across those submissions — the
    /// shared template, discovered rather than declared.
    shared: Vec<i32>,
    /// A replication pass ran for this key (export succeeded; adopters
    /// took what they could).
    replicated: bool,
    /// Engine the first submission routed to.
    home: Option<usize>,
}

/// FNV-1a over a token slice (same constants as the router's rolling
/// block fingerprints; used here only as a map key for heat tracking).
fn fnv(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for byte in t.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Rebuild a [`StepEvent`] with a translated (fleet-level) id.
fn remap(ev: StepEvent, fid: RequestId) -> StepEvent {
    match ev {
        StepEvent::Admitted { .. } => StepEvent::Admitted { id: fid },
        StepEvent::Token { token, .. } => StepEvent::Token { id: fid, token },
        StepEvent::Finished { reason, .. } => StepEvent::Finished { id: fid, reason },
        StepEvent::Rejected { reason, .. } => StepEvent::Rejected { id: fid, reason },
    }
}

/// The multi-engine executor.  See the module docs for the policy design;
/// the API mirrors the solo [`Engine`]: `submit`, `step`, `poll_events`,
/// `take_finished`, `cancel`, `has_work`, plus fleet-level metrics.
pub struct FleetExecutor {
    cfg: FleetConfig,
    engines: Vec<Engine>,
    pool: ThreadPool,
    router: PrefixAffinityRouter,
    /// Static admission limits, captured at construction so the door
    /// check ([`validate_request`]) needs no engine access.
    vocab: usize,
    max_context: usize,
    block_size: usize,
    next_id: RequestId,
    /// Per-engine: engine-local id → fleet id.
    local2fleet: Vec<HashMap<RequestId, RequestId>>,
    /// Fleet id → (engine, engine-local id); absent for shed requests.
    placement: HashMap<RequestId, (usize, RequestId)>,
    /// Fleet id → (tenant, charged tokens), released on terminal events.
    charges: HashMap<RequestId, (String, u64)>,
    /// In-flight charged tokens per tenant (BTreeMap: deterministic
    /// iteration for debugging/metrics).
    tenant_inflight: BTreeMap<String, u64>,
    /// Heat per first-block prefix key (BTreeMap: the replication retry
    /// scan must be deterministic).
    hot: BTreeMap<u64, HotPrefix>,
    events: VecDeque<FleetEvent>,
    finished: Vec<FinishedRequest>,
    submitted: u64,
    shed: u64,
    replications: u64,
    replicated_blocks: u64,
    replication_hits: u64,
    ticks: u64,
}

impl FleetExecutor {
    /// Build a fleet of identical reference-model engines (the same
    /// deterministic backend [`Engine::reference`] uses — identical seeds
    /// on every instance are what make replication and the bit-identity
    /// oracle sound).
    pub fn reference(model: ReferenceModelConfig, cfg: FleetConfig) -> anyhow::Result<Self> {
        anyhow::ensure!(cfg.engines > 0, "fleet needs at least one engine");
        anyhow::ensure!(
            cfg.max_queue_per_engine > 0,
            "per-engine queue bound must be ≥ 1"
        );
        let mut engines = Vec::with_capacity(cfg.engines);
        for _ in 0..cfg.engines {
            engines.push(Engine::reference(model.clone(), cfg.engine.clone())?);
        }
        let max_context = engines[0].max_context();
        let block_size = cfg.engine.block_size;
        let mut router =
            PrefixAffinityRouter::new(cfg.engines, block_size, cfg.max_tracked_prefixes);
        if let Some(t) = cfg.spill_threshold {
            router = router.with_spill(t);
        }
        let threads = if cfg.threads == 0 {
            cfg.engines
        } else {
            cfg.threads
        };
        let local2fleet = (0..cfg.engines).map(|_| HashMap::new()).collect();
        Ok(FleetExecutor {
            engines,
            pool: ThreadPool::new(threads),
            router,
            vocab: model.vocab,
            max_context,
            block_size,
            next_id: 1,
            local2fleet,
            placement: HashMap::new(),
            charges: HashMap::new(),
            tenant_inflight: BTreeMap::new(),
            hot: BTreeMap::new(),
            events: VecDeque::new(),
            finished: Vec::new(),
            submitted: 0,
            shed: 0,
            replications: 0,
            replicated_blocks: 0,
            replication_hits: 0,
            ticks: 0,
            cfg,
        })
    }

    /// Submit under the default tenant.  Drop-in superset of
    /// [`Engine::submit`]: same builder in, a handle out — but the fleet
    /// validates at the door (shared [`validate_request`] path) instead of
    /// panicking, and overload surfaces as a `Rejected{Backpressure}`
    /// event on the returned handle's id rather than unbounded queueing.
    pub fn submit(&mut self, req: GenerationRequest) -> Result<FleetHandle, AdmitError> {
        self.submit_for("default", req)
    }

    /// Submit on behalf of a tenant (the unit of token-rate fairness).
    ///
    /// Static validation errors return `Err` synchronously — no id is
    /// allocated, nothing is routed.  QoS rejections (queue bound, tenant
    /// budget) *do* allocate an id and return `Ok`: the rejection is
    /// delivered as a [`FleetEvent`] `Rejected{Backpressure}` plus an
    /// empty [`FinishedRequest`], exactly how the solo engine reports
    /// `KvCapacity` rejections — one consumer loop handles both.
    pub fn submit_for(
        &mut self,
        tenant: &str,
        req: GenerationRequest,
    ) -> Result<FleetHandle, AdmitError> {
        validate_request(
            req.prompt(),
            req.max_new_tokens(),
            self.max_context,
            self.vocab,
        )?;
        let w = self.router.route(req.prompt());
        let fid = self.next_id;
        self.next_id += 1;
        self.submitted += 1;

        // Heat tracking: shed traffic still heats its prefix — overload is
        // precisely when replication should be relieving the hotspot.
        let bs = self.block_size;
        let aligned = req.prompt().len() / bs * bs;
        if self.cfg.replication && aligned >= bs {
            let key = fnv(&req.prompt()[..bs]);
            let hp = self.hot.entry(key).or_insert_with(|| HotPrefix {
                count: 0,
                shared: req.prompt()[..aligned].to_vec(),
                replicated: false,
                home: None,
            });
            hp.count += 1;
            if hp.home.is_none() {
                hp.home = Some(w);
            }
            // Shrink the template to the common block-aligned prefix of
            // everything observed under this key.
            let common = hp
                .shared
                .iter()
                .zip(req.prompt())
                .take_while(|(a, b)| a == b)
                .count();
            hp.shared.truncate(common / bs * bs);
            if hp.replicated && hp.home != Some(w) && self.engines[w].peek_prefix_tokens(req.prompt()) > 0
            {
                self.replication_hits += 1;
            }
        }

        // QoS: charge only the unshared suffix (prefix-aware admission),
        // check the tenant budget and the target queue bound.
        let hit = self.engines[w].peek_prefix_tokens(req.prompt());
        let charge = (req.prompt().len() - hit + req.max_new_tokens()) as u64;
        let over_queue = self.engines[w].queue_depth() >= self.cfg.max_queue_per_engine;
        let over_budget = match self.cfg.tenant_token_budget {
            Some(b) => self.tenant_inflight.get(tenant).copied().unwrap_or(0) + charge > b,
            None => false,
        };
        if over_queue || over_budget {
            self.router.finish(w); // release the load `route` recorded
            self.shed += 1;
            self.events.push_back(FleetEvent {
                engine: w,
                event: StepEvent::Rejected {
                    id: fid,
                    reason: RejectReason::Backpressure,
                },
            });
            self.finished.push(FinishedRequest {
                id: fid,
                tokens: Vec::new(),
                reason: FinishReason::Aborted,
            });
            return Ok(FleetHandle { id: fid, engine: w });
        }

        *self.tenant_inflight.entry(tenant.to_string()).or_insert(0) += charge;
        self.charges.insert(fid, (tenant.to_string(), charge));
        let local = self.engines[w].submit(req);
        self.local2fleet[w].insert(local.id(), fid);
        self.placement.insert(fid, (w, local.id()));
        Ok(FleetHandle { id: fid, engine: w })
    }

    /// Drive one tick on every engine concurrently, then drain and
    /// translate their event streams.  Returns `true` while any engine
    /// made progress.
    ///
    /// The engines are moved onto the pool ([`ThreadPool::map`] is
    /// order-preserving and re-raises worker panics), restored *first*,
    /// and only then is the first step error propagated — an engine
    /// failure never strands its siblings outside the executor.  Event
    /// drains run on the coordinator thread in engine-index order, which
    /// is what keeps the observable stream deterministic.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        self.ticks += 1;
        let engines = mem::take(&mut self.engines);
        let results = self.pool.map(engines, |mut e: Engine| {
            let r = e.step();
            (e, r)
        });
        let mut progressed = false;
        let mut first_err = None;
        for (e, r) in results {
            self.engines.push(e);
            match r {
                Ok(p) => progressed |= p,
                Err(err) => {
                    if first_err.is_none() {
                        first_err = Some(err);
                    }
                }
            }
        }
        if let Some(err) = first_err {
            return Err(err);
        }

        for w in 0..self.engines.len() {
            let mut terminal: Vec<RequestId> = Vec::new();
            for ev in self.engines[w].poll_events() {
                let lid = ev.id();
                let Some(&fid) = self.local2fleet[w].get(&lid) else {
                    continue;
                };
                if matches!(ev, StepEvent::Finished { .. } | StepEvent::Rejected { .. }) {
                    terminal.push(lid);
                    self.router.finish(w);
                    if let Some((tenant, charge)) = self.charges.remove(&fid) {
                        if let Some(v) = self.tenant_inflight.get_mut(&tenant) {
                            *v = v.saturating_sub(charge);
                        }
                    }
                }
                self.events.push_back(FleetEvent {
                    engine: w,
                    event: remap(ev, fid),
                });
            }
            for mut f in self.engines[w].take_finished() {
                if let Some(&fid) = self.local2fleet[w].get(&f.id) {
                    f.id = fid;
                    self.finished.push(f);
                }
            }
            for lid in terminal {
                if let Some(fid) = self.local2fleet[w].remove(&lid) {
                    self.placement.remove(&fid);
                }
            }
        }

        self.drive_replication();
        Ok(progressed)
    }

    /// Retry pass for hot prefixes not yet replicated: a donor only holds
    /// the chain once prefill has actually run, so replication triggers at
    /// submit time but *lands* here, a tick or two later.  Deterministic:
    /// keys scan in `BTreeMap` order, donors in engine-index order.
    fn drive_replication(&mut self) {
        if !self.cfg.replication || self.engines.len() < 2 {
            return;
        }
        let pending: Vec<(u64, Vec<i32>)> = self
            .hot
            .iter()
            .filter(|(_, hp)| {
                hp.count >= self.cfg.replicate_hot_after
                    && !hp.replicated
                    && hp.shared.len() >= self.block_size
            })
            .map(|(&k, hp)| (k, hp.shared.clone()))
            .collect();
        for (key, shared) in pending {
            // Probe one token past the template: `peek`/`export` cap
            // matches below the probe length (admission semantics), so the
            // extended probe lets the *full* template chain export.
            let mut probe = shared.clone();
            probe.push(shared[0]);
            let donor = (0..self.engines.len())
                .find(|&w| self.engines[w].peek_prefix_tokens(&probe) >= shared.len());
            let Some(d) = donor else { continue };
            let Some((tokens, latents)) = self.engines[d].export_prefix_latents(&probe) else {
                continue;
            };
            let mut adopted = 0usize;
            for w in 0..self.engines.len() {
                if w != d {
                    adopted += self.engines[w].adopt_replicated_prefix(&tokens, &latents);
                }
            }
            let hp = self.hot.get_mut(&key).expect("pending key exists");
            hp.replicated = true;
            if adopted > 0 {
                self.replications += 1;
                self.replicated_blocks += adopted as u64;
            }
        }
    }

    /// Cancel by fleet handle — forwarded to the owning engine; identical
    /// queued/running semantics to [`Engine::cancel`].  `false` for
    /// unknown, shed, or already-terminal requests.
    pub fn cancel(&mut self, h: FleetHandle) -> bool {
        match self.placement.get(&h.id) {
            Some(&(w, lid)) => self.engines[w].cancel(lid),
            None => false,
        }
    }

    /// Drain the engine-stamped, fleet-id-translated event stream
    /// accumulated since the last call (submit-time backpressure
    /// rejections included).
    pub fn poll_events(&mut self) -> Vec<FleetEvent> {
        self.events.drain(..).collect()
    }

    /// Drain terminal results (fleet ids), the solo-API complement.
    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        mem::take(&mut self.finished)
    }

    pub fn has_work(&self) -> bool {
        self.engines.iter().any(|e| e.has_work())
    }

    /// Step until every engine drains; returns ticks driven.
    pub fn run_until_idle(&mut self) -> anyhow::Result<u64> {
        let mut n = 0u64;
        while self.has_work() {
            self.step()?;
            n += 1;
            anyhow::ensure!(n < 10_000_000, "fleet run did not converge");
        }
        Ok(n)
    }

    pub fn engines(&self) -> usize {
        self.engines.len()
    }

    /// Read access to one engine (tests, leak audits, per-engine gauges).
    pub fn engine(&self, w: usize) -> &Engine {
        &self.engines[w]
    }

    /// Requests shed with `Rejected{Backpressure}`.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Replication passes that adopted at least one block somewhere.
    pub fn replications(&self) -> u64 {
        self.replications
    }

    /// Off-home submissions that found their prefix already cached via a
    /// replica.
    pub fn replication_hits(&self) -> u64 {
        self.replication_hits
    }

    /// All engines' serving metrics folded through
    /// [`ServingMetrics::merge`] — rates recompute from merged totals.
    pub fn merged_metrics(&self) -> ServingMetrics {
        let mut m = ServingMetrics::new();
        for e in &self.engines {
            m.merge(e.metrics());
        }
        m
    }

    /// Fleet-level registry (`flashmla_fleet_*`), kept separate from the
    /// per-engine [`ServingMetrics::registry`] so the merge-parity
    /// invariant (merged registry ≡ recomputed-from-totals registry)
    /// stays intact.
    pub fn fleet_registry(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.gauge(
            "flashmla_fleet_engines",
            "Engine instances behind the fleet router.",
            self.engines.len() as f64,
        );
        r.counter(
            "flashmla_fleet_ticks_total",
            "Fleet ticks driven (each tick steps every engine once).",
            self.ticks,
        );
        r.counter(
            "flashmla_fleet_submitted_total",
            "Requests entering the fleet door (sheds included).",
            self.submitted,
        );
        r.counter(
            "flashmla_fleet_shed_total",
            "Requests rejected with Backpressure (queue bound or tenant budget).",
            self.shed,
        );
        r.counter(
            "flashmla_fleet_replications_total",
            "Hot-prefix replication passes that adopted ≥ 1 block.",
            self.replications,
        );
        r.counter(
            "flashmla_fleet_replicated_blocks_total",
            "KV blocks materialized on non-donor engines by replication.",
            self.replicated_blocks,
        );
        r.counter(
            "flashmla_fleet_replication_hits_total",
            "Off-home submissions whose prefix was already cached via a replica.",
            self.replication_hits,
        );
        let load: BTreeMap<usize, u64> = (0..self.engines.len())
            .map(|w| {
                (
                    w,
                    (self.engines[w].queue_depth() + self.engines[w].active_requests()) as u64,
                )
            })
            .collect();
        r.series(
            "flashmla_fleet_engine_load",
            "Queued + active requests per engine.",
            "engine",
            &load,
        );
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RejectReason;

    fn model() -> ReferenceModelConfig {
        ReferenceModelConfig {
            vocab: 64,
            n_layers: 2,
            latent_dim: 8,
            seed: 0xF1EE_7001,
            batch_buckets: vec![1, 2, 4],
            kv_buckets: vec![32, 64, 128],
        }
    }

    fn engine_cfg() -> EngineConfig {
        EngineConfig {
            max_slots: 4,
            kv_blocks: 64,
            block_size: 4,
            ..EngineConfig::default()
        }
    }

    fn fleet_cfg(engines: usize) -> FleetConfig {
        FleetConfig {
            engines,
            engine: engine_cfg(),
            max_queue_per_engine: 64,
            replicate_hot_after: 2,
            spill_threshold: Some(1),
            ..FleetConfig::default()
        }
    }

    /// Token stream of `prompt` on a fresh solo engine — the oracle.
    fn solo_stream(prompt: &[i32], budget: usize) -> Vec<i32> {
        let mut e = Engine::reference(model(), engine_cfg()).unwrap();
        let h = e.submit(GenerationRequest::new(prompt.to_vec(), budget));
        let mut out = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            e.step().unwrap();
            for ev in e.poll_events() {
                if let StepEvent::Token { id, token } = ev {
                    if id == h.id() {
                        out.push(token);
                    }
                }
            }
            guard += 1;
            assert!(guard < 10_000, "solo oracle did not converge");
        }
        out
    }

    fn prompt(system: i32, user: i32) -> Vec<i32> {
        let mut p = vec![system; 8];
        p.extend(vec![user; 4]);
        p
    }

    #[test]
    fn fleet_streams_match_solo_oracle() {
        let mut fleet = FleetExecutor::reference(model(), fleet_cfg(2)).unwrap();
        let mut want: HashMap<RequestId, Vec<i32>> = HashMap::new();
        for (s, u) in [(1, 10), (2, 20), (1, 11), (3, 30), (2, 21), (1, 12)] {
            let p = prompt(s, u);
            let h = fleet.submit(GenerationRequest::new(p.clone(), 6)).unwrap();
            want.insert(h.id(), solo_stream(&p, 6));
        }
        fleet.run_until_idle().unwrap();
        let mut got: HashMap<RequestId, Vec<i32>> = HashMap::new();
        for ev in fleet.poll_events() {
            if let StepEvent::Token { id, token } = ev.event {
                got.entry(id).or_default().push(token);
            }
        }
        assert_eq!(got, want, "fleet streams must be bit-identical to solo");
        // take_finished carries the same vectors under fleet ids.
        for f in fleet.take_finished() {
            assert_eq!(&f.tokens, want.get(&f.id).unwrap());
        }
    }

    #[test]
    fn door_validation_is_the_shared_path() {
        let mut fleet = FleetExecutor::reference(model(), fleet_cfg(2)).unwrap();
        assert_eq!(
            fleet
                .submit_for("t", GenerationRequest::new(vec![1, 99], 2))
                .unwrap_err(),
            AdmitError::BadToken { tok: 99, vocab: 64 }
        );
        assert!(matches!(
            fleet
                .submit_for("t", GenerationRequest::new(vec![1; 120], 100))
                .unwrap_err(),
            AdmitError::ContextTooLong { .. }
        ));
        // Static rejections allocate nothing.
        assert_eq!(fleet.poll_events().len(), 0);
        let ok = fleet.submit(GenerationRequest::new(vec![1, 2], 2)).unwrap();
        assert_eq!(ok.id(), 1, "failed validations never burned an id");
    }

    #[test]
    fn queue_bound_sheds_with_backpressure_event() {
        let mut cfg = fleet_cfg(1);
        cfg.max_queue_per_engine = 1;
        let mut fleet = FleetExecutor::reference(model(), cfg).unwrap();
        let a = fleet.submit(GenerationRequest::new(prompt(1, 1), 2)).unwrap();
        let b = fleet.submit(GenerationRequest::new(prompt(2, 2), 2)).unwrap();
        assert_eq!(fleet.shed(), 1);
        let evs = fleet.poll_events();
        assert!(evs.contains(&FleetEvent {
            engine: b.engine(),
            event: StepEvent::Rejected {
                id: b.id(),
                reason: RejectReason::Backpressure,
            },
        }));
        let fin = fleet.take_finished();
        assert_eq!(fin.len(), 1);
        assert_eq!(fin[0].id, b.id());
        assert!(fin[0].tokens.is_empty());
        assert_eq!(fin[0].reason, FinishReason::Aborted);
        // Shed requests cannot be cancelled (they never held anything)...
        assert!(!fleet.cancel(b));
        // ...and the survivor still serves to completion.
        fleet.run_until_idle().unwrap();
        let done: Vec<_> = fleet.take_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, a.id());
        assert!(!done[0].tokens.is_empty());
    }

    #[test]
    fn tenant_budget_enforces_fairness() {
        let mut cfg = fleet_cfg(1);
        // prompt(·,·) is 12 tokens; charge = 12 - hit + 4.  Budget fits one
        // cold request (16) but not two.
        cfg.tenant_token_budget = Some(20);
        cfg.replication = false;
        let mut fleet = FleetExecutor::reference(model(), cfg).unwrap();
        let _a = fleet
            .submit_for("alice", GenerationRequest::new(prompt(1, 1), 4))
            .unwrap();
        fleet
            .submit_for("alice", GenerationRequest::new(prompt(2, 2), 4))
            .unwrap();
        assert_eq!(fleet.shed(), 1, "alice's second request exceeds her budget");
        // A different tenant is unaffected by alice's spend.
        fleet
            .submit_for("bob", GenerationRequest::new(prompt(3, 3), 4))
            .unwrap();
        assert_eq!(fleet.shed(), 1);
        // Once alice's request terminates, her budget frees up.
        fleet.run_until_idle().unwrap();
        fleet
            .submit_for("alice", GenerationRequest::new(prompt(4, 4), 4))
            .unwrap();
        assert_eq!(fleet.shed(), 1);
    }

    #[test]
    fn hot_prefix_replicates_across_engines() {
        let mut fleet = FleetExecutor::reference(model(), fleet_cfg(2)).unwrap();
        // Two requests sharing an 8-token system prompt: the second marks
        // the prefix hot; the chain lands on the donor during its prefill
        // and the retry pass in step() copies it to the other engine.
        fleet.submit(GenerationRequest::new(prompt(7, 1), 4)).unwrap();
        fleet.run_until_idle().unwrap();
        fleet.submit(GenerationRequest::new(prompt(7, 2), 4)).unwrap();
        fleet.run_until_idle().unwrap();
        assert_eq!(fleet.replications(), 1, "one replication pass adopted blocks");
        // Both engines now cache the shared head: 8 tokens = 2 blocks at
        // block_size 4, visible to a peek through either engine.
        let probe = prompt(7, 3);
        for w in 0..fleet.engines() {
            assert!(
                fleet.engine(w).peek_prefix_tokens(&probe) >= 8,
                "engine {w} should cache the replicated head"
            );
        }
        // Replicated chains stay tree-pinned, not leaked: every block is
        // free or prefix-cached on both engines.
        for w in 0..fleet.engines() {
            let e = fleet.engine(w);
            assert_eq!(e.free_kv_blocks() + e.prefix_cached_blocks(), 64);
        }
        let reg = fleet.fleet_registry();
        assert!(reg.get("flashmla_fleet_replications_total").is_some());
    }

    #[test]
    fn fleet_is_deterministic_across_identical_runs() {
        let drive = || -> (Vec<(usize, StepEvent)>, Vec<usize>) {
            let mut fleet = FleetExecutor::reference(model(), fleet_cfg(2)).unwrap();
            let mut placed = Vec::new();
            let mut evs = Vec::new();
            for (i, (s, u)) in [(1, 1), (2, 2), (1, 3), (2, 4), (1, 5)].iter().enumerate() {
                let h = fleet
                    .submit(GenerationRequest::new(prompt(*s, *u), 3 + i % 2))
                    .unwrap();
                placed.push(h.engine());
                fleet.step().unwrap();
            }
            fleet.run_until_idle().unwrap();
            for ev in fleet.poll_events() {
                evs.push((ev.engine, ev.event));
            }
            (evs, placed)
        };
        let (ev_a, place_a) = drive();
        let (ev_b, place_b) = drive();
        assert_eq!(place_a, place_b, "routing is reproducible");
        assert_eq!(ev_a, ev_b, "event streams are reproducible");
    }

    #[test]
    fn merged_metrics_sum_engine_totals() {
        let mut fleet = FleetExecutor::reference(model(), fleet_cfg(2)).unwrap();
        for s in 0..4 {
            fleet
                .submit(GenerationRequest::new(prompt(s, s), 3))
                .unwrap();
        }
        fleet.run_until_idle().unwrap();
        let merged = fleet.merged_metrics();
        let per_engine: u64 = (0..fleet.engines())
            .map(|w| fleet.engine(w).metrics().requests_finished)
            .sum();
        assert_eq!(merged.requests_finished, per_engine);
        assert_eq!(merged.requests_finished, 4);
        assert!(merged.tokens_generated >= 4 * 3);
    }
}
