//! Fast-path CPU kernel subsystem: blocked/vectorized attention and
//! GEMM primitives plus the dispatch layer that selects them.
//!
//! The paper's thesis is that kernel *restructuring* — not new math —
//! recovers the throughput the hardware already offers (ETAP aligns KV
//! with the WGMMA M dimension on H20).  This module applies the same
//! discipline to the repo's CPU execution substrate:
//!
//! * [`simd`] — fixed-order 8-lane primitives (`dot8`, `axpy8`,
//!   `matvec8`), portable-SIMD-style on stable Rust.
//! * [`attn`] — the blocked/tiled attention family
//!   (`naive8 | blocked | blocked_parallel`), bitwise-identical to each
//!   other at every block size and thread count.
//! * [`KernelDispatch`] — runtime selection via `[engine.kernels]`
//!   config; the reference backend asks it for the execution mode and
//!   the slot-parallelism pool, benches and the coordinator's fallback
//!   ask it for whole attention calls.
//!
//! ## Determinism contract (docs/attention-kernels.md)
//!
//! Engine outputs are **bit-identical across every dispatch mode**.
//! `naive` keeps the seed backend's sequential scalar order; `blocked`
//! re-tiles the same arithmetic without reordering any f32 reduction;
//! `blocked_parallel` adds slot-level parallelism, which the slot
//! isolation contract makes bitwise-invisible.  The deep 8-lane
//! vectorization lives in [`attn`] at the paper shape, where
//! `benches/attention_cpu.rs` measures it; it uses a *different* (fixed,
//! documented) reduction order than the scalar baseline, so it is
//! tolerance-compared against `attention::naive_f32` and bitwise-compared
//! only within its own family.

pub mod attn;
pub mod simd;

use std::sync::Arc;

use crate::attention::{self, AttnShape};
use crate::util::threadpool::ThreadPool;

/// Which execution path the dispatcher routes to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Seed behavior: sequential scalar loops, slot-by-slot.
    Naive,
    /// KV-tiled, bounds-check-free loops; still single-threaded.
    Blocked,
    /// `Blocked` per slot, slots fanned out over a [`ThreadPool`].
    BlockedParallel,
}

impl KernelMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "naive" => Ok(KernelMode::Naive),
            "blocked" => Ok(KernelMode::Blocked),
            "blocked_parallel" => Ok(KernelMode::BlockedParallel),
            other => anyhow::bail!(
                "unknown kernels.mode {other:?} (naive | blocked | blocked_parallel)"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelMode::Naive => "naive",
            KernelMode::Blocked => "blocked",
            KernelMode::BlockedParallel => "blocked_parallel",
        }
    }
}

/// `[engine.kernels]` — fast-path selection knobs.
#[derive(Clone, Debug)]
pub struct KernelConfig {
    pub mode: KernelMode,
    /// Worker threads for `blocked_parallel` (0 = autodetect, capped).
    pub threads: usize,
    /// KV rows per tile in the blocked kernels.
    pub block_kv: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            mode: KernelMode::Naive,
            threads: 0,
            block_kv: 64,
        }
    }
}

impl KernelConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.block_kv >= 1, "kernels.block_kv must be >= 1");
        anyhow::ensure!(
            self.threads <= 64,
            "kernels.threads {} is implausible (max 64, 0 = auto)",
            self.threads
        );
        Ok(())
    }
}

/// Runtime kernel selector.  Built once per engine (or per bench) from a
/// validated [`KernelConfig`]; owns the slot-parallelism pool so worker
/// threads are spawned once, not per tick.
pub struct KernelDispatch {
    cfg: KernelConfig,
    pool: Option<ThreadPool>,
}

impl KernelDispatch {
    pub fn new(cfg: KernelConfig) -> anyhow::Result<Arc<Self>> {
        cfg.validate()?;
        let pool = match cfg.mode {
            KernelMode::BlockedParallel => {
                Some(ThreadPool::new(attn::resolve_threads(cfg.threads)))
            }
            _ => None,
        };
        Ok(Arc::new(KernelDispatch { cfg, pool }))
    }

    /// The seed-equivalent dispatcher (`naive`, no pool) — what
    /// `ReferenceModel::runner` uses so existing callers see the exact
    /// pre-fast-path behavior.
    pub fn naive() -> Arc<Self> {
        Self::new(KernelConfig::default()).expect("default kernel config is valid")
    }

    pub fn mode(&self) -> KernelMode {
        self.cfg.mode
    }

    pub fn block_kv(&self) -> usize {
        self.cfg.block_kv
    }

    pub fn threads(&self) -> usize {
        self.cfg.threads
    }

    /// The slot-parallelism pool — `Some` only in `blocked_parallel`
    /// mode, so sequential modes never pay for idle workers.
    pub fn pool(&self) -> Option<&ThreadPool> {
        self.pool.as_ref()
    }

    /// One whole-request attention call routed by mode: the scalar
    /// reference for `naive`, the 8-lane blocked family otherwise.
    pub fn attention(&self, shape: &AttnShape, q: &[f32], cache: &[f32], scale: f32) -> Vec<f32> {
        match self.cfg.mode {
            KernelMode::Naive => attention::naive_f32(shape, q, cache, scale),
            KernelMode::Blocked => attn::blocked_f32(shape, q, cache, scale, self.cfg.block_kv),
            KernelMode::BlockedParallel => attn::blocked_parallel_f32(
                shape,
                q,
                cache,
                scale,
                self.cfg.block_kv,
                self.cfg.threads,
            ),
        }
    }

    /// Decode-side GEMM fast path: sequential scalar rows in `naive`
    /// mode (seed order), [`simd::matvec8`] rows otherwise.
    pub fn matvec(&self, w: &[f32], x: &[f32], out: &mut [f32]) {
        match self.cfg.mode {
            KernelMode::Naive => {
                for (o, row) in out.iter_mut().zip(w.chunks_exact(x.len())) {
                    let mut acc = 0.0f32;
                    for (&wi, &xi) in row.iter().zip(x) {
                        acc += wi * xi;
                    }
                    *o = acc;
                }
            }
            KernelMode::Blocked | KernelMode::BlockedParallel => simd::matvec8(w, x, out),
        }
    }
}

impl std::fmt::Debug for KernelDispatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelDispatch")
            .field("cfg", &self.cfg)
            .field("pool", &self.pool.as_ref().map(ThreadPool::size))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mode_parsing_round_trips() {
        for mode in [
            KernelMode::Naive,
            KernelMode::Blocked,
            KernelMode::BlockedParallel,
        ] {
            assert_eq!(KernelMode::parse(mode.as_str()).unwrap(), mode);
        }
        assert!(KernelMode::parse("fast").is_err());
    }

    #[test]
    fn config_validation_rejects_bad_values() {
        let bad_block = KernelConfig {
            block_kv: 0,
            ..KernelConfig::default()
        };
        assert!(bad_block.validate().is_err());
        let bad_threads = KernelConfig {
            threads: 65,
            ..KernelConfig::default()
        };
        assert!(bad_threads.validate().is_err());
        assert!(KernelConfig::default().validate().is_ok());
    }

    #[test]
    fn pool_exists_only_for_parallel_mode() {
        let naive = KernelDispatch::naive();
        assert!(naive.pool().is_none());
        let par = KernelDispatch::new(KernelConfig {
            mode: KernelMode::BlockedParallel,
            threads: 2,
            block_kv: 32,
        })
        .unwrap();
        assert_eq!(par.pool().unwrap().size(), 2);
    }

    #[test]
    fn dispatch_attention_routes_all_modes_consistently() {
        let shape = AttnShape { h: 2, d: 16, dv: 8, n: 24 };
        let mut rng = Rng::new(5);
        let q = rng.normal_vec(shape.q_len());
        let cache = rng.normal_vec(shape.cache_len());
        let scale = 0.25f32;
        let outs: Vec<Vec<f32>> = [
            KernelMode::Naive,
            KernelMode::Blocked,
            KernelMode::BlockedParallel,
        ]
        .into_iter()
        .map(|mode| {
            let d = KernelDispatch::new(KernelConfig {
                mode,
                threads: 2,
                block_kv: 7,
            })
            .unwrap();
            d.attention(&shape, &q, &cache, scale)
        })
        .collect();
        // Blocked family is bitwise-identical; naive agrees to tolerance.
        assert_eq!(
            outs[1].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            outs[2].iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        for (a, b) in outs[0].iter().zip(&outs[1]) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_modes_agree_to_tolerance() {
        let mut rng = Rng::new(6);
        let (rows, cols) = (12, 40);
        let w = rng.normal_vec(rows * cols);
        let x = rng.normal_vec(cols);
        let mut a = vec![0.0f32; rows];
        let mut b = vec![0.0f32; rows];
        KernelDispatch::naive().matvec(&w, &x, &mut a);
        KernelDispatch::new(KernelConfig {
            mode: KernelMode::Blocked,
            ..KernelConfig::default()
        })
        .unwrap()
        .matvec(&w, &x, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
