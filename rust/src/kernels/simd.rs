//! Fixed-order 8-lane vector primitives (portable-SIMD substitute).
//!
//! Stable Rust has no `std::simd`, so the fast-path kernels get their
//! vectorization the portable way: manually unrolled inner loops over
//! eight *independent* lane accumulators, which breaks the sequential
//! FP dependence chain (the thing that actually caps a scalar dot
//! product at ~1 FLOP per add-latency) and hands the autovectorizer a
//! shape it reliably turns into SSE/AVX/NEON code.
//!
//! ## The fixed-reduction-order contract
//!
//! Every primitive here commits to one bit-reproducible evaluation
//! order, documented per function.  This is what lets the kernel family
//! in [`super::attn`] promise *bitwise* parity between its sequential
//! and parallel variants (`docs/attention-kernels.md`): parallel
//! decompositions only ever reorder work whose FP result is
//! order-independent (disjoint elements, or merges of the associative
//! `max`), never the accumulations below.
//!
//! * [`dot8`]: lane `l` accumulates elements `l, l+8, l+16, …` in
//!   ascending order; lanes combine in the fixed tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`; the scalar tail (length
//!   `% 8`) is added last, ascending.  The result differs from a
//!   sequential scalar dot (different association) but is identical on
//!   every call, every thread count, every platform.
//! * [`axpy8`]: elementwise, so unrolling is rounding-neutral — the
//!   result is bit-identical to the textbook `y[i] += a * x[i]` loop.

/// Unroll width of the manual vector primitives.
pub const LANES: usize = 8;

/// Fixed-order 8-lane dot product.  See the module docs for the exact
/// reduction order; `a` and `b` must have equal length.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot8 operand lengths");
    let mut lanes = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for (acc, (&x, &y)) in lanes.iter_mut().zip(xa.iter().zip(xb)) {
            *acc += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    (((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])))
        + tail
}

/// `y += alpha * x`, unrolled 8 wide.  Elementwise, hence bit-identical
/// to the scalar loop — unrolling only changes *which* independent
/// elements are in flight, never how any one element rounds.
#[inline]
pub fn axpy8(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len(), "axpy8 operand lengths");
    let mut cy = y.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (ya, xa) in cy.by_ref().zip(cx.by_ref()) {
        for (o, &v) in ya.iter_mut().zip(xa) {
            *o += alpha * v;
        }
    }
    for (o, &v) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *o += alpha * v;
    }
}

/// Row-major matrix–vector product `out = W · x` with one [`dot8`] per
/// row — the decode-side GEMM fast path (decode GEMMs are matvecs per
/// token).  `w` is `[out.len() × x.len()]`.
pub fn matvec8(w: &[f32], x: &[f32], out: &mut [f32]) {
    assert!(!x.is_empty(), "matvec8 needs at least one column");
    debug_assert_eq!(w.len(), out.len() * x.len(), "matvec8 matrix shape");
    for (o, row) in out.iter_mut().zip(w.chunks_exact(x.len())) {
        *o = dot8(row, x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vecs(len: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(len), rng.normal_vec(len))
    }

    fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn dot8_matches_f64_oracle_at_every_tail_length() {
        for len in [0, 1, 7, 8, 9, 15, 16, 23, 64, 576, 577] {
            let (a, b) = vecs(len, 0xD0_7000 + len as u64);
            let got = dot8(&a, &b) as f64;
            let want = dot_f64(&a, &b);
            let tol = 1e-4 * (len.max(1) as f64).sqrt();
            assert!(
                (got - want).abs() <= tol,
                "len {len}: dot8 {got} vs f64 {want}"
            );
        }
    }

    #[test]
    fn dot8_is_bit_reproducible() {
        let (a, b) = vecs(576, 42);
        let first = dot8(&a, &b).to_bits();
        for _ in 0..8 {
            assert_eq!(dot8(&a, &b).to_bits(), first);
        }
    }

    #[test]
    fn dot8_short_inputs_equal_sequential_scalar() {
        // With fewer than LANES elements everything is tail: the fixed
        // order degenerates to the plain ascending scalar dot.
        let (a, b) = vecs(7, 9);
        let mut seq = 0.0f32;
        for (&x, &y) in a.iter().zip(&b) {
            seq += x * y;
        }
        assert_eq!(dot8(&a, &b).to_bits(), seq.to_bits());
    }

    #[test]
    fn axpy8_is_bitwise_the_scalar_loop() {
        for len in [0, 1, 7, 8, 9, 31, 512, 515] {
            let (x, y0) = vecs(len, 0xA9 + len as u64);
            let alpha = 0.37f32;
            let mut fast = y0.clone();
            axpy8(alpha, &x, &mut fast);
            let mut slow = y0.clone();
            for (o, &v) in slow.iter_mut().zip(&x) {
                *o += alpha * v;
            }
            assert_eq!(
                fast.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                slow.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "len {len}"
            );
        }
    }

    #[test]
    fn matvec8_matches_f64_oracle() {
        let rows = 33;
        let cols = 20;
        let mut rng = Rng::new(77);
        let w = rng.normal_vec(rows * cols);
        let x = rng.normal_vec(cols);
        let mut out = vec![0.0f32; rows];
        matvec8(&w, &x, &mut out);
        for (r, &o) in out.iter().enumerate() {
            let want = dot_f64(&w[r * cols..(r + 1) * cols], &x);
            assert!((o as f64 - want).abs() < 1e-4, "row {r}");
        }
    }
}
