//! Blocked, cache-tiled, 8-lane-vectorized f32 decode attention — the
//! CPU fast-path family behind [`super::KernelDispatch`].
//!
//! Three variants share one arithmetic skeleton and are **bitwise
//! identical** to each other by construction (the family parity that
//! `rust/tests/kernel_parity.rs` enforces):
//!
//! * [`naive8_f32`] — per-head, query-major, full-softmax order exactly
//!   mirroring [`crate::attention::naive_f32`], with the sequential
//!   scalar dot replaced by the fixed-order [`dot8`].  This is the
//!   family's readable baseline and its parity anchor.
//! * [`blocked_f32`] — KV-major ETAP blocking lifted from
//!   [`crate::attention::etap_f32`]: the KV tile is the outer loop, a
//!   materialized `S^T` (`[n × h]`) keeps heads on the inner column
//!   axis, and the per-*column* softmax max is merged tile-by-tile
//!   exactly as Algorithm 1 does.  Unlike the GPU kernel it defers the
//!   normalizer to a second sequential pass instead of rescaling the
//!   accumulator online: the online `r = exp(m_old − m_new)` rescale
//!   changes the FP reduction order, and the CPU family trades that
//!   last bit of fusion for a bitwise determinism contract
//!   (`docs/attention-kernels.md`).  The win over `naive8` is memory
//!   traffic: one streaming pass over the KV cache for scores and one
//!   for values, versus one of each *per head*.
//! * [`blocked_parallel_f32`] — the same passes decomposed across
//!   threads along axes whose FP result is order-independent: disjoint
//!   `S^T` row ranges in the score pass (per-column maxes merge by the
//!   associative `max`), disjoint value-dimension bands in the output
//!   pass (each `(head, v-dim)` accumulator lives entirely on one
//!   thread, ascending-`j` order preserved).  `std::thread::scope` is
//!   used rather than [`crate::util::threadpool::ThreadPool`] because
//!   scoped workers can borrow the multi-hundred-MB cache slice; the
//!   pool's `'static` jobs would have to copy it.
//!
//! Layouts follow [`AttnShape`]: `q [h × d]`, `cache [n × d]` (K = full
//! row, V = first `dv` dims), output `[h × dv]`.

use crate::attention::AttnShape;

use super::simd::{axpy8, dot8};

/// Per-head query-major attention with [`dot8`] scores — the family's
/// bitwise baseline (loop structure of [`crate::attention::naive_f32`]).
pub fn naive8_f32(shape: &AttnShape, q: &[f32], cache: &[f32], scale: f32) -> Vec<f32> {
    shape.validate(q, cache);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);
    let mut out = vec![0.0f32; h * dv];
    let mut scores = vec![0.0f32; n];
    for hi in 0..h {
        let qrow = &q[hi * d..(hi + 1) * d];
        let mut m = f32::NEG_INFINITY;
        for (j, s) in scores.iter_mut().enumerate() {
            *s = dot8(qrow, &cache[j * d..(j + 1) * d]) * scale;
            m = m.max(*s);
        }
        let mut l = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            l += *s;
        }
        let orow = &mut out[hi * dv..(hi + 1) * dv];
        for (j, &p) in scores.iter().enumerate() {
            axpy8(p / l, &cache[j * d..j * d + dv], orow);
        }
    }
    out
}

/// KV-major blocked fast path, single-threaded.  Bitwise equal to
/// [`naive8_f32`] (see the module docs for the order argument).
pub fn blocked_f32(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
) -> Vec<f32> {
    blocked_impl(shape, q, cache, scale, block_kv, 1)
}

/// KV-major blocked fast path across `threads` workers (0 = all
/// available cores, capped at 8).  Bitwise equal to [`blocked_f32`] at
/// every thread count.
pub fn blocked_parallel_f32(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
    threads: usize,
) -> Vec<f32> {
    blocked_impl(shape, q, cache, scale, block_kv, resolve_threads(threads))
}

/// 0 → autodetect (capped so tiny machines and huge ones behave alike).
pub(crate) fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8)
    }
}

fn blocked_impl(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
    threads: usize,
) -> Vec<f32> {
    shape.validate(q, cache);
    assert!(block_kv >= 1, "block_kv must be positive");
    assert!(threads >= 1);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);

    // Pass 1 — S^T [n × h]: KV-major score tiles, per-column max.
    // Parallel split: disjoint tile-aligned row ranges of S^T; each
    // worker's local column maxes fold in ascending-j order, and the
    // ascending cross-worker merge below equals the global ascending
    // fold because `max` is associative and commutative.
    let mut s_t = vec![0.0f32; n * h];
    let tiles = n.div_ceil(block_kv);
    let t1 = threads.min(tiles);
    let chunk_rows = tiles.div_ceil(t1) * block_kv;
    let worker_maxes: Vec<Vec<f32>> = if t1 == 1 {
        vec![score_rows(shape, q, cache, scale, block_kv, 0, &mut s_t)]
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = s_t
                .chunks_mut(chunk_rows * h)
                .enumerate()
                .map(|(w, rows)| {
                    scope.spawn(move || {
                        score_rows(shape, q, cache, scale, block_kv, w * chunk_rows, rows)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|hdl| hdl.join().expect("score worker panicked"))
                .collect()
        })
    };
    let mut m = vec![f32::NEG_INFINITY; h];
    for wm in &worker_maxes {
        for (mh, &x) in m.iter_mut().zip(wm) {
            *mh = mh.max(x);
        }
    }

    // Pass 2 — sequential: p = exp(s − m), column sums in ascending-j
    // order (the one reduction whose order the contract pins and f32
    // addition cannot reassociate, so it stays on one thread; it is
    // O(n·h) against the passes' O(n·h·d) — Amdahl-negligible).
    let mut l = vec![0.0f32; h];
    for srow in s_t.chunks_exact_mut(h) {
        for ((s, &mh), lh) in srow.iter_mut().zip(&m).zip(l.iter_mut()) {
            *s = (*s - mh).exp();
            *lh += *s;
        }
    }

    // Pass 3 — V^T · P accumulation over disjoint value-dim bands.
    // Every (head, v-dim) element accumulates ascending-j inside a
    // single worker, so the parallel split is bitwise-invisible; each
    // worker streams only its contiguous band of every cache row, so
    // total value traffic stays one pass.
    let t3 = threads.min(dv).max(1);
    let band = dv.div_ceil(t3).max(1);
    let bands: Vec<(usize, usize)> = (0..dv)
        .step_by(band)
        .map(|vd0| (vd0, band.min(dv - vd0)))
        .collect();
    let accs: Vec<Vec<f32>> = if bands.len() <= 1 {
        bands
            .iter()
            .map(|&(vd0, bw)| out_band(shape, cache, &s_t, &l, block_kv, vd0, bw))
            .collect()
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = bands
                .iter()
                .map(|&(vd0, bw)| {
                    let (s_t, l) = (&s_t, &l);
                    scope.spawn(move || out_band(shape, cache, s_t, l, block_kv, vd0, bw))
                })
                .collect();
            handles
                .into_iter()
                .map(|hdl| hdl.join().expect("output worker panicked"))
                .collect()
        })
    };

    // Epilogue: scatter the [h × band] accumulators into [h × dv] — a
    // pure copy, exact by definition (the ETAP final transpose, eq. 4).
    let mut out = vec![0.0f32; h * dv];
    for (&(vd0, bw), acc) in bands.iter().zip(&accs) {
        for hi in 0..h {
            out[hi * dv + vd0..hi * dv + vd0 + bw]
                .copy_from_slice(&acc[hi * bw..(hi + 1) * bw]);
        }
    }
    out
}

/// Pass-1 worker: fill `S^T` rows `j0 .. j0 + rows/h` tile by tile and
/// return this range's per-column maxes (ascending-j fold).
fn score_rows(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
    j0: usize,
    s_rows: &mut [f32],
) -> Vec<f32> {
    let (h, d) = (shape.h, shape.d);
    let rows = s_rows.len() / h;
    let mut m = vec![f32::NEG_INFINITY; h];
    let mut jj = 0;
    while jj < rows {
        let bc = block_kv.min(rows - jj);
        let tile = &mut s_rows[jj * h..(jj + bc) * h];
        for (r, srow) in tile.chunks_exact_mut(h).enumerate() {
            let j = j0 + jj + r;
            let krow = &cache[j * d..(j + 1) * d];
            for (hi, s) in srow.iter_mut().enumerate() {
                *s = dot8(&q[hi * d..(hi + 1) * d], krow) * scale;
            }
        }
        for srow in tile.chunks_exact(h) {
            for (mh, &s) in m.iter_mut().zip(srow) {
                *mh = mh.max(s);
            }
        }
        jj += bc;
    }
    m
}

/// Pass-3 worker: accumulate output columns `vd0 .. vd0 + bw` for every
/// head into a local `[h × bw]` block, ascending-j, tile by tile.
fn out_band(
    shape: &AttnShape,
    cache: &[f32],
    s_t: &[f32],
    l: &[f32],
    block_kv: usize,
    vd0: usize,
    bw: usize,
) -> Vec<f32> {
    let (h, d, n) = (shape.h, shape.d, shape.n);
    let mut acc = vec![0.0f32; h * bw];
    let mut j0 = 0;
    while j0 < n {
        let bc = block_kv.min(n - j0);
        for jj in 0..bc {
            let j = j0 + jj;
            let vrow = &cache[j * d + vd0..j * d + vd0 + bw];
            let srow = &s_t[j * h..(j + 1) * h];
            for (hi, (&p, &lh)) in srow.iter().zip(l).enumerate() {
                axpy8(p / lh, vrow, &mut acc[hi * bw..(hi + 1) * bw]);
            }
        }
        j0 += bc;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{naive_f32, naive_f64};
    use crate::util::rng::Rng;

    fn request(shape: &AttnShape, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            rng.normal_vec(shape.q_len()),
            rng.normal_vec(shape.cache_len()),
        )
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn family_is_bitwise_identical() {
        for (shape, seed) in [
            (AttnShape { h: 3, d: 24, dv: 16, n: 37 }, 1u64),
            (AttnShape { h: 4, d: 19, dv: 13, n: 64 }, 2), // non-multiple-of-8 dims
            (AttnShape { h: 1, d: 8, dv: 8, n: 1 }, 3),
            (AttnShape::paper(96), 4),
        ] {
            let (q, cache) = request(&shape, seed);
            let scale = 1.0 / (shape.d as f32).sqrt();
            let base = naive8_f32(&shape, &q, &cache, scale);
            for block_kv in [1, 7, 16, 1024] {
                let blk = blocked_f32(&shape, &q, &cache, scale, block_kv);
                assert_eq!(bits(&base), bits(&blk), "blocked bk={block_kv} {shape:?}");
                for threads in [2, 3, 5] {
                    let par =
                        blocked_parallel_f32(&shape, &q, &cache, scale, block_kv, threads);
                    assert_eq!(
                        bits(&base),
                        bits(&par),
                        "parallel bk={block_kv} t={threads} {shape:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn family_matches_scalar_naive_within_tolerance() {
        let shape = AttnShape::paper(128);
        let (q, cache) = request(&shape, 11);
        let scale = 1.0 / (shape.d as f32).sqrt();
        let want = naive_f32(&shape, &q, &cache, scale);
        let got = blocked_f32(&shape, &q, &cache, scale, 32);
        for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn family_tracks_f64_oracle() {
        let shape = AttnShape::paper(256);
        let (q, cache) = request(&shape, 13);
        let scale = 1.0 / (shape.d as f32).sqrt();
        let oracle = naive_f64(&shape, &q, &cache, scale as f64);
        let got = blocked_parallel_f32(&shape, &q, &cache, scale, 64, 3);
        let rmse = (got
            .iter()
            .zip(&oracle)
            .map(|(&a, &b)| (a as f64 - b).powi(2))
            .sum::<f64>()
            / oracle.len() as f64)
            .sqrt();
        assert!(rmse < 1e-5, "rmse vs f64 oracle: {rmse}");
    }

    #[test]
    fn thread_count_never_changes_bits() {
        let shape = AttnShape { h: 5, d: 40, dv: 24, n: 200 };
        let (q, cache) = request(&shape, 17);
        let one = blocked_parallel_f32(&shape, &q, &cache, 0.1, 16, 1);
        for threads in 2..=6 {
            let t = blocked_parallel_f32(&shape, &q, &cache, 0.1, 16, threads);
            assert_eq!(bits(&one), bits(&t), "threads {threads}");
        }
    }
}
