//! `bench_compare` — diff `BENCH_*.json` documents and render a Markdown
//! regression report (see `docs/benchmarking.md`).
//!
//! Modes:
//!
//! * `bench_compare <baseline.json> <current.json>` — align cases and
//!   metrics by name, print the report, exit 1 on a threshold breach.
//! * `bench_compare --trajectory BENCH_trajectory` — render the
//!   checked-in per-PR history as one table per scenario (informational;
//!   never gates).
//! * `bench_compare --validate <path>` — schema-check a `BENCH_*.json`
//!   file or a trajectory directory; exit 2 if anything is malformed.
//!   Trajectory entries with `commit: "pending"` get a stderr warning
//!   (not a failure) so a PR can land the placeholder and stamp it
//!   post-merge.
//! * `bench_compare --stamp-commit <entry.json> [--commit <sha>]` —
//!   replace a trajectory entry's `commit: "pending"` with the given
//!   sha (default: `git rev-parse --short HEAD`), preserving the file's
//!   formatting.  Refuses (exit 2) if the entry is already stamped.
//!
//! Exit codes: 0 clean, 1 threshold breach, 2 usage error or malformed
//! input.  Missing/new columns are never dropped silently — they get ⚠
//! rows (and gate only under `--fail-on-missing`).

use std::path::{Path, PathBuf};

use flashmla_etap::bench::{
    compare, parse_bench_doc, parse_trajectory_entry, trajectory_report, BenchDoc, Bencher,
    Thresholds, TrajectoryEntry,
};
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::json::parse_file;

fn main() {
    let p = ArgParser::new(
        "bench_compare",
        "diff BENCH_*.json documents and gate on regression thresholds",
    )
    .positional("baseline.json", "baseline bench document")
    .positional("current.json", "current bench document")
    .opt("tol-time", Some("2.0"), "max current/baseline wall-time ratio")
    .opt("tol-metric", Some("1.10"), "max worsening ratio for derived metrics")
    .opt("out", None, "write the Markdown report here (default: stdout)")
    .opt("trajectory", None, "render a trajectory directory instead of comparing")
    .opt("validate", None, "schema-check a bench file or trajectory directory")
    .opt("stamp-commit", None, "replace a trajectory entry's pending commit")
    .opt("commit", None, "sha for --stamp-commit (default: git HEAD)")
    .flag("fail-on-missing", "treat columns missing from current as breaches");
    let a = p.parse_or_exit();
    std::process::exit(run(&a));
}

fn run(a: &flashmla_etap::util::argparse::Args) -> i32 {
    if let Some(path) = a.get("stamp-commit") {
        return stamp_commit(Path::new(path), a.get("commit"));
    }
    if let Some(path) = a.get("validate") {
        return validate(Path::new(path));
    }
    if let Some(dir) = a.get("trajectory") {
        return trajectory(Path::new(dir), a.get("out"));
    }

    let pos = a.positionals();
    if pos.len() != 2 {
        eprintln!(
            "bench_compare: need exactly two positional files (baseline, current), \
             got {}; see --help",
            pos.len()
        );
        return 2;
    }
    let th = match thresholds(a) {
        Ok(th) => th,
        Err(msg) => {
            eprintln!("bench_compare: {msg}");
            return 2;
        }
    };
    let baseline = match load_doc(Path::new(&pos[0])) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            return 2;
        }
    };
    let current = match load_doc(Path::new(&pos[1])) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            return 2;
        }
    };
    let report = compare(&baseline, &current, &th);
    if emit(&report.markdown, a.get("out")).is_err() {
        return 2;
    }
    for b in &report.breaches {
        eprintln!("bench_compare: BREACH: {b}");
    }
    report.exit_code()
}

fn thresholds(a: &flashmla_etap::util::argparse::Args) -> Result<Thresholds, String> {
    Ok(Thresholds {
        time_ratio: a.get_f64("tol-time")?,
        metric_ratio: a.get_f64("tol-metric")?,
        fail_on_missing: a.has("fail-on-missing"),
    })
}

fn label_of(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string())
}

fn load_doc(path: &Path) -> anyhow::Result<BenchDoc> {
    let json = parse_file(path)?;
    parse_bench_doc(&label_of(path), &json)
}

/// Entry files in a trajectory directory, sorted by file name — entries
/// are named `NNNN_<commit>.json` so lexical order is chronological.
fn trajectory_files(dir: &Path) -> anyhow::Result<Vec<PathBuf>> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "json"))
        .collect();
    anyhow::ensure!(
        !files.is_empty(),
        "{}: no .json trajectory entries",
        dir.display()
    );
    files.sort();
    Ok(files)
}

fn load_trajectory(dir: &Path) -> anyhow::Result<Vec<TrajectoryEntry>> {
    let mut entries = Vec::new();
    for path in trajectory_files(dir)? {
        let json = parse_file(&path)?;
        entries.push(parse_trajectory_entry(&label_of(&path), &json)?);
    }
    Ok(entries)
}

fn trajectory(dir: &Path, out: Option<&str>) -> i32 {
    let entries = match load_trajectory(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            return 2;
        }
    };
    let md = trajectory_report(&entries);
    if emit(&md, out).is_err() {
        return 2;
    }
    0
}

/// Schema-check a single bench document, or every entry of a trajectory
/// directory.  Prints what passed; any malformed file is exit 2.
fn validate(path: &Path) -> i32 {
    let outcome: anyhow::Result<String> = if path.is_dir() {
        load_trajectory(path).map(|entries| {
            // A pending commit is a workflow state, not a schema error:
            // the entry lands with the PR and gets stamped post-merge
            // (`--stamp-commit`).  Warn so it isn't forgotten.
            for e in &entries {
                if e.commit == "pending" {
                    eprintln!(
                        "bench_compare: WARNING: trajectory entry `{}` has commit \
                         \"pending\" — stamp it with --stamp-commit",
                        e.label
                    );
                }
            }
            format!(
                "{}: {} trajectory entr{} valid",
                path.display(),
                entries.len(),
                if entries.len() == 1 { "y" } else { "ies" }
            )
        })
    } else {
        load_doc(path).map(|doc| {
            format!(
                "{}: bench `{}` valid ({} cases, {} metrics)",
                path.display(),
                doc.bench,
                doc.cases.len(),
                doc.metrics.len()
            )
        })
    };
    match outcome {
        Ok(msg) => {
            println!("{msg}");
            0
        }
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            2
        }
    }
}

/// `--stamp-commit`: rewrite a trajectory entry's `"commit": "pending"`
/// to a real sha.  The replacement is textual (the one `"pending"`
/// token after the `"commit"` key) so the checked-in file keeps its
/// hand formatting; the entry is schema-checked first so we never stamp
/// a malformed file.
fn stamp_commit(path: &Path, sha: Option<&str>) -> i32 {
    let entry = match parse_file(path).and_then(|j| parse_trajectory_entry(&label_of(path), &j)) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            return 2;
        }
    };
    if entry.commit != "pending" {
        eprintln!(
            "bench_compare: {}: commit is already `{}`, refusing to re-stamp",
            path.display(),
            entry.commit
        );
        return 2;
    }
    let sha = match sha {
        Some(s) if !s.is_empty() => s.to_string(),
        _ => {
            let head = Bencher::git_commit();
            if head == "unknown" {
                eprintln!(
                    "bench_compare: not in a git repo and no --commit given; cannot stamp"
                );
                return 2;
            }
            head
        }
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench_compare: cannot read {}: {e}", path.display());
            return 2;
        }
    };
    let Some(stamped) = replace_pending_commit(&text, &sha) else {
        eprintln!(
            "bench_compare: {}: could not locate `\"commit\": \"pending\"` textually",
            path.display()
        );
        return 2;
    };
    if std::fs::write(path, &stamped).is_err() {
        eprintln!("bench_compare: cannot write {}", path.display());
        return 2;
    }
    println!("{}: stamped commit `{sha}`", path.display());
    0
}

/// Replace the `"pending"` value of the top `"commit"` key in raw JSON
/// text, tolerating arbitrary whitespace around the colon.  Returns
/// `None` if the pattern isn't found (caller reports it).
fn replace_pending_commit(text: &str, sha: &str) -> Option<String> {
    let key_at = text.find("\"commit\"")?;
    let rest = &text[key_at + "\"commit\"".len()..];
    let after_ws = rest.trim_start();
    let after_colon = after_ws.strip_prefix(':')?.trim_start();
    if !after_colon.starts_with("\"pending\"") {
        return None;
    }
    // Byte offset of the `"pending"` token within `text`.
    let offset = text.len() - after_colon.len();
    let mut out = String::with_capacity(text.len());
    out.push_str(&text[..offset]);
    out.push('"');
    out.push_str(sha);
    out.push('"');
    out.push_str(&text[offset + "\"pending\"".len()..]);
    Some(out)
}

fn emit(markdown: &str, out: Option<&str>) -> Result<(), ()> {
    match out {
        Some(path) => std::fs::write(path, markdown).map_err(|e| {
            eprintln!("bench_compare: cannot write {path}: {e}");
        }),
        None => {
            print!("{markdown}");
            Ok(())
        }
    }
}
