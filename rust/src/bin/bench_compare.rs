//! `bench_compare` — diff `BENCH_*.json` documents and render a Markdown
//! regression report (see `docs/benchmarking.md`).
//!
//! Modes:
//!
//! * `bench_compare <baseline.json> <current.json>` — align cases and
//!   metrics by name, print the report, exit 1 on a threshold breach.
//! * `bench_compare --trajectory BENCH_trajectory` — render the
//!   checked-in per-PR history as one table per scenario (informational;
//!   never gates).
//! * `bench_compare --validate <path>` — schema-check a `BENCH_*.json`
//!   file or a trajectory directory; exit 2 if anything is malformed.
//!
//! Exit codes: 0 clean, 1 threshold breach, 2 usage error or malformed
//! input.  Missing/new columns are never dropped silently — they get ⚠
//! rows (and gate only under `--fail-on-missing`).

use std::path::{Path, PathBuf};

use flashmla_etap::bench::{
    compare, parse_bench_doc, parse_trajectory_entry, trajectory_report, BenchDoc, Thresholds,
    TrajectoryEntry,
};
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::json::parse_file;

fn main() {
    let p = ArgParser::new(
        "bench_compare",
        "diff BENCH_*.json documents and gate on regression thresholds",
    )
    .positional("baseline.json", "baseline bench document")
    .positional("current.json", "current bench document")
    .opt("tol-time", Some("2.0"), "max current/baseline wall-time ratio")
    .opt("tol-metric", Some("1.10"), "max worsening ratio for derived metrics")
    .opt("out", None, "write the Markdown report here (default: stdout)")
    .opt("trajectory", None, "render a trajectory directory instead of comparing")
    .opt("validate", None, "schema-check a bench file or trajectory directory")
    .flag("fail-on-missing", "treat columns missing from current as breaches");
    let a = p.parse_or_exit();
    std::process::exit(run(&a));
}

fn run(a: &flashmla_etap::util::argparse::Args) -> i32 {
    if let Some(path) = a.get("validate") {
        return validate(Path::new(path));
    }
    if let Some(dir) = a.get("trajectory") {
        return trajectory(Path::new(dir), a.get("out"));
    }

    let pos = a.positionals();
    if pos.len() != 2 {
        eprintln!(
            "bench_compare: need exactly two positional files (baseline, current), \
             got {}; see --help",
            pos.len()
        );
        return 2;
    }
    let th = match thresholds(a) {
        Ok(th) => th,
        Err(msg) => {
            eprintln!("bench_compare: {msg}");
            return 2;
        }
    };
    let baseline = match load_doc(Path::new(&pos[0])) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            return 2;
        }
    };
    let current = match load_doc(Path::new(&pos[1])) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            return 2;
        }
    };
    let report = compare(&baseline, &current, &th);
    if emit(&report.markdown, a.get("out")).is_err() {
        return 2;
    }
    for b in &report.breaches {
        eprintln!("bench_compare: BREACH: {b}");
    }
    report.exit_code()
}

fn thresholds(a: &flashmla_etap::util::argparse::Args) -> Result<Thresholds, String> {
    Ok(Thresholds {
        time_ratio: a.get_f64("tol-time")?,
        metric_ratio: a.get_f64("tol-metric")?,
        fail_on_missing: a.has("fail-on-missing"),
    })
}

fn label_of(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string())
}

fn load_doc(path: &Path) -> anyhow::Result<BenchDoc> {
    let json = parse_file(path)?;
    parse_bench_doc(&label_of(path), &json)
}

/// Entry files in a trajectory directory, sorted by file name — entries
/// are named `NNNN_<commit>.json` so lexical order is chronological.
fn trajectory_files(dir: &Path) -> anyhow::Result<Vec<PathBuf>> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "json"))
        .collect();
    anyhow::ensure!(
        !files.is_empty(),
        "{}: no .json trajectory entries",
        dir.display()
    );
    files.sort();
    Ok(files)
}

fn load_trajectory(dir: &Path) -> anyhow::Result<Vec<TrajectoryEntry>> {
    let mut entries = Vec::new();
    for path in trajectory_files(dir)? {
        let json = parse_file(&path)?;
        entries.push(parse_trajectory_entry(&label_of(&path), &json)?);
    }
    Ok(entries)
}

fn trajectory(dir: &Path, out: Option<&str>) -> i32 {
    let entries = match load_trajectory(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            return 2;
        }
    };
    let md = trajectory_report(&entries);
    if emit(&md, out).is_err() {
        return 2;
    }
    0
}

/// Schema-check a single bench document, or every entry of a trajectory
/// directory.  Prints what passed; any malformed file is exit 2.
fn validate(path: &Path) -> i32 {
    let outcome: anyhow::Result<String> = if path.is_dir() {
        load_trajectory(path).map(|entries| {
            format!(
                "{}: {} trajectory entr{} valid",
                path.display(),
                entries.len(),
                if entries.len() == 1 { "y" } else { "ies" }
            )
        })
    } else {
        load_doc(path).map(|doc| {
            format!(
                "{}: bench `{}` valid ({} cases, {} metrics)",
                path.display(),
                doc.bench,
                doc.cases.len(),
                doc.metrics.len()
            )
        })
    };
    match outcome {
        Ok(msg) => {
            println!("{msg}");
            0
        }
        Err(e) => {
            eprintln!("bench_compare: {e:#}");
            2
        }
    }
}

fn emit(markdown: &str, out: Option<&str>) -> Result<(), ()> {
    match out {
        Some(path) => std::fs::write(path, markdown).map_err(|e| {
            eprintln!("bench_compare: cannot write {path}: {e}");
        }),
        None => {
            print!("{markdown}");
            Ok(())
        }
    }
}
