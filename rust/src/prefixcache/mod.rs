//! Radix-tree prefix cache with copy-on-write block sharing.
//!
//! Serving workloads re-prefill the same prompt prefixes constantly: system
//! prompts, few-shot templates, multi-turn history.  MLA's compressed
//! latent cache (576 floats/token) makes cross-request sharing unusually
//! cheap, and the paged store already has the two primitives sharing needs
//! — per-block refcounts and copy-on-write appends.  This module adds the
//! missing piece: a radix tree over token-id prefixes whose nodes own
//! chains of physical [`BlockId`]s in the paged latent pool.
//!
//! Design (see `docs/prefix-cache.md`):
//!
//! * **Block granularity.**  Edges carry token runs that are exact
//!   multiples of `block_size`; matching proceeds block-by-block, so every
//!   edge split lands on a block boundary and a matched prefix maps 1:1
//!   onto a chain of whole physical blocks.
//! * **Ownership via refcounts.**  The tree holds one allocator reference
//!   per cached block (taken at [`PrefixTree::insert`]).  A hit adopts the
//!   chain into a fresh [`SeqId`] with
//!   [`PagedLatentCache::adopt_chain`], which takes the sequence's own
//!   references; divergence past the shared prefix is handled by the
//!   store's existing copy-on-write append.  Nothing is ever copied on the
//!   hit path.
//! * **LRU eviction.**  Under block-pool pressure the engine asks the tree
//!   to release leaves, oldest-access first.  Pressure eviction only takes
//!   *unreferenced* leaves (refcount 1 — the tree holds the last
//!   reference), so it always returns blocks to the free list; budget
//!   eviction (`max_blocks`) may also drop still-shared leaves to bound
//!   tree size.
//!
//! Related work: SGLang's RadixAttention and vLLM's prefix caching use the
//! same tree-of-blocks shape over a refcounted paged pool.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::kvcache::{BlockId, PagedLatentCache};
use crate::obs;

/// Counters the tree maintains; surfaced through `ServingMetrics`.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    /// `match_prefix` calls.
    pub lookups: u64,
    /// Lookups that matched at least one block.
    pub hits: u64,
    /// Tokens covered by matched prefixes (prefill work avoided).
    pub hit_tokens: u64,
    /// Physical blocks handed out to adopters across all hits.
    pub hit_blocks: u64,
    /// Blocks adopted into the tree by `insert`.
    pub inserted_blocks: u64,
    /// Blocks released by eviction.
    pub evicted_blocks: u64,
    /// Leaf nodes evicted.
    pub evictions: u64,
}

#[derive(Debug)]
struct Node {
    /// Token run on the edge into this node; always a multiple of
    /// `block_size` tokens (empty only for the root).
    key: Vec<i32>,
    /// Physical blocks covering `key` (`key.len() / block_size` of them).
    blocks: Vec<BlockId>,
    /// Children keyed by the first token of their edge.
    children: HashMap<i32, usize>,
    parent: usize,
    /// Logical timestamp of the last lookup/insert touching this node.
    last_access: u64,
}

/// Outcome of a prefix lookup.
#[derive(Clone, Debug, Default)]
pub struct PrefixMatch {
    /// Tokens covered (multiple of `block_size`).
    pub tokens: usize,
    /// The physical chain backing those tokens, in prefix order.  Pass to
    /// [`PagedLatentCache::adopt_chain`] to create a sequence over it.
    pub blocks: Vec<BlockId>,
}

struct Walk {
    matched_tokens: usize,
    blocks: Vec<BlockId>,
    /// Fully-entered nodes, in root→leaf order (root excluded).
    path: Vec<usize>,
    /// Edge matched only partially: (node, chunks matched).
    partial: Option<(usize, usize)>,
}

/// The radix tree.  One per engine; not thread-safe by itself (the engine
/// owns it behind its own synchronization, like the paged store).
pub struct PrefixTree {
    block_size: usize,
    /// Optional cap on blocks the tree may keep referenced.
    max_blocks: Option<usize>,
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    clock: u64,
    cached_blocks: usize,
    /// Lazy LRU min-heap of `(last_access, node)` snapshots.  Every
    /// recency bump pushes a fresh entry; [`PrefixTree::evict`] pops and
    /// discards entries whose snapshot no longer matches the node (stale
    /// bump, evicted slot, interior node).  Turns the old
    /// O(leaves)-per-victim scan into O(log n) amortized — the ROADMAP
    /// "eviction heap" item.  Snapshot pairs are unique because the clock
    /// advances on every tree operation, so a reused node slot can never
    /// collide with a stale entry.
    lru: BinaryHeap<Reverse<(u64, usize)>>,
    stats: PrefixStats,
}

const ROOT: usize = 0;

impl PrefixTree {
    pub fn new(block_size: usize, max_blocks: Option<usize>) -> Self {
        assert!(block_size > 0);
        PrefixTree {
            block_size,
            max_blocks,
            nodes: vec![Some(Node {
                key: Vec::new(),
                blocks: Vec::new(),
                children: HashMap::new(),
                parent: ROOT,
                last_access: 0,
            })],
            free_slots: Vec::new(),
            clock: 0,
            cached_blocks: 0,
            lru: BinaryHeap::new(),
            stats: PrefixStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Blocks currently referenced by the tree.
    pub fn cached_blocks(&self) -> usize {
        self.cached_blocks
    }

    /// Live nodes (excluding the root).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().flatten().count() - 1
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Heap entries currently held (tests assert compaction bounds this).
    #[cfg(test)]
    fn lru_len(&self) -> usize {
        self.lru.len()
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("dangling node index")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.nodes[i].as_mut().expect("dangling node index")
    }

    /// Set a node's recency and mirror it into the LRU heap (the heap is
    /// lazy: older snapshots for the same node become stale and are
    /// discarded at pop time).
    fn bump(&mut self, i: usize, clock: u64) {
        if i == ROOT {
            return;
        }
        self.node_mut(i).last_access = clock;
        self.lru.push(Reverse((clock, i)));
        self.maybe_compact_lru();
    }

    /// Bound the lazy heap: stale snapshots otherwise accumulate one per
    /// recency bump and are only drained by eviction, which may never run
    /// on an unpressured pool.  When the heap outgrows the node table by
    /// 4x, rebuild it from the live nodes' current recency — O(nodes),
    /// amortized O(1) per push, and memory stays O(peak nodes) instead of
    /// O(total lookups).
    fn maybe_compact_lru(&mut self) {
        if self.lru.len() <= 64 + 4 * self.nodes.len() {
            return;
        }
        self.lru.clear();
        for (i, slot) in self.nodes.iter().enumerate() {
            if i == ROOT {
                continue;
            }
            if let Some(n) = slot {
                self.lru.push(Reverse((n.last_access, i)));
            }
        }
    }

    fn alloc_node(&mut self, n: Node) -> usize {
        match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot] = Some(n);
                slot
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    /// Whole blocks of `key` matched against `tokens` (both from offset 0).
    fn chunks_matched(&self, key: &[i32], tokens: &[i32]) -> usize {
        let bs = self.block_size;
        let mut k = 0usize;
        while (k + 1) * bs <= key.len()
            && (k + 1) * bs <= tokens.len()
            && key[k * bs..(k + 1) * bs] == tokens[k * bs..(k + 1) * bs]
        {
            k += 1;
        }
        k
    }

    fn walk(&self, tokens: &[i32]) -> Walk {
        let bs = self.block_size;
        let mut node = ROOT;
        let mut pos = 0usize;
        let mut blocks = Vec::new();
        let mut path = Vec::new();
        let mut partial = None;
        while pos < tokens.len() {
            let Some(&child) = self.node(node).children.get(&tokens[pos]) else {
                break;
            };
            let k = self.chunks_matched(&self.node(child).key, &tokens[pos..]);
            if k == 0 {
                // First token matched but the first block differs: a
                // block-granularity tree cannot split inside a block.
                break;
            }
            blocks.extend_from_slice(&self.node(child).blocks[..k]);
            pos += k * bs;
            if k * bs == self.node(child).key.len() {
                path.push(child);
                node = child;
            } else {
                partial = Some((child, k));
                break;
            }
        }
        Walk {
            matched_tokens: pos,
            blocks,
            path,
            partial,
        }
    }

    /// Longest cached prefix of `tokens`, without touching LRU state or
    /// stats.  Used by admission control to charge only the unshared
    /// suffix.
    pub fn peek_match(&self, tokens: &[i32]) -> usize {
        self.walk(tokens).matched_tokens
    }

    /// Longest cached prefix of `tokens`; bumps LRU recency on the path
    /// and records hit statistics.  The returned chain stays owned by the
    /// tree — adopt it into a sequence before the next eviction.
    pub fn match_prefix(&mut self, tokens: &[i32]) -> PrefixMatch {
        let w = self.walk(tokens);
        self.clock += 1;
        let clock = self.clock;
        for i in 0..w.path.len() {
            let n = w.path[i];
            self.bump(n, clock);
        }
        if let Some((n, _)) = w.partial {
            self.bump(n, clock);
        }
        self.stats.lookups += 1;
        if w.matched_tokens > 0 {
            self.stats.hits += 1;
            self.stats.hit_tokens += w.matched_tokens as u64;
            self.stats.hit_blocks += w.blocks.len() as u64;
            obs::event_with("prefix", "hit", || {
                format!("tokens={} blocks={}", w.matched_tokens, w.blocks.len())
            });
        } else {
            obs::event("prefix", "miss");
        }
        PrefixMatch {
            tokens: w.matched_tokens,
            blocks: w.blocks,
        }
    }

    /// Insert the (block-aligned) prefix `tokens`, backed by `chain` — the
    /// first `tokens.len() / block_size` physical blocks of the sequence
    /// that just finished prefilling.  The tree takes its own reference on
    /// every block it adopts; fully-cached prefixes adopt nothing (dedup).
    /// Returns the number of blocks newly adopted.
    pub fn insert(
        &mut self,
        tokens: &[i32],
        chain: &[BlockId],
        cache: &mut PagedLatentCache,
    ) -> usize {
        let bs = self.block_size;
        assert!(
            tokens.len() % bs == 0,
            "insert of unaligned prefix ({} tokens, block {bs})",
            tokens.len()
        );
        assert!(
            chain.len() * bs >= tokens.len(),
            "chain too short: {} blocks for {} tokens",
            chain.len(),
            tokens.len()
        );
        if tokens.is_empty() {
            return 0;
        }
        let w = self.walk(tokens);
        self.clock += 1;
        let clock = self.clock;
        for i in 0..w.path.len() {
            let n = w.path[i];
            self.bump(n, clock);
        }
        if w.matched_tokens == tokens.len() {
            if let Some((n, _)) = w.partial {
                self.bump(n, clock);
            }
            return 0;
        }
        // Attach point: split a partially-matched edge at the block
        // boundary, otherwise hang off the deepest fully-entered node.
        let attach = match w.partial {
            Some((child, k)) => {
                let bs_off = k * bs;
                if self.node(child).key[bs_off] == tokens[w.matched_tokens] {
                    // First-block conflict under the same first token right
                    // after the split point: the existing entry wins (a
                    // block-granularity tree cannot split inside a block).
                    self.bump(child, clock);
                    return 0;
                }
                self.split_edge(child, k, clock)
            }
            None => {
                if self
                    .node(w.path.last().copied().unwrap_or(ROOT))
                    .children
                    .contains_key(&tokens[w.matched_tokens])
                {
                    // First-block conflict under the same first token: the
                    // existing entry wins (cannot split inside a block).
                    return 0;
                }
                w.path.last().copied().unwrap_or(ROOT)
            }
        };
        let start_block = w.matched_tokens / bs;
        let new_blocks: Vec<BlockId> = chain[start_block..tokens.len() / bs].to_vec();
        for &b in &new_blocks {
            cache.retain_block(b);
        }
        let adopted = new_blocks.len();
        self.cached_blocks += adopted;
        self.stats.inserted_blocks += adopted as u64;
        obs::event_with("prefix", "insert", || {
            format!("tokens={} blocks={adopted}", tokens.len() - w.matched_tokens)
        });
        let idx = self.alloc_node(Node {
            key: tokens[w.matched_tokens..].to_vec(),
            blocks: new_blocks,
            children: HashMap::new(),
            parent: attach,
            last_access: clock,
        });
        self.lru.push(Reverse((clock, idx)));
        self.node_mut(attach)
            .children
            .insert(tokens[w.matched_tokens], idx);
        if let Some(budget) = self.max_blocks {
            if self.cached_blocks > budget {
                let excess = self.cached_blocks - budget;
                self.evict(excess, cache, false);
            }
        }
        adopted
    }

    /// Split `child`'s edge after `k` whole blocks; returns the new
    /// intermediate node (which becomes the attach point).
    fn split_edge(&mut self, child: usize, k: usize, clock: u64) -> usize {
        let bs = self.block_size;
        let parent = self.node(child).parent;
        let key = self.node(child).key.clone();
        let blocks = self.node(child).blocks.clone();
        debug_assert!(k > 0 && k * bs < key.len());
        let mid = self.alloc_node(Node {
            key: key[..k * bs].to_vec(),
            blocks: blocks[..k].to_vec(),
            children: HashMap::from([(key[k * bs], child)]),
            parent,
            last_access: clock,
        });
        self.lru.push(Reverse((clock, mid)));
        {
            let c = self.node_mut(child);
            c.key = key[k * bs..].to_vec();
            c.blocks = blocks[k..].to_vec();
            c.parent = mid;
        }
        let first = key[0];
        self.node_mut(parent).children.insert(first, mid);
        mid
    }

    /// Release leaves, least-recently-used first, until at least
    /// `want_blocks` blocks have been dropped or no candidates remain.
    ///
    /// With `only_unreferenced` set (pool-pressure path), only leaves whose
    /// blocks the tree holds the *last* reference to are taken, so every
    /// released block goes straight back to the free list.  Without it
    /// (budget path), still-shared leaves may be dropped too; their blocks
    /// free later when the sharing sequences finish.  Returns the number of
    /// blocks released.
    pub fn evict(
        &mut self,
        want_blocks: usize,
        cache: &mut PagedLatentCache,
        only_unreferenced: bool,
    ) -> usize {
        let mut released = 0usize;
        // Leaves skipped because a live sequence still shares their blocks;
        // re-pushed after the round so later evictions reconsider them at
        // unchanged recency.
        let mut deferred: Vec<Reverse<(u64, usize)>> = Vec::new();
        while released < want_blocks {
            let Some(Reverse((clock, idx))) = self.lru.pop() else { break };
            // Lazy-deletion validity: the snapshot must still describe a
            // live leaf.  (A reused slot can't false-match: the clock is
            // strictly monotone, so a new occupant's last_access is newer
            // than any stale snapshot for that slot.)
            let valid = idx != ROOT
                && match &self.nodes[idx] {
                    Some(n) => n.last_access == clock && n.children.is_empty(),
                    None => false,
                };
            if !valid {
                continue;
            }
            if only_unreferenced
                && self
                    .node(idx)
                    .blocks
                    .iter()
                    .any(|&b| cache.block_refcount(b) > 1)
            {
                deferred.push(Reverse((clock, idx)));
                continue;
            }
            let node = self.nodes[idx].take().expect("validated above");
            self.free_slots.push(idx);
            let first = node.key[0];
            self.node_mut(node.parent).children.remove(&first);
            // Parent promotion: losing a child may turn the parent into a
            // leaf; give it a heap entry at its current recency so it is
            // reachable as a victim.  (Harmless duplicate if the parent
            // still has children — validity filtering drops it.)
            if node.parent != ROOT {
                let pa = self.node(node.parent).last_access;
                self.lru.push(Reverse((pa, node.parent)));
            }
            for &b in &node.blocks {
                cache.release_block(b);
            }
            released += node.blocks.len();
            self.cached_blocks -= node.blocks.len();
            self.stats.evicted_blocks += node.blocks.len() as u64;
            self.stats.evictions += 1;
        }
        self.lru.extend(deferred);
        if released > 0 {
            obs::event_with("prefix", "evict", || format!("blocks={released}"));
        }
        released
    }

    /// The pre-heap victim selection — a full scan of all leaves per
    /// victim — kept verbatim as the test oracle: the heap path must evict
    /// the exact same victims in the exact same order.
    #[cfg(test)]
    fn evict_scan(
        &mut self,
        want_blocks: usize,
        cache: &mut PagedLatentCache,
        only_unreferenced: bool,
    ) -> usize {
        let mut released = 0usize;
        while released < want_blocks {
            let mut victim: Option<(u64, usize)> = None;
            for (i, slot) in self.nodes.iter().enumerate() {
                let Some(n) = slot else { continue };
                if i == ROOT || !n.children.is_empty() {
                    continue;
                }
                if only_unreferenced
                    && n.blocks.iter().any(|&b| cache.block_refcount(b) > 1)
                {
                    continue;
                }
                match victim {
                    Some((t, _)) if n.last_access >= t => {}
                    _ => victim = Some((n.last_access, i)),
                }
            }
            let Some((_, idx)) = victim else { break };
            let node = self.nodes[idx].take().expect("victim exists");
            self.free_slots.push(idx);
            let first = node.key[0];
            self.node_mut(node.parent).children.remove(&first);
            for &b in &node.blocks {
                cache.release_block(b);
            }
            released += node.blocks.len();
            self.cached_blocks -= node.blocks.len();
            self.stats.evicted_blocks += node.blocks.len() as u64;
            self.stats.evictions += 1;
        }
        released
    }

    /// Release every block the tree holds (shutdown / tests).
    pub fn clear(&mut self, cache: &mut PagedLatentCache) {
        for slot in self.nodes.iter_mut().skip(1) {
            if let Some(n) = slot.take() {
                for &b in &n.blocks {
                    cache.release_block(b);
                }
            }
        }
        self.nodes.truncate(1);
        self.free_slots.clear();
        self.lru.clear();
        self.node_mut(ROOT).children.clear();
        self.cached_blocks = 0;
    }

    /// Largest block-aligned prefix length strictly shorter than `len`.
    ///
    /// Admission caps matches with this so at least one prefill step always
    /// runs: the decode contract emits the first generated token from the
    /// last prompt token's logits, which the cache does not store.
    pub fn usable_prefix_len(&self, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        ((len - 1) / self.block_size) * self.block_size
    }

    /// Cross-tree replication read: the longest cached block-aligned
    /// prefix of `tokens` together with its block chain, **without**
    /// touching LRU recency or hit statistics — a fleet replication read
    /// is bookkeeping, not a request lookup.  The chain stays owned by
    /// the tree; callers that need the latent data adopt it into a
    /// temporary sequence (`PagedLatentCache::adopt_chain`) for the
    /// duration of the copy.
    pub fn peek_chain(&self, tokens: &[i32]) -> PrefixMatch {
        let w = self.walk(tokens);
        PrefixMatch {
            tokens: w.matched_tokens,
            blocks: w.blocks,
        }
    }
}

/// Cross-tree replication entry point (fleet serving): materialize a
/// prefix chain exported from another engine's tree into `cache` and
/// insert it into `tree`.
///
/// `latents` is the donor's flat per-token latent data —
/// `tokens.len() × latent_dim` values, exactly what
/// `PagedLatentCache::token_latent` yields position by position.  Block
/// ids are store-local, so replication copies data rather than sharing
/// refcounts: the target tree ends up owning an independent refcounted
/// chain, and donor-side eviction can never invalidate it (the
/// `replicated_chain_survives_*` tests pin this).
///
/// Best-effort by design — returns the number of blocks newly adopted,
/// and 0 (without touching the pool) when the prefix is unaligned or
/// empty, already fully cached, or the pool lacks free blocks for the
/// copy: replication must never starve admission.
pub fn replicate_chain(
    tree: &mut PrefixTree,
    cache: &mut PagedLatentCache,
    tokens: &[i32],
    latents: &[f32],
) -> usize {
    let bs = tree.block_size();
    let ld = cache.config().latent_dim;
    if tokens.is_empty() || tokens.len() % bs != 0 {
        return 0;
    }
    assert_eq!(
        latents.len(),
        tokens.len() * ld,
        "replicated latents must cover every token exactly"
    );
    // Dedup before paying for the copy: a fully-cached prefix would adopt
    // nothing, so don't burn pool blocks appending one.
    if tree.peek_match(tokens) == tokens.len() {
        return 0;
    }
    if cache.free_blocks() * bs < tokens.len() {
        return 0;
    }
    let seq = cache.new_seq();
    for latent in latents.chunks(ld) {
        if cache.append(seq, latent).is_err() {
            cache.free_seq(seq);
            return 0;
        }
    }
    let chain = cache.blocks_of(seq).to_vec();
    let adopted = tree.insert(tokens, &chain, cache);
    cache.free_seq(seq);
    adopted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::prop_assert;
    use crate::testing::{forall, Config};

    const BS: usize = 4;

    fn cache(blocks: usize) -> PagedLatentCache {
        PagedLatentCache::new(CacheConfig {
            block_size: BS,
            latent_dim: 2,
            num_blocks: blocks,
        })
    }

    /// Build a sequence holding `tokens.len()` latents tagged by token id.
    fn seed_seq(c: &mut PagedLatentCache, tokens: &[i32]) -> crate::kvcache::SeqId {
        let s = c.new_seq();
        for &t in tokens {
            c.append(s, &[t as f32, 0.5]).unwrap();
        }
        s
    }

    fn insert_prompt(tree: &mut PrefixTree, c: &mut PagedLatentCache, tokens: &[i32]) {
        let aligned = (tokens.len() / BS) * BS;
        let s = seed_seq(c, tokens);
        let chain = c.blocks_of(s).to_vec();
        tree.insert(&tokens[..aligned], &chain[..aligned / BS], c);
        c.free_seq(s);
    }

    fn toks(spec: &[(i32, usize)]) -> Vec<i32> {
        let mut v = Vec::new();
        for &(t, n) in spec {
            v.extend(std::iter::repeat(t).take(n));
        }
        v
    }

    #[test]
    fn miss_on_empty_tree() {
        let mut tree = PrefixTree::new(BS, None);
        let m = tree.match_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(m.tokens, 0);
        assert!(m.blocks.is_empty());
        assert_eq!(tree.stats().lookups, 1);
        assert_eq!(tree.stats().hits, 0);
    }

    #[test]
    fn insert_then_match_block_granularity() {
        let mut c = cache(16);
        let mut tree = PrefixTree::new(BS, None);
        let prompt = toks(&[(7, 10)]); // 10 tokens → 2 aligned blocks
        insert_prompt(&mut tree, &mut c, &prompt);
        assert_eq!(tree.cached_blocks(), 2);
        // Sequence freed but tree keeps the blocks alive.
        assert_eq!(16 - c.free_blocks(), 2);

        let m = tree.match_prefix(&prompt);
        assert_eq!(m.tokens, 8, "matches whole blocks only");
        assert_eq!(m.blocks.len(), 2);
        // Shorter and longer queries with the same prefix.
        assert_eq!(tree.peek_match(&toks(&[(7, 4)])), 4);
        assert_eq!(tree.peek_match(&toks(&[(7, 3)])), 0, "sub-block: no match");
        assert_eq!(tree.peek_match(&toks(&[(7, 64)])), 8);
        assert_eq!(tree.peek_match(&toks(&[(9, 8)])), 0);
    }

    #[test]
    fn adopted_chain_serves_latents() {
        let mut c = cache(16);
        let mut tree = PrefixTree::new(BS, None);
        let prompt: Vec<i32> = (100..108).collect();
        insert_prompt(&mut tree, &mut c, &prompt);
        let m = tree.match_prefix(&prompt);
        let s = c.adopt_chain(&m.blocks, m.tokens);
        assert_eq!(c.len(s), 8);
        for (t, &tok) in prompt.iter().enumerate() {
            assert_eq!(c.token_latent(s, t), [tok as f32, 0.5]);
        }
        c.free_seq(s);
    }

    #[test]
    fn edge_split_on_divergence() {
        let mut c = cache(32);
        let mut tree = PrefixTree::new(BS, None);
        // Two prompts sharing the first two blocks, diverging after.
        let a = toks(&[(1, 8), (2, 8)]);
        let b = toks(&[(1, 8), (3, 8)]);
        insert_prompt(&mut tree, &mut c, &a);
        insert_prompt(&mut tree, &mut c, &b);
        // Shared prefix stored once: 2 shared + 2 + 2 divergent.
        assert_eq!(tree.cached_blocks(), 6);
        assert_eq!(tree.node_count(), 3, "split produced an interior node");
        assert_eq!(tree.match_prefix(&a).tokens, 16);
        assert_eq!(tree.match_prefix(&b).tokens, 16);
        assert_eq!(tree.match_prefix(&toks(&[(1, 8), (4, 8)])).tokens, 8);
    }

    #[test]
    fn duplicate_insert_adopts_nothing() {
        let mut c = cache(16);
        let mut tree = PrefixTree::new(BS, None);
        let prompt = toks(&[(5, 8)]);
        insert_prompt(&mut tree, &mut c, &prompt);
        let used = 16 - c.free_blocks();
        insert_prompt(&mut tree, &mut c, &prompt);
        assert_eq!(tree.cached_blocks(), 2, "dedup");
        assert_eq!(16 - c.free_blocks(), used, "no extra blocks pinned");
    }

    #[test]
    fn lru_eviction_frees_unreferenced_leaves() {
        let mut c = cache(16);
        let mut tree = PrefixTree::new(BS, None);
        let old = toks(&[(1, 8)]);
        let newer = toks(&[(2, 8)]);
        insert_prompt(&mut tree, &mut c, &old);
        insert_prompt(&mut tree, &mut c, &newer);
        tree.match_prefix(&newer); // bump recency
        tree.match_prefix(&old);
        tree.match_prefix(&newer); // `newer` is most recent
        let freed = tree.evict(2, &mut c, true);
        assert_eq!(freed, 2);
        assert_eq!(tree.peek_match(&old), 0, "LRU victim was `old`");
        assert_eq!(tree.peek_match(&newer), 8);
        assert_eq!(c.free_blocks(), 16 - 2);
        assert_eq!(tree.stats().evictions, 1);
    }

    #[test]
    fn pressure_eviction_skips_shared_leaves() {
        let mut c = cache(16);
        let mut tree = PrefixTree::new(BS, None);
        let shared = toks(&[(1, 8)]);
        insert_prompt(&mut tree, &mut c, &shared);
        let m = tree.match_prefix(&shared);
        let live = c.adopt_chain(&m.blocks, m.tokens); // an active request
        assert_eq!(tree.evict(2, &mut c, true), 0, "leaf is referenced");
        assert_eq!(tree.peek_match(&shared), 8, "entry survives");
        c.free_seq(live);
        assert_eq!(tree.evict(2, &mut c, true), 2);
        assert_eq!(c.free_blocks(), 16);
    }

    #[test]
    fn interior_nodes_become_evictable_leaves() {
        let mut c = cache(32);
        let mut tree = PrefixTree::new(BS, None);
        insert_prompt(&mut tree, &mut c, &toks(&[(1, 8), (2, 8)]));
        insert_prompt(&mut tree, &mut c, &toks(&[(1, 8), (3, 8)]));
        // Evict everything: children first, then the interior node.
        let freed = tree.evict(6, &mut c, true);
        assert_eq!(freed, 6);
        assert_eq!(tree.node_count(), 0);
        assert_eq!(c.free_blocks(), 32);
    }

    #[test]
    fn max_blocks_budget_enforced_on_insert() {
        let mut c = cache(32);
        let mut tree = PrefixTree::new(BS, Some(4));
        insert_prompt(&mut tree, &mut c, &toks(&[(1, 8)]));
        insert_prompt(&mut tree, &mut c, &toks(&[(2, 8)]));
        insert_prompt(&mut tree, &mut c, &toks(&[(3, 8)]));
        assert!(tree.cached_blocks() <= 4, "budget respected");
        assert!(tree.stats().evicted_blocks >= 2);
    }

    #[test]
    fn usable_prefix_len_always_leaves_one_step() {
        let tree = PrefixTree::new(4, None);
        assert_eq!(tree.usable_prefix_len(0), 0);
        assert_eq!(tree.usable_prefix_len(1), 0);
        assert_eq!(tree.usable_prefix_len(4), 0);
        assert_eq!(tree.usable_prefix_len(5), 4);
        assert_eq!(tree.usable_prefix_len(8), 4);
        assert_eq!(tree.usable_prefix_len(9), 8);
    }

    #[test]
    fn property_match_is_longest_common_block_prefix() {
        // Against a shadow list of inserted prefixes, match length must be
        // the longest shared whole-block prefix with any inserted prompt,
        // and adopted chains must replay the right latents.
        forall(Config::default().cases(60), |g| {
            let mut c = cache(256);
            let mut tree = PrefixTree::new(BS, None);
            let mut inserted: Vec<Vec<i32>> = Vec::new();
            for _ in 0..g.usize(1..8) {
                let prompt = g.tokens(BS..8 * BS, 3);
                insert_prompt(&mut tree, &mut c, &prompt);
                inserted.push(prompt);
            }
            for _ in 0..g.usize(1..8) {
                let q = g.tokens(1..8 * BS, 3);
                let got = tree.peek_match(&q);
                let want = inserted
                    .iter()
                    .map(|p| {
                        let aligned = (p.len() / BS) * BS;
                        let mut k = 0;
                        while (k + 1) * BS <= aligned
                            && (k + 1) * BS <= q.len()
                            && p[k * BS..(k + 1) * BS] == q[k * BS..(k + 1) * BS]
                        {
                            k += 1;
                        }
                        k * BS
                    })
                    .max()
                    .unwrap_or(0);
                // A block-granularity tree can under-match when two inserted
                // prompts collide inside a first block (first-token equal,
                // block content different) — never over-match.
                prop_assert!(
                    got <= want,
                    "over-match: got {got}, longest common is {want}"
                );
                let m = tree.match_prefix(&q);
                prop_assert!(m.tokens == got, "peek vs match disagree");
                if m.tokens > 0 {
                    let s = c.adopt_chain(&m.blocks, m.tokens);
                    for t in 0..m.tokens {
                        prop_assert!(
                            c.token_latent(s, t) == [q[t] as f32, 0.5],
                            "wrong latent at {t}"
                        );
                    }
                    c.free_seq(s);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lru_heap_stays_bounded_under_hot_lookups() {
        // A hot cached prompt on an unpressured pool: millions of lookups
        // must not grow the lazy heap without bound (compaction rebuilds
        // it from live nodes once it outgrows the node table 4x).
        let mut c = cache(16);
        let mut tree = PrefixTree::new(BS, None);
        let hot = toks(&[(7, 8)]);
        insert_prompt(&mut tree, &mut c, &hot);
        for _ in 0..10_000 {
            tree.match_prefix(&hot);
        }
        let bound = 64 + 5 * tree.node_count().max(4);
        assert!(
            tree.lru_len() <= bound,
            "heap grew to {} entries for {} nodes",
            tree.lru_len(),
            tree.node_count()
        );
        // And eviction still works right after compaction churn.
        assert_eq!(tree.evict(2, &mut c, true), 2);
        assert_eq!(c.free_blocks(), 16);
    }

    #[test]
    fn heap_eviction_order_matches_scan_oracle() {
        // Deterministic scenario exercising recency bumps, edge splits,
        // interior-node promotion, and one-victim-at-a-time eviction: the
        // heap path must pick the identical victim sequence as the old
        // all-leaves scan.
        let build = |c: &mut PagedLatentCache| {
            let mut tree = PrefixTree::new(BS, None);
            insert_prompt(&mut tree, c, &toks(&[(1, 8), (2, 8)]));
            insert_prompt(&mut tree, c, &toks(&[(1, 8), (3, 8)]));
            insert_prompt(&mut tree, c, &toks(&[(4, 8)]));
            insert_prompt(&mut tree, c, &toks(&[(5, 12)]));
            tree.match_prefix(&toks(&[(4, 8)])); // bump the (4,…) leaf
            tree.match_prefix(&toks(&[(1, 8), (2, 8)]));
            tree
        };
        let mut c_heap = cache(64);
        let mut c_scan = cache(64);
        let mut heap = build(&mut c_heap);
        let mut scan = build(&mut c_scan);
        let probes: Vec<Vec<i32>> = vec![
            toks(&[(1, 8), (2, 8)]),
            toks(&[(1, 8), (3, 8)]),
            toks(&[(4, 8)]),
            toks(&[(5, 12)]),
        ];
        // Pin one leaf with a live adopted chain (mirrored in both caches)
        // and then bump every *other* prompt, leaving the pinned leaf as
        // the LRU candidate: each eviction round must pop it first, defer
        // it (refcount > 1) without losing it, and take the next-oldest
        // unreferenced leaf instead — exactly like the scan's filter.
        let pin = toks(&[(5, 12)]);
        let m_h = heap.match_prefix(&pin);
        let live_h = c_heap.adopt_chain(&m_h.blocks, m_h.tokens);
        let m_s = scan.match_prefix(&pin);
        let live_s = c_scan.adopt_chain(&m_s.blocks, m_s.tokens);
        for p in probes.iter().filter(|p| **p != pin) {
            heap.match_prefix(p);
            scan.match_prefix(p);
        }
        for round in 0..4 {
            let a = heap.evict(1, &mut c_heap, true);
            let b = scan.evict_scan(1, &mut c_scan, true);
            assert_eq!(a, b, "pinned round {round}: released diverge");
            assert_eq!(heap.peek_match(&pin), 12, "pinned leaf must survive");
            assert_eq!(scan.peek_match(&pin), 12);
            for p in &probes {
                assert_eq!(heap.peek_match(p), scan.peek_match(p), "round {round}");
            }
        }
        // Unpin; the deferred entry must still be reachable as a victim.
        c_heap.free_seq(live_h);
        c_scan.free_seq(live_s);
        // Evict one victim at a time until both trees are empty; after
        // every single eviction the observable state must agree.
        for round in 0..16 {
            let a = heap.evict(1, &mut c_heap, true);
            let b = scan.evict_scan(1, &mut c_scan, true);
            assert_eq!(a, b, "round {round}: released counts diverge");
            assert_eq!(
                heap.cached_blocks(),
                scan.cached_blocks(),
                "round {round}: cached blocks diverge"
            );
            assert_eq!(
                heap.node_count(),
                scan.node_count(),
                "round {round}: node counts diverge"
            );
            for p in &probes {
                assert_eq!(
                    heap.peek_match(p),
                    scan.peek_match(p),
                    "round {round}: surviving entries diverge on {p:?}"
                );
            }
            if a == 0 {
                break;
            }
        }
        assert_eq!(heap.node_count(), 0, "everything eventually evicted");
    }

    #[test]
    fn property_heap_eviction_order_matches_scan_oracle() {
        // Randomized mirror of the scenario above: identical op sequences
        // on two trees, then lock-step single-victim eviction (with random
        // extra inserts interleaved) must stay observably identical.
        forall(Config::default().cases(60), |g| {
            let mut c_heap = cache(256);
            let mut c_scan = cache(256);
            let mut heap = PrefixTree::new(BS, None);
            let mut scan = PrefixTree::new(BS, None);
            let mut prompts: Vec<Vec<i32>> = Vec::new();
            let mut op = |heap: &mut PrefixTree,
                          scan: &mut PrefixTree,
                          c_heap: &mut PagedLatentCache,
                          c_scan: &mut PagedLatentCache,
                          prompts: &mut Vec<Vec<i32>>,
                          p: Vec<i32>,
                          lookup: bool| {
                if lookup {
                    heap.match_prefix(&p);
                    scan.match_prefix(&p);
                } else {
                    insert_prompt(heap, c_heap, &p);
                    insert_prompt(scan, c_scan, &p);
                    prompts.push(p);
                }
            };
            for _ in 0..g.usize(2..10) {
                let p = g.tokens(BS..6 * BS, 3);
                op(&mut heap, &mut scan, &mut c_heap, &mut c_scan, &mut prompts, p, false);
            }
            for _ in 0..g.usize(0..8) {
                let p = if g.bool() && !prompts.is_empty() {
                    g.choose(&prompts).clone()
                } else {
                    g.tokens(1..6 * BS, 3)
                };
                op(&mut heap, &mut scan, &mut c_heap, &mut c_scan, &mut prompts, p, true);
            }
            let mut guard = 0;
            loop {
                guard += 1;
                prop_assert!(guard < 1000, "eviction failed to drain");
                let a = heap.evict(1, &mut c_heap, true);
                let b = scan.evict_scan(1, &mut c_scan, true);
                prop_assert!(a == b, "released diverge: {a} vs {b}");
                prop_assert!(
                    heap.cached_blocks() == scan.cached_blocks(),
                    "cached blocks diverge"
                );
                for p in &prompts {
                    prop_assert!(
                        heap.peek_match(p) == scan.peek_match(p),
                        "survivors diverge on {p:?}"
                    );
                }
                if a == 0 {
                    break;
                }
                // Occasionally insert mid-drain to exercise heap staleness.
                if guard % 3 == 0 {
                    let p = g.tokens(BS..4 * BS, 3);
                    op(&mut heap, &mut scan, &mut c_heap, &mut c_scan, &mut prompts, p, false);
                }
            }
            prop_assert!(heap.node_count() == scan.node_count());
            prop_assert!(c_heap.free_blocks() == c_scan.free_blocks());
            Ok(())
        });
    }

    #[test]
    fn property_eviction_restores_all_blocks() {
        // Insert random prompts, evict everything: the pool must return to
        // fully free, and the tree to empty.
        forall(Config::default().cases(40), |g| {
            let mut c = cache(256);
            let mut tree = PrefixTree::new(BS, None);
            for _ in 0..g.usize(1..10) {
                let prompt = g.tokens(BS..10 * BS, 4);
                insert_prompt(&mut tree, &mut c, &prompt);
            }
            let held = tree.cached_blocks();
            prop_assert!(256 - c.free_blocks() == held, "tree is sole owner");
            let freed = tree.evict(usize::MAX, &mut c, true);
            prop_assert!(freed == held, "freed {freed} of {held}");
            prop_assert!(c.free_blocks() == 256);
            prop_assert!(tree.node_count() == 0);
            Ok(())
        });
    }

    /// Donor-side export for the replication tests: peek the chain and
    /// copy its latents out through a temporary adoption, exactly the
    /// engine's `export_prefix_latents` idiom.
    fn export_latents(tree: &PrefixTree, c: &mut PagedLatentCache, tokens: &[i32]) -> Vec<f32> {
        let m = tree.peek_chain(tokens);
        assert_eq!(m.tokens, tokens.len(), "export expects a full match");
        let s = c.adopt_chain(&m.blocks, m.tokens);
        let mut out = Vec::new();
        for pos in 0..m.tokens {
            out.extend_from_slice(c.token_latent(s, pos));
        }
        c.free_seq(s);
        out
    }

    #[test]
    fn peek_chain_matches_without_lru_or_stats() {
        let mut c = cache(16);
        let mut tree = PrefixTree::new(BS, None);
        let prompt = toks(&[(7, 8)]);
        insert_prompt(&mut tree, &mut c, &prompt);
        let lookups_before = tree.stats().lookups;
        let m = tree.peek_chain(&prompt);
        assert_eq!(m.tokens, 8);
        assert_eq!(m.blocks.len(), 2);
        assert_eq!(tree.stats().lookups, lookups_before, "not a request lookup");
        assert_eq!(tree.stats().hits, 0);
    }

    #[test]
    fn replicate_chain_copies_into_second_tree() {
        let mut c_a = cache(16);
        let mut tree_a = PrefixTree::new(BS, None);
        let mut c_b = cache(16);
        let mut tree_b = PrefixTree::new(BS, None);
        let prompt: Vec<i32> = (100..108).collect();
        insert_prompt(&mut tree_a, &mut c_a, &prompt);

        let latents = export_latents(&tree_a, &mut c_a, &prompt);
        let adopted = replicate_chain(&mut tree_b, &mut c_b, &prompt, &latents);
        assert_eq!(adopted, 2);
        assert_eq!(tree_b.cached_blocks(), 2);

        // The replica serves the same latent data through B's own store.
        let m = tree_b.match_prefix(&prompt);
        assert_eq!(m.tokens, 8);
        let s = c_b.adopt_chain(&m.blocks, m.tokens);
        for (t, &tok) in prompt.iter().enumerate() {
            assert_eq!(c_b.token_latent(s, t), [tok as f32, 0.5]);
        }
        c_b.free_seq(s);
        // Donor state untouched by the export (no stats, same blocks).
        assert_eq!(tree_a.cached_blocks(), 2);
        assert_eq!(16 - c_a.free_blocks(), 2);
    }

    #[test]
    fn replicate_chain_is_best_effort() {
        let mut c_a = cache(16);
        let mut tree_a = PrefixTree::new(BS, None);
        let prompt: Vec<i32> = (50..58).collect();
        insert_prompt(&mut tree_a, &mut c_a, &prompt);
        let latents = export_latents(&tree_a, &mut c_a, &prompt);

        // Unaligned prefix: refused outright.
        let mut c_b = cache(16);
        let mut tree_b = PrefixTree::new(BS, None);
        assert_eq!(
            replicate_chain(&mut tree_b, &mut c_b, &prompt[..6], &latents[..12]),
            0
        );
        // Pool too small for the copy: refused without touching it.
        let mut c_tiny = cache(1);
        let free_before = c_tiny.free_blocks();
        assert_eq!(replicate_chain(&mut tree_b, &mut c_tiny, &prompt, &latents), 0);
        assert_eq!(c_tiny.free_blocks(), free_before);
        // Happy path, then dedup: the second replication adopts nothing
        // and releases its temporary copy.
        assert_eq!(replicate_chain(&mut tree_b, &mut c_b, &prompt, &latents), 2);
        let free_after_first = c_b.free_blocks();
        assert_eq!(replicate_chain(&mut tree_b, &mut c_b, &prompt, &latents), 0);
        assert_eq!(c_b.free_blocks(), free_after_first, "dedup leaks nothing");
    }

    #[test]
    fn property_replicated_chain_survives_donor_eviction() {
        // The replication refcount property: replicating a chain from tree
        // A to tree B creates fully independent refcounts, so evicting the
        // chain on either side leaves the other side's copy intact and
        // still serving the exact latents — and dropping both returns both
        // pools to fully free.
        forall(Config::default().cases(40), |g| {
            let mut c_a = cache(64);
            let mut tree_a = PrefixTree::new(BS, None);
            let mut c_b = cache(64);
            let mut tree_b = PrefixTree::new(BS, None);
            let mut replicated: Vec<Vec<i32>> = Vec::new();
            for _ in 0..g.usize(1..6) {
                let prompt = g.tokens(BS..8 * BS, 4);
                let aligned = (prompt.len() / BS) * BS;
                if aligned == 0 {
                    continue;
                }
                insert_prompt(&mut tree_a, &mut c_a, &prompt);
                let head = prompt[..aligned].to_vec();
                // The tree may have matched a shorter aligned head if an
                // earlier prompt shares blocks; export what it holds.
                let held = tree_a.peek_chain(&head).tokens;
                if held == 0 {
                    continue;
                }
                let latents = export_latents(&tree_a, &mut c_a, &head[..held]);
                replicate_chain(&mut tree_b, &mut c_b, &head[..held], &latents);
                replicated.push(head[..held].to_vec());
            }
            let evict_a_first = g.bool();
            let (first_tree, first_cache, survivor_tree, survivor_cache) = if evict_a_first {
                (&mut tree_a, &mut c_a, &mut tree_b, &mut c_b)
            } else {
                (&mut tree_b, &mut c_b, &mut tree_a, &mut c_a)
            };
            first_tree.evict(usize::MAX, first_cache, true);
            prop_assert!(first_tree.cached_blocks() == 0, "evicted side drained");
            prop_assert!(first_cache.free_blocks() == 64, "evicted pool fully free");
            for p in &replicated {
                let m = survivor_tree.peek_chain(p);
                prop_assert!(
                    m.tokens == p.len(),
                    "survivor lost a replicated chain ({} of {} tokens)",
                    m.tokens,
                    p.len()
                );
                let s = survivor_cache.adopt_chain(&m.blocks, m.tokens);
                for (t, &tok) in p.iter().enumerate() {
                    let got = survivor_cache.token_latent(s, t);
                    prop_assert!(
                        got == [tok as f32, 0.5],
                        "latent diverged at {t}: {got:?}"
                    );
                }
                survivor_cache.free_seq(s);
            }
            survivor_tree.evict(usize::MAX, survivor_cache, true);
            prop_assert!(survivor_cache.free_blocks() == 64, "no leaked refcounts");
            Ok(())
        });
    }
}
