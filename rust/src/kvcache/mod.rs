//! Paged latent-KV cache manager.
//!
//! MLA's low-rank joint compression means the per-token cache entry is one
//! `latent_dim`-vector (512 c_kv + 64 rope = 576 for DeepSeek-R1) shared
//! by K and V — this is what makes single-server deployment of a 671B
//! model feasible at all, and what the coordinator manages here.
//!
//! Design follows vLLM's PagedAttention bookkeeping, specialized to the
//! latent layout:
//!
//! * fixed-size blocks of `block_size` token latents, owned by a free-list
//!   allocator with per-block reference counts;
//! * sequences hold block tables; forking a sequence (prefix sharing for
//!   beam/parallel sampling) bumps refcounts — copy-on-write on append;
//! * `gather_padded` materializes the contiguous `[n_bucket × latent]`
//!   tensor the AOT attention artifacts consume.

pub mod allocator;
pub mod paged;

pub use allocator::{AllocError, BlockAllocator, BlockId};
pub use paged::{CacheConfig, PagedLatentCache, SeqId};
