//! Reference-counted block allocator (free list).

/// Index of a physical cache block.
pub type BlockId = u32;

/// Allocation failure.
#[derive(Debug, PartialEq)]
pub enum AllocError {
    OutOfBlocks { capacity: usize },
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::OutOfBlocks { capacity } => {
                write!(f, "out of cache blocks ({capacity} total, all in use)")
            }
        }
    }
}

impl std::error::Error for AllocError {}

/// Free-list allocator with per-block refcounts.
#[derive(Debug)]
pub struct BlockAllocator {
    refcounts: Vec<u32>,
    free: Vec<BlockId>,
}

impl BlockAllocator {
    pub fn new(capacity: usize) -> Self {
        BlockAllocator {
            refcounts: vec![0; capacity],
            // LIFO free list: most-recently-freed first (cache-warm reuse).
            free: (0..capacity as BlockId).rev().collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.refcounts.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity() - self.free_blocks()
    }

    /// Allocate one block with refcount 1.
    pub fn alloc(&mut self) -> Result<BlockId, AllocError> {
        let id = self.free.pop().ok_or(AllocError::OutOfBlocks {
            capacity: self.capacity(),
        })?;
        debug_assert_eq!(self.refcounts[id as usize], 0);
        self.refcounts[id as usize] = 1;
        Ok(id)
    }

    /// Increment the refcount (prefix sharing).
    pub fn retain(&mut self, id: BlockId) {
        let rc = &mut self.refcounts[id as usize];
        assert!(*rc > 0, "retain of free block {id}");
        *rc += 1;
    }

    /// Decrement; returns the block to the free list at zero.
    pub fn release(&mut self, id: BlockId) {
        let rc = &mut self.refcounts[id as usize];
        assert!(*rc > 0, "release of free block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
        }
    }

    /// Current refcount (0 = free).
    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refcounts[id as usize]
    }

    /// Is the block exclusively owned? (copy-on-write test)
    pub fn is_exclusive(&self, id: BlockId) -> bool {
        self.refcounts[id as usize] == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Config};
    use crate::prop_assert;

    #[test]
    fn alloc_until_exhaustion() {
        let mut a = BlockAllocator::new(4);
        let ids: Vec<_> = (0..4).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(a.alloc(), Err(AllocError::OutOfBlocks { capacity: 4 }));
        // All distinct.
        let mut sorted = ids.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }

    #[test]
    fn release_recycles() {
        let mut a = BlockAllocator::new(2);
        let x = a.alloc().unwrap();
        let _y = a.alloc().unwrap();
        a.release(x);
        let z = a.alloc().unwrap();
        assert_eq!(z, x, "LIFO reuse");
    }

    #[test]
    fn refcounting_delays_free() {
        let mut a = BlockAllocator::new(1);
        let x = a.alloc().unwrap();
        a.retain(x);
        a.release(x);
        assert!(a.alloc().is_err(), "still retained");
        a.release(x);
        assert_eq!(a.alloc().unwrap(), x);
    }

    #[test]
    #[should_panic(expected = "release of free block")]
    fn double_release_panics() {
        let mut a = BlockAllocator::new(1);
        let x = a.alloc().unwrap();
        a.release(x);
        a.release(x);
    }

    #[test]
    fn property_never_double_allocates_and_conserves() {
        forall(Config::default().cases(200), |g| {
            let cap = g.usize(1..64);
            let mut a = BlockAllocator::new(cap);
            let mut live: Vec<BlockId> = Vec::new();
            for _ in 0..g.usize(1..200) {
                if g.bool() || live.is_empty() {
                    match a.alloc() {
                        Ok(id) => {
                            prop_assert!(
                                !live.contains(&id),
                                "double allocation of {id}"
                            );
                            live.push(id);
                        }
                        Err(_) => {
                            prop_assert!(
                                live.len() == cap,
                                "OOM with {} live of {cap}",
                                live.len()
                            );
                        }
                    }
                } else {
                    let idx = g.usize(0..live.len());
                    let id = live.swap_remove(idx);
                    a.release(id);
                }
                prop_assert!(
                    a.used_blocks() == live.len(),
                    "conservation: used {} vs live {}",
                    a.used_blocks(),
                    live.len()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_refcount_sharing() {
        forall(Config::default().cases(100), |g| {
            let mut a = BlockAllocator::new(8);
            let id = a.alloc().unwrap();
            let extra = g.usize(1..10);
            for _ in 0..extra {
                a.retain(id);
            }
            prop_assert!(a.refcount(id) == extra as u32 + 1);
            for i in 0..extra {
                a.release(id);
                prop_assert!(a.free_blocks() == 7, "freed too early at {i}");
            }
            a.release(id);
            prop_assert!(a.free_blocks() == 8);
            Ok(())
        });
    }
}
