//! Paged latent cache: block tables + the physical latent pool.

use std::collections::HashMap;

use super::allocator::{AllocError, BlockAllocator, BlockId};

/// Sequence handle.
pub type SeqId = u64;

/// Cache geometry.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Tokens per block (vLLM default 16).
    pub block_size: usize,
    /// Latent dim per token (576 for DeepSeek-R1; d_ckv + rope).
    pub latent_dim: usize,
    /// Physical blocks in the pool.
    pub num_blocks: usize,
}

impl CacheConfig {
    pub fn total_tokens(&self) -> usize {
        self.block_size * self.num_blocks
    }

    pub fn bytes(&self) -> usize {
        self.total_tokens() * self.latent_dim * std::mem::size_of::<f32>()
    }
}

#[derive(Debug, Clone)]
struct SeqState {
    blocks: Vec<BlockId>,
    len: usize,
}

/// The paged latent-KV cache.
pub struct PagedLatentCache {
    cfg: CacheConfig,
    pool: Vec<f32>,
    allocator: BlockAllocator,
    seqs: HashMap<SeqId, SeqState>,
    next_id: SeqId,
}

impl PagedLatentCache {
    pub fn new(cfg: CacheConfig) -> Self {
        assert!(cfg.block_size > 0 && cfg.latent_dim > 0 && cfg.num_blocks > 0);
        PagedLatentCache {
            pool: vec![0.0; cfg.total_tokens() * cfg.latent_dim],
            allocator: BlockAllocator::new(cfg.num_blocks),
            seqs: HashMap::new(),
            cfg,
            next_id: 1,
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Create an empty sequence.
    pub fn new_seq(&mut self) -> SeqId {
        let id = self.next_id;
        self.next_id += 1;
        self.seqs.insert(
            id,
            SeqState {
                blocks: Vec::new(),
                len: 0,
            },
        );
        id
    }

    /// Drop a sequence, releasing one reference on each of its blocks.
    ///
    /// Refcount-correct for forked/shared sequences: a block returns to the
    /// free list only when its *last* reference drops (the allocator counts
    /// references; forks, adopted chains, and the prefix tree each hold
    /// their own).  Freeing an unknown or already-freed `SeqId` is a no-op
    /// — double-free must never panic the serving loop.
    pub fn free_seq(&mut self, id: SeqId) {
        if let Some(state) = self.seqs.remove(&id) {
            for b in state.blocks {
                self.allocator.release(b);
            }
        }
    }

    /// Tokens cached for a sequence.
    pub fn len(&self, id: SeqId) -> usize {
        self.seqs.get(&id).map(|s| s.len).unwrap_or(0)
    }

    pub fn is_empty(&self, id: SeqId) -> bool {
        self.len(id) == 0
    }

    /// Can `tokens` more tokens be appended without running out of blocks?
    ///
    /// Accounts for copy-on-write: if the tail block is shared and partially
    /// filled, the first append into it must deep-copy it, which costs one
    /// extra block beyond the capacity arithmetic.
    pub fn can_append(&self, id: SeqId, tokens: usize) -> bool {
        let state = match self.seqs.get(&id) {
            Some(s) => s,
            None => return false,
        };
        if tokens == 0 {
            return true;
        }
        let mut extra = 0usize;
        // CoW of a shared, partially-filled tail block.
        if state.len % self.cfg.block_size != 0 {
            let tail = *state.blocks.last().expect("partial len implies a block");
            if !self.allocator.is_exclusive(tail) {
                extra += 1;
            }
        }
        let have = state.blocks.len() * self.cfg.block_size;
        let need = state.len + tokens;
        if need > have {
            extra += (need - have).div_ceil(self.cfg.block_size);
        }
        extra <= self.allocator.free_blocks()
    }

    /// Append one token's latent vector.  Copy-on-write if the tail block
    /// is shared.
    pub fn append(&mut self, id: SeqId, latent: &[f32]) -> Result<(), AllocError> {
        assert_eq!(latent.len(), self.cfg.latent_dim, "latent dim mismatch");
        let bs = self.cfg.block_size;
        let ld = self.cfg.latent_dim;

        let state = self.seqs.get(&id).expect("unknown sequence").clone();
        let slot = state.len % bs;
        let mut blocks = state.blocks;

        if state.len == blocks.len() * bs {
            // Need a fresh block.
            let b = self.allocator.alloc()?;
            blocks.push(b);
        } else {
            // Writing into the tail block: copy-on-write if shared.
            let tail = *blocks.last().unwrap();
            if !self.allocator.is_exclusive(tail) {
                let fresh = self.allocator.alloc()?;
                let (src, dst) = (self.block_range(tail), self.block_range(fresh));
                self.pool.copy_within(src, dst.start);
                self.allocator.release(tail);
                *blocks.last_mut().unwrap() = fresh;
            }
        }

        let tail = *blocks.last().unwrap();
        let off = self.block_range(tail).start + slot * ld;
        self.pool[off..off + ld].copy_from_slice(latent);

        let state = self.seqs.get_mut(&id).unwrap();
        state.blocks = blocks;
        state.len += 1;
        Ok(())
    }

    /// Truncate a sequence to `new_len` tokens, releasing one reference on
    /// every whole block past the new boundary.  This is the speculative-
    /// decoding rollback primitive: rejected KV positions must never
    /// survive in the store (they hold latents of tokens that were never
    /// generated).  The engine rolls back to the request's exact
    /// `kv_len()` — the count of validly-written positions — so the store
    /// boundary always coincides with the live literal's write frontier.
    /// Whole-block release keeps the refcount story
    /// identical to `free_seq` — a shared block survives for its other
    /// owners.  The kept tail block may hold stale latents past `new_len`;
    /// that region is unreachable (`gather_padded`/`append` are length-
    /// driven) and the next `append` into a *shared* tail still deep-copies
    /// first.  Truncating to ≥ the current length is a no-op.
    pub fn truncate(&mut self, id: SeqId, new_len: usize) {
        let dropped = {
            let state = self.seqs.get_mut(&id).expect("unknown sequence");
            if new_len >= state.len {
                return;
            }
            let keep = new_len.div_ceil(self.cfg.block_size);
            state.len = new_len;
            state.blocks.split_off(keep)
        };
        for b in dropped {
            self.allocator.release(b);
        }
    }

    /// Fork a sequence: shares all blocks (refcount++), O(blocks).
    pub fn fork(&mut self, parent: SeqId) -> SeqId {
        let state = self.seqs.get(&parent).expect("unknown sequence").clone();
        for &b in &state.blocks {
            self.allocator.retain(b);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.seqs.insert(id, state);
        id
    }

    /// Physical block chain backing a sequence (prefix order).
    pub fn blocks_of(&self, id: SeqId) -> &[BlockId] {
        self.seqs
            .get(&id)
            .map(|s| s.blocks.as_slice())
            .unwrap_or(&[])
    }

    /// Take an extra reference on a block (external owner, e.g. the prefix
    /// tree adopting a chain into a node).
    pub fn retain_block(&mut self, b: BlockId) {
        self.allocator.retain(b);
    }

    /// Drop one external reference on a block; frees it at refcount zero.
    pub fn release_block(&mut self, b: BlockId) {
        self.allocator.release(b);
    }

    /// Current refcount of a block (0 = free).
    pub fn block_refcount(&self, b: BlockId) -> u32 {
        self.allocator.refcount(b)
    }

    /// Export the first `n_blocks` blocks of a sequence, taking one extra
    /// reference on each on behalf of the caller (who must eventually
    /// `release_block` them).  Used by the prefix tree to take ownership of
    /// a completed prefill's prompt blocks.
    pub fn export_chain(&mut self, id: SeqId, n_blocks: usize) -> Vec<BlockId> {
        let state = self.seqs.get(&id).expect("unknown sequence");
        assert!(
            n_blocks <= state.blocks.len(),
            "export {n_blocks} of {} blocks",
            state.blocks.len()
        );
        let chain: Vec<BlockId> = state.blocks[..n_blocks].to_vec();
        for &b in &chain {
            self.allocator.retain(b);
        }
        chain
    }

    /// Create a sequence backed by an existing (shared) block chain holding
    /// `len` tokens.  Takes one reference per block on behalf of the new
    /// sequence; the donor (e.g. the prefix tree) keeps its own references.
    /// Copy-on-write applies on the first append into a shared tail block,
    /// exactly as after [`fork`](Self::fork).
    pub fn adopt_chain(&mut self, chain: &[BlockId], len: usize) -> SeqId {
        assert!(
            len <= chain.len() * self.cfg.block_size,
            "len {len} exceeds chain capacity {}",
            chain.len() * self.cfg.block_size
        );
        assert!(
            chain.is_empty() || len > (chain.len() - 1) * self.cfg.block_size,
            "len {len} leaves trailing unused blocks in the chain"
        );
        for &b in chain {
            self.allocator.retain(b);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.seqs.insert(
            id,
            SeqState {
                blocks: chain.to_vec(),
                len,
            },
        );
        id
    }

    /// Materialize the contiguous padded `[n_bucket × latent]` tensor the
    /// AOT attention artifact consumes.  Returns the valid length.
    pub fn gather_padded(&self, id: SeqId, n_bucket: usize, out: &mut [f32]) -> usize {
        let ld = self.cfg.latent_dim;
        assert_eq!(out.len(), n_bucket * ld, "output buffer size");
        let state = self.seqs.get(&id).expect("unknown sequence");
        assert!(state.len <= n_bucket, "sequence longer than bucket");
        let bs = self.cfg.block_size;
        let mut written = 0usize;
        for (bi, &b) in state.blocks.iter().enumerate() {
            let tokens = (state.len - bi * bs).min(bs);
            if tokens == 0 {
                break;
            }
            let src = self.block_range(b).start;
            out[written * ld..(written + tokens) * ld]
                .copy_from_slice(&self.pool[src..src + tokens * ld]);
            written += tokens;
        }
        // Zero the padding region (defence in depth: the kernels mask by
        // length, but deterministic padding makes outputs reproducible).
        out[written * ld..].fill(0.0);
        state.len
    }

    /// Read back one token's latent (tests / debugging).
    pub fn token_latent(&self, id: SeqId, pos: usize) -> &[f32] {
        let state = self.seqs.get(&id).expect("unknown sequence");
        assert!(pos < state.len);
        let bs = self.cfg.block_size;
        let ld = self.cfg.latent_dim;
        let b = state.blocks[pos / bs];
        let off = self.block_range(b).start + (pos % bs) * ld;
        &self.pool[off..off + ld]
    }

    /// Pool usage as a fraction.
    pub fn usage(&self) -> f64 {
        self.allocator.used_blocks() as f64 / self.cfg.num_blocks as f64
    }

    pub fn free_blocks(&self) -> usize {
        self.allocator.free_blocks()
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    fn block_range(&self, b: BlockId) -> std::ops::Range<usize> {
        let stride = self.cfg.block_size * self.cfg.latent_dim;
        let start = b as usize * stride;
        start..start + stride
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{forall, Config};

    fn cfg(blocks: usize) -> CacheConfig {
        CacheConfig {
            block_size: 4,
            latent_dim: 3,
            num_blocks: blocks,
        }
    }

    fn latent(tag: f32, dim: usize) -> Vec<f32> {
        (0..dim).map(|i| tag + i as f32 * 0.01).collect()
    }

    #[test]
    fn append_and_gather_round_trip() {
        let mut c = PagedLatentCache::new(cfg(4));
        let s = c.new_seq();
        for t in 0..10 {
            c.append(s, &latent(t as f32, 3)).unwrap();
        }
        assert_eq!(c.len(s), 10);
        let mut out = vec![0.0; 16 * 3];
        let n = c.gather_padded(s, 16, &mut out);
        assert_eq!(n, 10);
        for t in 0..10 {
            assert_eq!(&out[t * 3..t * 3 + 3], latent(t as f32, 3).as_slice());
        }
        assert!(out[30..].iter().all(|&x| x == 0.0), "padding zeroed");
    }

    #[test]
    fn out_of_blocks_reported() {
        let mut c = PagedLatentCache::new(cfg(2)); // 8 tokens max
        let s = c.new_seq();
        for t in 0..8 {
            c.append(s, &latent(t as f32, 3)).unwrap();
        }
        assert!(matches!(
            c.append(s, &latent(9.0, 3)),
            Err(AllocError::OutOfBlocks { .. })
        ));
    }

    #[test]
    fn free_seq_releases_blocks() {
        let mut c = PagedLatentCache::new(cfg(2));
        let s = c.new_seq();
        for t in 0..8 {
            c.append(s, &latent(t as f32, 3)).unwrap();
        }
        assert_eq!(c.free_blocks(), 0);
        c.free_seq(s);
        assert_eq!(c.free_blocks(), 2);
    }

    #[test]
    fn can_append_accounts_for_partial_blocks() {
        let mut c = PagedLatentCache::new(cfg(2));
        let s = c.new_seq();
        c.append(s, &latent(0.0, 3)).unwrap(); // 1 of 4 slots in block 0
        assert!(c.can_append(s, 3)); // fits in the same block
        assert!(c.can_append(s, 7)); // needs 1 more block — available
        assert!(!c.can_append(s, 8)); // would need 2 more — only 1 free
    }

    #[test]
    fn fork_shares_then_copy_on_write() {
        let mut c = PagedLatentCache::new(cfg(4));
        let a = c.new_seq();
        for t in 0..6 {
            c.append(a, &latent(t as f32, 3)).unwrap();
        }
        let used_before = 4 - c.free_blocks();
        let b = c.fork(a);
        assert_eq!(c.len(b), 6);
        assert_eq!(4 - c.free_blocks(), used_before, "fork allocates nothing");
        // Divergent appends: b's tail block must COW, a's data unchanged.
        c.append(b, &latent(100.0, 3)).unwrap();
        c.append(a, &latent(200.0, 3)).unwrap();
        assert_eq!(c.token_latent(a, 6), latent(200.0, 3).as_slice());
        assert_eq!(c.token_latent(b, 6), latent(100.0, 3).as_slice());
        // Shared prefix identical.
        for t in 0..6 {
            assert_eq!(c.token_latent(a, t), c.token_latent(b, t));
        }
    }

    #[test]
    fn gather_empty_sequence() {
        let mut c = PagedLatentCache::new(cfg(1));
        let s = c.new_seq();
        let mut out = vec![7.0; 4 * 3];
        assert_eq!(c.gather_padded(s, 4, &mut out), 0);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn property_gather_matches_appends() {
        forall(Config::default().cases(100), |g| {
            let bs = g.usize(1..8);
            let nb = g.usize(1..16);
            let ld = g.usize(1..6);
            let mut c = PagedLatentCache::new(CacheConfig {
                block_size: bs,
                latent_dim: ld,
                num_blocks: nb,
            });
            let s = c.new_seq();
            let n_tokens = g.usize(0..bs * nb + 1);
            let mut expect = Vec::new();
            for t in 0..n_tokens {
                let v: Vec<f32> = (0..ld).map(|k| (t * 31 + k) as f32).collect();
                if c.append(s, &v).is_ok() {
                    expect.push(v);
                }
            }
            let bucket = bs * nb;
            let mut out = vec![0.0; bucket * ld];
            let n = c.gather_padded(s, bucket, &mut out);
            prop_assert!(n == expect.len(), "length {n} vs {}", expect.len());
            for (t, v) in expect.iter().enumerate() {
                prop_assert!(
                    &out[t * ld..(t + 1) * ld] == v.as_slice(),
                    "mismatch at token {t}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn double_free_seq_is_noop() {
        let mut c = PagedLatentCache::new(cfg(2));
        let s = c.new_seq();
        for t in 0..8 {
            c.append(s, &latent(t as f32, 3)).unwrap();
        }
        c.free_seq(s);
        assert_eq!(c.free_blocks(), 2);
        c.free_seq(s); // must not panic or double-release
        assert_eq!(c.free_blocks(), 2);
        c.free_seq(9999); // unknown id: also a no-op
        assert_eq!(c.free_blocks(), 2);
    }

    #[test]
    fn free_seq_keeps_blocks_shared_with_fork() {
        let mut c = PagedLatentCache::new(cfg(4));
        let a = c.new_seq();
        for t in 0..8 {
            c.append(a, &latent(t as f32, 3)).unwrap();
        }
        let b = c.fork(a);
        c.free_seq(a);
        // Fork still owns the blocks: nothing returned to the free list.
        assert_eq!(c.free_blocks(), 2);
        for t in 0..8 {
            assert_eq!(c.token_latent(b, t), latent(t as f32, 3).as_slice());
        }
        c.free_seq(b);
        assert_eq!(c.free_blocks(), 4);
    }

    #[test]
    fn adopt_chain_shares_and_cows() {
        let mut c = PagedLatentCache::new(cfg(4));
        let a = c.new_seq();
        for t in 0..8 {
            c.append(a, &latent(t as f32, 3)).unwrap();
        }
        let chain = c.export_chain(a, 2); // donor reference (the "tree")
        assert_eq!(chain.len(), 2);
        let b = c.adopt_chain(&chain, 8);
        assert_eq!(c.len(b), 8);
        assert_eq!(c.free_blocks(), 2, "adoption allocates nothing");
        // Divergent appends: both sequences extend without corrupting the
        // shared prefix.
        c.append(b, &latent(100.0, 3)).unwrap();
        c.append(a, &latent(200.0, 3)).unwrap();
        assert_eq!(c.token_latent(a, 8), latent(200.0, 3).as_slice());
        assert_eq!(c.token_latent(b, 8), latent(100.0, 3).as_slice());
        for t in 0..8 {
            assert_eq!(c.token_latent(a, t), c.token_latent(b, t));
        }
        // Donor references survive both sequences.
        c.free_seq(a);
        c.free_seq(b);
        assert_eq!(c.block_refcount(chain[0]), 1);
        for &blk in &chain {
            c.release_block(blk);
        }
        assert_eq!(c.free_blocks(), 4);
    }

    #[test]
    fn adopt_chain_partial_tail_copy_on_write() {
        let mut c = PagedLatentCache::new(cfg(4));
        let a = c.new_seq();
        for t in 0..6 {
            // 1.5 blocks
            c.append(a, &latent(t as f32, 3)).unwrap();
        }
        let chain = c.export_chain(a, 2);
        let b = c.adopt_chain(&chain, 6); // shared partial tail
        c.append(b, &latent(50.0, 3)).unwrap(); // must deep-copy the tail
        assert_eq!(c.token_latent(b, 6), latent(50.0, 3).as_slice());
        assert_eq!(c.len(a), 6, "donor untouched");
        for t in 0..6 {
            assert_eq!(c.token_latent(a, t), c.token_latent(b, t));
        }
        c.free_seq(a);
        c.free_seq(b);
        for &blk in &chain {
            c.release_block(blk);
        }
        assert_eq!(c.free_blocks(), 4);
    }

    #[test]
    fn can_append_charges_cow_of_shared_tail() {
        let mut c = PagedLatentCache::new(cfg(2));
        let a = c.new_seq();
        for t in 0..6 {
            // block 0 full, block 1 half-full — pool exhausted
            c.append(a, &latent(t as f32, 3)).unwrap();
        }
        let b = c.fork(a);
        assert_eq!(c.free_blocks(), 0);
        // b's tail is shared and partial: appending would need a CoW block
        // that does not exist.
        assert!(!c.can_append(b, 1), "CoW cost must be charged");
        assert!(matches!(
            c.append(b, &latent(9.0, 3)),
            Err(AllocError::OutOfBlocks { .. })
        ));
        // After the donor frees, the fork still can't append (blocks still
        // referenced by b itself — CoW of tail needs a *new* block).
        c.free_seq(a);
        assert!(c.can_append(b, 1));
        c.append(b, &latent(9.0, 3)).unwrap();
    }

    #[test]
    fn truncate_releases_whole_blocks_and_replays() {
        let mut c = PagedLatentCache::new(cfg(4)); // block_size 4
        let s = c.new_seq();
        for t in 0..10 {
            c.append(s, &latent(t as f32, 3)).unwrap();
        }
        assert_eq!(c.free_blocks(), 1);
        c.truncate(s, 5); // keep blocks 0..=1, drop block 2
        assert_eq!(c.len(s), 5);
        assert_eq!(c.free_blocks(), 2);
        // Prefix untouched; re-appending overwrites the stale tail slots.
        for t in 0..5 {
            assert_eq!(c.token_latent(s, t), latent(t as f32, 3).as_slice());
        }
        for t in 5..9 {
            c.append(s, &latent(100.0 + t as f32, 3)).unwrap();
        }
        for t in 5..9 {
            assert_eq!(c.token_latent(s, t), latent(100.0 + t as f32, 3).as_slice());
        }
        // No-ops: truncating to the current or a larger length.
        c.truncate(s, 9);
        c.truncate(s, 50);
        assert_eq!(c.len(s), 9);
        // To zero: everything returns to the pool.
        c.truncate(s, 0);
        assert_eq!(c.len(s), 0);
        assert_eq!(c.free_blocks(), 4);
    }

    #[test]
    fn truncate_respects_shared_blocks() {
        let mut c = PagedLatentCache::new(cfg(4));
        let a = c.new_seq();
        for t in 0..8 {
            c.append(a, &latent(t as f32, 3)).unwrap();
        }
        let b = c.fork(a);
        c.truncate(b, 2); // drops b's reference on block 1 only
        assert_eq!(c.len(b), 2);
        assert_eq!(c.free_blocks(), 2, "block 1 still owned by a");
        for t in 0..8 {
            assert_eq!(c.token_latent(a, t), latent(t as f32, 3).as_slice());
        }
        // b's tail block is still shared with a: appending must CoW, not
        // clobber a's token 2.
        c.append(b, &latent(55.0, 3)).unwrap();
        assert_eq!(c.token_latent(b, 2), latent(55.0, 3).as_slice());
        assert_eq!(c.token_latent(a, 2), latent(2.0, 3).as_slice());
        c.free_seq(a);
        c.free_seq(b);
        assert_eq!(c.free_blocks(), 4);
    }

    #[test]
    fn property_truncate_then_append_equals_fresh() {
        // Rollback must be invisible: truncate + re-append produces the
        // same contents and allocator state as a sequence that never held
        // the rejected suffix, under arbitrary block geometry and sharing.
        forall(Config::default().cases(80), |g| {
            let bs = g.usize(1..6);
            let nb = g.usize(8..32);
            let mk = |c: &mut PagedLatentCache, toks: &[f32]| {
                let s = c.new_seq();
                for &v in toks {
                    c.append(s, &[v]).unwrap();
                }
                s
            };
            // Keep full + tail within pool capacity so appends can't fail.
            let cap = bs * nb;
            let full_len = g.usize(1..30).min(cap.saturating_sub(8)).max(1);
            let full: Vec<f32> = (0..full_len).map(|t| t as f32 + 1.0).collect();
            let cut = g.usize(0..full.len() + 1).min(full.len());
            let tail: Vec<f32> = (0..g.usize(0..8)).map(|t| 1000.0 + t as f32).collect();

            let mut c1 = PagedLatentCache::new(CacheConfig {
                block_size: bs,
                latent_dim: 1,
                num_blocks: nb,
            });
            let s1 = mk(&mut c1, &full);
            c1.truncate(s1, cut);
            for &v in &tail {
                c1.append(s1, &[v]).unwrap();
            }

            let mut c2 = PagedLatentCache::new(CacheConfig {
                block_size: bs,
                latent_dim: 1,
                num_blocks: nb,
            });
            let s2 = mk(&mut c2, &full[..cut]);
            for &v in &tail {
                c2.append(s2, &[v]).unwrap();
            }

            prop_assert!(c1.len(s1) == c2.len(s2), "length diverged");
            prop_assert!(
                c1.free_blocks() == c2.free_blocks(),
                "allocator diverged: {} vs {}",
                c1.free_blocks(),
                c2.free_blocks()
            );
            for t in 0..c1.len(s1) {
                prop_assert!(
                    c1.token_latent(s1, t) == c2.token_latent(s2, t),
                    "content diverged at {t}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_forks_never_corrupt_parent() {
        forall(Config::default().cases(60), |g| {
            let mut c = PagedLatentCache::new(CacheConfig {
                block_size: 4,
                latent_dim: 2,
                num_blocks: 32,
            });
            let a = c.new_seq();
            let prefix = g.usize(1..24);
            for t in 0..prefix {
                c.append(a, &[t as f32, -(t as f32)]).unwrap();
            }
            let b = c.fork(a);
            // Interleave divergent appends.
            for i in 0..g.usize(1..12) {
                let tgt = if g.bool() { a } else { b };
                let _ = c.append(tgt, &[1000.0 + i as f32, 0.0]);
            }
            for t in 0..prefix {
                prop_assert!(
                    c.token_latent(a, t) == [t as f32, -(t as f32)],
                    "parent corrupted at {t}"
                );
                prop_assert!(
                    c.token_latent(b, t) == [t as f32, -(t as f32)],
                    "fork prefix corrupted at {t}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_fork_divergence_only_past_fork_point() {
        // After fork + divergent appends, parent and child latents differ
        // only past the fork point.
        forall(Config::default().cases(80), |g| {
            let bs = g.usize(1..6);
            let mut c = PagedLatentCache::new(CacheConfig {
                block_size: bs,
                latent_dim: 2,
                num_blocks: 64,
            });
            let a = c.new_seq();
            let fork_at = g.usize(1..20);
            for t in 0..fork_at {
                c.append(a, &[t as f32, 1.0]).unwrap();
            }
            let b = c.fork(a);
            let extend_a = g.usize(1..10);
            let extend_b = g.usize(1..10);
            // Interleave so CoW triggers in arbitrary order.
            let mut ia = 0usize;
            let mut ib = 0usize;
            while ia < extend_a || ib < extend_b {
                if ib >= extend_b || (ia < extend_a && g.bool()) {
                    c.append(a, &[1000.0 + ia as f32, 2.0]).unwrap();
                    ia += 1;
                } else {
                    c.append(b, &[2000.0 + ib as f32, 3.0]).unwrap();
                    ib += 1;
                }
            }
            for t in 0..fork_at {
                prop_assert!(
                    c.token_latent(a, t) == c.token_latent(b, t),
                    "prefix diverged at {t} (fork at {fork_at})"
                );
            }
            for t in 0..extend_a.min(extend_b) {
                prop_assert!(
                    c.token_latent(a, fork_at + t) != c.token_latent(b, fork_at + t),
                    "suffix should diverge at {t}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_refcounts_and_free_list_under_fork_append_free() {
        // Allocator invariants under random fork/append/free interleavings:
        // every block's refcount equals the number of live block tables
        // containing it, used == distinct live blocks, and contents always
        // match a shadow model.
        use std::collections::{BTreeMap, HashMap};
        forall(Config::default().cases(60), |g| {
            let bs = g.usize(1..5);
            let nb = g.usize(4..32);
            let mut c = PagedLatentCache::new(CacheConfig {
                block_size: bs,
                latent_dim: 1,
                num_blocks: nb,
            });
            // BTreeMap so `g.choose` over keys is deterministic per seed.
            let mut shadow: BTreeMap<SeqId, Vec<f32>> = BTreeMap::new();
            let first = c.new_seq();
            shadow.insert(first, Vec::new());
            let mut tick = 0f32;
            for _ in 0..g.usize(10..120) {
                let live: Vec<SeqId> = shadow.keys().copied().collect();
                match g.usize(0..10) {
                    // append (most common)
                    0..=5 if !live.is_empty() => {
                        let s = *g.choose(&live);
                        tick += 1.0;
                        if c.append(s, &[tick]).is_ok() {
                            shadow.get_mut(&s).unwrap().push(tick);
                        }
                    }
                    6..=7 if !live.is_empty() => {
                        let s = *g.choose(&live);
                        let f = c.fork(s);
                        let cloned = shadow[&s].clone();
                        shadow.insert(f, cloned);
                    }
                    8 if live.len() > 1 => {
                        let s = *g.choose(&live);
                        c.free_seq(s);
                        shadow.remove(&s);
                    }
                    _ => {
                        let s = c.new_seq();
                        shadow.insert(s, Vec::new());
                    }
                }
                // Refcount invariant: count block-table references.
                let mut want: HashMap<BlockId, u32> = HashMap::new();
                for (&s, _) in &shadow {
                    for &b in c.blocks_of(s) {
                        *want.entry(b).or_insert(0) += 1;
                    }
                }
                for (&b, &rc) in &want {
                    prop_assert!(
                        c.block_refcount(b) == rc,
                        "block {b}: refcount {} want {rc}",
                        c.block_refcount(b)
                    );
                }
                prop_assert!(
                    nb - c.free_blocks() == want.len(),
                    "used {} vs distinct live blocks {}",
                    nb - c.free_blocks(),
                    want.len()
                );
                // Content invariant for every live sequence.
                for (&s, vals) in &shadow {
                    prop_assert!(c.len(s) == vals.len(), "len mismatch for {s}");
                    for (t, v) in vals.iter().enumerate() {
                        prop_assert!(
                            c.token_latent(s, t) == [*v],
                            "content mismatch seq {s} tok {t}"
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
