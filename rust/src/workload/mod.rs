//! Scenario-driven workload layer: deterministic serving traces, a
//! registry of named scenarios, and a runner that derives per-scenario
//! stats from the observability surface.
//!
//! This is the measurement substrate of the bench observatory
//! (`docs/benchmarking.md`):
//!
//! * [`trace`] — seeded trace generation (bursty Poisson arrivals,
//!   random prompts) and the [`WorkloadTrace`] data model.  Same seed ⇒
//!   byte-identical trace.
//! * [`scenario`] — the named-scenario registry ([`registry`]): each
//!   [`Scenario`] declares its trace seed, engine shape, and config
//!   snapshot, scaled by [`Scale`] (quick CI mode vs full).
//! * [`runner`] — replays a trace against a live engine over the
//!   serving API and derives [`ScenarioStats`] (TTFT / e2e / queue in
//!   engine ticks, tokens per step, `kv_slots_per_token`,
//!   prefill/prefix/spec attribution) from `Engine::timeline` +
//!   `ServingMetrics`.
//!
//! `rust/benches/workloads.rs` runs every registered scenario and emits
//! `BENCH_workloads.json`; `bench_compare` diffs those files across
//! runs; `BENCH_trajectory/` keeps the per-PR history.

pub mod runner;
pub mod scenario;
pub mod trace;

pub use runner::{run, run_setup, run_setup_fleet, RunOptions, ScenarioOutcome, ScenarioStats};
pub use scenario::{find, registry, Scale, Scenario, ScenarioSetup};
pub use trace::{bursty_poisson_arrivals, random_prompt, TraceRequest, WorkloadTrace};
