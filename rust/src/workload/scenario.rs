//! Named workload scenarios: seeded trace + engine shape + config
//! snapshot, registered in [`registry`].
//!
//! Each scenario captures one serving regime the ROADMAP cares about —
//! bursty open-loop arrival pressure, shared-prefix tenant traffic,
//! long-context documents on the paper's kv_len ladder, cancellation
//! storms, and stop-token-heavy mixes.  A scenario is *pure data about a
//! run*: a deterministic [`WorkloadTrace`] plus the
//! `ReferenceModelConfig`/`EngineConfig` to serve it under, plus the
//! knob snapshot the bench harness stamps into `BENCH_*.json`.  The
//! [`super::runner`] executes it; nothing here steps an engine.
//!
//! Quick mode ([`Scale::quick`], from `FLASHMLA_BENCH_QUICK`) shrinks
//! request counts and the context ladder so CI replays every scenario in
//! milliseconds.  Full mode runs the ladder out to the paper's 64K:
//! the `blocked_parallel` kernel fast path (`crate::kernels`, ROADMAP
//! item 3) makes the top rungs feasible where the seed's scalar
//! reference backend capped out at 4096.  Quick mode keeps the seed's
//! `naive` dispatch so CI also replays the unoptimized path.

use crate::coordinator::EngineConfig;
use crate::kernels::{KernelConfig, KernelMode};
use crate::prefill::PrefillConfig;
use crate::runtime::ReferenceModelConfig;
use crate::spec::SpecConfig;
use crate::util::rng::Rng;

use super::trace::{bursty_poisson_arrivals, random_prompt, TraceRequest, WorkloadTrace};

/// Workload scale: quick (CI) or full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scale {
    pub quick: bool,
}

impl Scale {
    pub fn quick() -> Self {
        Scale { quick: true }
    }

    pub fn full() -> Self {
        Scale { quick: false }
    }

    /// Resolve from `FLASHMLA_BENCH_QUICK`, like the bench harness.
    pub fn from_env() -> Self {
        Scale {
            quick: crate::bench::Bencher::quick_mode(),
        }
    }

    fn n(&self, quick: usize, full: usize) -> usize {
        if self.quick { quick } else { full }
    }

    /// The kv_len ladder for the long-context scenario (geometric, after
    /// the paper's Figure-1 sweep).  Full mode reaches the paper's 64K
    /// endpoint on the blocked-parallel fast path; quick keeps two tiny
    /// rungs so CI replays the scenario in milliseconds.
    pub fn kv_ladder(&self) -> Vec<usize> {
        if self.quick {
            vec![128, 256]
        } else {
            vec![512, 1024, 2048, 4096, 8192, 16384, 32768, 65536]
        }
    }
}

/// Everything the runner needs to execute one scenario.
#[derive(Clone, Debug)]
pub struct ScenarioSetup {
    pub model: ReferenceModelConfig,
    pub engine: EngineConfig,
    pub trace: WorkloadTrace,
    /// Declared knob snapshot (knob → value) for `BENCH_*.json` meta.
    pub config: Vec<(String, String)>,
}

/// A named, seeded workload scenario.
#[derive(Clone, Copy)]
pub struct Scenario {
    pub name: &'static str,
    pub about: &'static str,
    pub seed: u64,
    build: fn(Scale, u64) -> ScenarioSetup,
}

impl Scenario {
    /// Materialize the trace + engine shape at the given scale.
    pub fn build(&self, scale: Scale) -> ScenarioSetup {
        let mut setup = (self.build)(scale, self.seed);
        setup
            .config
            .push(("scenario".into(), self.name.to_string()));
        setup.config.push(("seed".into(), self.seed.to_string()));
        setup
            .config
            .push(("quick".into(), scale.quick.to_string()));
        setup
    }
}

/// All registered scenarios, in report order.
pub fn registry() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "bursty_poisson",
            about: "open-loop bursty Poisson arrivals against a small slot pool",
            seed: 0xB0_0001,
            build: build_bursty_poisson,
        },
        Scenario {
            name: "shared_prefix_tenants",
            about: "tenant mix sharing per-tenant system prefixes (prefix cache on)",
            seed: 0xB0_0002,
            build: build_shared_prefix,
        },
        Scenario {
            name: "long_context_ladder",
            about: "one long-context document per kv_len rung (chunked prefill)",
            seed: 0xB0_0003,
            build: build_long_context,
        },
        Scenario {
            name: "cancel_storm",
            about: "cancel-heavy mix: queued cancels, mid-stream cancels, survivors",
            seed: 0xB0_0004,
            build: build_cancel_storm,
        },
        Scenario {
            name: "stop_token_mix",
            about: "stop-token-heavy mix: per-request stop sets end streams early",
            seed: 0xB0_0005,
            build: build_stop_tokens,
        },
        Scenario {
            name: "fleet_tenants",
            about: "multi-tenant shared-prefix traffic for the fleet executor (QoS + replication)",
            seed: 0xB0_0006,
            build: build_fleet_tenants,
        },
    ]
}

/// Look a scenario up by name.
pub fn find(name: &str) -> Option<Scenario> {
    registry().into_iter().find(|s| s.name == name)
}

const VOCAB: usize = 64;

fn small_model(seed: u64) -> ReferenceModelConfig {
    ReferenceModelConfig {
        vocab: VOCAB,
        n_layers: 2,
        latent_dim: 8,
        seed,
        batch_buckets: vec![1, 2, 4],
        kv_buckets: vec![32, 64, 128],
    }
}

fn build_bursty_poisson(scale: Scale, seed: u64) -> ScenarioSetup {
    let n = scale.n(8, 24);
    let mut rng = Rng::new(seed);
    let arrivals = bursty_poisson_arrivals(&mut rng, n, 0.15, 1.5, 24);
    let requests = arrivals
        .into_iter()
        .map(|t| {
            let len = rng.range(8, 17) as usize;
            TraceRequest::new(t, random_prompt(&mut rng, len, VOCAB), 16)
        })
        .collect();
    ScenarioSetup {
        model: small_model(29),
        engine: EngineConfig {
            max_slots: 4,
            kv_blocks: 128,
            block_size: 8,
            prefix_cache: false,
            ..EngineConfig::default()
        },
        trace: WorkloadTrace { requests }.sorted(),
        config: vec![
            ("requests".into(), n.to_string()),
            ("arrivals".into(), "poisson base=0.15 burst=1.5 phase=24".into()),
            ("max_new".into(), "16".into()),
        ],
    }
}

fn build_shared_prefix(scale: Scale, seed: u64) -> ScenarioSetup {
    const TENANTS: usize = 4;
    const BLOCK: usize = 8;
    let per_tenant = scale.n(2, 6);
    let mut rng = Rng::new(seed);
    // One fixed system prefix per tenant, three blocks long so the radix
    // tree has whole blocks to share.
    let prefixes: Vec<Vec<i32>> = (0..TENANTS)
        .map(|_| random_prompt(&mut rng, 3 * BLOCK, VOCAB))
        .collect();
    // Steady (non-bursty) trickle: a tenant's first request has time to
    // finish prefilling — and insert its prefix blocks into the tree —
    // before the tenant's next request arrives to re-hit them.
    let arrivals =
        bursty_poisson_arrivals(&mut rng, TENANTS * per_tenant, 0.25, 0.25, 1_000_000);
    let requests = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let tenant = i % TENANTS;
            let mut prompt = prefixes[tenant].clone();
            prompt.extend(random_prompt(&mut rng, BLOCK, VOCAB));
            TraceRequest::new(t, prompt, 12)
        })
        .collect();
    ScenarioSetup {
        model: small_model(31),
        engine: EngineConfig {
            max_slots: 4,
            kv_blocks: 128,
            block_size: BLOCK,
            prefix_cache: true,
            ..EngineConfig::default()
        },
        trace: WorkloadTrace { requests }.sorted(),
        config: vec![
            ("tenants".into(), TENANTS.to_string()),
            ("per_tenant".into(), per_tenant.to_string()),
            ("prefix_tokens".into(), (3 * BLOCK).to_string()),
            ("max_new".into(), "12".into()),
        ],
    }
}

fn build_long_context(scale: Scale, seed: u64) -> ScenarioSetup {
    const MAX_NEW: usize = 8;
    const BLOCK: usize = 16;
    let ladder = scale.kv_ladder();
    // Full mode climbs to 64K contexts, which is only tractable on the
    // blocked-parallel fast path; quick mode keeps the seed's naive
    // dispatch so the unoptimized path stays exercised in CI.
    let kernels = if scale.quick {
        KernelConfig::default()
    } else {
        KernelConfig {
            mode: KernelMode::BlockedParallel,
            ..KernelConfig::default()
        }
    };
    let mut rng = Rng::new(seed);
    // One document per rung, arriving back to back: context (prompt +
    // generation) lands exactly on the rung, so each request exercises
    // its kv bucket edge.
    let requests = ladder
        .iter()
        .enumerate()
        .map(|(i, &rung)| {
            TraceRequest::new(
                i as u64,
                random_prompt(&mut rng, rung - MAX_NEW, VOCAB),
                MAX_NEW,
            )
        })
        .collect();
    let total_tokens: usize = ladder.iter().sum();
    let kv_blocks = (total_tokens / BLOCK) * 2 + 16;
    ScenarioSetup {
        model: ReferenceModelConfig {
            kv_buckets: ladder.clone(),
            batch_buckets: vec![1, 2],
            ..small_model(37)
        },
        engine: EngineConfig {
            max_slots: 2,
            kv_blocks,
            block_size: BLOCK,
            prefix_cache: false,
            // Big chunks: a 4096-token prompt should cost ~64 ticks of
            // prefill, not 4096 — this is the chunked-prefill workload.
            prefill: PrefillConfig {
                step_token_budget: 128,
                chunk_tokens: 64,
                ..PrefillConfig::default()
            },
            kernels: kernels.clone(),
            ..EngineConfig::default()
        },
        trace: WorkloadTrace { requests }.sorted(),
        config: vec![
            (
                "kv_ladder".into(),
                ladder
                    .iter()
                    .map(|l| l.to_string())
                    .collect::<Vec<_>>()
                    .join(","),
            ),
            ("max_new".into(), MAX_NEW.to_string()),
            ("chunk_tokens".into(), "64".into()),
            ("kernels".into(), kernels.mode.as_str().into()),
        ],
    }
}

fn build_cancel_storm(scale: Scale, seed: u64) -> ScenarioSetup {
    let n = scale.n(9, 21);
    let mut rng = Rng::new(seed);
    let arrivals = bursty_poisson_arrivals(&mut rng, n, 0.3, 3.0, 16);
    let requests = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let mut req =
                TraceRequest::new(t, random_prompt(&mut rng, 10, VOCAB), 24);
            // Deterministic thirds: queued cancel, mid-stream cancel,
            // survivor.
            req.cancel_after_tokens = match i % 3 {
                0 => Some(0),
                1 => Some(4),
                _ => None,
            };
            req
        })
        .collect();
    ScenarioSetup {
        model: small_model(41),
        engine: EngineConfig {
            // Two slots under a burst: cancels happen while queued.
            max_slots: 2,
            kv_blocks: 96,
            block_size: 8,
            prefix_cache: false,
            ..EngineConfig::default()
        },
        trace: WorkloadTrace { requests }.sorted(),
        config: vec![
            ("requests".into(), n.to_string()),
            ("cancel_mix".into(), "1/3 queued, 1/3 after 4 tokens".into()),
            ("max_new".into(), "24".into()),
        ],
    }
}

fn build_stop_tokens(scale: Scale, seed: u64) -> ScenarioSetup {
    let n = scale.n(6, 16);
    let mut rng = Rng::new(seed);
    let arrivals = bursty_poisson_arrivals(&mut rng, n, 0.5, 0.5, 1_000_000);
    let requests = arrivals
        .into_iter()
        .map(|t| {
            let mut req =
                TraceRequest::new(t, random_prompt(&mut rng, 12, VOCAB), 32);
            // Eight distinct stop tokens per request: with a 64-token
            // vocab, greedy streams routinely hit one well before the
            // 32-token budget, exercising the early-stop path.
            let mut stops: Vec<i32> = Vec::new();
            while stops.len() < 8 {
                let t = rng.range(1, VOCAB as u64 - 1) as i32;
                if !stops.contains(&t) {
                    stops.push(t);
                }
            }
            req.stop_tokens = stops;
            req
        })
        .collect();
    ScenarioSetup {
        model: small_model(43),
        engine: EngineConfig {
            max_slots: 4,
            kv_blocks: 128,
            block_size: 8,
            prefix_cache: false,
            spec: SpecConfig::default(),
            ..EngineConfig::default()
        },
        trace: WorkloadTrace { requests }.sorted(),
        config: vec![
            ("requests".into(), n.to_string()),
            ("stop_tokens_per_request".into(), "8".into()),
            ("max_new".into(), "32".into()),
        ],
    }
}

fn build_fleet_tenants(scale: Scale, seed: u64) -> ScenarioSetup {
    const TENANTS: usize = 3;
    const BLOCK: usize = 8;
    let per_tenant = scale.n(4, 10);
    let mut rng = Rng::new(seed);
    // One fixed two-block system prefix per tenant: hot enough that the
    // fleet replicates it, shared enough that prefix-aware admission
    // charges most requests only their one-block suffix.
    let prefixes: Vec<Vec<i32>> = (0..TENANTS)
        .map(|_| random_prompt(&mut rng, 2 * BLOCK, VOCAB))
        .collect();
    let arrivals =
        bursty_poisson_arrivals(&mut rng, TENANTS * per_tenant, 0.4, 0.4, 1_000_000);
    let requests = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let tenant = i % TENANTS;
            let mut prompt = prefixes[tenant].clone();
            prompt.extend(random_prompt(&mut rng, BLOCK, VOCAB));
            let mut req = TraceRequest::new(t, prompt, 8);
            req.tenant = Some(format!("tenant{tenant}"));
            req
        })
        .collect();
    ScenarioSetup {
        model: small_model(47),
        engine: EngineConfig {
            max_slots: 4,
            kv_blocks: 128,
            block_size: BLOCK,
            prefix_cache: true,
            ..EngineConfig::default()
        },
        trace: WorkloadTrace { requests }.sorted(),
        config: vec![
            ("tenants".into(), TENANTS.to_string()),
            ("per_tenant".into(), per_tenant.to_string()),
            ("prefix_tokens".into(), (2 * BLOCK).to_string()),
            ("max_new".into(), "8".into()),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_sufficient() {
        let scenarios = registry();
        assert!(scenarios.len() >= 4, "compare reports need ≥ 4 scenarios");
        let mut names: Vec<_> = scenarios.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), scenarios.len(), "duplicate scenario name");
        assert!(find("bursty_poisson").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn every_scenario_fits_its_engine() {
        for scale in [Scale::quick(), Scale::full()] {
            for s in registry() {
                let setup = s.build(scale);
                assert!(
                    !setup.trace.requests.is_empty(),
                    "{}: empty trace",
                    s.name
                );
                let max_kv = *setup.model.kv_buckets.iter().max().unwrap();
                let capacity = setup.engine.kv_blocks * setup.engine.block_size;
                for r in &setup.trace.requests {
                    let peak = r.prompt.len() + r.max_new_tokens;
                    assert!(
                        peak <= max_kv,
                        "{}: request peak {} exceeds kv bucket {}",
                        s.name,
                        peak,
                        max_kv
                    );
                    assert!(
                        peak <= capacity,
                        "{}: request peak {} exceeds paged capacity {}",
                        s.name,
                        peak,
                        capacity
                    );
                }
                // Declared snapshot always carries the attribution keys.
                let keys: Vec<_> =
                    setup.config.iter().map(|(k, _)| k.as_str()).collect();
                assert!(keys.contains(&"scenario") && keys.contains(&"seed"));
            }
        }
    }

    #[test]
    fn long_context_ladder_reaches_64k_on_fast_path() {
        let full = find("long_context_ladder").unwrap().build(Scale::full());
        assert_eq!(*Scale::full().kv_ladder().last().unwrap(), 65536);
        assert_eq!(full.engine.kernels.mode, KernelMode::BlockedParallel);
        // Quick stays on the seed path with its tiny rungs.
        let quick = find("long_context_ladder").unwrap().build(Scale::quick());
        assert_eq!(quick.engine.kernels.mode, KernelMode::Naive);
        assert_eq!(Scale::quick().kv_ladder(), vec![128, 256]);
    }

    #[test]
    fn build_is_deterministic() {
        for s in registry() {
            let a = s.build(Scale::quick()).trace.to_json().dump();
            let b = s.build(Scale::quick()).trace.to_json().dump();
            assert_eq!(a, b, "{}: trace not reproducible", s.name);
        }
    }
}
