//! Scenario runner: replay a [`WorkloadTrace`] against a live engine and
//! derive per-scenario stats from the observability surface.
//!
//! The runner is the only piece of the workload layer that touches an
//! engine.  It drives the manual serving loop (`submit` → `step` →
//! `poll_events` → `take_finished`), honoring each request's arrival
//! tick and cancellation intent, then derives [`ScenarioStats`] from two
//! sources PR 6 built exactly for this: per-request
//! [`RequestTimeline`]s (TTFT / e2e / queue in engine ticks, exact
//! per-request) and the engine's `ServingMetrics` (tokens, steps,
//! `kv_slots_per_token`, prefill/prefix/spec attribution).
//!
//! Time model: the trace's `arrive_tick` counts *engine steps*.  A
//! request is submitted once the engine has stepped that many times;
//! when the engine goes idle with arrivals still pending, the clock
//! fast-forwards to the next arrival (idle wall time is not simulated —
//! queueing behaviour under pressure is what the scenarios probe).
//! Everything except `wall_us` is deterministic for a given trace.
//!
//! [`RequestTimeline`]: crate::obs::RequestTimeline
//! [`WorkloadTrace`]: super::trace::WorkloadTrace

use std::collections::BTreeMap;
use std::time::Instant;

use crate::coordinator::{
    Engine, FinishedRequest, GenerationRequest, RequestHandle, ServingMetrics, StepEvent,
};
use crate::fleet::{FleetConfig, FleetExecutor, FleetHandle};
use crate::prefill::PrefillConfig;
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

use super::scenario::{Scale, Scenario, ScenarioSetup};

/// Per-run overrides on top of the scenario's declared engine shape.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunOptions {
    /// Override the prefill planner (`PrefillConfig::per_token()` replays
    /// the scenario on the pre-chunking pipeline — the
    /// scheduler-invariance axis of the determinism suite).
    pub prefill: Option<PrefillConfig>,
    /// Override the flight-recorder ring size (`Some(0)` forces it off).
    pub flight_recorder_ticks: Option<usize>,
}

/// Everything a scenario run produced.
pub struct ScenarioOutcome {
    pub stats: ScenarioStats,
    /// Terminal results sorted by request id — the bit-identity surface
    /// (tokens and finish reasons) the determinism tests compare.
    pub outputs: Vec<FinishedRequest>,
    /// Final engine metrics (for `Bencher::record_serving_metrics` or
    /// cross-scenario merges).
    pub metrics: ServingMetrics,
}

/// Derived per-scenario statistics.  All step-denominated (wall time is
/// confined to `wall_us`), so two same-seed runs agree on every other
/// field — `deterministic_json` is the comparable rendering.
#[derive(Clone, Debug)]
pub struct ScenarioStats {
    pub scenario: String,
    pub requests: usize,
    pub finished: u64,
    pub cancelled: u64,
    pub rejected: u64,
    pub steps: u64,
    pub tokens: u64,
    pub tokens_per_step: f64,
    pub ttft_steps_mean: f64,
    pub ttft_steps_p99: f64,
    pub e2e_steps_mean: f64,
    pub e2e_steps_p99: f64,
    pub queue_steps_mean: f64,
    pub kv_slots_per_token: f64,
    pub prefill_tokens: u64,
    pub prefill_chunks: u64,
    pub prefix_hit_tokens: u64,
    pub spec_drafted: u64,
    pub spec_accepted: u64,
    /// Useful modeled GFLOPs per engine tick ([`crate::obs::ledger`] at
    /// the paper kernel shape) — the "effective compute" the scenario
    /// actually delivered; deterministic.
    pub effective_gflops_per_tick: f64,
    /// Wasted share of issued modeled FLOPs, in `[0, 1)`; deterministic.
    pub waste_fraction: f64,
    /// Wall-clock run time — the one non-deterministic field.
    pub wall_us: f64,
}

impl ScenarioStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(self.scenario.clone())),
            ("requests", Json::num(self.requests as f64)),
            ("finished", Json::num(self.finished as f64)),
            ("cancelled", Json::num(self.cancelled as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("tokens_per_step", Json::num(self.tokens_per_step)),
            ("ttft_steps_mean", Json::num(self.ttft_steps_mean)),
            ("ttft_steps_p99", Json::num(self.ttft_steps_p99)),
            ("e2e_steps_mean", Json::num(self.e2e_steps_mean)),
            ("e2e_steps_p99", Json::num(self.e2e_steps_p99)),
            ("queue_steps_mean", Json::num(self.queue_steps_mean)),
            ("kv_slots_per_token", Json::num(self.kv_slots_per_token)),
            ("prefill_tokens", Json::num(self.prefill_tokens as f64)),
            ("prefill_chunks", Json::num(self.prefill_chunks as f64)),
            (
                "prefix_hit_tokens",
                Json::num(self.prefix_hit_tokens as f64),
            ),
            ("spec_drafted", Json::num(self.spec_drafted as f64)),
            ("spec_accepted", Json::num(self.spec_accepted as f64)),
            (
                "effective_gflops_per_tick",
                Json::num(self.effective_gflops_per_tick),
            ),
            ("waste_fraction", Json::num(self.waste_fraction)),
            ("wall_us", Json::num(self.wall_us)),
        ])
    }

    /// [`to_json`](Self::to_json) with the wall clock zeroed — byte-equal
    /// across same-seed runs.
    pub fn deterministic_json(&self) -> Json {
        let mut s = self.clone();
        s.wall_us = 0.0;
        s.to_json()
    }

    /// `(name, value)` pairs for `Bencher::record_metric`, prefixed with
    /// the scenario name (`bursty_poisson.ttft_steps_mean`, …).  These
    /// are the columns `bench_compare` aligns across runs.
    pub fn metric_pairs(&self) -> Vec<(String, f64)> {
        let p = |k: &str| format!("{}.{}", self.scenario, k);
        vec![
            (p("ttft_steps_mean"), self.ttft_steps_mean),
            (p("ttft_steps_p99"), self.ttft_steps_p99),
            (p("e2e_steps_mean"), self.e2e_steps_mean),
            (p("e2e_steps_p99"), self.e2e_steps_p99),
            (p("queue_steps_mean"), self.queue_steps_mean),
            (p("tokens_per_step"), self.tokens_per_step),
            (p("kv_slots_per_token"), self.kv_slots_per_token),
            (p("steps"), self.steps as f64),
            (p("tokens"), self.tokens as f64),
            (p("finished"), self.finished as f64),
            (p("cancelled"), self.cancelled as f64),
            (p("rejected"), self.rejected as f64),
            (
                p("effective_gflops_per_tick"),
                self.effective_gflops_per_tick,
            ),
            (p("waste_fraction"), self.waste_fraction),
        ]
    }
}

/// Build and run a registered scenario at the given scale.
pub fn run(
    scenario: &Scenario,
    scale: Scale,
    opts: &RunOptions,
) -> anyhow::Result<ScenarioOutcome> {
    let setup = scenario.build(scale);
    run_setup(scenario.name, &setup, opts)
}

/// Replay an already-built setup (used by the determinism tests to pin
/// one setup while varying [`RunOptions`]).
pub fn run_setup(
    name: &str,
    setup: &ScenarioSetup,
    opts: &RunOptions,
) -> anyhow::Result<ScenarioOutcome> {
    let mut cfg = setup.engine.clone();
    if let Some(p) = opts.prefill {
        cfg.prefill = p;
    }
    if let Some(n) = opts.flight_recorder_ticks {
        cfg.flight_recorder_ticks = n;
    }
    // Keep the compute ledger live for the whole run: every scenario's
    // stats carry deterministic FLOP/byte attribution.  A pure observer —
    // tokens and plans are bit-identical with the guard absent (asserted
    // in `rust/tests/workload_determinism.rs`).
    let _ledger = crate::obs::ledger::LedgerGuard::new();
    let mut engine = Engine::reference(setup.model.clone(), cfg)?;

    let t0 = Instant::now();
    let mut pending = setup.trace.requests.clone();
    pending.reverse(); // pop() from the back = earliest arrival first
    let mut handles: Vec<RequestHandle> = Vec::with_capacity(pending.len());
    // Cancellation intents: request id → cancel-after-token threshold.
    let mut cancel_at: BTreeMap<u64, usize> = BTreeMap::new();
    let mut streamed: BTreeMap<u64, usize> = BTreeMap::new();
    let mut outputs: Vec<FinishedRequest> = Vec::new();

    let mut tick: u64 = 0;
    let mut guard: u64 = 0;
    loop {
        // Submit everything whose arrival tick has come; queued cancels
        // (`cancel_after_tokens == 0`) fire immediately after submit.
        while pending.last().is_some_and(|r| r.arrive_tick <= tick) {
            let r = pending.pop().unwrap();
            let mut req = GenerationRequest::new(r.prompt, r.max_new_tokens);
            if !r.stop_tokens.is_empty() {
                req = req.stop_tokens(&r.stop_tokens);
            }
            if let Some(params) = r.sampling {
                req = req.sampling(params);
            }
            let h = engine.submit(req);
            handles.push(h);
            match r.cancel_after_tokens {
                Some(0) => {
                    engine.cancel(h.id());
                }
                Some(n) => {
                    cancel_at.insert(h.id(), n);
                }
                None => {}
            }
        }

        if !engine.has_work() {
            match pending.last() {
                // Idle with arrivals still due: fast-forward the clock.
                Some(r) => {
                    tick = r.arrive_tick;
                    continue;
                }
                None => break,
            }
        }

        engine.step()?;
        tick += 1;
        guard += 1;
        anyhow::ensure!(
            guard < 10_000_000,
            "scenario `{name}` did not drain (runaway loop)"
        );

        for ev in engine.poll_events() {
            if let StepEvent::Token { id, .. } = ev {
                let n = streamed.entry(id).or_insert(0);
                *n += 1;
                if cancel_at.get(&id) == Some(&*n) {
                    engine.cancel(id);
                }
            }
        }
        outputs.extend(engine.take_finished());
    }
    outputs.extend(engine.take_finished());
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;

    // Per-request step intervals from the surviving timelines.
    let mut ttft: Vec<f64> = Vec::new();
    let mut e2e: Vec<f64> = Vec::new();
    let mut queue: Vec<f64> = Vec::new();
    for &h in &handles {
        if let Some(tl) = engine.timeline(h) {
            if let Some(v) = tl.ttft_steps() {
                ttft.push(v as f64);
            }
            if let Some(v) = tl.e2e_steps() {
                e2e.push(v as f64);
            }
            if let Some(v) = tl.queue_steps() {
                queue.push(v as f64);
            }
        }
    }

    outputs.sort_by_key(|f| f.id);
    let requests = handles.len();
    let report = engine.into_report();
    let m = report.metrics;
    let stats = ScenarioStats {
        scenario: name.to_string(),
        requests,
        finished: m.requests_finished,
        cancelled: m.requests_cancelled,
        rejected: m.requests_rejected,
        steps: m.steps,
        tokens: m.tokens_generated,
        tokens_per_step: if m.steps == 0 {
            0.0
        } else {
            m.tokens_generated as f64 / m.steps as f64
        },
        ttft_steps_mean: mean(&ttft),
        ttft_steps_p99: percentile(&ttft, 99.0),
        e2e_steps_mean: mean(&e2e),
        e2e_steps_p99: percentile(&e2e, 99.0),
        queue_steps_mean: mean(&queue),
        kv_slots_per_token: m.kv_slots_per_token(),
        prefill_tokens: m.prefill_tokens,
        prefill_chunks: m.prefill_chunks,
        prefix_hit_tokens: m.prefix.hit_tokens,
        spec_drafted: m.spec_drafted,
        spec_accepted: m.spec_accepted,
        effective_gflops_per_tick: if m.steps == 0 {
            0.0
        } else {
            m.compute.useful_flops / m.steps as f64 / 1e9
        },
        waste_fraction: m.compute.waste_fraction(),
        wall_us,
    };
    Ok(ScenarioOutcome {
        stats,
        outputs,
        metrics: m,
    })
}

/// Replay a setup against a [`FleetExecutor`] instead of a solo engine.
///
/// Same time model as [`run_setup`] — `arrive_tick` counts *fleet* ticks
/// (one fleet tick steps every engine once) and the clock fast-forwards
/// over idle gaps.  Per-request latency stats are derived from the
/// fleet's translated event stream rather than engine timelines, so they
/// are denominated in fleet ticks; `rejected` counts engine-side
/// rejections *plus* submit-time backpressure sheds.  The scenario's
/// engine shape overrides `fleet.engine` so a registered scenario runs on
/// the hardware it declared.
pub fn run_setup_fleet(
    name: &str,
    setup: &ScenarioSetup,
    fleet: &FleetConfig,
) -> anyhow::Result<ScenarioOutcome> {
    let mut cfg = fleet.clone();
    cfg.engine = setup.engine.clone();
    let _ledger = crate::obs::ledger::LedgerGuard::new();
    let mut exec = FleetExecutor::reference(setup.model.clone(), cfg)?;

    let t0 = Instant::now();
    let mut pending = setup.trace.requests.clone();
    pending.reverse();
    let mut handles: Vec<FleetHandle> = Vec::with_capacity(pending.len());
    let mut by_id: BTreeMap<u64, FleetHandle> = BTreeMap::new();
    let mut cancel_at: BTreeMap<u64, usize> = BTreeMap::new();
    let mut streamed: BTreeMap<u64, usize> = BTreeMap::new();
    let mut outputs: Vec<FinishedRequest> = Vec::new();
    // Fleet-tick timestamps per request id, for the latency stats.
    let mut submit_tick: BTreeMap<u64, u64> = BTreeMap::new();
    let mut admit_tick: BTreeMap<u64, u64> = BTreeMap::new();
    let mut first_token_tick: BTreeMap<u64, u64> = BTreeMap::new();
    let mut done_tick: BTreeMap<u64, u64> = BTreeMap::new();

    let mut tick: u64 = 0;
    let mut fleet_ticks: u64 = 0;
    #[allow(clippy::too_many_arguments)]
    fn drain(
        exec: &mut FleetExecutor,
        tick: u64,
        outputs: &mut Vec<FinishedRequest>,
        admit_tick: &mut BTreeMap<u64, u64>,
        first_token_tick: &mut BTreeMap<u64, u64>,
        done_tick: &mut BTreeMap<u64, u64>,
        streamed: &mut BTreeMap<u64, usize>,
        cancel_at: &BTreeMap<u64, usize>,
        by_id: &BTreeMap<u64, FleetHandle>,
    ) {
        for ev in exec.poll_events() {
            match ev.event {
                StepEvent::Admitted { id } => {
                    admit_tick.entry(id).or_insert(tick);
                }
                StepEvent::Token { id, .. } => {
                    first_token_tick.entry(id).or_insert(tick);
                    let n = streamed.entry(id).or_insert(0);
                    *n += 1;
                    if cancel_at.get(&id) == Some(&*n) {
                        if let Some(&h) = by_id.get(&id) {
                            exec.cancel(h);
                        }
                    }
                }
                StepEvent::Finished { id, .. } | StepEvent::Rejected { id, .. } => {
                    done_tick.entry(id).or_insert(tick);
                }
            }
        }
        outputs.extend(exec.take_finished());
    }

    let mut guard: u64 = 0;
    loop {
        while pending.last().is_some_and(|r| r.arrive_tick <= tick) {
            let r = pending.pop().unwrap();
            let mut req = GenerationRequest::new(r.prompt, r.max_new_tokens);
            if !r.stop_tokens.is_empty() {
                req = req.stop_tokens(&r.stop_tokens);
            }
            if let Some(params) = r.sampling {
                req = req.sampling(params);
            }
            let tenant = r.tenant.as_deref().unwrap_or("default");
            let h = exec
                .submit_for(tenant, req)
                .map_err(|e| anyhow::anyhow!("scenario `{name}`: {e}"))?;
            handles.push(h);
            by_id.insert(h.id(), h);
            submit_tick.insert(h.id(), tick);
            match r.cancel_after_tokens {
                Some(0) => {
                    exec.cancel(h);
                }
                Some(n) => {
                    cancel_at.insert(h.id(), n);
                }
                None => {}
            }
        }

        if !exec.has_work() {
            // Flush submit-time sheds before fast-forwarding or exiting.
            drain(
                &mut exec,
                tick,
                &mut outputs,
                &mut admit_tick,
                &mut first_token_tick,
                &mut done_tick,
                &mut streamed,
                &cancel_at,
                &by_id,
            );
            match pending.last() {
                Some(r) => {
                    tick = r.arrive_tick;
                    continue;
                }
                None => break,
            }
        }

        exec.step()?;
        tick += 1;
        fleet_ticks += 1;
        guard += 1;
        anyhow::ensure!(
            guard < 10_000_000,
            "fleet scenario `{name}` did not drain (runaway loop)"
        );
        drain(
            &mut exec,
            tick,
            &mut outputs,
            &mut admit_tick,
            &mut first_token_tick,
            &mut done_tick,
            &mut streamed,
            &cancel_at,
            &by_id,
        );
    }
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;

    let mut ttft: Vec<f64> = Vec::new();
    let mut e2e: Vec<f64> = Vec::new();
    let mut queue: Vec<f64> = Vec::new();
    for (&id, &s) in &submit_tick {
        if let Some(&t) = first_token_tick.get(&id) {
            ttft.push(t.saturating_sub(s) as f64);
        }
        if let Some(&t) = done_tick.get(&id) {
            e2e.push(t.saturating_sub(s) as f64);
        }
        if let Some(&t) = admit_tick.get(&id) {
            queue.push(t.saturating_sub(s) as f64);
        }
    }

    outputs.sort_by_key(|f| f.id);
    let m = exec.merged_metrics();
    let stats = ScenarioStats {
        scenario: name.to_string(),
        requests: handles.len(),
        finished: m.requests_finished,
        cancelled: m.requests_cancelled,
        rejected: m.requests_rejected + exec.shed(),
        steps: fleet_ticks,
        tokens: m.tokens_generated,
        tokens_per_step: if fleet_ticks == 0 {
            0.0
        } else {
            m.tokens_generated as f64 / fleet_ticks as f64
        },
        ttft_steps_mean: mean(&ttft),
        ttft_steps_p99: percentile(&ttft, 99.0),
        e2e_steps_mean: mean(&e2e),
        e2e_steps_p99: percentile(&e2e, 99.0),
        queue_steps_mean: mean(&queue),
        kv_slots_per_token: m.kv_slots_per_token(),
        prefill_tokens: m.prefill_tokens,
        prefill_chunks: m.prefill_chunks,
        prefix_hit_tokens: m.prefix.hit_tokens,
        spec_drafted: m.spec_drafted,
        spec_accepted: m.spec_accepted,
        effective_gflops_per_tick: if fleet_ticks == 0 {
            0.0
        } else {
            m.compute.useful_flops / fleet_ticks as f64 / 1e9
        },
        waste_fraction: m.compute.waste_fraction(),
        wall_us,
    };
    Ok(ScenarioOutcome {
        stats,
        outputs,
        metrics: m,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::scenario;

    #[test]
    fn bursty_scenario_runs_and_reports() {
        let s = scenario::find("bursty_poisson").unwrap();
        let out = run(&s, Scale::quick(), &RunOptions::default()).unwrap();
        assert_eq!(out.stats.requests, 8);
        assert_eq!(out.outputs.len(), 8, "every request terminates");
        assert!(out.stats.tokens > 0);
        assert!(out.stats.steps > 0);
        assert!(out.stats.tokens_per_step > 0.0);
        assert!(out.stats.ttft_steps_mean >= 1.0, "first token needs a step");
        assert!(
            out.stats.e2e_steps_mean >= out.stats.ttft_steps_mean,
            "e2e dominates ttft"
        );
        // Exact-KV convention: strictly below one slot per token.
        assert!(out.stats.kv_slots_per_token < 1.0);
        assert!(out.stats.kv_slots_per_token > 0.0);
        // Compute ledger: a real run delivers useful FLOPs every tick,
        // wastes some (bucket + mask padding at minimum), never all.
        assert!(out.stats.effective_gflops_per_tick > 0.0);
        assert!(out.stats.waste_fraction > 0.0);
        assert!(out.stats.waste_fraction < 1.0);
        assert!(out.metrics.compute.useful_flops > 0.0);
        assert_eq!(
            out.metrics.compute.chunk_refeed_flops, 0.0,
            "reference backend chunks natively — no wavefront re-feeds"
        );
    }

    #[test]
    fn cancel_storm_cancels() {
        let s = scenario::find("cancel_storm").unwrap();
        let out = run(&s, Scale::quick(), &RunOptions::default()).unwrap();
        assert!(out.stats.cancelled > 0, "cancel mix must cancel something");
        assert!(out.stats.finished > 0, "survivors finish");
        assert_eq!(
            out.stats.finished + out.stats.cancelled + out.stats.rejected,
            out.stats.requests as u64,
            "every request accounted for"
        );
    }

    #[test]
    fn stop_tokens_shorten_streams() {
        let s = scenario::find("stop_token_mix").unwrap();
        let out = run(&s, Scale::quick(), &RunOptions::default()).unwrap();
        let budget: usize = 32 * out.stats.requests;
        assert!(
            (out.stats.tokens as usize) < budget,
            "stop sets must end at least one stream early ({} vs {})",
            out.stats.tokens,
            budget
        );
    }

    #[test]
    fn shared_prefix_hits_cache() {
        let s = scenario::find("shared_prefix_tenants").unwrap();
        let out = run(&s, Scale::quick(), &RunOptions::default()).unwrap();
        assert!(
            out.stats.prefix_hit_tokens > 0,
            "tenant mix must re-hit its system prefixes"
        );
    }

    #[test]
    fn fleet_tenants_runs_on_a_fleet() {
        let s = scenario::find("fleet_tenants").unwrap();
        let setup = s.build(Scale::quick());
        let fleet = FleetConfig {
            engines: 2,
            ..FleetConfig::default()
        };
        let out = run_setup_fleet(s.name, &setup, &fleet).unwrap();
        assert_eq!(
            out.stats.finished + out.stats.cancelled + out.stats.rejected,
            out.stats.requests as u64,
            "every request accounted for across the fleet"
        );
        assert_eq!(out.outputs.len(), out.stats.requests);
        assert!(out.stats.tokens > 0);
        assert!(out.stats.steps > 0);
        assert!(
            out.stats.prefix_hit_tokens > 0,
            "tenant prefixes must re-hit the caches"
        );
        assert!(out.stats.effective_gflops_per_tick > 0.0);
        // Same trace, same fleet shape ⇒ byte-identical deterministic stats.
        let again = run_setup_fleet(s.name, &setup, &fleet).unwrap();
        assert_eq!(
            out.stats.deterministic_json().dump(),
            again.stats.deterministic_json().dump()
        );
    }

    #[test]
    fn fleet_of_one_matches_solo_runner_streams() {
        // The drop-in-superset claim, at workload scale: a 1-engine fleet
        // with QoS headroom serves the same trace with bit-identical
        // token streams to the solo runner.
        let s = scenario::find("shared_prefix_tenants").unwrap();
        let setup = s.build(Scale::quick());
        let solo = run_setup(s.name, &setup, &RunOptions::default()).unwrap();
        let fleet = FleetConfig {
            engines: 1,
            ..FleetConfig::default()
        };
        let f = run_setup_fleet(s.name, &setup, &fleet).unwrap();
        let solo_streams: Vec<(Vec<i32>, _)> = solo
            .outputs
            .iter()
            .map(|o| (o.tokens.clone(), o.reason))
            .collect();
        let fleet_streams: Vec<(Vec<i32>, _)> = f
            .outputs
            .iter()
            .map(|o| (o.tokens.clone(), o.reason))
            .collect();
        assert_eq!(solo_streams, fleet_streams);
    }

    #[test]
    fn stats_json_round_trips() {
        let s = scenario::find("bursty_poisson").unwrap();
        let out = run(&s, Scale::quick(), &RunOptions::default()).unwrap();
        let doc = crate::util::json::parse(&out.stats.to_json().dump()).expect("stats parse");
        assert_eq!(doc.get("scenario").as_str(), Some("bursty_poisson"));
        assert!(doc.get("wall_us").as_f64().unwrap() > 0.0);
        assert_eq!(doc.get("tokens").as_f64(), Some(out.stats.tokens as f64));
        // Deterministic rendering zeroes exactly the wall clock.
        let det = out.stats.deterministic_json();
        assert_eq!(det.get("wall_us").as_f64(), Some(0.0));
        assert_eq!(det.get("tokens").as_f64(), Some(out.stats.tokens as f64));
        assert_eq!(
            det.get("waste_fraction").as_f64(),
            Some(out.stats.waste_fraction),
            "ledger stats are part of the deterministic surface"
        );
        assert!(det.get("effective_gflops_per_tick").as_f64().unwrap() > 0.0);
    }
}
