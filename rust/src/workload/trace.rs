//! Deterministic workload traces: seeded request streams replayed
//! against the serving API.
//!
//! A [`WorkloadTrace`] is pure data — arrival ticks, prompts, stop sets,
//! sampling and cancellation intents — generated from a [`Rng`] seed and
//! nothing else, so the same seed reproduces the same trace byte for
//! byte ([`WorkloadTrace::to_json`] is the canonical rendering the
//! determinism tests compare).  The [`super::runner`] replays a trace
//! against an engine; this module never touches one.

use crate::coordinator::SamplingParams;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    /// Virtual engine tick at which the request is submitted.  The runner
    /// submits every request whose tick has come before stepping; when
    /// the engine idles, it fast-forwards to the next arrival.
    pub arrive_tick: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Early-stop token set (empty = length-only stopping).
    pub stop_tokens: Vec<i32>,
    /// `None` = greedy decoding.
    pub sampling: Option<SamplingParams>,
    /// Cancel once this many tokens have streamed (`Some(0)` cancels
    /// right after submission — the queued-cancel path).
    pub cancel_after_tokens: Option<usize>,
    /// Tenant the request bills to (`None` = the default tenant).  Only
    /// the fleet runner's QoS admission reads this; the solo runner
    /// ignores it.
    pub tenant: Option<String>,
}

impl TraceRequest {
    /// A plain greedy request arriving at `tick`.
    pub fn new(arrive_tick: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        TraceRequest {
            arrive_tick,
            prompt,
            max_new_tokens,
            stop_tokens: Vec::new(),
            sampling: None,
            cancel_after_tokens: None,
            tenant: None,
        }
    }

    fn to_json(&self) -> Json {
        let sampling = match &self.sampling {
            None => Json::Null,
            Some(p) => Json::obj(vec![
                ("temperature", Json::num(p.temperature as f64)),
                ("top_k", Json::num(p.top_k as f64)),
                ("top_p", Json::num(p.top_p as f64)),
                (
                    "seed",
                    p.seed.map(|s| Json::num(s as f64)).unwrap_or(Json::Null),
                ),
            ]),
        };
        Json::obj(vec![
            ("arrive_tick", Json::num(self.arrive_tick as f64)),
            (
                "prompt",
                Json::Arr(self.prompt.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::num(self.max_new_tokens as f64)),
            (
                "stop_tokens",
                Json::Arr(
                    self.stop_tokens
                        .iter()
                        .map(|&t| Json::num(t as f64))
                        .collect(),
                ),
            ),
            ("sampling", sampling),
            (
                "cancel_after_tokens",
                self.cancel_after_tokens
                    .map(|n| Json::num(n as f64))
                    .unwrap_or(Json::Null),
            ),
            (
                "tenant",
                self.tenant
                    .as_ref()
                    .map(|t| Json::str(t.as_str()))
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

/// An arrival-ordered request stream.
#[derive(Clone, Debug, Default)]
pub struct WorkloadTrace {
    pub requests: Vec<TraceRequest>,
}

impl WorkloadTrace {
    /// Sort by arrival tick (stable, so equal-tick requests keep their
    /// generation order) and return self — generators call this last.
    pub fn sorted(mut self) -> Self {
        self.requests.sort_by_key(|r| r.arrive_tick);
        self
    }

    /// Total prompt tokens across the trace.
    pub fn prompt_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.prompt.len()).sum()
    }

    /// Canonical JSON rendering — fully deterministic for a given seed;
    /// the determinism suite compares `to_json().dump()` byte for byte.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "requests",
            Json::Arr(self.requests.iter().map(|r| r.to_json()).collect()),
        )])
    }
}

/// Bursty Poisson arrival ticks: exponential inter-arrivals whose rate
/// alternates between `burst_rate` and `base_rate` every `phase_ticks`
/// of virtual time — the classic open-loop bursty client.  Returns `n`
/// non-decreasing ticks.
pub fn bursty_poisson_arrivals(
    rng: &mut Rng,
    n: usize,
    base_rate: f64,
    burst_rate: f64,
    phase_ticks: u64,
) -> Vec<u64> {
    assert!(base_rate > 0.0 && burst_rate > 0.0 && phase_ticks > 0);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let in_burst = (t as u64 / phase_ticks) % 2 == 0;
        let rate = if in_burst { burst_rate } else { base_rate };
        t += rng.exponential(rate);
        out.push(t as u64);
    }
    out
}

/// Uniform random prompt over `[1, vocab - 1)` — token 0 is left out so
/// prompts never collide with a padding-style id, and the top id stays
/// free for stop-token scenarios.
pub fn random_prompt(rng: &mut Rng, len: usize, vocab: usize) -> Vec<i32> {
    assert!(vocab >= 4, "vocab too small for prompt generation");
    (0..len)
        .map(|_| rng.range(1, vocab as u64 - 1) as i32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_trace_bytes() {
        let build = |seed: u64| {
            let mut rng = Rng::new(seed);
            let arrivals = bursty_poisson_arrivals(&mut rng, 8, 0.2, 2.0, 16);
            let requests = arrivals
                .into_iter()
                .map(|t| TraceRequest::new(t, random_prompt(&mut rng, 6, 64), 4))
                .collect();
            WorkloadTrace { requests }.sorted()
        };
        assert_eq!(build(7).to_json().dump(), build(7).to_json().dump());
        assert_ne!(build(7).to_json().dump(), build(8).to_json().dump());
    }

    #[test]
    fn arrivals_are_sorted_and_bursty() {
        let mut rng = Rng::new(3);
        let a = bursty_poisson_arrivals(&mut rng, 64, 0.05, 4.0, 32);
        assert_eq!(a.len(), 64);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "non-decreasing");
        // A burst phase at 80x the base rate must pack arrivals tighter
        // than the trace-wide average somewhere.
        let gaps: Vec<u64> = a.windows(2).map(|w| w[1] - w[0]).collect();
        let min = *gaps.iter().min().unwrap();
        let max = *gaps.iter().max().unwrap();
        assert!(min < max, "rate alternation shows up in the gaps");
    }

    #[test]
    fn prompts_stay_in_vocab() {
        let mut rng = Rng::new(11);
        let p = random_prompt(&mut rng, 256, 64);
        assert!(p.iter().all(|&t| (1..63).contains(&t)));
    }
}
