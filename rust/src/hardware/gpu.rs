//! GPU specification database.
//!
//! Numbers are published datasheet values.  The H20 entry is the paper's
//! testbed (§4.1): 148 TFLOPS dense FP16/BF16, 96 GB HBM3 at 4.0 TB/s.

/// The native matmul instruction atom of an architecture.
///
/// On Hopper this is WGMMA (`m64 nN k16`, M fixed at 64); on a TPU the
/// analogue is the 128×128 MXU systolic tile (DESIGN.md §8).  `min_m` is
/// the dimension whose underfill creates the paper's padding pathology.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MatmulAtom {
    /// Minimum/granule M (rows of the accumulator tile).
    pub min_m: usize,
    /// N granularity (Hopper WGMMA: multiples of 8 up to 256).
    pub n_step: usize,
    pub max_n: usize,
    /// K depth per instruction at 16-bit input.
    pub k: usize,
}

impl MatmulAtom {
    /// Hopper WGMMA for FP16/BF16 inputs.
    pub const fn wgmma() -> Self {
        MatmulAtom {
            min_m: 64,
            n_step: 8,
            max_n: 256,
            k: 16,
        }
    }

    /// TPU MXU systolic array tile (the repo's deployment target analogue).
    /// The moving operand streams through in 8-row sublane groups, so the
    /// N side has granularity 8 while the stationary M side is the full
    /// 128-row systolic dimension — the same wide-M/narrow-N asymmetry as
    /// WGMMA, which is why ETAP transfers (DESIGN.md §8).
    pub const fn mxu() -> Self {
        MatmulAtom {
            min_m: 128,
            n_step: 8,
            max_n: 128,
            k: 128,
        }
    }
}

/// Published per-GPU specification.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Dense (non-sparsity) FP16/BF16 tensor-core TFLOPS.
    pub fp16_tflops: f64,
    /// HBM capacity in GiB.
    pub hbm_gib: f64,
    /// HBM bandwidth in TB/s.
    pub hbm_tbps: f64,
    /// Streaming multiprocessors.
    pub sm_count: usize,
    /// Shared memory per SM in KiB (Hopper: 228 usable).
    pub smem_kib: usize,
    pub atom: MatmulAtom,
}

impl GpuSpec {
    /// NVIDIA H20 — the paper's testbed (§4.1).
    pub fn h20() -> Self {
        GpuSpec {
            name: "H20",
            fp16_tflops: 148.0,
            hbm_gib: 96.0,
            hbm_tbps: 4.0,
            sm_count: 78,
            smem_kib: 228,
            atom: MatmulAtom::wgmma(),
        }
    }

    /// NVIDIA H100 SXM (for the "optimized for high-end" contrast).
    pub fn h100() -> Self {
        GpuSpec {
            name: "H100",
            fp16_tflops: 989.0,
            hbm_gib: 80.0,
            hbm_tbps: 3.35,
            sm_count: 132,
            smem_kib: 228,
            atom: MatmulAtom::wgmma(),
        }
    }

    /// NVIDIA H800 (export-variant H100: same compute, clipped interconnect).
    pub fn h800() -> Self {
        GpuSpec {
            name: "H800",
            fp16_tflops: 989.0,
            hbm_gib: 80.0,
            hbm_tbps: 3.35,
            sm_count: 132,
            smem_kib: 228,
            atom: MatmulAtom::wgmma(),
        }
    }

    /// NVIDIA A100 SXM (pre-Hopper: mma.sync, min M effectively 16).
    pub fn a100() -> Self {
        GpuSpec {
            name: "A100",
            fp16_tflops: 312.0,
            hbm_gib: 80.0,
            hbm_tbps: 2.04,
            sm_count: 108,
            smem_kib: 164,
            atom: MatmulAtom {
                min_m: 16,
                n_step: 8,
                max_n: 16,
                k: 16,
            },
        }
    }

    /// TPU-like spec used for the hardware-adaptation analysis (DESIGN.md
    /// §8): one TensorCore of a v5p-class part.
    pub fn tpu_like() -> Self {
        GpuSpec {
            name: "TPU-like",
            fp16_tflops: 229.0,
            hbm_gib: 95.0,
            hbm_tbps: 2.76,
            sm_count: 1,
            smem_kib: 16 * 1024, // 16 MiB VMEM plays the SMEM role
            atom: MatmulAtom::mxu(),
        }
    }

    /// Look up by name (CLI convenience).
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_lowercase().as_str() {
            "h20" => Some(Self::h20()),
            "h100" => Some(Self::h100()),
            "h800" => Some(Self::h800()),
            "a100" => Some(Self::a100()),
            "tpu" | "tpu-like" => Some(Self::tpu_like()),
            _ => None,
        }
    }

    /// HBM bandwidth in bytes/µs.
    pub fn bytes_per_us(&self) -> f64 {
        self.hbm_tbps * 1e12 / 1e6
    }

    /// Peak FLOPs/µs at FP16.
    pub fn flops_per_us(&self) -> f64 {
        self.fp16_tflops * 1e12 / 1e6
    }

    /// The compute intensity (FLOPs/byte) at which compute and memory time
    /// are equal — the roofline ridge point.
    pub fn ridge_flops_per_byte(&self) -> f64 {
        self.flops_per_us() / self.bytes_per_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h20_matches_paper() {
        let g = GpuSpec::h20();
        assert_eq!(g.fp16_tflops, 148.0); // paper §1, §4.1
        assert_eq!(g.hbm_tbps, 4.0);
        assert_eq!(g.hbm_gib, 96.0);
        assert_eq!(g.atom.min_m, 64); // the WGMMA constraint (§3.1)
    }

    #[test]
    fn h20_vs_h100_compute_gap() {
        // The paper motivates with "148 vs 1979 (with sparsity)"; dense
        // H100 is 989 — either way the H20 is compute-starved per byte.
        let h20 = GpuSpec::h20();
        let h100 = GpuSpec::h100();
        assert!(h100.fp16_tflops / h20.fp16_tflops > 6.0);
        // And the H20's ridge point is far LOWER: it becomes compute-bound
        // at much lower intensity, so padding waste hurts more.
        assert!(h20.ridge_flops_per_byte() < h100.ridge_flops_per_byte());
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuSpec::by_name("h20").unwrap().name, "H20");
        assert_eq!(GpuSpec::by_name("H100").unwrap().name, "H100");
        assert!(GpuSpec::by_name("b200").is_none());
    }

    #[test]
    fn unit_conversions() {
        let g = GpuSpec::h20();
        assert!((g.bytes_per_us() - 4.0e6).abs() < 1.0);
        assert!((g.flops_per_us() - 148.0e6).abs() < 1.0);
        assert!((g.ridge_flops_per_byte() - 37.0).abs() < 1e-9);
    }
}
