//! WGMMA instruction-shape algebra — the arithmetic core of the paper.
//!
//! Hopper's warpgroup MMA computes a `m64 × nN × k16` tile per instruction:
//! the M side is fixed at 64 rows.  A decode workload that puts the 16
//! per-GPU query heads on M must issue 64-row instructions with 48 rows of
//! garbage — `padding_factor(16) == 4.0`, i.e. 75 % of issued FLOPs are
//! thrown away, capping utilization at 25 % (paper §1, §3.1).  ETAP's whole
//! contribution is choosing operand orientation so M is the KV length.

use super::gpu::MatmulAtom;

/// Hopper WGMMA minimum/only M.
pub const WGMMA_MIN_M: usize = 64;
/// WGMMA N granularity.
pub const WGMMA_N_STEP: usize = 8;
/// WGMMA K depth for 16-bit inputs.
pub const WGMMA_K_FP16: usize = 16;

/// Rows actually issued for a logical row count (padded up to the atom).
pub fn padded_rows(rows: usize, atom: &MatmulAtom) -> usize {
    assert!(rows > 0, "empty M");
    rows.div_ceil(atom.min_m) * atom.min_m
}

/// Issued-FLOPs multiplier from M-padding: `padded / logical ≥ 1`.
pub fn padding_factor(rows: usize, atom: &MatmulAtom) -> f64 {
    padded_rows(rows, atom) as f64 / rows as f64
}

/// Columns issued for a logical column count (padded to `n_step`, capped
/// tiles of `max_n`).
pub fn padded_cols(cols: usize, atom: &MatmulAtom) -> usize {
    assert!(cols > 0, "empty N");
    cols.div_ceil(atom.n_step) * atom.n_step
}

/// Number of WGMMA instructions for a (M × N × K) GEMM.
pub fn instruction_count(m: usize, n: usize, k: usize, atom: &MatmulAtom) -> usize {
    let m_tiles = m.div_ceil(atom.min_m);
    let n_tiles = padded_cols(n, atom).div_ceil(atom.max_n.min(padded_cols(n, atom)));
    let k_tiles = k.div_ceil(atom.k);
    m_tiles * n_tiles.max(1) * k_tiles
}

/// Compute utilization ceiling from M-padding alone (the paper's "<25 %").
pub fn utilization_ceiling(rows: usize, atom: &MatmulAtom) -> f64 {
    1.0 / padding_factor(rows, atom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::gpu::MatmulAtom;

    #[test]
    fn paper_headline_padding() {
        let wgmma = MatmulAtom::wgmma();
        // 16 heads per GPU (128 heads / 8 GPUs) → 4× padding, ≤25 % util.
        assert_eq!(padded_rows(16, &wgmma), 64);
        assert_eq!(padding_factor(16, &wgmma), 4.0);
        assert!(utilization_ceiling(16, &wgmma) <= 0.25);
    }

    #[test]
    fn no_padding_when_kv_major() {
        let wgmma = MatmulAtom::wgmma();
        // ETAP's M = KV block (multiples of 64) → factor exactly 1.
        for bc in [64, 128, 256, 65536] {
            assert_eq!(padding_factor(bc, &wgmma), 1.0);
        }
        // Non-aligned long KV still ~1 (amortized over many tiles).
        assert!(padding_factor(65537, &wgmma) < 1.001);
    }

    #[test]
    fn padding_monotone_decreasing_in_rows() {
        let wgmma = MatmulAtom::wgmma();
        let mut prev = f64::INFINITY;
        for rows in [1, 2, 4, 8, 16, 32, 64] {
            let f = padding_factor(rows, &wgmma);
            assert!(f <= prev);
            prev = f;
        }
        assert_eq!(padding_factor(1, &wgmma), 64.0);
    }

    #[test]
    fn mxu_underfill_analogue() {
        // The TPU adaptation: 16 rows into a 128-row systolic array → 8×.
        let mxu = MatmulAtom::mxu();
        assert_eq!(padding_factor(16, &mxu), 8.0);
        assert_eq!(padding_factor(128, &mxu), 1.0);
    }

    #[test]
    fn a100_does_not_suffer() {
        // Pre-Hopper mma.sync m16: 16 heads fit exactly — the pathology is
        // Hopper-specific, which is why the paper targets WGMMA.
        let a100 = MatmulAtom {
            min_m: 16,
            n_step: 8,
            max_n: 16,
            k: 16,
        };
        assert_eq!(padding_factor(16, &a100), 1.0);
    }

    #[test]
    fn instruction_counts() {
        let wgmma = MatmulAtom::wgmma();
        // 64×64×576 GEMM: 1 M-tile × 1 N-tile(64≤256 → padded 64) × 36 K.
        assert_eq!(instruction_count(64, 64, 576, &wgmma), 36);
        // 16 rows cost the same as 64.
        assert_eq!(
            instruction_count(16, 64, 576, &wgmma),
            instruction_count(64, 64, 576, &wgmma)
        );
    }

    #[test]
    #[should_panic(expected = "empty M")]
    fn zero_rows_panics() {
        padded_rows(0, &MatmulAtom::wgmma());
    }
}
