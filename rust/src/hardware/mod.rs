//! GPU hardware substrate: published specs for the H20 and its relatives,
//! plus the matmul-atom (WGMMA / MXU) shape algebra the paper's argument
//! rests on.
//!
//! We have no H20 (repro band 0/5); these specs parameterize the analytic
//! performance simulator in `crate::sim` (see DESIGN.md §2 for why this
//! substitution preserves the paper's effect).

pub mod gpu;
pub mod wgmma;

pub use gpu::{GpuSpec, MatmulAtom};
pub use wgmma::{padded_rows, padding_factor, WGMMA_K_FP16, WGMMA_MIN_M, WGMMA_N_STEP};
