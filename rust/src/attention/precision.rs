//! FP16 precision emulation — the Table 1 experiment (§4.3).
//!
//! The paper reports RMSE of each kernel's FP16 output against an FP64
//! reference: FlashAttention-3 1.9e-4, FlashMLA-ETAP 1.25e-5 (15.2× lower).
//!
//! Mechanism reproduced here (DESIGN.md §2 substitution table): the error
//! gap is an *accumulation-precision and rescale-chain* effect.
//!
//! * `fa3_fp16` — models a kernel that keeps the growing output block in
//!   FP16 registers: every per-block rescale (`O *= α`) and every MAC of
//!   `P̃·V` rounds through FP16.  Over `T_c` blocks the rounding errors of
//!   the rescale chain compound.
//! * `etap_fp16` — models Algorithm 1: the `O^T` accumulator stays in FP32
//!   on-chip for the whole context (split halves, lines 14/26); only the
//!   epilogue (line 30) rounds to FP16, once.
//!
//! In both models the *inputs* (q, cache) and the S/P̃ operands are FP16 —
//! that part is identical, as both kernels feed FP16 tiles to the MMA unit.

use crate::util::half::{mac_f16_acc, round_f16};
use crate::util::rng::Rng;
use crate::util::stats::rmse_f32_vs_f64;

use super::naive::naive_f64;
use super::AttnShape;

/// Quantize a slice to FP16 precision (round-to-nearest-even).
pub fn quantize_f16(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| round_f16(x)).collect()
}

/// FA-3-style FP16 pipeline: online softmax with the output accumulator,
/// rescale chain, and MACs all rounding through FP16.
pub fn fa3_fp16(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
) -> Vec<f32> {
    shape.validate(q, cache);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);
    let mut acc = vec![0.0f32; h * dv]; // values always f16-rounded
    let mut m = vec![f32::NEG_INFINITY; h];
    let mut l = vec![0.0f32; h]; // softmax stats stay f32 (both kernels do)
    let mut s_blk = vec![0.0f32; block_kv];

    let mut j0 = 0;
    while j0 < n {
        let bc = block_kv.min(n - j0);
        for hi in 0..h {
            let qrow = &q[hi * d..(hi + 1) * d];
            let mut blk_max = f32::NEG_INFINITY;
            for (jj, s) in s_blk[..bc].iter_mut().enumerate() {
                let krow = &cache[(j0 + jj) * d..(j0 + jj) * d + d];
                // QK^T accumulates in f32 (tensor cores do f32 accumulate
                // for S in both kernels).
                let mut dot = 0.0f32;
                for k in 0..d {
                    dot += qrow[k] * krow[k];
                }
                *s = dot * scale;
                blk_max = blk_max.max(*s);
            }
            let m_new = m[hi].max(blk_max);
            let alpha = round_f16((m[hi] - m_new).exp());
            let orow = &mut acc[hi * dv..(hi + 1) * dv];
            if alpha != 1.0 {
                for o in orow.iter_mut() {
                    *o = round_f16(*o * alpha); // FP16 rescale chain
                }
            }
            let mut block_l = 0.0f32;
            for (jj, &s) in s_blk[..bc].iter().enumerate() {
                let p = round_f16((s - m_new).exp()); // P̃ as FP16 operand
                block_l += p;
                let vrow = &cache[(j0 + jj) * d..(j0 + jj) * d + dv];
                for (o, &v) in orow.iter_mut().zip(vrow) {
                    *o = mac_f16_acc(p, v, *o); // FP16 accumulate
                }
            }
            l[hi] = l[hi] * alpha + block_l;
            m[hi] = m_new;
        }
        j0 += bc;
    }
    for hi in 0..h {
        let inv = 1.0 / l[hi].max(1e-38);
        for o in &mut acc[hi * dv..(hi + 1) * dv] {
            *o = round_f16(*o * inv);
        }
    }
    acc
}

/// ETAP FP16 pipeline: FP16 operands (P̃, V), FP32 `O^T` accumulator and
/// rescale, single FP16 rounding in the epilogue (Algorithm 1).
pub fn etap_fp16(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
) -> Vec<f32> {
    shape.validate(q, cache);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);
    let half = dv / 2;
    let mut acc_t = vec![0.0f32; dv * h]; // FP32 on-chip accumulator
    let mut m = vec![f32::NEG_INFINITY; h];
    let mut l = vec![0.0f32; h];
    let mut p_t = vec![0.0f32; block_kv * h];
    let mut r = vec![0.0f32; h];

    let mut j0 = 0;
    while j0 < n {
        let bc = block_kv.min(n - j0);
        let mut blk_max = vec![f32::NEG_INFINITY; h];
        for jj in 0..bc {
            let krow = &cache[(j0 + jj) * d..(j0 + jj) * d + d];
            for hi in 0..h {
                let qrow = &q[hi * d..(hi + 1) * d];
                let mut dot = 0.0f32;
                for k in 0..d {
                    dot += krow[k] * qrow[k];
                }
                let s = dot * scale;
                p_t[jj * h + hi] = s;
                blk_max[hi] = blk_max[hi].max(s);
            }
        }
        for hi in 0..h {
            let m_new = m[hi].max(blk_max[hi]);
            r[hi] = (m[hi] - m_new).exp(); // R_i in f32 (line 12)
            m[hi] = m_new;
        }
        for jj in 0..bc {
            for hi in 0..h {
                // P̃^T is an FP16 MMA operand in ETAP too.
                p_t[jj * h + hi] = round_f16((p_t[jj * h + hi] - m[hi]).exp());
            }
        }
        for hi in 0..h {
            let mut col = 0.0f32;
            for jj in 0..bc {
                col += p_t[jj * h + hi];
            }
            l[hi] = l[hi] * r[hi] + col;
        }
        for (lo, hi_end) in [(0usize, half), (half, dv)] {
            for vd in lo..hi_end {
                let arow = &mut acc_t[vd * h..(vd + 1) * h];
                for (a, rr) in arow.iter_mut().zip(&r) {
                    *a *= rr; // FP32 rescale — no rounding
                }
                for jj in 0..bc {
                    let v = round_f16(cache[(j0 + jj) * d + vd]); // FP16 operand
                    let prow = &p_t[jj * h..jj * h + h];
                    for (a, &p) in arow.iter_mut().zip(prow) {
                        *a += v * p; // FP32 accumulate
                    }
                }
            }
        }
        j0 += bc;
    }

    let mut out = vec![0.0f32; h * dv];
    for hi in 0..h {
        let inv = 1.0 / l[hi].max(1e-38);
        for vd in 0..dv {
            // Single epilogue rounding (line 30).
            out[hi * dv + vd] = round_f16(acc_t[vd * h + hi] * inv);
        }
    }
    out
}

/// Result of one Table 1 measurement.
#[derive(Clone, Debug)]
pub struct RmseResult {
    pub framework: &'static str,
    pub rmse: f64,
}

/// Run the Table 1 experiment: FP16 inputs, FP64 reference, RMSE per
/// framework, averaged over `reps` random workloads.
pub fn table1_experiment(
    shape: &AttnShape,
    scale: f32,
    block_kv: usize,
    reps: usize,
    seed: u64,
) -> Vec<RmseResult> {
    let mut rng = Rng::new(seed);
    let mut se_fa3 = 0.0f64;
    let mut se_etap = 0.0f64;
    let mut count = 0usize;
    for _ in 0..reps {
        let q = quantize_f16(&rng.normal_vec(shape.q_len()));
        let cache = quantize_f16(&rng.normal_vec(shape.cache_len()));
        let reference = naive_f64(shape, &q, &cache, scale as f64);
        let fa3 = fa3_fp16(shape, &q, &cache, scale, block_kv);
        let etap = etap_fp16(shape, &q, &cache, scale, block_kv);
        let r_fa3 = rmse_f32_vs_f64(&fa3, &reference);
        let r_etap = rmse_f32_vs_f64(&etap, &reference);
        se_fa3 += r_fa3 * r_fa3;
        se_etap += r_etap * r_etap;
        count += 1;
    }
    vec![
        RmseResult {
            framework: "FlashAttention-3",
            rmse: (se_fa3 / count as f64).sqrt(),
        },
        RmseResult {
            framework: "FlashMLA-ETAP",
            rmse: (se_etap / count as f64).sqrt(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_pipelines_approximate_reference() {
        let shape = AttnShape {
            h: 4,
            d: 64,
            dv: 32,
            n: 256,
        };
        let mut rng = Rng::new(21);
        let q = quantize_f16(&rng.normal_vec(shape.q_len()));
        let cache = quantize_f16(&rng.normal_vec(shape.cache_len()));
        let reference = naive_f64(&shape, &q, &cache, 0.125);
        for out in [
            fa3_fp16(&shape, &q, &cache, 0.125, 64),
            etap_fp16(&shape, &q, &cache, 0.125, 64),
        ] {
            let r = rmse_f32_vs_f64(&out, &reference);
            assert!(r < 1e-2, "rmse {r} too large — broken pipeline");
            assert!(r > 0.0, "exact match is suspicious for fp16");
        }
    }

    #[test]
    fn etap_beats_fa3_rmse() {
        // Table 1's shape: the FP32-accumulator pipeline is much more
        // accurate than the FP16 rescale-chain pipeline.
        let shape = AttnShape {
            h: 8,
            d: 64,
            dv: 64,
            n: 2048,
        };
        let res = table1_experiment(&shape, 0.125, 64, 2, 42);
        let fa3 = res[0].rmse;
        let etap = res[1].rmse;
        assert!(
            etap * 4.0 < fa3,
            "expected ≥4× gap at n=2048: fa3 {fa3:e} etap {etap:e}"
        );
    }

    #[test]
    fn fa3_error_grows_with_context() {
        // More blocks → longer rescale chain → more FP16 roundings.
        let scale = 0.125;
        let mk = |n| AttnShape {
            h: 4,
            d: 64,
            dv: 32,
            n,
        };
        let short = table1_experiment(&mk(256), scale, 64, 2, 7)[0].rmse;
        let long = table1_experiment(&mk(4096), scale, 64, 2, 7)[0].rmse;
        assert!(
            long > short,
            "fa3 rmse should grow with context: {short:e} → {long:e}"
        );
    }
}
