//! Query-major online-softmax attention (the FlashMLA baseline order) in
//! f32 — blockwise over KV with running (m, l) per head, matching the L1
//! Pallas kernel `mla_decode.py` operation for operation.

use super::AttnShape;

/// Blockwise online-softmax decode attention for one request.
pub fn online_f32(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
) -> Vec<f32> {
    shape.validate(q, cache);
    assert!(block_kv >= 1);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);

    let mut acc = vec![0.0f32; h * dv];
    let mut m = vec![f32::NEG_INFINITY; h];
    let mut l = vec![0.0f32; h];
    let mut s_blk = vec![0.0f32; block_kv];

    let mut j0 = 0;
    while j0 < n {
        let bc = block_kv.min(n - j0);
        for hi in 0..h {
            let qrow = &q[hi * d..(hi + 1) * d];
            // S block for this head.
            let mut blk_max = f32::NEG_INFINITY;
            for (jj, s) in s_blk[..bc].iter_mut().enumerate() {
                let krow = &cache[(j0 + jj) * d..(j0 + jj) * d + d];
                let mut dot = 0.0f32;
                for k in 0..d {
                    dot += qrow[k] * krow[k];
                }
                *s = dot * scale;
                blk_max = blk_max.max(*s);
            }
            // Online rescale.
            let m_new = m[hi].max(blk_max);
            let alpha = (m[hi] - m_new).exp();
            let orow = &mut acc[hi * dv..(hi + 1) * dv];
            if alpha != 1.0 {
                for o in orow.iter_mut() {
                    *o *= alpha;
                }
            }
            let mut block_l = 0.0f32;
            for (jj, &s) in s_blk[..bc].iter().enumerate() {
                let p = (s - m_new).exp();
                block_l += p;
                let vrow = &cache[(j0 + jj) * d..(j0 + jj) * d + dv];
                for (o, &v) in orow.iter_mut().zip(vrow) {
                    *o += p * v;
                }
            }
            l[hi] = l[hi] * alpha + block_l;
            m[hi] = m_new;
        }
        j0 += bc;
    }

    for hi in 0..h {
        let inv = 1.0 / l[hi].max(1e-38);
        for o in &mut acc[hi * dv..(hi + 1) * dv] {
            *o *= inv;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::naive::naive_f32;
    use crate::util::rng::Rng;

    fn case(h: usize, d: usize, dv: usize, n: usize, seed: u64) -> (AttnShape, Vec<f32>, Vec<f32>) {
        let shape = AttnShape { h, d, dv, n };
        let mut rng = Rng::new(seed);
        let q = rng.normal_vec(shape.q_len());
        let cache = rng.normal_vec(shape.cache_len());
        (shape, q, cache)
    }

    #[test]
    fn matches_naive_various_blocks() {
        let (shape, q, cache) = case(4, 32, 16, 200, 7);
        let want = naive_f32(&shape, &q, &cache, 0.2);
        for block in [1, 3, 64, 200, 256] {
            let got = online_f32(&shape, &q, &cache, 0.2, block);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "block {block}");
            }
        }
    }

    #[test]
    fn single_block_equals_naive_exactly_shaped() {
        let (shape, q, cache) = case(2, 16, 8, 64, 8);
        let a = online_f32(&shape, &q, &cache, 0.3, 64);
        let b = naive_f32(&shape, &q, &cache, 0.3);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn extreme_scores_stay_finite() {
        // Large-magnitude q would overflow a non-online softmax in f32.
        let shape = AttnShape {
            h: 1,
            d: 8,
            dv: 4,
            n: 96,
        };
        let q = vec![40.0f32; shape.q_len()];
        let mut rng = Rng::new(9);
        let cache = rng.normal_vec(shape.cache_len());
        let out = online_f32(&shape, &q, &cache, 1.0, 32);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
