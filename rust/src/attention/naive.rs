//! Naive full-matrix attention references (f64 and f32).
//!
//! The f64 version is the oracle everything else is measured against —
//! including the Table 1 RMSE experiment, matching the paper's §4.3
//! methodology ("RMSE between the FP16 outputs … and a double-precision
//! (FP64) reference implementation").

use super::AttnShape;

/// Full-precision f64 MLA decode attention for one request.
pub fn naive_f64(shape: &AttnShape, q: &[f32], cache: &[f32], scale: f64) -> Vec<f64> {
    shape.validate(q, cache);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);
    let mut out = vec![0.0f64; h * dv];
    let mut scores = vec![0.0f64; n];
    for hi in 0..h {
        let qrow = &q[hi * d..(hi + 1) * d];
        let mut m = f64::NEG_INFINITY;
        for (j, s) in scores.iter_mut().enumerate() {
            let krow = &cache[j * d..(j + 1) * d];
            let mut acc = 0.0f64;
            for k in 0..d {
                acc += qrow[k] as f64 * krow[k] as f64;
            }
            *s = acc * scale;
            m = m.max(*s);
        }
        let mut l = 0.0f64;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            l += *s;
        }
        let orow = &mut out[hi * dv..(hi + 1) * dv];
        for (j, &p) in scores.iter().enumerate() {
            let w = p / l;
            let vrow = &cache[j * d..j * d + dv];
            for (o, &v) in orow.iter_mut().zip(vrow) {
                *o += w * v as f64;
            }
        }
    }
    out
}

/// Full-matrix f32 attention (same math, f32 arithmetic).
pub fn naive_f32(shape: &AttnShape, q: &[f32], cache: &[f32], scale: f32) -> Vec<f32> {
    shape.validate(q, cache);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);
    let mut out = vec![0.0f32; h * dv];
    let mut scores = vec![0.0f32; n];
    for hi in 0..h {
        let qrow = &q[hi * d..(hi + 1) * d];
        let mut m = f32::NEG_INFINITY;
        for (j, s) in scores.iter_mut().enumerate() {
            let krow = &cache[j * d..(j + 1) * d];
            let mut acc = 0.0f32;
            for k in 0..d {
                acc += qrow[k] * krow[k];
            }
            *s = acc * scale;
            m = m.max(*s);
        }
        let mut l = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            l += *s;
        }
        let orow = &mut out[hi * dv..(hi + 1) * dv];
        for (j, &p) in scores.iter().enumerate() {
            let w = p / l;
            let vrow = &cache[j * d..j * d + dv];
            for (o, &v) in orow.iter_mut().zip(vrow) {
                *o += w * v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_position_is_identity() {
        // n=1: softmax over one score is 1 → output == V row.
        let shape = AttnShape {
            h: 2,
            d: 4,
            dv: 3,
            n: 1,
        };
        let mut rng = Rng::new(1);
        let q = rng.normal_vec(shape.q_len());
        let cache = rng.normal_vec(shape.cache_len());
        let out = naive_f64(&shape, &q, &cache, 0.5);
        for hi in 0..2 {
            for k in 0..3 {
                assert!((out[hi * 3 + k] - cache[k] as f64).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn uniform_scores_average_values() {
        // q = 0 → all scores equal → output = mean of V rows.
        let shape = AttnShape {
            h: 1,
            d: 4,
            dv: 2,
            n: 8,
        };
        let mut rng = Rng::new(2);
        let q = vec![0.0f32; shape.q_len()];
        let cache = rng.normal_vec(shape.cache_len());
        let out = naive_f64(&shape, &q, &cache, 1.0);
        for k in 0..2 {
            let mean: f64 = (0..8).map(|j| cache[j * 4 + k] as f64).sum::<f64>() / 8.0;
            assert!((out[k] - mean).abs() < 1e-12);
        }
    }

    #[test]
    fn f32_close_to_f64() {
        let shape = AttnShape {
            h: 4,
            d: 32,
            dv: 16,
            n: 128,
        };
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(shape.q_len());
        let cache = rng.normal_vec(shape.cache_len());
        let o64 = naive_f64(&shape, &q, &cache, 0.17);
        let o32 = naive_f32(&shape, &q, &cache, 0.17);
        for (a, b) in o32.iter().zip(&o64) {
            assert!((*a as f64 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn output_in_value_convex_hull() {
        // Attention output is a convex combination of V rows.
        let shape = AttnShape {
            h: 2,
            d: 8,
            dv: 4,
            n: 16,
        };
        let mut rng = Rng::new(4);
        let q = rng.normal_vec(shape.q_len());
        let cache = rng.normal_vec(shape.cache_len());
        let out = naive_f64(&shape, &q, &cache, 1.0);
        for k in 0..4 {
            let lo = (0..16)
                .map(|j| cache[j * 8 + k] as f64)
                .fold(f64::INFINITY, f64::min);
            let hi = (0..16)
                .map(|j| cache[j * 8 + k] as f64)
                .fold(f64::NEG_INFINITY, f64::max);
            for hh in 0..2 {
                let v = out[hh * 4 + k];
                assert!(v >= lo - 1e-12 && v <= hi + 1e-12);
            }
        }
    }
}
