//! ETAP-ordered (KV-major / transposed) attention in f32 — the CPU mirror
//! of the L1 Pallas kernel `etap_decode.py` and of Algorithm 1:
//!
//! * the KV block is the outer ("M") loop, heads the inner column axis;
//! * softmax statistics are tracked per *column* of `S^T`;
//! * the output accumulator lives transposed (`O^T`, `[dv × h]`) with the
//!   split-V halves updated separately (Algorithm 1 lines 14/26);
//! * one final transpose at the end (eq. 4).

use super::AttnShape;

/// Blockwise ETAP decode attention for one request.
pub fn etap_f32(
    shape: &AttnShape,
    q: &[f32],
    cache: &[f32],
    scale: f32,
    block_kv: usize,
) -> Vec<f32> {
    shape.validate(q, cache);
    assert!(block_kv >= 1);
    let (h, d, dv, n) = (shape.h, shape.d, shape.dv, shape.n);
    let half = dv / 2;

    // O^T accumulator [dv × h] and per-column (per-head) stats.
    let mut acc_t = vec![0.0f32; dv * h];
    let mut m = vec![f32::NEG_INFINITY; h];
    let mut l = vec![0.0f32; h];
    let mut s_t = vec![0.0f32; block_kv * h]; // S^T block [bc × h]
    let mut r = vec![0.0f32; h];

    let mut j0 = 0;
    while j0 < n {
        let bc = block_kv.min(n - j0);
        // S^T = K · Q^T for this block (eq. 1).
        let mut blk_max = vec![f32::NEG_INFINITY; h];
        for jj in 0..bc {
            let krow = &cache[(j0 + jj) * d..(j0 + jj) * d + d];
            for hi in 0..h {
                let qrow = &q[hi * d..(hi + 1) * d];
                let mut dot = 0.0f32;
                for k in 0..d {
                    dot += krow[k] * qrow[k];
                }
                let s = dot * scale;
                s_t[jj * h + hi] = s;
                blk_max[hi] = blk_max[hi].max(s);
            }
        }
        // Column-wise online softmax (eq. 2): R_i = exp(m_old - m_new).
        for hi in 0..h {
            let m_new = m[hi].max(blk_max[hi]);
            r[hi] = (m[hi] - m_new).exp();
            m[hi] = m_new;
        }
        // P^T and column sums.
        for jj in 0..bc {
            for hi in 0..h {
                let p = (s_t[jj * h + hi] - m[hi]).exp();
                s_t[jj * h + hi] = p;
            }
        }
        for hi in 0..h {
            let mut col = 0.0f32;
            for jj in 0..bc {
                col += s_t[jj * h + hi];
            }
            l[hi] = l[hi] * r[hi] + col;
        }
        // O^T += V^T · P^T, split into the two V halves (lines 14/26):
        // rescale each accumulator row by R, then add the block product.
        for (lo, hi_end) in [(0usize, half), (half, dv)] {
            for vd in lo..hi_end {
                let arow = &mut acc_t[vd * h..(vd + 1) * h];
                for (a, rr) in arow.iter_mut().zip(&r) {
                    *a *= rr;
                }
                for jj in 0..bc {
                    let v = cache[(j0 + jj) * d + vd];
                    let prow = &s_t[jj * h..jj * h + h];
                    for (a, &p) in arow.iter_mut().zip(prow) {
                        *a += v * p;
                    }
                }
            }
        }
        j0 += bc;
    }

    // Epilogue: normalize (line 29) and the single transpose (line 30).
    let mut out = vec![0.0f32; h * dv];
    for hi in 0..h {
        let inv = 1.0 / l[hi].max(1e-38);
        for vd in 0..dv {
            out[hi * dv + vd] = acc_t[vd * h + hi] * inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::naive::{naive_f32, naive_f64};
    use crate::attention::online::online_f32;
    use crate::util::rng::Rng;

    fn case(h: usize, d: usize, dv: usize, n: usize, seed: u64) -> (AttnShape, Vec<f32>, Vec<f32>) {
        let shape = AttnShape { h, d, dv, n };
        let mut rng = Rng::new(seed);
        let q = rng.normal_vec(shape.q_len());
        let cache = rng.normal_vec(shape.cache_len());
        (shape, q, cache)
    }

    #[test]
    fn matches_naive() {
        let (shape, q, cache) = case(4, 32, 16, 150, 11);
        let want = naive_f32(&shape, &q, &cache, 0.2);
        for block in [1, 32, 64, 150, 512] {
            let got = etap_f32(&shape, &q, &cache, 0.2, block);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "block {block}");
            }
        }
    }

    #[test]
    fn matches_query_major_order() {
        // The paper's §3.1 equivalence: same attention, different order.
        let (shape, q, cache) = case(16, 64, 32, 256, 12);
        let a = etap_f32(&shape, &q, &cache, 0.125, 64);
        let b = online_f32(&shape, &q, &cache, 0.125, 64);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn paper_geometry_against_f64() {
        let (shape, q, cache) = case(16, 576, 512, 512, 13);
        let scale = 1.0 / (576.0f32).sqrt();
        let got = etap_f32(&shape, &q, &cache, scale, 64);
        let want = naive_f64(&shape, &q, &cache, scale as f64);
        for (a, b) in got.iter().zip(&want) {
            assert!((*a as f64 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn odd_dv_split_handled() {
        // dv not divisible by 2 → halves (0, dv/2) and (dv/2, dv) still
        // cover everything.
        let (shape, q, cache) = case(2, 8, 5, 32, 14);
        let got = etap_f32(&shape, &q, &cache, 0.3, 16);
        let want = naive_f32(&shape, &q, &cache, 0.3);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
