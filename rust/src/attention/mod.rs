//! CPU attention numerics substrate.
//!
//! Exact implementations of the decode-attention pipelines in f64/f32 plus
//! precision-emulated FP16 variants.  These serve three purposes:
//!
//! 1. ground truth for property tests (online softmax == naive softmax;
//!    ETAP order == query-major order),
//! 2. the Table 1 RMSE experiment (`precision`), and
//! 3. a pure-Rust fallback attention used by the coordinator when PJRT
//!    artifacts are not available (tests, simulation-only runs).
//!
//! Layout conventions: row-major flat slices.  One *request* is
//! `q [h × d]`, `cache [n × d]` (latent: K = full row, V = first dv dims),
//! output `[h × dv]`.

pub mod etap;
pub mod naive;
pub mod online;
pub mod precision;

pub use etap::etap_f32;
pub use naive::{naive_f32, naive_f64};
pub use online::online_f32;

/// Shape of one decode-attention request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AttnShape {
    /// Heads.
    pub h: usize,
    /// Query/key (latent) dim.
    pub d: usize,
    /// Value dim (first `dv` latent dims).
    pub dv: usize,
    /// KV context length.
    pub n: usize,
}

impl AttnShape {
    /// DeepSeek-R1 per-GPU shard geometry (paper §4.1).
    pub fn paper(n: usize) -> Self {
        AttnShape {
            h: 16,
            d: 576,
            dv: 512,
            n,
        }
    }

    pub fn q_len(&self) -> usize {
        self.h * self.d
    }

    pub fn cache_len(&self) -> usize {
        self.n * self.d
    }

    pub fn out_len(&self) -> usize {
        self.h * self.dv
    }

    pub fn validate(&self, q: &[f32], cache: &[f32]) {
        assert_eq!(q.len(), self.q_len(), "q length");
        assert_eq!(cache.len(), self.cache_len(), "cache length");
        assert!(self.dv <= self.d, "dv must fit in the latent");
        assert!(self.n > 0 && self.h > 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape() {
        let s = AttnShape::paper(1024);
        assert_eq!(s.q_len(), 16 * 576);
        assert_eq!(s.out_len(), 16 * 512);
        assert_eq!(s.cache_len(), 1024 * 576);
    }
}
