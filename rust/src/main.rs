//! `flashmla-etap` — leader CLI.
//!
//! Subcommands:
//!   sweep     reproduce Fig. 1 (TFLOPS/s per framework per seq len)
//!   rmse      reproduce Table 1 (FP16 RMSE vs FP64 reference)
//!   serve     end-to-end serving demo on the AOT artifacts (PJRT CPU)
//!   simulate  paper-scale 8×H20 cluster serving simulation
//!   padding   WGMMA padding / utilization analysis (§3.1)
//!   info      artifact manifest summary
//!
//! Run `flashmla-etap <cmd> --help` for the per-command flags.

use std::path::PathBuf;
use std::time::Instant;

use flashmla_etap::attention::precision::table1_experiment;
use flashmla_etap::attention::AttnShape;
use flashmla_etap::bench::Table;
use flashmla_etap::config::Config;
use flashmla_etap::coordinator::{ClusterSim, Engine, GenerationRequest, TraceRequest};
use flashmla_etap::hardware::{padding_factor, GpuSpec};
use flashmla_etap::sim::figures;
use flashmla_etap::util::argparse::ArgParser;
use flashmla_etap::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, rest)) if !c.starts_with('-') => (c.clone(), rest.to_vec()),
        _ => {
            eprintln!(
                "usage: flashmla-etap <sweep|rmse|serve|simulate|padding|info> [flags]\n\
                 run a subcommand with --help for details"
            );
            std::process::exit(2);
        }
    };
    let code = match cmd.as_str() {
        "sweep" => cmd_sweep(&rest),
        "rmse" => cmd_rmse(&rest),
        "serve" => cmd_serve(&rest),
        "simulate" => cmd_simulate(&rest),
        "padding" => cmd_padding(&rest),
        "info" => cmd_info(&rest),
        other => {
            eprintln!("unknown command `{other}`");
            2
        }
    };
    std::process::exit(code);
}

fn parse_or_exit(p: &ArgParser, argv: &[String]) -> flashmla_etap::util::argparse::Args {
    match p.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let p = ArgParser::new("flashmla-etap sweep", "reproduce Fig. 1")
        .opt("batch", Some("16"), "batch size (16 or 32; 0 = both)")
        .opt("gpu", Some("h20"), "gpu spec (h20|h100|h800|a100)")
        .flag("csv", "emit CSV instead of a table");
    let a = parse_or_exit(&p, argv);
    let gpu = match GpuSpec::by_name(a.get("gpu").unwrap()) {
        Some(g) => g,
        None => {
            eprintln!("unknown gpu");
            return 2;
        }
    };
    let batches: Vec<usize> = match a.get("batch").unwrap() {
        "0" => vec![16, 32],
        s => vec![s.parse().unwrap_or(16)],
    };
    for b in batches {
        let t = figures::figure1_table(b, &gpu);
        if a.has("csv") {
            print!("{}", t.csv());
        } else {
            t.print();
            let r = figures::headline_ratios(b, &gpu);
            println!(
                "headline (batch {b}): ETAP vs FlashMLA {:.2}x @64K ({:.2}x @512), \
                 vs FA-3 {:.2}x, vs FlashInfer {:.2}x | paper: 2.78x (1.44x), 5.24x, 4.94x @BS16\n",
                r.speedup_vs_flashmla_64k,
                r.speedup_vs_flashmla_512,
                r.speedup_vs_fa3_64k,
                r.speedup_vs_flashinfer_64k
            );
        }
    }
    0
}

fn cmd_rmse(argv: &[String]) -> i32 {
    let p = ArgParser::new("flashmla-etap rmse", "reproduce Table 1")
        .opt("kv-len", Some("4096"), "context length")
        .opt("heads", Some("16"), "attention heads")
        .opt("reps", Some("3"), "random workloads to average")
        .opt("seed", Some("42"), "rng seed");
    let a = parse_or_exit(&p, argv);
    let n = a.get_usize("kv-len").unwrap();
    let h = a.get_usize("heads").unwrap();
    let shape = AttnShape {
        h,
        d: 576,
        dv: 512,
        n,
    };
    let scale = 1.0 / (192.0f32).sqrt();
    println!(
        "Table 1 — FP16 RMSE vs FP64 reference (h={h}, d=576, dv=512, n={n})"
    );
    let t0 = Instant::now();
    let results = table1_experiment(
        &shape,
        scale,
        64,
        a.get_usize("reps").unwrap(),
        a.get_u64("seed").unwrap(),
    );
    let mut t = Table::new("Table 1", &["Framework", "RMSE (model)", "RMSE (paper)"]);
    let paper = [1.9e-4, 1.25e-5];
    for (r, p) in results.iter().zip(paper) {
        t.row(&[
            r.framework.to_string(),
            format!("{:.3e}", r.rmse),
            format!("{p:.3e}"),
        ]);
    }
    t.print();
    let ratio = results[0].rmse / results[1].rmse;
    println!(
        "ratio: {ratio:.1}x lower for ETAP (paper: 15.2x) [{:.1}s]",
        t0.elapsed().as_secs_f64()
    );
    0
}

fn cmd_serve(argv: &[String]) -> i32 {
    let p = ArgParser::new(
        "flashmla-etap serve",
        "serve synthetic requests end-to-end on the PJRT artifacts",
    )
    .opt("artifacts", Some("artifacts"), "artifacts directory")
    .opt("config", None, "optional TOML/JSON config file")
    .opt("kernel", Some("etap"), "attention mode (etap|flashmla)")
    .opt("requests", Some("12"), "number of synthetic requests")
    .opt("slots", Some("4"), "batch slots")
    .opt("max-new", Some("16"), "max new tokens per request")
    .opt("seed", Some("42"), "rng seed");
    let a = parse_or_exit(&p, argv);

    let mut cfg = match a.get("config") {
        Some(path) => match Config::from_file(&PathBuf::from(path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config: {e}");
                return 2;
            }
        },
        None => Config::default(),
    };
    cfg.engine.kernel = a.get("kernel").unwrap().to_string();
    cfg.engine.max_slots = a.get_usize("slots").unwrap();
    let dir = PathBuf::from(a.get("artifacts").unwrap());

    let mut engine = match Engine::new(&dir, cfg.engine.clone()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine: {e} (did you run `make artifacts`?)");
            return 1;
        }
    };
    let mut rng = Rng::new(a.get_u64("seed").unwrap());
    let n_req = a.get_usize("requests").unwrap();
    let max_new = a.get_usize("max-new").unwrap();
    for _ in 0..n_req {
        let plen = rng.range(1, 12) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.range(1, 500) as i32).collect();
        let budget = rng.range(2, max_new as u64 + 1) as usize;
        engine.submit(GenerationRequest::new(prompt, budget));
    }
    let t0 = Instant::now();
    let report = match engine.run_to_completion() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve: {e}");
            return 1;
        }
    };
    println!(
        "served {n_req} requests in {:.2}s ({} recompositions)",
        t0.elapsed().as_secs_f64(),
        report.recompositions
    );
    println!("{}", report.metrics.report());
    0
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let p = ArgParser::new(
        "flashmla-etap simulate",
        "paper-scale 8xH20 serving simulation",
    )
    .opt("kernel", Some("etap"), "kernel model (etap|flashmla|fa3|flashinfer)")
    .opt("requests", Some("64"), "trace length")
    .opt("context", Some("16384"), "KV context per request at arrival")
    .opt("gen", Some("64"), "tokens generated per request")
    .opt("batch", Some("16"), "max batch")
    .opt("rate", Some("4.0"), "arrival rate (requests/s)")
    .opt("seed", Some("42"), "rng seed");
    let a = parse_or_exit(&p, argv);
    let mut cfg = flashmla_etap::coordinator::ClusterConfig::default();
    cfg.kernel = a.get("kernel").unwrap().to_string();
    let sim = match ClusterSim::new(cfg, GpuSpec::h20()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut rng = Rng::new(a.get_u64("seed").unwrap());
    let rate = a.get_f64("rate").unwrap();
    let mut t = 0.0f64;
    let trace: Vec<TraceRequest> = (0..a.get_usize("requests").unwrap())
        .map(|_| {
            t += rng.exponential(rate) * 1e6;
            TraceRequest {
                arrival_us: t,
                context_len: a.get_usize("context").unwrap(),
                gen_len: a.get_usize("gen").unwrap(),
            }
        })
        .collect();
    let rep = sim.serve_trace(&trace, a.get_usize("batch").unwrap());
    println!(
        "kernel={} | {:.1} tok/s over {:.2} simulated s | mean batch {:.1} | \
         TPOT p50 {:.1} ms p99 {:.1} ms | mean queue wait {:.1} ms",
        a.get("kernel").unwrap(),
        rep.tokens_per_s,
        rep.simulated_s,
        rep.mean_batch,
        rep.tpot_p50_ms,
        rep.tpot_p99_ms,
        rep.mean_wait_ms
    );
    0
}

fn cmd_padding(argv: &[String]) -> i32 {
    let p = ArgParser::new(
        "flashmla-etap padding",
        "WGMMA padding / utilization analysis (paper s3.1)",
    )
    .opt("gpu", Some("h20"), "gpu spec");
    let a = parse_or_exit(&p, argv);
    let gpu = GpuSpec::by_name(a.get("gpu").unwrap()).unwrap_or_else(GpuSpec::h20);
    let mut t = Table::new(
        &format!("M-dimension padding on {} ({}xM atom)", gpu.name, gpu.atom.min_m),
        &["heads/GPU", "padding factor", "utilization ceiling"],
    );
    for heads in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let f = padding_factor(heads, &gpu.atom);
        t.row(&[
            heads.to_string(),
            format!("{f:.2}x"),
            format!("{:.1}%", 100.0 / f),
        ]);
    }
    t.print();
    println!(
        "DeepSeek-R1 on 8 GPUs -> 16 heads/GPU -> {:.0}x padding, <={:.0}% utilization \
         (paper: \"often reducing compute utilization to below 25%\")",
        padding_factor(16, &gpu.atom),
        100.0 / padding_factor(16, &gpu.atom)
    );
    0
}

fn cmd_info(argv: &[String]) -> i32 {
    let p = ArgParser::new("flashmla-etap info", "artifact manifest summary")
        .opt("artifacts", Some("artifacts"), "artifacts directory");
    let a = parse_or_exit(&p, argv);
    let dir = PathBuf::from(a.get("artifacts").unwrap());
    match flashmla_etap::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("{} artifacts in {}", m.artifacts.len(), dir.display());
            for kind in ["attention", "decode_step"] {
                for kernel in ["etap", "flashmla"] {
                    let buckets = m.buckets(kind, kernel);
                    if !buckets.is_empty() {
                        println!("  {kind}/{kernel}: {buckets:?}");
                    }
                }
            }
            if let Some(model) = &m.model {
                println!(
                    "  model: {} layers, d_model {}, vocab {}, latent {} ({} weights)",
                    model.n_layers,
                    model.d_model,
                    model.vocab_size,
                    model.latent_dim,
                    model.weights.len()
                );
            }
            0
        }
        Err(e) => {
            eprintln!("info: {e} (run `make artifacts`)");
            1
        }
    }
}
