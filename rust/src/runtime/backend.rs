//! Backend abstraction for the decode engine.
//!
//! The engine drives one fixed-shape "decode step" per tick; where that
//! step executes is a backend detail.  Two implementations exist:
//!
//! * [`DecodeRunner`](super::DecodeRunner) — the PJRT path over AOT HLO
//!   artifacts (requires `make artifacts` and a native `xla` build);
//! * [`ReferenceRunner`](super::reference::ReferenceRunner) — a pure-Rust
//!   deterministic tiny model honoring the same step contract, available
//!   everywhere (tests, examples, CI).
//!
//! The contract (fixed by `aot.py`): given per-slot input tokens, the live
//! cache literal `[L × B × N × latent]`, and per-slot lengths, write each
//! slot's new latent at position `lengths[b]` and return
//! `(logits [B × vocab], new_cache)`.  The engine passes each request's
//! exact `kv_len()` (latents actually written — the sampled-but-unfed
//! newest token never counts), so writes are always contiguous: prompt
//! token `i` lands at position `i`, generated token `j` at
//! `prompt.len() + j`, and attention windows contain only written rows.
//!
//! **Multi-token steps.**  The chunked-prefill pipeline
//! (`crate::prefill`, `docs/chunked-prefill.md`) extends the contract with
//! [`StepRunner::prefill_chunk`]: slot `b` consumes `chunks[b]` tokens in
//! one call, writing latents at `start_pos[b] ..`, and gets back the
//! logits of its *last* consumed token.  Two contract properties every
//! backend must honor make the default per-token fallback below exact:
//!
//! * **slot isolation** — a step reads and writes only each slot's own
//!   cache rows, so per-slot progress can differ freely;
//! * **write purity** — the latent written at `(slot, pos)` is a pure
//!   function of the input token and the cache rows *before* `pos`, never
//!   of the value previously stored at `pos`.  Re-feeding a slot its last
//!   token at its last position therefore rewrites bit-identical data (and
//!   recomputes bit-identical logits), which is how the fallback holds
//!   finished slots in place while longer chunks drain.
//!
//! **Verification steps.**  Speculative decoding (`crate::spec`,
//! `docs/speculative-decoding.md`) adds [`StepRunner::verify_chunk`]: the
//! same multi-token execution, but returning the greedy argmax after
//! *every* consumed token so the engine can accept the longest draft
//! prefix that matches plain decode.  Write purity is also what makes
//! speculation exact: a rejected draft position is rewritten by the next
//! correct token before anything ever attends to it.

/// One decode step over a fixed `(batch, kv_bucket)` shape.
pub trait StepRunner {
    /// Execute one step.  `lengths[b]` is the tokens already cached for
    /// slot `b`; the new latent is written at that position.
    fn step(
        &self,
        tokens: &[i32],
        cache: &xla::Literal,
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)>;

    /// Multi-token mixed step: slot `b` consumes `chunks[b]` in order,
    /// writing latents at `start_pos[b] .. start_pos[b] + chunks[b].len()`.
    /// Returns the logits of each slot's **last** consumed token plus the
    /// new cache.
    ///
    /// * A one-token chunk is exactly [`step`](Self::step) for that slot;
    ///   a call where every chunk has length ≤ 1 is exactly one `step`.
    /// * An **empty** chunk marks a padded slot.  Its logits row and its
    ///   row-0 cache latent are unspecified scratch (the engine never
    ///   reads either), but implementations must produce them the same
    ///   way `step` does for padded slots — by processing token 0 at
    ///   position 0 — so chunked and per-token execution stay
    ///   bit-identical literal-wide.
    ///
    /// The default implementation is the documented **per-token fallback**
    /// used by the PJRT [`DecodeRunner`](super::DecodeRunner) until a
    /// chunked artifact lands: it loops `step`, advancing each slot
    /// through its chunk and re-feeding finished slots their last token
    /// (a bit-identical no-op under the write-purity contract above).  It
    /// is correct but does not reduce dispatch count; backends with a
    /// native multi-token path (the reference model today, a chunked AOT
    /// artifact tomorrow) override it.
    fn prefill_chunk(
        &self,
        chunks: &[Vec<i32>],
        cache: &xla::Literal,
        start_pos: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        prefill_chunk_fallback(self, chunks, cache, start_pos)
    }

    /// Multi-token **verification** step for speculative decoding: the
    /// same execution as [`prefill_chunk`](Self::prefill_chunk) — slot `b`
    /// consumes `chunks[b]` in order, writing latents at `start_pos[b] ..`
    /// — but instead of only the last logits row, it returns the **greedy
    /// argmax after every consumed token** (`out[b][j]` = argmax of the
    /// logits after `chunks[b][j]`), which is exactly what the engine
    /// needs to accept the longest draft prefix matching plain decode.
    ///
    /// Contract (tested against the reference backend):
    ///
    /// * **cache-identical to `prefill_chunk`** on the same inputs — a
    ///   verification tick must leave bit-identical state to the prefill
    ///   path, or speculation would not be a pure optimization;
    /// * `out[b].len() == chunks[b].len()`; a padded (empty) chunk gets an
    ///   empty argmax vector plus the same scratch write `prefill_chunk`
    ///   performs;
    /// * `out[b].last()` equals the argmax of the logits row
    ///   `prefill_chunk` would have returned for slot `b`.
    ///
    /// The default implementation ([`verify_chunk_fallback`]) reuses
    /// `prefill_chunk` one wavefront at a time — correct everywhere, one
    /// dispatch per draft position on PJRT (the engine disables
    /// speculation there until a chunked artifact lands, mirroring the
    /// chunked-prefill degrade).  Backends with a native multi-token path
    /// override it and record the argmax as they go.
    fn verify_chunk(
        &self,
        chunks: &[Vec<i32>],
        cache: &xla::Literal,
        start_pos: &[i32],
    ) -> anyhow::Result<(Vec<Vec<i32>>, xla::Literal)> {
        verify_chunk_fallback(self, chunks, cache, start_pos)
    }

    /// Does this backend execute multi-token chunks natively (one pass
    /// over each slot's tokens), or via the per-token wavefront fallbacks
    /// above (re-feeding short slots while the longest chunk drains)?
    ///
    /// Purely informational — execution is identical either way under the
    /// write-purity contract.  The compute ledger
    /// ([`crate::obs::ledger`]) uses it to attribute fallback re-feed
    /// dispatches to the `chunk_refeed` waste category.  Backends that
    /// override both [`prefill_chunk`](Self::prefill_chunk) and
    /// [`verify_chunk`](Self::verify_chunk) with single-pass
    /// implementations return `true`.
    fn native_chunking(&self) -> bool {
        false
    }

    /// Vocabulary size (logits row width).
    fn vocab(&self) -> usize;

    /// Human-readable runner name (for logs).
    fn name(&self) -> &str;
}

/// Ledger-instrumented wrapper over [`StepRunner::step`]: records each
/// slot as one useful token attending `lengths[b] + 1` rows (the row
/// being written included) over the dispatched `kv_bucket`, then
/// delegates.  Costs one relaxed atomic load when the ledger is off.
///
/// `step` has no padded-slot signal (the engine encodes padding as
/// token 0 / length 0, indistinguishable from a real first token), so
/// every slot is attributed as useful; the engine's chunked hot path
/// goes through [`run_prefill_chunk`]/[`run_verify_chunk`], which do
/// see padding.
pub fn run_step(
    runner: &dyn StepRunner,
    tokens: &[i32],
    cache: &xla::Literal,
    lengths: &[i32],
    kv_bucket: usize,
) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
    if crate::obs::ledger::enabled() {
        use crate::obs::ledger::{record_token, TokenKind};
        for &len in lengths {
            let rows = len.max(0) as usize + 1;
            record_token(TokenKind::Useful, rows, kv_bucket);
        }
    }
    runner.step(tokens, cache, lengths)
}

/// Walk one chunked call's shapes into the compute ledger.  Shared by
/// [`run_prefill_chunk`] and [`run_verify_chunk`] — the two entry points
/// have identical dispatch structure.  Inner fallback calls
/// (`prefill_chunk_fallback` looping `step`, `verify_chunk_fallback`
/// looping `prefill_chunk`) invoke trait methods directly, never these
/// wrappers, so nothing is double-counted.
fn record_chunk_shapes(chunks: &[Vec<i32>], start_pos: &[i32], kv_bucket: usize, native: bool) {
    if !crate::obs::ledger::enabled() {
        return;
    }
    let max_k = chunks.iter().map(|c| c.len().max(1)).max().unwrap_or(1);
    for (slot, chunk) in chunks.iter().enumerate() {
        let start = start_pos.get(slot).copied().unwrap_or(0).max(0) as usize;
        crate::obs::ledger::record_slot(chunk.len(), start, max_k, kv_bucket, native);
    }
}

/// Ledger-instrumented wrapper over [`StepRunner::prefill_chunk`]: the
/// engine hot path calls this instead of the trait method so every
/// backend — reference, fallback, PJRT — is costed from shape
/// information alone, without touching kernel internals.  `kv_bucket` is
/// the KV bucket the engine dispatched (rows every query logically
/// covers).  One relaxed atomic load when the ledger is off.
pub fn run_prefill_chunk(
    runner: &dyn StepRunner,
    chunks: &[Vec<i32>],
    cache: &xla::Literal,
    start_pos: &[i32],
    kv_bucket: usize,
) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
    record_chunk_shapes(chunks, start_pos, kv_bucket, runner.native_chunking());
    runner.prefill_chunk(chunks, cache, start_pos)
}

/// Ledger-instrumented wrapper over [`StepRunner::verify_chunk`]; see
/// [`run_prefill_chunk`].  Draft positions are recorded as useful here —
/// the call boundary can't know verification outcomes — and the engine
/// reclassifies rejected positions via
/// [`crate::obs::ledger::reclassify_rejected`] once it has them.
pub fn run_verify_chunk(
    runner: &dyn StepRunner,
    chunks: &[Vec<i32>],
    cache: &xla::Literal,
    start_pos: &[i32],
    kv_bucket: usize,
) -> anyhow::Result<(Vec<Vec<i32>>, xla::Literal)> {
    record_chunk_shapes(chunks, start_pos, kv_bucket, runner.native_chunking());
    runner.verify_chunk(chunks, cache, start_pos)
}

/// The per-token multi-token-step fallback (the default body of
/// [`StepRunner::prefill_chunk`]), callable directly so equivalence tests
/// can pit a backend's native chunked path against it.
///
/// Walks all chunks in lockstep with repeated [`StepRunner::step`] calls:
/// iteration `j` feeds slot `b` its `j`-th chunk token at
/// `start_pos[b] + j`; slots whose chunk is exhausted re-feed their last
/// token at their last position, which under the write-purity contract
/// rewrites bit-identical data and recomputes bit-identical logits.
/// Padded (empty-chunk) slots feed token 0 at position 0, the same
/// scratch write the engine has always issued for padded slots.
pub fn prefill_chunk_fallback<R: StepRunner + ?Sized>(
    runner: &R,
    chunks: &[Vec<i32>],
    cache: &xla::Literal,
    start_pos: &[i32],
) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
    anyhow::ensure!(
        chunks.len() == start_pos.len(),
        "chunks len {} != start_pos len {}",
        chunks.len(),
        start_pos.len()
    );
    let b = chunks.len();
    let max_k = chunks.iter().map(|c| c.len().max(1)).max().unwrap_or(1);
    let mut tokens = vec![0i32; b];
    let mut lengths = vec![0i32; b];
    let mut logits: Vec<f32> = Vec::new();
    let mut cur: Option<xla::Literal> = None;
    for j in 0..max_k {
        for slot in 0..b {
            if chunks[slot].is_empty() {
                // Padded slot: same scratch write `step` performs.
                tokens[slot] = 0;
                lengths[slot] = 0;
            } else {
                // Clamp: finished slots re-feed their last token at their
                // last position (pure rewrite, see module docs).
                let jb = j.min(chunks[slot].len() - 1);
                tokens[slot] = chunks[slot][jb];
                lengths[slot] = start_pos[slot] + jb as i32;
            }
        }
        let (lg, c) = runner.step(&tokens, cur.as_ref().unwrap_or(cache), &lengths)?;
        logits = lg;
        cur = Some(c);
    }
    Ok((logits, cur.expect("max_k ≥ 1")))
}

/// The wavefront verification fallback (the default body of
/// [`StepRunner::verify_chunk`]), callable directly so equivalence tests
/// can pit a backend's native verification against it.
///
/// Iteration `j` feeds every slot its `j`-th chunk token through a
/// single-token [`StepRunner::prefill_chunk`] call and records the greedy
/// argmax for slots still inside their chunk.  Slot clamping mirrors
/// [`prefill_chunk_fallback`] exactly — finished slots re-feed their last
/// token at their last position (a pure rewrite under the write-purity
/// contract), padded slots re-issue the token-0/position-0 scratch write —
/// so the final cache is bit-identical to one `prefill_chunk` call over
/// the same chunks, regardless of how the backend interleaves slots
/// internally (slot isolation makes per-slot results order-independent).
pub fn verify_chunk_fallback<R: StepRunner + ?Sized>(
    runner: &R,
    chunks: &[Vec<i32>],
    cache: &xla::Literal,
    start_pos: &[i32],
) -> anyhow::Result<(Vec<Vec<i32>>, xla::Literal)> {
    anyhow::ensure!(
        chunks.len() == start_pos.len(),
        "chunks len {} != start_pos len {}",
        chunks.len(),
        start_pos.len()
    );
    let b = chunks.len();
    let vocab = runner.vocab();
    let max_k = chunks.iter().map(|c| c.len().max(1)).max().unwrap_or(1);
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); b];
    let mut cur: Option<xla::Literal> = None;
    for j in 0..max_k {
        let mut wave: Vec<Vec<i32>> = Vec::with_capacity(b);
        let mut pos = vec![0i32; b];
        for slot in 0..b {
            if chunks[slot].is_empty() {
                wave.push(Vec::new());
            } else {
                let jb = j.min(chunks[slot].len() - 1);
                wave.push(vec![chunks[slot][jb]]);
                pos[slot] = start_pos[slot] + jb as i32;
            }
        }
        let (logits, c) = runner.prefill_chunk(&wave, cur.as_ref().unwrap_or(cache), &pos)?;
        for (slot, o) in out.iter_mut().enumerate() {
            if j < chunks[slot].len() {
                o.push(super::DecodeRunner::argmax_row(&logits, vocab, slot));
            }
        }
        cur = Some(c);
    }
    Ok((out, cur.expect("max_k ≥ 1")))
}

impl StepRunner for super::DecodeRunner {
    fn step(
        &self,
        tokens: &[i32],
        cache: &xla::Literal,
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        super::DecodeRunner::step(self, tokens, cache, lengths)
    }

    // `prefill_chunk` and `verify_chunk` intentionally NOT overridden: the
    // PJRT path uses the per-token fallbacks until a chunked decode
    // artifact is compiled (see ROADMAP "chunked PJRT artifact"); the
    // engine degrades to per-token prefill and disables speculation there.

    fn vocab(&self) -> usize {
        super::DecodeRunner::vocab(self)
    }

    fn name(&self) -> &str {
        super::DecodeRunner::name(self)
    }
}
