//! Backend abstraction for the decode engine.
//!
//! The engine drives one fixed-shape "decode step" per tick; where that
//! step executes is a backend detail.  Two implementations exist:
//!
//! * [`DecodeRunner`](super::DecodeRunner) — the PJRT path over AOT HLO
//!   artifacts (requires `make artifacts` and a native `xla` build);
//! * [`ReferenceRunner`](super::reference::ReferenceRunner) — a pure-Rust
//!   deterministic tiny model honoring the same step contract, available
//!   everywhere (tests, examples, CI).
//!
//! The contract (fixed by `aot.py`): given per-slot input tokens, the live
//! cache literal `[L × B × N × latent]`, and per-slot lengths, write each
//! slot's new latent at position `lengths[b]` and return
//! `(logits [B × vocab], new_cache)`.

/// One decode step over a fixed `(batch, kv_bucket)` shape.
pub trait StepRunner {
    /// Execute one step.  `lengths[b]` is the tokens already cached for
    /// slot `b`; the new latent is written at that position.
    fn step(
        &self,
        tokens: &[i32],
        cache: &xla::Literal,
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)>;

    /// Vocabulary size (logits row width).
    fn vocab(&self) -> usize;

    /// Human-readable runner name (for logs).
    fn name(&self) -> &str;
}

impl StepRunner for super::DecodeRunner {
    fn step(
        &self,
        tokens: &[i32],
        cache: &xla::Literal,
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        super::DecodeRunner::step(self, tokens, cache, lengths)
    }

    fn vocab(&self) -> usize {
        super::DecodeRunner::vocab(self)
    }

    fn name(&self) -> &str {
        super::DecodeRunner::name(self)
    }
}
