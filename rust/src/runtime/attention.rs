//! Typed runner for the attention-core artifacts (paper geometry).

use std::sync::Arc;

use super::client::{literal_f32, literal_from_f32, literal_from_i32, LoadedExec, Runtime};

/// Executes `attn_{kernel}_b{B}_n{N}` artifacts.
pub struct AttentionRunner {
    exec: Arc<LoadedExec>,
    pub batch: usize,
    pub heads: usize,
    pub d: usize,
    pub dv: usize,
    pub kv_bucket: usize,
}

impl AttentionRunner {
    /// Load the named attention artifact.
    pub fn new(rt: &Runtime, name: &str) -> anyhow::Result<Self> {
        let exec = rt.load(name)?;
        let m = &exec.meta;
        anyhow::ensure!(m.kind == "attention", "{name} is not an attention artifact");
        Ok(AttentionRunner {
            batch: m.batch,
            heads: m.heads,
            d: m.d,
            dv: m.dv,
            kv_bucket: m.kv_bucket,
            exec,
        })
    }

    /// Pick the best bucket for (kernel, batch, kv_len) and load it.
    pub fn best(rt: &Runtime, kernel: &str, batch: usize, kv_len: usize) -> anyhow::Result<Self> {
        let meta = rt
            .manifest()
            .best_bucket("attention", kernel, batch, kv_len)
            .ok_or_else(|| {
                anyhow::anyhow!("no attention bucket for kernel={kernel} b={batch} n={kv_len}")
            })?
            .clone();
        Self::new(rt, &meta.name)
    }

    /// Run one decode-attention pass.
    ///
    /// `q` is `[batch × heads × d]`, `cache` is `[batch × kv_bucket × d]`
    /// (padded), `lengths` the valid lengths.  Returns
    /// `(out [batch × heads × dv], lse [batch × heads])`.
    pub fn run(
        &self,
        q: &[f32],
        cache: &[f32],
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (b, h, d, n) = (self.batch, self.heads, self.d, self.kv_bucket);
        anyhow::ensure!(q.len() == b * h * d, "q: {} != {}", q.len(), b * h * d);
        anyhow::ensure!(
            cache.len() == b * n * d,
            "cache: {} != {}",
            cache.len(),
            b * n * d
        );
        anyhow::ensure!(lengths.len() == b, "lengths: {} != {b}", lengths.len());
        for &l in lengths {
            anyhow::ensure!(l >= 0 && l as usize <= n, "length {l} out of bucket {n}");
        }

        let lits = self.exec.run(&[
            literal_from_f32(q, &[b as i64, h as i64, d as i64])?,
            literal_from_f32(cache, &[b as i64, n as i64, d as i64])?,
            literal_from_i32(lengths, &[b as i64])?,
        ])?;
        anyhow::ensure!(lits.len() == 2, "expected (out, lse), got {}", lits.len());
        Ok((literal_f32(&lits[0])?, literal_f32(&lits[1])?))
    }

    pub fn name(&self) -> &str {
        &self.exec.meta.name
    }
}
