//! The PJRT client wrapper: compile-once executable cache over the
//! artifact manifest.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::artifact::{ArtifactMeta, Manifest};
use crate::log_info;

/// A compiled artifact ready to execute.
pub struct LoadedExec {
    pub meta: ArtifactMeta,
    pub exe: xla::PjRtLoadedExecutable,
}

impl LoadedExec {
    /// Execute with literal inputs; returns the un-tupled output literals.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.meta.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal {}: {e:?}", self.meta.name))?;
        // aot.py lowers with return_tuple=True → always a tuple.
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", self.meta.name))
    }

    /// Execute with device-buffer inputs (hot path: weights/cache stay on
    /// device); returns raw output buffers.
    pub fn run_buffers(
        &self,
        inputs: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<xla::PjRtBuffer>> {
        let mut bufs = self
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow::anyhow!("execute_b {}: {e:?}", self.meta.name))?;
        Ok(bufs.remove(0))
    }
}

/// PJRT CPU runtime over an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<LoadedExec>>>,
}

impl Runtime {
    /// Create a CPU runtime and load the manifest.
    pub fn cpu(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        log_info!(
            "runtime",
            "PJRT {} with {} artifact(s) from {}",
            client.platform_name(),
            manifest.artifacts.len(),
            artifacts_dir.display()
        );
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> anyhow::Result<Arc<LoadedExec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact named `{name}`"))?
            .clone();
        let path = self.manifest.artifact_path(&meta);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        log_info!(
            "runtime",
            "compiled {name} in {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        let loaded = Arc::new(LoadedExec { meta, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&loaded));
        Ok(loaded)
    }

    /// Upload a host f32 tensor as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    /// Upload a host i32 tensor as a device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    /// Number of compiled executables held in the cache.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// Literal → Vec<f32> with error context.
pub fn literal_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to f32: {e:?}"))
}

/// Literal → Vec<i32>.
pub fn literal_i32(lit: &xla::Literal) -> anyhow::Result<Vec<i32>> {
    lit.to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("literal to i32: {e:?}"))
}

/// Build an f32 literal with the given logical dims.
pub fn literal_from_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape f32 literal to {dims:?}: {e:?}"))
}

/// Build an i32 literal with the given logical dims.
pub fn literal_from_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape i32 literal to {dims:?}: {e:?}"))
}
