//! Typed runner for the tiny-model decode-step artifacts.
//!
//! Input order (fixed by `aot.py`): `tokens, cache, lengths, *weights`
//! with weights in canonical (sorted-name) order.
//!
//! Perf note (EXPERIMENTS.md §Perf): weights are uploaded to the PJRT
//! device **once** at load and the step path uses `execute_b` over device
//! buffers.  The naive literal path re-marshals the full 10.9 MB weight
//! blob host→device on every step; keeping weights resident removes that
//! entirely (the dominant per-step overhead outside the computation).

use std::sync::Arc;

use super::artifact::{load_weights, ModelMeta};
use super::client::{literal_f32, literal_from_f32, literal_from_i32, LoadedExec, Runtime};

/// Executes `decode_{kernel}_b{B}_n{N}` artifacts.
pub struct DecodeRunner {
    exec: Arc<LoadedExec>,
    /// Device-resident weight buffers (canonical order).
    weights: Vec<xla::PjRtBuffer>,
    pub model: ModelMeta,
    pub batch: usize,
    pub kv_bucket: usize,
}

impl DecodeRunner {
    /// Load the named decode artifact plus the weights blob.
    pub fn new(rt: &Runtime, name: &str) -> anyhow::Result<Self> {
        let exec = rt.load(name)?;
        anyhow::ensure!(
            exec.meta.kind == "decode_step",
            "{name} is not a decode_step artifact"
        );
        let model = rt
            .manifest()
            .model
            .clone()
            .ok_or_else(|| anyhow::anyhow!("manifest has no model section"))?;
        let raw = load_weights(&rt.manifest().dir, &model)?;
        let mut weights = Vec::with_capacity(raw.len());
        for (_name, shape, vals) in &raw {
            // Upload once; stays on the PJRT device for the runner's life.
            weights.push(rt.upload_f32(vals, shape)?);
        }
        Ok(DecodeRunner {
            batch: exec.meta.batch,
            kv_bucket: exec.meta.kv_bucket,
            exec,
            weights,
            model,
        })
    }

    /// Pick the smallest bucket fitting (kernel, batch, kv_len).
    pub fn best(rt: &Runtime, kernel: &str, batch: usize, kv_len: usize) -> anyhow::Result<Self> {
        let meta = rt
            .manifest()
            .best_bucket("decode_step", kernel, batch, kv_len)
            .ok_or_else(|| {
                anyhow::anyhow!("no decode bucket for kernel={kernel} b={batch} n={kv_len}")
            })?
            .clone();
        Self::new(rt, &meta.name)
    }

    /// A zeroed cache literal `[L × B × N × latent]`.
    pub fn fresh_cache(&self) -> anyhow::Result<xla::Literal> {
        let dims = [
            self.model.n_layers as i64,
            self.batch as i64,
            self.kv_bucket as i64,
            self.model.latent_dim as i64,
        ];
        let n: usize = dims.iter().map(|&d| d as usize).product();
        literal_from_f32(&vec![0.0; n], &dims)
    }

    /// One decode step.  `lengths[b]` is the tokens already cached for
    /// request b (positions are written at `lengths[b]`); the caller
    /// advances lengths for active requests.
    ///
    /// Returns `(logits [batch × vocab], new_cache)`.
    pub fn step(
        &self,
        tokens: &[i32],
        cache: &xla::Literal,
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        anyhow::ensure!(tokens.len() == self.batch, "tokens len");
        anyhow::ensure!(lengths.len() == self.batch, "lengths len");
        for &l in lengths {
            anyhow::ensure!(
                (l as usize) < self.kv_bucket,
                "length {l} overflows bucket {} (no room for this token)",
                self.kv_bucket
            );
        }
        let client = self.exec.exe.client();
        // Small per-step uploads; weights stay device-resident.
        let tok = client
            .buffer_from_host_buffer(tokens, &[self.batch], None)
            .map_err(|e| anyhow::anyhow!("upload tokens: {e:?}"))?;
        let len = client
            .buffer_from_host_buffer(lengths, &[self.batch], None)
            .map_err(|e| anyhow::anyhow!("upload lengths: {e:?}"))?;
        let cache_buf = client
            .buffer_from_host_literal(None, cache)
            .map_err(|e| anyhow::anyhow!("upload cache: {e:?}"))?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(3 + self.weights.len());
        inputs.push(&tok);
        inputs.push(&cache_buf);
        inputs.push(&len);
        for w in &self.weights {
            inputs.push(w);
        }
        let out = self.exec.run_buffers(&inputs)?;
        let lit = out[0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let mut lits = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(lits.len() == 2, "expected (logits, cache)");
        let cache_out = lits.pop().unwrap();
        let logits = literal_f32(&lits[0])?;
        Ok((logits, cache_out))
    }

    /// Greedy argmax over one request's logits row.
    pub fn argmax_row(logits: &[f32], vocab: usize, row: usize) -> i32 {
        let slice = &logits[row * vocab..(row + 1) * vocab];
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in slice.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best as i32
    }

    pub fn name(&self) -> &str {
        &self.exec.meta.name
    }

    pub fn vocab(&self) -> usize {
        self.model.vocab_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_row_picks_max_per_row() {
        let logits = vec![0.1, 0.9, 0.5, /* row 1 */ 7.0, -1.0, 2.0];
        assert_eq!(DecodeRunner::argmax_row(&logits, 3, 0), 1);
        assert_eq!(DecodeRunner::argmax_row(&logits, 3, 1), 0);
    }
}
