//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! request path.
//!
//! This is the only place the `xla` crate is touched.  Flow (see
//! /opt/xla-example/load_hlo/): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` (once per artifact, cached) → `execute`/`execute_b`.
//!
//! Python never runs here: artifacts are produced by `make artifacts`
//! (`python/compile/aot.py`) and described by `artifacts/manifest.json`.

pub mod artifact;
pub mod attention;
pub mod backend;
pub mod client;
pub mod decode;
pub mod reference;

pub use artifact::{ArtifactMeta, Dtype, Manifest, ModelMeta, TensorSpec};
pub use attention::AttentionRunner;
pub use backend::{
    prefill_chunk_fallback, run_prefill_chunk, run_step, run_verify_chunk, verify_chunk_fallback,
    StepRunner,
};
pub use client::Runtime;
pub use decode::DecodeRunner;
pub use reference::{ReferenceModel, ReferenceModelConfig, ReferenceRunner};
