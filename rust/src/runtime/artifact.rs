//! Artifact manifest: the machine-readable index `aot.py` writes next to
//! the HLO text files.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Element type of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" => Ok(Dtype::S32),
            other => anyhow::bail!("unsupported dtype `{other}`"),
        }
    }
}

/// One named tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> anyhow::Result<Self> {
        let shape = v
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|s| s.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: v.str_of("name")?.to_string(),
            shape,
            dtype: Dtype::parse(v.str_of("dtype")?)?,
        })
    }
}

/// One AOT artifact (attention core or full decode step).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,   // "attention" | "decode_step"
    pub kernel: String, // "etap" | "flashmla"
    pub batch: usize,
    pub kv_bucket: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    // Attention-only geometry (0 when absent).
    pub heads: usize,
    pub d: usize,
    pub dv: usize,
    pub scale: f64,
}

impl ArtifactMeta {
    fn parse(v: &Json) -> anyhow::Result<Self> {
        let specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
            v.get(key)
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("missing {key}"))?
                .iter()
                .map(TensorSpec::parse)
                .collect()
        };
        Ok(ArtifactMeta {
            name: v.str_of("name")?.to_string(),
            file: v.str_of("file")?.to_string(),
            kind: v.str_of("kind")?.to_string(),
            kernel: v.str_of("kernel")?.to_string(),
            batch: v.usize_of("batch")?,
            kv_bucket: v.usize_of("kv_bucket")?,
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            heads: v.get("heads").as_usize().unwrap_or(0),
            d: v.get("d").as_usize().unwrap_or(0),
            dv: v.get("dv").as_usize().unwrap_or(0),
            scale: v.get("scale").as_f64().unwrap_or(0.0),
        })
    }
}

/// Tiny-model metadata (weights blob + geometry).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub kv_lora_rank: usize,
    pub rope_dim: usize,
    pub latent_dim: usize,
    pub weights_file: String,
    pub weights_sha256: String,
    /// (name, shape) in canonical (sorted) order == AOT input order.
    pub weights: Vec<(String, Vec<usize>)>,
}

impl ModelMeta {
    fn parse(v: &Json) -> anyhow::Result<Self> {
        let cfg = v.get("config");
        let weights = v
            .get("weights")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing model.weights"))?
            .iter()
            .map(|w| {
                let shape = w
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("bad weight shape"))?
                    .iter()
                    .map(|s| s.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                Ok((w.str_of("name")?.to_string(), shape))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ModelMeta {
            vocab_size: cfg.usize_of("vocab_size")?,
            d_model: cfg.usize_of("d_model")?,
            n_layers: cfg.usize_of("n_layers")?,
            n_heads: cfg.usize_of("n_heads")?,
            kv_lora_rank: cfg.usize_of("kv_lora_rank")?,
            rope_dim: cfg.usize_of("rope_dim")?,
            latent_dim: cfg.usize_of("latent_dim")?,
            weights_file: v.str_of("weights_file")?.to_string(),
            weights_sha256: v.str_of("weights_sha256")?.to_string(),
            weights,
        })
    }

    /// Total f32 elements in the weights blob.
    pub fn total_weight_elems(&self) -> usize {
        self.weights.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// The parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    pub model: Option<ModelMeta>,
}

impl Manifest {
    /// Load from an artifacts directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let v = json::parse_file(&dir.join("manifest.json"))?;
        let artifacts = v
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
            .iter()
            .map(ArtifactMeta::parse)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let model = match v.get("model") {
            Json::Null => None,
            m => Some(ModelMeta::parse(m)?),
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            model,
        })
    }

    /// Find an artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest bucket artifact that fits (kind, kernel, batch ≥ b, n ≥ len).
    pub fn best_bucket(
        &self,
        kind: &str,
        kernel: &str,
        batch: usize,
        kv_len: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == kind && a.kernel == kernel && a.batch >= batch && a.kv_bucket >= kv_len
            })
            .min_by_key(|a| (a.batch, a.kv_bucket))
    }

    /// All (batch, kv_bucket) pairs available for a (kind, kernel).
    pub fn buckets(&self, kind: &str, kernel: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind && a.kernel == kernel)
            .map(|a| (a.batch, a.kv_bucket))
            .collect();
        v.sort();
        v
    }

    pub fn artifact_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

/// Load the raw little-endian f32 weights blob described by `model`.
pub fn load_weights(dir: &Path, model: &ModelMeta) -> anyhow::Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
    let blob = std::fs::read(dir.join(&model.weights_file))?;
    let expected = model.total_weight_elems() * 4;
    anyhow::ensure!(
        blob.len() == expected,
        "weights blob {} bytes, expected {expected}",
        blob.len()
    );
    let mut out = Vec::with_capacity(model.weights.len());
    let mut off = 0usize;
    for (name, shape) in &model.weights {
        let n: usize = shape.iter().product();
        let mut vals = Vec::with_capacity(n);
        for i in 0..n {
            let b = &blob[off + i * 4..off + i * 4 + 4];
            vals.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        off += n * 4;
        out.push((name.clone(), shape.clone(), vals));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn sample() -> &'static str {
        r#"{
          "format_version": 1,
          "artifacts": [
            {"name": "attn_etap_b1_n256", "file": "a.hlo.txt", "kind": "attention",
             "kernel": "etap", "batch": 1, "kv_bucket": 256,
             "heads": 16, "d": 576, "dv": 512, "scale": 0.07,
             "inputs": [{"name": "q", "shape": [1, 16, 576], "dtype": "f32"}],
             "outputs": [{"name": "out", "shape": [1, 16, 512], "dtype": "f32"}]},
            {"name": "attn_etap_b4_n512", "file": "b.hlo.txt", "kind": "attention",
             "kernel": "etap", "batch": 4, "kv_bucket": 512,
             "inputs": [], "outputs": []},
            {"name": "attn_flashmla_b1_n256", "file": "c.hlo.txt", "kind": "attention",
             "kernel": "flashmla", "batch": 1, "kv_bucket": 256,
             "inputs": [], "outputs": []}
          ],
          "model": null
        }"#
    }

    #[test]
    fn parse_and_lookup() {
        let dir = std::env::temp_dir().join(format!("manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, sample());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert!(m.model.is_none());
        let a = m.by_name("attn_etap_b1_n256").unwrap();
        assert_eq!(a.heads, 16);
        assert_eq!(a.inputs[0].shape, vec![1, 16, 576]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bucket_selection_picks_smallest_fit() {
        let dir = std::env::temp_dir().join(format!("manifest_test2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, sample());
        let m = Manifest::load(&dir).unwrap();
        // 1 request, 100 tokens → the b1/n256 artifact, not b4/n512.
        let a = m.best_bucket("attention", "etap", 1, 100).unwrap();
        assert_eq!(a.name, "attn_etap_b1_n256");
        // 2 requests → must take b4.
        let a = m.best_bucket("attention", "etap", 2, 100).unwrap();
        assert_eq!(a.name, "attn_etap_b4_n512");
        // 600 tokens → nothing fits.
        assert!(m.best_bucket("attention", "etap", 1, 600).is_none());
        // kernel filter respected.
        let a = m.best_bucket("attention", "flashmla", 1, 256).unwrap();
        assert_eq!(a.name, "attn_flashmla_b1_n256");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn buckets_listing_sorted() {
        let dir = std::env::temp_dir().join(format!("manifest_test3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, sample());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.buckets("attention", "etap"), vec![(1, 256), (4, 512)]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
