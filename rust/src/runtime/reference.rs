//! Pure-Rust deterministic reference decode backend.
//!
//! A tiny MLA-shaped recurrent attention model that honors the AOT decode
//! artifact contract exactly (see [`super::backend`]), with three
//! properties the serving stack's tests depend on:
//!
//! * **Bit-deterministic.**  All arithmetic is sequential f32 with a fixed
//!   reduction order and seeded weights, so equal token histories produce
//!   bit-identical latents and logits on every platform.
//! * **Batch/bucket invariant.**  Each slot's computation reads only its
//!   own cache rows and valid positions, so outputs do not change when the
//!   engine migrates a request across slots or grows buckets — the same
//!   isolation contract the real artifacts guarantee.
//! * **History sensitive.**  The written latent depends on the hidden
//!   state, which attends over every cached position, so a single corrupted
//!   or misplaced cache entry changes all later logits (bitwise — an
//!   argmax may or may not flip, which is why `rust/tests/kv_exact_e2e.rs`
//!   probes cache rows and raw logits rather than outputs alone).  This is
//!   what makes it a real end-to-end check for paged-store and
//!   prefix-cache plumbing rather than a mock.
//!
//! Per slot with context length `t` and input token `x`:
//!
//! ```text
//! e   = emb[x]
//! h_0 = e
//! for layer l:
//!     c_l = tanh(W_l · h_l + p_l · (t+1)/32)     # written at cache[l, b, t]
//!     q_l = Q_l · h_l
//!     a   = softmax_{j ≤ t}(q_l · cache[l, b, j] / √d)
//!     h_{l+1} = tanh(h_l + Σ_j a_j · cache[l, b, j])
//! logits = O · h_L
//! ```

use std::sync::Arc;

use crate::obs;
use crate::util::rng::Rng;

use super::backend::StepRunner;

/// Geometry + seed for the reference model, plus the bucket grid the
/// engine may compile against (mirrors the artifact manifest's role).
#[derive(Clone, Debug)]
pub struct ReferenceModelConfig {
    pub vocab: usize,
    pub n_layers: usize,
    pub latent_dim: usize,
    pub seed: u64,
    /// Batch-size buckets, ascending.
    pub batch_buckets: Vec<usize>,
    /// KV-length buckets, ascending.
    pub kv_buckets: Vec<usize>,
}

impl Default for ReferenceModelConfig {
    fn default() -> Self {
        ReferenceModelConfig {
            vocab: 512,
            n_layers: 2,
            latent_dim: 16,
            seed: 0xE7A9_0001,
            batch_buckets: vec![1, 2, 4, 8],
            kv_buckets: vec![32, 64, 128, 256],
        }
    }
}

/// Seeded weights, shared by every runner the engine creates.
pub struct ReferenceModel {
    cfg: ReferenceModelConfig,
    /// `[vocab × d]` token embeddings.
    emb: Vec<f32>,
    /// `[L × d × d]` latent projections.
    w_latent: Vec<f32>,
    /// `[L × d × d]` query projections.
    w_query: Vec<f32>,
    /// `[L × d]` positional mix-in.
    pos_mix: Vec<f32>,
    /// `[vocab × d]` output projection.
    out_proj: Vec<f32>,
}

impl ReferenceModel {
    pub fn new(cfg: ReferenceModelConfig) -> Arc<Self> {
        assert!(cfg.vocab > 0 && cfg.n_layers > 0 && cfg.latent_dim > 0);
        assert!(!cfg.batch_buckets.is_empty() && !cfg.kv_buckets.is_empty());
        let (v, l, d) = (cfg.vocab, cfg.n_layers, cfg.latent_dim);
        let mut rng = Rng::new(cfg.seed);
        let scale = 1.0 / (d as f32).sqrt();
        let mut mat = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32() * scale).collect()
        };
        Arc::new(ReferenceModel {
            emb: mat(v * d),
            w_latent: mat(l * d * d),
            w_query: mat(l * d * d),
            pos_mix: mat(l * d),
            out_proj: mat(v * d),
            cfg,
        })
    }

    pub fn config(&self) -> &ReferenceModelConfig {
        &self.cfg
    }

    /// A runner bound to one `(batch, kv_bucket)` shape.
    pub fn runner(self: &Arc<Self>, batch: usize, kv_bucket: usize) -> ReferenceRunner {
        ReferenceRunner {
            name: format!("reference_b{batch}_n{kv_bucket}"),
            model: Arc::clone(self),
            batch,
            kv_bucket,
        }
    }
}

/// Executes reference decode steps at a fixed shape.
pub struct ReferenceRunner {
    model: Arc<ReferenceModel>,
    name: String,
    pub batch: usize,
    pub kv_bucket: usize,
}

impl ReferenceRunner {
    /// A zeroed cache literal `[L × B × N × d]`.
    pub fn fresh_cache(&self) -> anyhow::Result<xla::Literal> {
        let c = &self.model.cfg;
        let dims = [
            c.n_layers as i64,
            self.batch as i64,
            self.kv_bucket as i64,
            c.latent_dim as i64,
        ];
        let n: usize = dims.iter().map(|&x| x as usize).product();
        super::client::literal_from_f32(&vec![0.0; n], &dims)
    }
}

impl ReferenceRunner {
    /// Process one token for one slot against the host cache: write the
    /// new latent at position `t` and fill `logits_row`.  This is the
    /// single shared per-slot kernel behind both [`StepRunner::step`] and
    /// the native [`StepRunner::prefill_chunk`], which makes their
    /// bit-identity structural rather than incidental (the chunked path
    /// runs exactly this code once per token).
    fn step_slot(
        &self,
        host: &mut [f32],
        slot: usize,
        token: i32,
        t: usize,
        logits_row: &mut [f32],
    ) -> anyhow::Result<()> {
        let m = &*self.model;
        let (v, nl, d) = (m.cfg.vocab, m.cfg.n_layers, m.cfg.latent_dim);
        let (b, n) = (self.batch, self.kv_bucket);
        anyhow::ensure!(
            t < n,
            "length {t} overflows bucket {n} (no room for this token)"
        );
        anyhow::ensure!(
            token >= 0 && (token as usize) < v,
            "token {token} outside vocab {v}"
        );
        let e = &m.emb[token as usize * d..(token as usize + 1) * d];
        let mut h: Vec<f32> = e.to_vec();
        let pos_scale = (t + 1) as f32 * 0.03125;
        for l in 0..nl {
            // New latent from the hidden state, written at position t.
            let wl = &m.w_latent[l * d * d..(l + 1) * d * d];
            let pm = &m.pos_mix[l * d..(l + 1) * d];
            let row = |j: usize| ((l * b + slot) * n + j) * d;
            let base = row(t);
            for i in 0..d {
                let mut acc = pm[i] * pos_scale;
                for (j, &hj) in h.iter().enumerate() {
                    acc += wl[i * d + j] * hj;
                }
                host[base + i] = acc.tanh();
            }
            // Attention over positions 0..=t of this slot's rows.
            let wq = &m.w_query[l * d * d..(l + 1) * d * d];
            let mut q = vec![0.0f32; d];
            for i in 0..d {
                let mut acc = 0.0f32;
                for (j, &hj) in h.iter().enumerate() {
                    acc += wq[i * d + j] * hj;
                }
                q[i] = acc;
            }
            let inv_sqrt_d = 1.0 / (d as f32).sqrt();
            let mut scores = Vec::with_capacity(t + 1);
            let mut max_s = f32::NEG_INFINITY;
            for j in 0..=t {
                let r = row(j);
                let mut s = 0.0f32;
                for i in 0..d {
                    s += q[i] * host[r + i];
                }
                let s = s * inv_sqrt_d;
                max_s = max_s.max(s);
                scores.push(s);
            }
            let mut norm = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - max_s).exp();
                norm += *s;
            }
            let mut ctx = vec![0.0f32; d];
            for (j, &w) in scores.iter().enumerate() {
                let r = row(j);
                let w = w / norm;
                for i in 0..d {
                    ctx[i] += w * host[r + i];
                }
            }
            for i in 0..d {
                h[i] = (h[i] + ctx[i]).tanh();
            }
        }
        for tok in 0..v {
            let o = &m.out_proj[tok * d..(tok + 1) * d];
            let mut acc = 0.0f32;
            for i in 0..d {
                acc += o[i] * h[i];
            }
            logits_row[tok] = acc;
        }
        Ok(())
    }

    /// Pull the cache literal to a host vector, validating its shape.
    fn host_cache(&self, cache: &xla::Literal) -> anyhow::Result<Vec<f32>> {
        let c = &self.model.cfg;
        let want = c.n_layers * self.batch * self.kv_bucket * c.latent_dim;
        let host: Vec<f32> = cache
            .to_vec()
            .map_err(|e| anyhow::anyhow!("cache to_vec: {e:?}"))?;
        anyhow::ensure!(
            host.len() == want,
            "cache has {} elems, want {want}",
            host.len()
        );
        Ok(host)
    }

    fn pack_cache(&self, host: &[f32]) -> anyhow::Result<xla::Literal> {
        let c = &self.model.cfg;
        let dims = [
            c.n_layers as i64,
            self.batch as i64,
            self.kv_bucket as i64,
            c.latent_dim as i64,
        ];
        super::client::literal_from_f32(host, &dims)
    }
}

impl StepRunner for ReferenceRunner {
    fn step(
        &self,
        tokens: &[i32],
        cache: &xla::Literal,
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        let _span = obs::span("runtime", "step");
        let v = self.model.cfg.vocab;
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b, "tokens len {} != batch {b}", tokens.len());
        anyhow::ensure!(lengths.len() == b, "lengths len {} != batch {b}", lengths.len());
        let mut host = self.host_cache(cache)?;
        let mut logits = vec![0.0f32; b * v];
        for slot in 0..b {
            let t = lengths[slot];
            anyhow::ensure!(
                t >= 0,
                "length {t} overflows bucket {} (no room for this token)",
                self.kv_bucket
            );
            let (lo, hi) = (slot * v, (slot + 1) * v);
            self.step_slot(&mut host, slot, tokens[slot], t as usize, &mut logits[lo..hi])?;
        }
        Ok((logits, self.pack_cache(&host)?))
    }

    /// Native multi-token path: one host round-trip for the whole mixed
    /// batch, then `step_slot` once per (slot, token) — bit-identical to
    /// the per-token fallback because slots are isolated and both paths
    /// run the identical per-slot kernel in the identical per-slot order.
    fn prefill_chunk(
        &self,
        chunks: &[Vec<i32>],
        cache: &xla::Literal,
        start_pos: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        let _span = obs::span("runtime", "prefill_chunk");
        let v = self.model.cfg.vocab;
        let b = self.batch;
        anyhow::ensure!(chunks.len() == b, "chunks len {} != batch {b}", chunks.len());
        anyhow::ensure!(
            start_pos.len() == b,
            "start_pos len {} != batch {b}",
            start_pos.len()
        );
        let mut host = self.host_cache(cache)?;
        let mut logits = vec![0.0f32; b * v];
        for slot in 0..b {
            let (lo, hi) = (slot * v, (slot + 1) * v);
            if chunks[slot].is_empty() {
                // Padded slot: same scratch write `step` performs.
                self.step_slot(&mut host, slot, 0, 0, &mut logits[lo..hi])?;
                continue;
            }
            anyhow::ensure!(start_pos[slot] >= 0, "negative start_pos");
            for (j, &tok) in chunks[slot].iter().enumerate() {
                let t = start_pos[slot] as usize + j;
                self.step_slot(&mut host, slot, tok, t, &mut logits[lo..hi])?;
            }
        }
        Ok((logits, self.pack_cache(&host)?))
    }

    /// Native verification: identical per-slot kernel walk to the native
    /// [`prefill_chunk`](Self::prefill_chunk) — same `step_slot` calls in
    /// the same order, hence bit-identical cache effects — recording the
    /// greedy argmax after every consumed token instead of keeping only
    /// the last logits row.
    fn verify_chunk(
        &self,
        chunks: &[Vec<i32>],
        cache: &xla::Literal,
        start_pos: &[i32],
    ) -> anyhow::Result<(Vec<Vec<i32>>, xla::Literal)> {
        let _span = obs::span("runtime", "verify_chunk");
        let v = self.model.cfg.vocab;
        let b = self.batch;
        anyhow::ensure!(chunks.len() == b, "chunks len {} != batch {b}", chunks.len());
        anyhow::ensure!(
            start_pos.len() == b,
            "start_pos len {} != batch {b}",
            start_pos.len()
        );
        let mut host = self.host_cache(cache)?;
        let mut logits_row = vec![0.0f32; v];
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); b];
        for slot in 0..b {
            if chunks[slot].is_empty() {
                // Padded slot: same scratch write `step` performs.
                self.step_slot(&mut host, slot, 0, 0, &mut logits_row)?;
                continue;
            }
            anyhow::ensure!(start_pos[slot] >= 0, "negative start_pos");
            for (j, &tok) in chunks[slot].iter().enumerate() {
                let t = start_pos[slot] as usize + j;
                self.step_slot(&mut host, slot, tok, t, &mut logits_row)?;
                out[slot].push(super::DecodeRunner::argmax_row(&logits_row, v, 0));
            }
        }
        Ok((out, self.pack_cache(&host)?))
    }

    /// Both chunk entry points above are single-pass: no wavefront
    /// re-feeds, so the compute ledger records no `chunk_refeed` waste
    /// for this backend.
    fn native_chunking(&self) -> bool {
        true
    }

    fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Arc<ReferenceModel> {
        ReferenceModel::new(ReferenceModelConfig {
            vocab: 32,
            n_layers: 2,
            latent_dim: 8,
            seed: 7,
            batch_buckets: vec![1, 2, 4],
            kv_buckets: vec![8, 16],
        })
    }

    fn decode_greedy(
        model: &Arc<ReferenceModel>,
        batch: usize,
        kv: usize,
        prompt: &[i32],
        new_tokens: usize,
        slot: usize,
    ) -> Vec<i32> {
        let r = model.runner(batch, kv);
        let mut cache = r.fresh_cache().unwrap();
        let mut lengths = vec![0i32; batch];
        let mut tokens = vec![0i32; batch];
        let mut out = Vec::new();
        let v = r.vocab();
        let mut next = prompt[0];
        let mut fed = 0usize;
        while out.len() < new_tokens {
            tokens[slot] = next;
            let (logits, c) = StepRunner::step(&r, &tokens, &cache, &lengths).unwrap();
            cache = c;
            lengths[slot] += 1;
            fed += 1;
            let arg = super::super::DecodeRunner::argmax_row(&logits, v, slot);
            if fed < prompt.len() {
                next = prompt[fed];
            } else {
                out.push(arg);
                next = arg;
            }
        }
        out
    }

    #[test]
    fn deterministic_across_runs() {
        let m = small();
        let a = decode_greedy(&m, 1, 16, &[3, 5, 7], 6, 0);
        let b = decode_greedy(&m, 1, 16, &[3, 5, 7], 6, 0);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&t| (0..32).contains(&t)));
    }

    #[test]
    fn slot_and_bucket_invariant() {
        // The same request must decode identically in any slot of any
        // bucket — the isolation contract the engine depends on.
        let m = small();
        let base = decode_greedy(&m, 1, 8, &[3, 5, 7], 4, 0);
        assert_eq!(decode_greedy(&m, 2, 8, &[3, 5, 7], 4, 1), base);
        assert_eq!(decode_greedy(&m, 4, 16, &[3, 5, 7], 4, 3), base);
    }

    #[test]
    fn history_changes_outputs() {
        let m = small();
        let a = decode_greedy(&m, 1, 16, &[3, 5, 7], 6, 0);
        let b = decode_greedy(&m, 1, 16, &[3, 5, 8], 6, 0);
        assert_ne!(a, b, "prompt change must change decode");
    }

    #[test]
    fn rejects_overflow_and_bad_tokens() {
        let m = small();
        let r = m.runner(1, 8);
        let cache = r.fresh_cache().unwrap();
        assert!(StepRunner::step(&r, &[1], &cache, &[8]).is_err());
        assert!(StepRunner::step(&r, &[99], &cache, &[0]).is_err());
        // Chunk overrunning the bucket fails too.
        assert!(r
            .prefill_chunk(&[(0..9).collect::<Vec<i32>>()], &cache, &[0])
            .is_err());
    }

    #[test]
    fn chunked_equals_per_token_loop() {
        // The headline contract: one prefill_chunk call over a prompt must
        // produce the bit-identical cache and final logits as feeding the
        // prompt one step at a time.
        let m = small();
        let r = m.runner(2, 16);
        let prompt: Vec<i32> = vec![3, 5, 7, 11, 2, 9];

        // Per-token loop in slot 0 (slot 1 padded, token 0 / length 0).
        let mut cache = r.fresh_cache().unwrap();
        let mut logits = Vec::new();
        for (t, &tok) in prompt.iter().enumerate() {
            let (lg, c) =
                StepRunner::step(&r, &[tok, 0], &cache, &[t as i32, 0]).unwrap();
            cache = c;
            logits = lg;
        }

        // One chunked call.
        let fresh = r.fresh_cache().unwrap();
        let (clogits, ccache) = r
            .prefill_chunk(&[prompt.clone(), Vec::new()], &fresh, &[0, 0])
            .unwrap();

        assert_eq!(clogits, logits, "final logits differ");
        assert_eq!(
            ccache.to_vec::<f32>().unwrap(),
            cache.to_vec::<f32>().unwrap(),
            "cache literal differs"
        );
    }

    #[test]
    fn native_chunk_equals_fallback() {
        // The native override must match the documented per-token fallback
        // bit-for-bit on a mixed batch: a long chunk, a decode-style
        // single token, and a padded slot.
        let m = small();
        let r = m.runner(4, 16);
        // Give the decode slot some history first.
        let mut cache = r.fresh_cache().unwrap();
        for (t, tok) in [4i32, 6, 8].into_iter().enumerate() {
            let (_, c) =
                StepRunner::step(&r, &[0, tok, 0, 0], &cache, &[0, t as i32, 0, 0]).unwrap();
            cache = c;
        }
        let chunks: Vec<Vec<i32>> = vec![
            vec![3, 5, 7, 11, 2],  // 5-token prefill chunk
            vec![12],              // decode: single token at position 3
            Vec::new(),            // padded
            vec![9, 1],            // 2-token chunk
        ];
        let start = [0, 3, 0, 0];
        let (nl, nc) = r.prefill_chunk(&chunks, &cache, &start).unwrap();
        let (fl, fc) =
            super::super::backend::prefill_chunk_fallback(&r, &chunks, &cache, &start).unwrap();
        assert_eq!(nl, fl, "logits differ between native and fallback");
        assert_eq!(
            nc.to_vec::<f32>().unwrap(),
            fc.to_vec::<f32>().unwrap(),
            "caches differ between native and fallback"
        );
    }

    #[test]
    fn verify_chunk_cache_identical_to_prefill_chunk() {
        // The speculative contract: a verification tick must leave the
        // exact cache a prefill tick over the same chunks would, and its
        // last argmax must match the prefill path's logits row.
        let m = small();
        let r = m.runner(3, 16);
        let mut cache = r.fresh_cache().unwrap();
        for (t, tok) in [4i32, 6].into_iter().enumerate() {
            let (_, c) =
                StepRunner::step(&r, &[0, tok, 0], &cache, &[0, t as i32, 0]).unwrap();
            cache = c;
        }
        let chunks: Vec<Vec<i32>> = vec![
            vec![3, 5, 7, 11], // prefill-style chunk
            vec![12, 1, 9],    // decode token + 2 draft tokens at position 2
            Vec::new(),        // padded
        ];
        let start = [0, 2, 0];
        let (pl, pc) = r.prefill_chunk(&chunks, &cache, &start).unwrap();
        let (am, vc) = r.verify_chunk(&chunks, &cache, &start).unwrap();
        assert_eq!(
            vc.to_vec::<f32>().unwrap(),
            pc.to_vec::<f32>().unwrap(),
            "verification changed the cache"
        );
        let v = StepRunner::vocab(&r);
        for slot in 0..2 {
            assert_eq!(am[slot].len(), chunks[slot].len());
            assert_eq!(
                *am[slot].last().unwrap(),
                super::super::DecodeRunner::argmax_row(&pl, v, slot),
                "slot {slot} final argmax diverges from prefill logits"
            );
        }
        assert!(am[2].is_empty(), "padded slot has no argmaxes");
    }

    #[test]
    fn verify_native_equals_fallback() {
        let m = small();
        let r = m.runner(4, 16);
        let mut cache = r.fresh_cache().unwrap();
        for (t, tok) in [4i32, 6, 8].into_iter().enumerate() {
            let (_, c) =
                StepRunner::step(&r, &[0, tok, 0, 0], &cache, &[0, t as i32, 0, 0]).unwrap();
            cache = c;
        }
        let chunks: Vec<Vec<i32>> = vec![
            vec![3, 5, 7, 11, 2], // long chunk
            vec![12, 9],          // decode + 1 draft at position 3
            Vec::new(),           // padded
            vec![9],              // single token
        ];
        let start = [0, 3, 0, 0];
        let (na, nc) = r.verify_chunk(&chunks, &cache, &start).unwrap();
        let (fa, fc) =
            super::super::backend::verify_chunk_fallback(&r, &chunks, &cache, &start).unwrap();
        assert_eq!(na, fa, "argmaxes differ between native and fallback");
        assert_eq!(
            nc.to_vec::<f32>().unwrap(),
            fc.to_vec::<f32>().unwrap(),
            "caches differ between native and fallback"
        );
    }

    #[test]
    fn verify_argmaxes_track_per_token_greedy() {
        // Position j's argmax must equal what a per-token step loop sees
        // after feeding the same j+1 tokens — the property the engine's
        // acceptance rule is built on.
        let m = small();
        let r = m.runner(1, 16);
        let toks: Vec<i32> = vec![3, 5, 7, 11, 2];
        let fresh = r.fresh_cache().unwrap();
        let (am, _) = r.verify_chunk(&[toks.clone()], &fresh, &[0]).unwrap();
        let mut cache = r.fresh_cache().unwrap();
        for (t, &tok) in toks.iter().enumerate() {
            let (lg, c) = StepRunner::step(&r, &[tok], &cache, &[t as i32]).unwrap();
            cache = c;
            assert_eq!(
                am[0][t],
                super::super::DecodeRunner::argmax_row(&lg, StepRunner::vocab(&r), 0),
                "argmax diverges at position {t}"
            );
        }
    }

    #[test]
    fn all_single_token_chunks_equal_one_step() {
        let m = small();
        let r = m.runner(2, 8);
        let cache = r.fresh_cache().unwrap();
        let (sl, sc) = StepRunner::step(&r, &[3, 5], &cache, &[0, 0]).unwrap();
        let (cl, cc) = r
            .prefill_chunk(&[vec![3], vec![5]], &cache, &[0, 0])
            .unwrap();
        assert_eq!(sl, cl);
        assert_eq!(sc.to_vec::<f32>().unwrap(), cc.to_vec::<f32>().unwrap());
    }
}
