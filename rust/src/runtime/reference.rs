//! Pure-Rust deterministic reference decode backend.
//!
//! A tiny MLA-shaped recurrent attention model that honors the AOT decode
//! artifact contract exactly (see [`super::backend`]), with three
//! properties the serving stack's tests depend on:
//!
//! * **Bit-deterministic.**  All arithmetic is sequential f32 with a fixed
//!   reduction order and seeded weights, so equal token histories produce
//!   bit-identical latents and logits on every platform.
//! * **Batch/bucket invariant.**  Each slot's computation reads only its
//!   own cache rows and valid positions, so outputs do not change when the
//!   engine migrates a request across slots or grows buckets — the same
//!   isolation contract the real artifacts guarantee.
//! * **History sensitive.**  The written latent depends on the hidden
//!   state, which attends over every cached position, so a single corrupted
//!   or misplaced cache entry changes all later logits (bitwise — an
//!   argmax may or may not flip, which is why `rust/tests/kv_exact_e2e.rs`
//!   probes cache rows and raw logits rather than outputs alone).  This is
//!   what makes it a real end-to-end check for paged-store and
//!   prefix-cache plumbing rather than a mock.
//!
//! Execution is routed through the fast-path dispatcher
//! ([`crate::kernels::KernelDispatch`], selected by `[engine.kernels]`):
//! per-slot work is extracted into a `SlotKernel` that runs on a
//! gathered per-slot cache buffer; `naive` keeps the seed's sequential
//! scalar loop order bit-for-bit, `blocked` re-tiles the same
//! arithmetic over KV tiles without reordering any f32 reduction, and
//! `blocked_parallel` fans independent slots across
//! [`crate::util::threadpool::ThreadPool::map`].  All three produce
//! bit-identical outputs (`docs/attention-kernels.md`), which is why
//! every pinned expectation below holds in every mode.
//!
//! Per slot with context length `t` and input token `x`:
//!
//! ```text
//! e   = emb[x]
//! h_0 = e
//! for layer l:
//!     c_l = tanh(W_l · h_l + p_l · (t+1)/32)     # written at cache[l, b, t]
//!     q_l = Q_l · h_l
//!     a   = softmax_{j ≤ t}(q_l · cache[l, b, j] / √d)
//!     h_{l+1} = tanh(h_l + Σ_j a_j · cache[l, b, j])
//! logits = O · h_L
//! ```

use std::sync::Arc;

use crate::kernels::{KernelDispatch, KernelMode};
use crate::obs;
use crate::util::rng::Rng;

use super::backend::StepRunner;

/// Geometry + seed for the reference model, plus the bucket grid the
/// engine may compile against (mirrors the artifact manifest's role).
#[derive(Clone, Debug)]
pub struct ReferenceModelConfig {
    pub vocab: usize,
    pub n_layers: usize,
    pub latent_dim: usize,
    pub seed: u64,
    /// Batch-size buckets, ascending.
    pub batch_buckets: Vec<usize>,
    /// KV-length buckets, ascending.
    pub kv_buckets: Vec<usize>,
}

impl Default for ReferenceModelConfig {
    fn default() -> Self {
        ReferenceModelConfig {
            vocab: 512,
            n_layers: 2,
            latent_dim: 16,
            seed: 0xE7A9_0001,
            batch_buckets: vec![1, 2, 4, 8],
            kv_buckets: vec![32, 64, 128, 256],
        }
    }
}

/// Seeded weights, shared by every runner the engine creates.
pub struct ReferenceModel {
    cfg: ReferenceModelConfig,
    /// `[vocab × d]` token embeddings.
    emb: Vec<f32>,
    /// `[L × d × d]` latent projections.
    w_latent: Vec<f32>,
    /// `[L × d × d]` query projections.
    w_query: Vec<f32>,
    /// `[L × d]` positional mix-in.
    pos_mix: Vec<f32>,
    /// `[vocab × d]` output projection.
    out_proj: Vec<f32>,
}

impl ReferenceModel {
    pub fn new(cfg: ReferenceModelConfig) -> Arc<Self> {
        assert!(cfg.vocab > 0 && cfg.n_layers > 0 && cfg.latent_dim > 0);
        assert!(!cfg.batch_buckets.is_empty() && !cfg.kv_buckets.is_empty());
        let (v, l, d) = (cfg.vocab, cfg.n_layers, cfg.latent_dim);
        let mut rng = Rng::new(cfg.seed);
        let scale = 1.0 / (d as f32).sqrt();
        let mut mat = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32() * scale).collect()
        };
        Arc::new(ReferenceModel {
            emb: mat(v * d),
            w_latent: mat(l * d * d),
            w_query: mat(l * d * d),
            pos_mix: mat(l * d),
            out_proj: mat(v * d),
            cfg,
        })
    }

    pub fn config(&self) -> &ReferenceModelConfig {
        &self.cfg
    }

    /// A runner bound to one `(batch, kv_bucket)` shape, on the seed's
    /// sequential scalar path (`naive` dispatch).
    pub fn runner(self: &Arc<Self>, batch: usize, kv_bucket: usize) -> ReferenceRunner {
        self.runner_with(batch, kv_bucket, KernelDispatch::naive())
    }

    /// A runner bound to one `(batch, kv_bucket)` shape executing via
    /// the given kernel dispatcher — how the engine threads its
    /// `[engine.kernels]` selection down to the compute loops.
    pub fn runner_with(
        self: &Arc<Self>,
        batch: usize,
        kv_bucket: usize,
        kernels: Arc<KernelDispatch>,
    ) -> ReferenceRunner {
        ReferenceRunner {
            name: format!("reference_b{batch}_n{kv_bucket}"),
            model: Arc::clone(self),
            batch,
            kv_bucket,
            kernels,
        }
    }
}

/// Executes reference decode steps at a fixed shape.
pub struct ReferenceRunner {
    model: Arc<ReferenceModel>,
    name: String,
    pub batch: usize,
    pub kv_bucket: usize,
    kernels: Arc<KernelDispatch>,
}

impl ReferenceRunner {
    /// A zeroed cache literal `[L × B × N × d]`.
    pub fn fresh_cache(&self) -> anyhow::Result<xla::Literal> {
        let c = &self.model.cfg;
        let dims = [
            c.n_layers as i64,
            self.batch as i64,
            self.kv_bucket as i64,
            c.latent_dim as i64,
        ];
        let n: usize = dims.iter().map(|&x| x as usize).product();
        super::client::literal_from_f32(&vec![0.0; n], &dims)
    }
}

/// The per-(slot, token) compute kernel, extracted from the runner so
/// the parallel tick path can ship it to pool workers (`'static` +
/// owned): weights via `Arc`, geometry by value, and the slot's cache
/// as a gathered contiguous buffer `[L × n × d]` with row `(l, j)` at
/// `(l·n + j)·d`.  Gather/scatter between this layout and the host
/// literal's `[L × B × n × d]` is a pure copy, so running every mode on
/// the gathered buffer changes no bits relative to the seed's in-place
/// walk.
#[derive(Clone)]
struct SlotKernel {
    model: Arc<ReferenceModel>,
    /// KV bucket — rows per layer in the slot buffer.
    n: usize,
    mode: KernelMode,
    block_kv: usize,
}

impl SlotKernel {
    /// Process one token: write the new latent at position `t` and fill
    /// `logits_row`.  This is the single shared kernel behind
    /// [`StepRunner::step`], the native [`StepRunner::prefill_chunk`]
    /// and [`StepRunner::verify_chunk`], which makes their bit-identity
    /// structural rather than incidental.
    fn step_token(
        &self,
        buf: &mut [f32],
        token: i32,
        t: usize,
        logits_row: &mut [f32],
    ) -> anyhow::Result<()> {
        let m = &*self.model;
        let (v, nl, d) = (m.cfg.vocab, m.cfg.n_layers, m.cfg.latent_dim);
        let n = self.n;
        anyhow::ensure!(
            t < n,
            "length {t} overflows bucket {n} (no room for this token)"
        );
        anyhow::ensure!(
            token >= 0 && (token as usize) < v,
            "token {token} outside vocab {v}"
        );
        let e = &m.emb[token as usize * d..(token as usize + 1) * d];
        let mut h: Vec<f32> = e.to_vec();
        let pos_scale = (t + 1) as f32 * 0.03125;
        for l in 0..nl {
            let wl = &m.w_latent[l * d * d..(l + 1) * d * d];
            let pm = &m.pos_mix[l * d..(l + 1) * d];
            let wq = &m.w_query[l * d * d..(l + 1) * d * d];
            match self.mode {
                KernelMode::Naive => self.layer_naive(buf, l, t, pos_scale, wl, pm, wq, &mut h),
                KernelMode::Blocked | KernelMode::BlockedParallel => {
                    self.layer_blocked(buf, l, t, pos_scale, wl, pm, wq, &mut h)
                }
            }
        }
        for tok in 0..v {
            let o = &m.out_proj[tok * d..(tok + 1) * d];
            let mut acc = 0.0f32;
            for (&oi, &hi) in o.iter().zip(&h) {
                acc += oi * hi;
            }
            logits_row[tok] = acc;
        }
        Ok(())
    }

    /// Seed-order layer step: sequential scalar loops, indexed exactly
    /// like the pre-dispatch `step_slot` (modulo the slot-buffer row
    /// mapping, which only changes addresses, never FP operations).
    #[allow(clippy::too_many_arguments)]
    fn layer_naive(
        &self,
        buf: &mut [f32],
        l: usize,
        t: usize,
        pos_scale: f32,
        wl: &[f32],
        pm: &[f32],
        wq: &[f32],
        h: &mut [f32],
    ) {
        let d = self.model.cfg.latent_dim;
        let n = self.n;
        let row = |j: usize| (l * n + j) * d;
        // New latent from the hidden state, written at position t.
        let base = row(t);
        for i in 0..d {
            let mut acc = pm[i] * pos_scale;
            for (j, &hj) in h.iter().enumerate() {
                acc += wl[i * d + j] * hj;
            }
            buf[base + i] = acc.tanh();
        }
        // Attention over positions 0..=t of this slot's rows.
        let mut q = vec![0.0f32; d];
        for i in 0..d {
            let mut acc = 0.0f32;
            for (j, &hj) in h.iter().enumerate() {
                acc += wq[i * d + j] * hj;
            }
            q[i] = acc;
        }
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let mut scores = Vec::with_capacity(t + 1);
        let mut max_s = f32::NEG_INFINITY;
        for j in 0..=t {
            let r = row(j);
            let mut s = 0.0f32;
            for i in 0..d {
                s += q[i] * buf[r + i];
            }
            let s = s * inv_sqrt_d;
            max_s = max_s.max(s);
            scores.push(s);
        }
        let mut norm = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - max_s).exp();
            norm += *s;
        }
        let mut ctx = vec![0.0f32; d];
        for (j, &w) in scores.iter().enumerate() {
            let r = row(j);
            let w = w / norm;
            for i in 0..d {
                ctx[i] += w * buf[r + i];
            }
        }
        for i in 0..d {
            h[i] = (h[i] + ctx[i]).tanh();
        }
    }

    /// Fast-path layer step: the same FP operations in the same order as
    /// [`layer_naive`](Self::layer_naive) — every reduction is still the
    /// ascending sequential fold — re-expressed over tight row slices
    /// (bounds-check-free iterator loops) and KV tiles of `block_kv`
    /// rows.  Tiling a loop whose per-row work is independent reorders
    /// nothing, so this arm is bitwise-identical to the naive arm; it is
    /// just faster to execute.  The deep 8-lane kernels live in
    /// [`crate::kernels::attn`] where bitwise parity with the seed is
    /// not a constraint.
    #[allow(clippy::too_many_arguments)]
    fn layer_blocked(
        &self,
        buf: &mut [f32],
        l: usize,
        t: usize,
        pos_scale: f32,
        wl: &[f32],
        pm: &[f32],
        wq: &[f32],
        h: &mut [f32],
    ) {
        let d = self.model.cfg.latent_dim;
        let n = self.n;
        let base = (l * n + t) * d;
        {
            let dst = &mut buf[base..base + d];
            for (i, o) in dst.iter_mut().enumerate() {
                let mut acc = pm[i] * pos_scale;
                for (&w, &hj) in wl[i * d..(i + 1) * d].iter().zip(h.iter()) {
                    acc += w * hj;
                }
                *o = acc.tanh();
            }
        }
        let mut q = vec![0.0f32; d];
        for (i, qo) in q.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (&w, &hj) in wq[i * d..(i + 1) * d].iter().zip(h.iter()) {
                acc += w * hj;
            }
            *qo = acc;
        }
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let layer = &buf[l * n * d..(l + 1) * n * d];
        let mut scores = Vec::with_capacity(t + 1);
        let mut max_s = f32::NEG_INFINITY;
        let mut j0 = 0;
        while j0 <= t {
            let bc = self.block_kv.min(t + 1 - j0);
            for krow in layer[j0 * d..(j0 + bc) * d].chunks_exact(d) {
                let mut s = 0.0f32;
                for (&qi, &ki) in q.iter().zip(krow) {
                    s += qi * ki;
                }
                let s = s * inv_sqrt_d;
                max_s = max_s.max(s);
                scores.push(s);
            }
            j0 += bc;
        }
        let mut norm = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - max_s).exp();
            norm += *s;
        }
        let mut ctx = vec![0.0f32; d];
        for (vrow, &p) in layer[..(t + 1) * d].chunks_exact(d).zip(scores.iter()) {
            let w = p / norm;
            for (c, &x) in ctx.iter_mut().zip(vrow) {
                *c += w * x;
            }
        }
        for (hi, &c) in h.iter_mut().zip(ctx.iter()) {
            *hi = (*hi + c).tanh();
        }
    }

    /// Run one slot's whole chunk in order: the empty chunk is the
    /// padded-slot scratch step (token 0 at position 0), matching what
    /// the per-token `step` path does for idle slots.  When `argmaxes`
    /// is supplied, the greedy argmax after every consumed token is
    /// recorded (the verification contract).
    fn run_chunk(
        &self,
        buf: &mut [f32],
        chunk: &[i32],
        start: i32,
        logits_row: &mut [f32],
        mut argmaxes: Option<&mut Vec<i32>>,
    ) -> anyhow::Result<()> {
        if chunk.is_empty() {
            // Padded slot: same scratch write `step` performs.
            return self.step_token(buf, 0, 0, logits_row);
        }
        anyhow::ensure!(start >= 0, "negative start_pos");
        let v = self.model.cfg.vocab;
        for (j, &tok) in chunk.iter().enumerate() {
            self.step_token(buf, tok, start as usize + j, logits_row)?;
            if let Some(out) = argmaxes.as_deref_mut() {
                out.push(super::DecodeRunner::argmax_row(logits_row, v, 0));
            }
        }
        Ok(())
    }
}

impl ReferenceRunner {
    /// The owned, thread-shippable kernel for this runner's shape.
    fn slot_kernel(&self) -> SlotKernel {
        SlotKernel {
            model: Arc::clone(&self.model),
            n: self.kv_bucket,
            mode: self.kernels.mode(),
            block_kv: self.kernels.block_kv(),
        }
    }

    /// Copy one slot's rows out of the `[L × B × n × d]` host literal
    /// into a contiguous `[L × n × d]` buffer (one memcpy per layer).
    fn gather_slot(&self, host: &[f32], slot: usize) -> Vec<f32> {
        let c = &self.model.cfg;
        let (nl, d) = (c.n_layers, c.latent_dim);
        let (b, n) = (self.batch, self.kv_bucket);
        let mut buf = vec![0.0f32; nl * n * d];
        for l in 0..nl {
            let src = (l * b + slot) * n * d;
            buf[l * n * d..(l + 1) * n * d].copy_from_slice(&host[src..src + n * d]);
        }
        buf
    }

    /// Copy a slot buffer back into its host-literal rows.
    fn scatter_slot(&self, host: &mut [f32], slot: usize, buf: &[f32]) {
        let c = &self.model.cfg;
        let (nl, d) = (c.n_layers, c.latent_dim);
        let (b, n) = (self.batch, self.kv_bucket);
        for l in 0..nl {
            let dst = (l * b + slot) * n * d;
            host[dst..dst + n * d].copy_from_slice(&buf[l * n * d..(l + 1) * n * d]);
        }
    }

    /// Pull the cache literal to a host vector, validating its shape.
    fn host_cache(&self, cache: &xla::Literal) -> anyhow::Result<Vec<f32>> {
        let c = &self.model.cfg;
        let want = c.n_layers * self.batch * self.kv_bucket * c.latent_dim;
        let host: Vec<f32> = cache
            .to_vec()
            .map_err(|e| anyhow::anyhow!("cache to_vec: {e:?}"))?;
        anyhow::ensure!(
            host.len() == want,
            "cache has {} elems, want {want}",
            host.len()
        );
        Ok(host)
    }

    fn pack_cache(&self, host: &[f32]) -> anyhow::Result<xla::Literal> {
        let c = &self.model.cfg;
        let dims = [
            c.n_layers as i64,
            self.batch as i64,
            self.kv_bucket as i64,
            c.latent_dim as i64,
        ];
        super::client::literal_from_f32(host, &dims)
    }

    /// Execute every slot's chunk — sequentially in slot order, or
    /// fanned out over the dispatcher's pool in `blocked_parallel` mode.
    /// Slot isolation plus the fixed per-slot reduction order inside
    /// [`SlotKernel`] make the two schedules bit-identical; `map`
    /// preserves input order, and errors surface in ascending slot
    /// order either way.  Returns per-slot `(logits_row, argmaxes)`.
    fn run_all_slots(
        &self,
        host: &mut [f32],
        work: Vec<(Vec<i32>, i32)>,
        want_argmaxes: bool,
    ) -> anyhow::Result<Vec<(Vec<f32>, Vec<i32>)>> {
        let v = self.model.cfg.vocab;
        let kernel = self.slot_kernel();
        if let Some(pool) = self.kernels.pool() {
            let items: Vec<(usize, Vec<f32>, Vec<i32>, i32)> = work
                .into_iter()
                .enumerate()
                .map(|(slot, (chunk, start))| (slot, self.gather_slot(host, slot), chunk, start))
                .collect();
            let results = pool.map(items, move |(slot, mut buf, chunk, start)| {
                let mut row = vec![0.0f32; kernel.model.cfg.vocab];
                let mut am = Vec::new();
                let argm = if want_argmaxes { Some(&mut am) } else { None };
                let r = kernel.run_chunk(&mut buf, &chunk, start, &mut row, argm);
                (slot, buf, row, am, r)
            });
            let mut out = Vec::with_capacity(results.len());
            for (slot, buf, row, am, r) in results {
                r?;
                self.scatter_slot(host, slot, &buf);
                out.push((row, am));
            }
            Ok(out)
        } else {
            let mut out = Vec::with_capacity(work.len());
            for (slot, (chunk, start)) in work.into_iter().enumerate() {
                let mut buf = self.gather_slot(host, slot);
                let mut row = vec![0.0f32; v];
                let mut am = Vec::new();
                let argm = if want_argmaxes { Some(&mut am) } else { None };
                kernel.run_chunk(&mut buf, &chunk, start, &mut row, argm)?;
                self.scatter_slot(host, slot, &buf);
                out.push((row, am));
            }
            Ok(out)
        }
    }
}

impl StepRunner for ReferenceRunner {
    fn step(
        &self,
        tokens: &[i32],
        cache: &xla::Literal,
        lengths: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        let _span = obs::span("runtime", "step");
        let v = self.model.cfg.vocab;
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b, "tokens len {} != batch {b}", tokens.len());
        anyhow::ensure!(lengths.len() == b, "lengths len {} != batch {b}", lengths.len());
        for &t in lengths {
            anyhow::ensure!(
                t >= 0,
                "length {t} overflows bucket {} (no room for this token)",
                self.kv_bucket
            );
        }
        let mut host = self.host_cache(cache)?;
        let work: Vec<(Vec<i32>, i32)> = tokens
            .iter()
            .zip(lengths)
            .map(|(&tok, &t)| (vec![tok], t))
            .collect();
        let outs = self.run_all_slots(&mut host, work, false)?;
        let mut logits = vec![0.0f32; b * v];
        for (slot, (row, _)) in outs.into_iter().enumerate() {
            logits[slot * v..(slot + 1) * v].copy_from_slice(&row);
        }
        Ok((logits, self.pack_cache(&host)?))
    }

    /// Native multi-token path: one host round-trip for the whole mixed
    /// batch, then [`SlotKernel::step_token`] once per (slot, token) —
    /// bit-identical to the per-token fallback because slots are
    /// isolated and both paths run the identical per-slot kernel in the
    /// identical per-slot order.
    fn prefill_chunk(
        &self,
        chunks: &[Vec<i32>],
        cache: &xla::Literal,
        start_pos: &[i32],
    ) -> anyhow::Result<(Vec<f32>, xla::Literal)> {
        let _span = obs::span("runtime", "prefill_chunk");
        let v = self.model.cfg.vocab;
        let b = self.batch;
        anyhow::ensure!(chunks.len() == b, "chunks len {} != batch {b}", chunks.len());
        anyhow::ensure!(
            start_pos.len() == b,
            "start_pos len {} != batch {b}",
            start_pos.len()
        );
        let mut host = self.host_cache(cache)?;
        let work: Vec<(Vec<i32>, i32)> = chunks
            .iter()
            .cloned()
            .zip(start_pos.iter().copied())
            .collect();
        let outs = self.run_all_slots(&mut host, work, false)?;
        let mut logits = vec![0.0f32; b * v];
        for (slot, (row, _)) in outs.into_iter().enumerate() {
            logits[slot * v..(slot + 1) * v].copy_from_slice(&row);
        }
        Ok((logits, self.pack_cache(&host)?))
    }

    /// Native verification: identical per-slot kernel walk to the native
    /// [`prefill_chunk`](Self::prefill_chunk) — same
    /// [`SlotKernel::step_token`] calls in the same order, hence
    /// bit-identical cache effects — recording the greedy argmax after
    /// every consumed token instead of keeping only the last logits row.
    fn verify_chunk(
        &self,
        chunks: &[Vec<i32>],
        cache: &xla::Literal,
        start_pos: &[i32],
    ) -> anyhow::Result<(Vec<Vec<i32>>, xla::Literal)> {
        let _span = obs::span("runtime", "verify_chunk");
        let b = self.batch;
        anyhow::ensure!(chunks.len() == b, "chunks len {} != batch {b}", chunks.len());
        anyhow::ensure!(
            start_pos.len() == b,
            "start_pos len {} != batch {b}",
            start_pos.len()
        );
        let mut host = self.host_cache(cache)?;
        let work: Vec<(Vec<i32>, i32)> = chunks
            .iter()
            .cloned()
            .zip(start_pos.iter().copied())
            .collect();
        let outs = self.run_all_slots(&mut host, work, true)?;
        let out: Vec<Vec<i32>> = outs.into_iter().map(|(_, am)| am).collect();
        Ok((out, self.pack_cache(&host)?))
    }

    /// Both chunk entry points above are single-pass: no wavefront
    /// re-feeds, so the compute ledger records no `chunk_refeed` waste
    /// for this backend.
    fn native_chunking(&self) -> bool {
        true
    }

    fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Arc<ReferenceModel> {
        ReferenceModel::new(ReferenceModelConfig {
            vocab: 32,
            n_layers: 2,
            latent_dim: 8,
            seed: 7,
            batch_buckets: vec![1, 2, 4],
            kv_buckets: vec![8, 16],
        })
    }

    fn decode_greedy(
        model: &Arc<ReferenceModel>,
        batch: usize,
        kv: usize,
        prompt: &[i32],
        new_tokens: usize,
        slot: usize,
    ) -> Vec<i32> {
        let r = model.runner(batch, kv);
        let mut cache = r.fresh_cache().unwrap();
        let mut lengths = vec![0i32; batch];
        let mut tokens = vec![0i32; batch];
        let mut out = Vec::new();
        let v = r.vocab();
        let mut next = prompt[0];
        let mut fed = 0usize;
        while out.len() < new_tokens {
            tokens[slot] = next;
            let (logits, c) = StepRunner::step(&r, &tokens, &cache, &lengths).unwrap();
            cache = c;
            lengths[slot] += 1;
            fed += 1;
            let arg = super::super::DecodeRunner::argmax_row(&logits, v, slot);
            if fed < prompt.len() {
                next = prompt[fed];
            } else {
                out.push(arg);
                next = arg;
            }
        }
        out
    }

    #[test]
    fn deterministic_across_runs() {
        let m = small();
        let a = decode_greedy(&m, 1, 16, &[3, 5, 7], 6, 0);
        let b = decode_greedy(&m, 1, 16, &[3, 5, 7], 6, 0);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&t| (0..32).contains(&t)));
    }

    #[test]
    fn slot_and_bucket_invariant() {
        // The same request must decode identically in any slot of any
        // bucket — the isolation contract the engine depends on.
        let m = small();
        let base = decode_greedy(&m, 1, 8, &[3, 5, 7], 4, 0);
        assert_eq!(decode_greedy(&m, 2, 8, &[3, 5, 7], 4, 1), base);
        assert_eq!(decode_greedy(&m, 4, 16, &[3, 5, 7], 4, 3), base);
    }

    #[test]
    fn history_changes_outputs() {
        let m = small();
        let a = decode_greedy(&m, 1, 16, &[3, 5, 7], 6, 0);
        let b = decode_greedy(&m, 1, 16, &[3, 5, 8], 6, 0);
        assert_ne!(a, b, "prompt change must change decode");
    }

    #[test]
    fn rejects_overflow_and_bad_tokens() {
        let m = small();
        let r = m.runner(1, 8);
        let cache = r.fresh_cache().unwrap();
        assert!(StepRunner::step(&r, &[1], &cache, &[8]).is_err());
        assert!(StepRunner::step(&r, &[99], &cache, &[0]).is_err());
        // Chunk overrunning the bucket fails too.
        assert!(r
            .prefill_chunk(&[(0..9).collect::<Vec<i32>>()], &cache, &[0])
            .is_err());
    }

    #[test]
    fn chunked_equals_per_token_loop() {
        // The headline contract: one prefill_chunk call over a prompt must
        // produce the bit-identical cache and final logits as feeding the
        // prompt one step at a time.
        let m = small();
        let r = m.runner(2, 16);
        let prompt: Vec<i32> = vec![3, 5, 7, 11, 2, 9];

        // Per-token loop in slot 0 (slot 1 padded, token 0 / length 0).
        let mut cache = r.fresh_cache().unwrap();
        let mut logits = Vec::new();
        for (t, &tok) in prompt.iter().enumerate() {
            let (lg, c) =
                StepRunner::step(&r, &[tok, 0], &cache, &[t as i32, 0]).unwrap();
            cache = c;
            logits = lg;
        }

        // One chunked call.
        let fresh = r.fresh_cache().unwrap();
        let (clogits, ccache) = r
            .prefill_chunk(&[prompt.clone(), Vec::new()], &fresh, &[0, 0])
            .unwrap();

        assert_eq!(clogits, logits, "final logits differ");
        assert_eq!(
            ccache.to_vec::<f32>().unwrap(),
            cache.to_vec::<f32>().unwrap(),
            "cache literal differs"
        );
    }

    #[test]
    fn native_chunk_equals_fallback() {
        // The native override must match the documented per-token fallback
        // bit-for-bit on a mixed batch: a long chunk, a decode-style
        // single token, and a padded slot.
        let m = small();
        let r = m.runner(4, 16);
        // Give the decode slot some history first.
        let mut cache = r.fresh_cache().unwrap();
        for (t, tok) in [4i32, 6, 8].into_iter().enumerate() {
            let (_, c) =
                StepRunner::step(&r, &[0, tok, 0, 0], &cache, &[0, t as i32, 0, 0]).unwrap();
            cache = c;
        }
        let chunks: Vec<Vec<i32>> = vec![
            vec![3, 5, 7, 11, 2],  // 5-token prefill chunk
            vec![12],              // decode: single token at position 3
            Vec::new(),            // padded
            vec![9, 1],            // 2-token chunk
        ];
        let start = [0, 3, 0, 0];
        let (nl, nc) = r.prefill_chunk(&chunks, &cache, &start).unwrap();
        let (fl, fc) =
            super::super::backend::prefill_chunk_fallback(&r, &chunks, &cache, &start).unwrap();
        assert_eq!(nl, fl, "logits differ between native and fallback");
        assert_eq!(
            nc.to_vec::<f32>().unwrap(),
            fc.to_vec::<f32>().unwrap(),
            "caches differ between native and fallback"
        );
    }

    #[test]
    fn verify_chunk_cache_identical_to_prefill_chunk() {
        // The speculative contract: a verification tick must leave the
        // exact cache a prefill tick over the same chunks would, and its
        // last argmax must match the prefill path's logits row.
        let m = small();
        let r = m.runner(3, 16);
        let mut cache = r.fresh_cache().unwrap();
        for (t, tok) in [4i32, 6].into_iter().enumerate() {
            let (_, c) =
                StepRunner::step(&r, &[0, tok, 0], &cache, &[0, t as i32, 0]).unwrap();
            cache = c;
        }
        let chunks: Vec<Vec<i32>> = vec![
            vec![3, 5, 7, 11], // prefill-style chunk
            vec![12, 1, 9],    // decode token + 2 draft tokens at position 2
            Vec::new(),        // padded
        ];
        let start = [0, 2, 0];
        let (pl, pc) = r.prefill_chunk(&chunks, &cache, &start).unwrap();
        let (am, vc) = r.verify_chunk(&chunks, &cache, &start).unwrap();
        assert_eq!(
            vc.to_vec::<f32>().unwrap(),
            pc.to_vec::<f32>().unwrap(),
            "verification changed the cache"
        );
        let v = StepRunner::vocab(&r);
        for slot in 0..2 {
            assert_eq!(am[slot].len(), chunks[slot].len());
            assert_eq!(
                *am[slot].last().unwrap(),
                super::super::DecodeRunner::argmax_row(&pl, v, slot),
                "slot {slot} final argmax diverges from prefill logits"
            );
        }
        assert!(am[2].is_empty(), "padded slot has no argmaxes");
    }

    #[test]
    fn verify_native_equals_fallback() {
        let m = small();
        let r = m.runner(4, 16);
        let mut cache = r.fresh_cache().unwrap();
        for (t, tok) in [4i32, 6, 8].into_iter().enumerate() {
            let (_, c) =
                StepRunner::step(&r, &[0, tok, 0, 0], &cache, &[0, t as i32, 0, 0]).unwrap();
            cache = c;
        }
        let chunks: Vec<Vec<i32>> = vec![
            vec![3, 5, 7, 11, 2], // long chunk
            vec![12, 9],          // decode + 1 draft at position 3
            Vec::new(),           // padded
            vec![9],              // single token
        ];
        let start = [0, 3, 0, 0];
        let (na, nc) = r.verify_chunk(&chunks, &cache, &start).unwrap();
        let (fa, fc) =
            super::super::backend::verify_chunk_fallback(&r, &chunks, &cache, &start).unwrap();
        assert_eq!(na, fa, "argmaxes differ between native and fallback");
        assert_eq!(
            nc.to_vec::<f32>().unwrap(),
            fc.to_vec::<f32>().unwrap(),
            "caches differ between native and fallback"
        );
    }

    #[test]
    fn verify_argmaxes_track_per_token_greedy() {
        // Position j's argmax must equal what a per-token step loop sees
        // after feeding the same j+1 tokens — the property the engine's
        // acceptance rule is built on.
        let m = small();
        let r = m.runner(1, 16);
        let toks: Vec<i32> = vec![3, 5, 7, 11, 2];
        let fresh = r.fresh_cache().unwrap();
        let (am, _) = r.verify_chunk(&[toks.clone()], &fresh, &[0]).unwrap();
        let mut cache = r.fresh_cache().unwrap();
        for (t, &tok) in toks.iter().enumerate() {
            let (lg, c) = StepRunner::step(&r, &[tok], &cache, &[t as i32]).unwrap();
            cache = c;
            assert_eq!(
                am[0][t],
                super::super::DecodeRunner::argmax_row(&lg, StepRunner::vocab(&r), 0),
                "argmax diverges at position {t}"
            );
        }
    }

    fn dispatch(
        mode: &str,
        threads: usize,
        block_kv: usize,
    ) -> Arc<crate::kernels::KernelDispatch> {
        crate::kernels::KernelDispatch::new(crate::kernels::KernelConfig {
            mode: crate::kernels::KernelMode::parse(mode).unwrap(),
            threads,
            block_kv,
        })
        .unwrap()
    }

    /// Mixed prefill + decode + padded workload under one kernel mode:
    /// returns (final logits, prefill cache, verify cache, argmaxes).
    fn run_mixed(
        mode: &str,
        threads: usize,
        block_kv: usize,
    ) -> (Vec<u32>, Vec<u32>, Vec<u32>, Vec<Vec<i32>>) {
        let m = small();
        let r = m.runner_with(4, 16, dispatch(mode, threads, block_kv));
        let mut cache = r.fresh_cache().unwrap();
        for (t, tok) in [4i32, 6, 8].into_iter().enumerate() {
            let (_, c) =
                StepRunner::step(&r, &[0, tok, 0, 0], &cache, &[0, t as i32, 0, 0]).unwrap();
            cache = c;
        }
        let chunks: Vec<Vec<i32>> = vec![vec![3, 5, 7, 11, 2], vec![12], Vec::new(), vec![9, 1]];
        let start = [0, 3, 0, 0];
        let (logits, pc) = r.prefill_chunk(&chunks, &cache, &start).unwrap();
        let (am, vc) = r.verify_chunk(&chunks, &cache, &start).unwrap();
        let bits = |v: Vec<f32>| v.into_iter().map(f32::to_bits).collect::<Vec<u32>>();
        (
            bits(logits),
            bits(pc.to_vec::<f32>().unwrap()),
            bits(vc.to_vec::<f32>().unwrap()),
            am,
        )
    }

    #[test]
    fn kernel_modes_are_bit_identical() {
        // The dispatcher's determinism contract at the runner level:
        // naive, blocked (any tile size) and blocked_parallel (any
        // thread count) produce bitwise-equal logits, caches and
        // verification argmaxes on a mixed prefill/decode/padded batch.
        let base = run_mixed("naive", 0, 64);
        for (mode, threads, block_kv) in [
            ("blocked", 0, 1),
            ("blocked", 0, 4),
            ("blocked", 0, 64),
            ("blocked_parallel", 1, 4),
            ("blocked_parallel", 2, 4),
            ("blocked_parallel", 3, 16),
        ] {
            let got = run_mixed(mode, threads, block_kv);
            assert_eq!(base, got, "mode {mode} t={threads} bk={block_kv}");
        }
    }

    #[test]
    fn all_single_token_chunks_equal_one_step() {
        let m = small();
        let r = m.runner(2, 8);
        let cache = r.fresh_cache().unwrap();
        let (sl, sc) = StepRunner::step(&r, &[3, 5], &cache, &[0, 0]).unwrap();
        let (cl, cc) = r
            .prefill_chunk(&[vec![3], vec![5]], &cache, &[0, 0])
            .unwrap();
        assert_eq!(sl, cl);
        assert_eq!(sc.to_vec::<f32>().unwrap(), cc.to_vec::<f32>().unwrap());
    }
}
