//! Bench harness (criterion substitute): warmup, adaptive iteration count,
//! robust summary stats, and table output for the paper-reproduction
//! benches under `rust/benches/`.

pub mod harness;
pub mod table;

pub use harness::{BenchResult, Bencher};
pub use table::Table;
