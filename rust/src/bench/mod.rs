//! Bench harness (criterion substitute): warmup, adaptive iteration count,
//! robust summary stats, table output for the paper-reproduction benches
//! under `rust/benches/`, and the bench-compare engine behind the
//! `bench_compare` binary (`docs/benchmarking.md`).

pub mod compare;
pub mod harness;
pub mod table;

pub use compare::{
    compare, metric_direction, parse_bench_doc, parse_trajectory_entry, trajectory_report,
    BenchDoc, CompareReport, ComputeSummary, Direction, Thresholds, TrajectoryEntry,
};
pub use harness::{BenchResult, Bencher};
pub use table::Table;
