//! bench-compare: align `BENCH_*.json` documents and render Markdown
//! regression reports (the `bench_compare` binary's engine).
//!
//! Pure data → data: this module parses bench documents ([`BenchDoc`])
//! and trajectory entries ([`TrajectoryEntry`]) out of
//! [`crate::util::json::Json`] values, aligns cases and metrics *by
//! name*, and produces a [`CompareReport`] — a Markdown table with
//! baseline/current/delta/ratio columns plus the list of threshold
//! breaches.  No file I/O here; the binary loads files and maps
//! `CompareReport::exit_code` onto the process exit status.
//!
//! Alignment policy — **no silent drops**: a case or metric present on
//! only one side gets an explicit ⚠ row (`missing in current` / `new`)
//! and a warning, never omission.  Gating policy: wall-time columns gate
//! on `Thresholds::time_ratio` only when *both* sides have enough
//! samples ([`BenchResult::LOW_CONFIDENCE_ITERS`]; low-n rows are
//! flagged ⚠ and never gate); derived metric columns gate on
//! `Thresholds::metric_ratio` in the direction [`metric_direction`]
//! infers from the name (TTFT/e2e/queue/`kv_slots_per_token`/`*_us`
//! up = worse, throughput down = worse, anything else informational).
//!
//! [`BenchResult::LOW_CONFIDENCE_ITERS`]: super::harness::BenchResult::LOW_CONFIDENCE_ITERS

use crate::util::json::Json;

use super::harness::BenchResult;

/// Regression thresholds (ratios are `worse/better` multipliers).
#[derive(Clone, Copy, Debug)]
pub struct Thresholds {
    /// Max allowed `current/baseline` for case wall times (mean µs).
    /// Generous by default: CI boxes are noisy and the deterministic
    /// step-count metrics are the precise signal.
    pub time_ratio: f64,
    /// Max allowed worsening ratio for derived metrics (TTFT steps,
    /// tokens/step, `kv_slots_per_token`, …).
    pub metric_ratio: f64,
    /// Treat a case/metric that disappeared from the current run as a
    /// breach (new columns are always just ⚠).
    pub fail_on_missing: bool,
}

impl Default for Thresholds {
    fn default() -> Self {
        Thresholds {
            time_ratio: 2.0,
            metric_ratio: 1.10,
            fail_on_missing: false,
        }
    }
}

/// Which direction of change is a regression for a metric column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    HigherWorse,
    LowerWorse,
    /// Reported but never gated (counts, identities).
    Informational,
}

/// Infer gating direction from a metric name.  Scenario prefixes
/// (`bursty_poisson.ttft_steps_mean`) are stripped before matching.
pub fn metric_direction(name: &str) -> Direction {
    let base = name.rsplit('.').next().unwrap_or(name);
    if base.contains("per_s") || base.contains("throughput") || base.contains("tokens_per_step") {
        Direction::LowerWorse
    } else if base.starts_with("ttft")
        || base.starts_with("e2e")
        || base.starts_with("queue")
        || base == "kv_slots_per_token"
        || base.ends_with("_us")
    {
        Direction::HigherWorse
    } else {
        Direction::Informational
    }
}

/// One case's stats, as read back from `BENCH_*.json`.
#[derive(Clone, Copy, Debug)]
pub struct CaseStats {
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p99_us: f64,
}

impl CaseStats {
    fn low_confidence(&self) -> bool {
        self.iters < BenchResult::LOW_CONFIDENCE_ITERS
    }
}

/// Parsed view of one `BENCH_*.json` document.
#[derive(Clone, Debug)]
pub struct BenchDoc {
    /// Where it came from (file stem) — report attribution.
    pub label: String,
    pub bench: String,
    pub commit: String,
    pub quick: bool,
    pub cases: Vec<(String, CaseStats)>,
    pub metrics: Vec<(String, f64)>,
}

/// Parse and schema-check one bench document.  Errors name the missing
/// or mistyped field so a malformed file fails loudly in CI.
pub fn parse_bench_doc(label: &str, doc: &Json) -> anyhow::Result<BenchDoc> {
    let bench = doc
        .get("bench")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing string field `bench`"))?
        .to_string();
    let meta = doc.get("meta");
    let commit = meta
        .get("git_commit")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing `meta.git_commit`"))?
        .to_string();
    let quick = meta
        .get("quick")
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing bool `meta.quick`"))?;
    let cases_json = doc
        .get("cases")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing array `cases`"))?;
    let mut cases = Vec::with_capacity(cases_json.len());
    for (i, c) in cases_json.iter().enumerate() {
        let name = c
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("{label}: cases[{i}] missing `name`"))?
            .to_string();
        let num = |field: &str| -> anyhow::Result<f64> {
            c.get(field)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{label}: case `{name}` missing `{field}`"))
        };
        cases.push((
            name.clone(),
            CaseStats {
                iters: num("iters")? as usize,
                mean_us: num("mean_us")?,
                median_us: num("median_us")?,
                p99_us: num("p99_us")?,
            },
        ));
    }
    let metrics_json = doc
        .get("metrics")
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing object `metrics`"))?;
    let mut metrics = Vec::with_capacity(metrics_json.len());
    for (k, v) in metrics_json {
        let v = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("{label}: metric `{k}` is not a number"))?;
        metrics.push((k.clone(), v));
    }
    Ok(BenchDoc {
        label: label.to_string(),
        bench,
        commit,
        quick,
        cases,
        metrics,
    })
}

/// The outcome of a comparison: the rendered report plus what gated.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub markdown: String,
    /// Threshold breaches — non-empty makes [`exit_code`](Self::exit_code)
    /// non-zero.
    pub breaches: Vec<String>,
    /// Non-gating anomalies (missing/new/low-confidence columns).
    pub warnings: Vec<String>,
}

impl CompareReport {
    /// Process exit status the binary maps this to: 0 clean, 1 breached.
    pub fn exit_code(&self) -> i32 {
        if self.breaches.is_empty() { 0 } else { 1 }
    }
}

fn fmt(v: f64) -> String {
    if !v.is_finite() {
        return "—".into();
    }
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 1000.0 {
        format!("{v:.0}")
    } else if a >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

fn fmt_ratio(cur: f64, base: f64) -> String {
    if base == 0.0 {
        "—".into()
    } else {
        format!("{:.3}x", cur / base)
    }
}

/// Names from both sides, baseline order first, current-only appended —
/// the no-silent-drops alignment.
fn aligned_names<T>(base: &[(String, T)], cur: &[(String, T)]) -> Vec<String> {
    let mut names: Vec<String> = base.iter().map(|(n, _)| n.clone()).collect();
    for (n, _) in cur {
        if !names.contains(n) {
            names.push(n.clone());
        }
    }
    names
}

fn lookup<'a, T>(list: &'a [(String, T)], name: &str) -> Option<&'a T> {
    list.iter().find(|(n, _)| n == name).map(|(_, v)| v)
}

/// Compare two bench documents and render the Markdown report.
pub fn compare(baseline: &BenchDoc, current: &BenchDoc, th: &Thresholds) -> CompareReport {
    let mut breaches = Vec::new();
    let mut warnings = Vec::new();
    let mut md = String::new();
    md.push_str(&format!("# Bench compare — `{}`\n\n", current.bench));
    if baseline.bench != current.bench {
        warnings.push(format!(
            "comparing different benches: `{}` vs `{}`",
            baseline.bench, current.bench
        ));
        md.push_str(&format!(
            "> ⚠ baseline is a different bench (`{}`)\n\n",
            baseline.bench
        ));
    }
    md.push_str("| | label | commit | quick |\n|---|---|---|---|\n");
    md.push_str(&format!(
        "| baseline | `{}` | `{}` | {} |\n",
        baseline.label, baseline.commit, baseline.quick
    ));
    md.push_str(&format!(
        "| current | `{}` | `{}` | {} |\n\n",
        current.label, current.commit, current.quick
    ));

    // Cases: wall-time columns.
    md.push_str("## Cases (wall time)\n\n");
    md.push_str(
        "| case | baseline mean µs | current mean µs | Δ µs | ratio | n (base→cur) | status |\n\
         |---|---:|---:|---:|---:|---:|---|\n",
    );
    for name in aligned_names(&baseline.cases, &current.cases) {
        let b = lookup(&baseline.cases, &name);
        let c = lookup(&current.cases, &name);
        match (b, c) {
            (Some(b), Some(c)) => {
                let delta = c.mean_us - b.mean_us;
                let low = b.low_confidence() || c.low_confidence();
                let status = if low {
                    warnings.push(format!(
                        "case `{name}`: low confidence (n {} → {}), delta not gated",
                        b.iters, c.iters
                    ));
                    "⚠ low-n".to_string()
                } else if b.mean_us > 0.0 && c.mean_us / b.mean_us > th.time_ratio {
                    let msg = format!(
                        "case `{name}`: mean {} µs → {} µs exceeds {:.2}x time threshold",
                        fmt(b.mean_us),
                        fmt(c.mean_us),
                        th.time_ratio
                    );
                    breaches.push(msg);
                    "✗ regression".to_string()
                } else {
                    "ok".to_string()
                };
                md.push_str(&format!(
                    "| {name} | {} | {} | {:+.2} | {} | {}→{} | {status} |\n",
                    fmt(b.mean_us),
                    fmt(c.mean_us),
                    delta,
                    fmt_ratio(c.mean_us, b.mean_us),
                    b.iters,
                    c.iters
                ));
            }
            (Some(b), None) => {
                let msg = format!("case `{name}` missing in current run");
                if th.fail_on_missing {
                    breaches.push(msg);
                } else {
                    warnings.push(msg);
                }
                md.push_str(&format!(
                    "| {name} | {} | — | — | — | {}→— | ⚠ missing in current |\n",
                    fmt(b.mean_us),
                    b.iters
                ));
            }
            (None, Some(c)) => {
                warnings.push(format!("case `{name}` is new (no baseline)"));
                md.push_str(&format!(
                    "| {name} | — | {} | — | — | —→{} | ⚠ new |\n",
                    fmt(c.mean_us),
                    c.iters
                ));
            }
            (None, None) => unreachable!("aligned name from neither side"),
        }
    }

    // Metrics: derived columns (step counts, ratios, throughputs).
    md.push_str("\n## Metrics\n\n");
    md.push_str(
        "| metric | baseline | current | Δ | ratio | status |\n|---|---:|---:|---:|---:|---|\n",
    );
    for name in aligned_names(&baseline.metrics, &current.metrics) {
        let b = lookup(&baseline.metrics, &name).copied();
        let c = lookup(&current.metrics, &name).copied();
        match (b, c) {
            (Some(b), Some(c)) => {
                let dir = metric_direction(&name);
                let worse_ratio = match dir {
                    Direction::HigherWorse if b != 0.0 => Some(c / b),
                    Direction::LowerWorse if c != 0.0 => Some(b / c),
                    _ => None,
                };
                let status = match worse_ratio {
                    Some(r) if r > th.metric_ratio => {
                        let msg = format!(
                            "metric `{name}`: {} → {} worsens beyond {:.2}x threshold",
                            fmt(b),
                            fmt(c),
                            th.metric_ratio
                        );
                        breaches.push(msg);
                        "✗ regression".to_string()
                    }
                    Some(_) => "ok".to_string(),
                    None if dir == Direction::Informational => "info".to_string(),
                    None => {
                        warnings.push(format!("metric `{name}`: zero baseline, no ratio"));
                        "⚠ zero".to_string()
                    }
                };
                md.push_str(&format!(
                    "| {name} | {} | {} | {:+.4} | {} | {status} |\n",
                    fmt(b),
                    fmt(c),
                    c - b,
                    fmt_ratio(c, b)
                ));
            }
            (Some(b), None) => {
                let msg = format!("metric `{name}` missing in current run");
                if th.fail_on_missing {
                    breaches.push(msg);
                } else {
                    warnings.push(msg);
                }
                md.push_str(&format!(
                    "| {name} | {} | — | — | — | ⚠ missing in current |\n",
                    fmt(b)
                ));
            }
            (None, Some(c)) => {
                warnings.push(format!("metric `{name}` is new (no baseline)"));
                md.push_str(&format!("| {name} | — | {} | — | — | ⚠ new |\n", fmt(c)));
            }
            (None, None) => unreachable!(),
        }
    }

    if !breaches.is_empty() {
        md.push_str("\n## Breaches\n\n");
        for b in &breaches {
            md.push_str(&format!("- ✗ {b}\n"));
        }
    }
    if !warnings.is_empty() {
        md.push_str("\n## Warnings\n\n");
        for w in &warnings {
            md.push_str(&format!("- ⚠ {w}\n"));
        }
    }
    CompareReport {
        markdown: md,
        breaches,
        warnings,
    }
}

/// One checked-in trajectory entry (`BENCH_trajectory/*.json`): a small
/// per-commit summary of the quick-mode scenario suite.
#[derive(Clone, Debug)]
pub struct TrajectoryEntry {
    pub label: String,
    pub commit: String,
    pub quick: bool,
    /// scenario → (metric, value), deterministic metrics only.
    pub scenarios: Vec<(String, Vec<(String, f64)>)>,
}

/// Parse and schema-check one trajectory entry.
pub fn parse_trajectory_entry(label: &str, doc: &Json) -> anyhow::Result<TrajectoryEntry> {
    let commit = doc
        .get("commit")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing string field `commit`"))?
        .to_string();
    let quick = doc
        .get("quick")
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing bool `quick`"))?;
    let scen_json = doc
        .get("scenarios")
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing object `scenarios`"))?;
    let mut scenarios = Vec::with_capacity(scen_json.len());
    for (name, entry) in scen_json {
        let obj = entry
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("{label}: scenario `{name}` is not an object"))?;
        let mut metrics = Vec::with_capacity(obj.len());
        for (k, v) in obj {
            let v = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("{label}: scenario `{name}` metric `{k}` is not a number")
            })?;
            metrics.push((k.clone(), v));
        }
        scenarios.push((name.clone(), metrics));
    }
    Ok(TrajectoryEntry {
        label: label.to_string(),
        commit,
        quick,
        scenarios,
    })
}

/// Render the trajectory as one Markdown table per scenario: one row per
/// metric, one column per entry (oldest → newest).  Informational — the
/// trajectory shows drift; gating happens in same-job compares.
pub fn trajectory_report(entries: &[TrajectoryEntry]) -> String {
    let mut md = String::from("# Perf trajectory\n\n");
    if entries.is_empty() {
        md.push_str("(no entries)\n");
        return md;
    }
    md.push_str("Entries (oldest → newest): ");
    md.push_str(
        &entries
            .iter()
            .map(|e| format!("`{}`", e.commit))
            .collect::<Vec<_>>()
            .join(", "),
    );
    md.push_str("\n\n");
    // Union of scenario names across entries, first-seen order.
    let mut scenario_names: Vec<String> = Vec::new();
    for e in entries {
        for (name, _) in &e.scenarios {
            if !scenario_names.contains(name) {
                scenario_names.push(name.clone());
            }
        }
    }
    for sname in &scenario_names {
        md.push_str(&format!("## {sname}\n\n| metric |"));
        for e in entries {
            md.push_str(&format!(" {} |", e.commit));
        }
        md.push_str("\n|---|");
        for _ in entries {
            md.push_str("---:|");
        }
        md.push('\n');
        // Union of metric names for this scenario, first-seen order.
        let mut metric_names: Vec<String> = Vec::new();
        for e in entries {
            if let Some(ms) = lookup(&e.scenarios, sname) {
                for (m, _) in ms {
                    if !metric_names.contains(m) {
                        metric_names.push(m.clone());
                    }
                }
            }
        }
        for m in &metric_names {
            md.push_str(&format!("| {m} |"));
            for e in entries {
                let v = lookup(&e.scenarios, sname).and_then(|ms| lookup(ms, m));
                match v {
                    Some(v) => md.push_str(&format!(" {} |", fmt(*v))),
                    None => md.push_str(" — |"),
                }
            }
            md.push('\n');
        }
        md.push('\n');
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn doc(label: &str, mean_a: f64, iters: usize, ttft: f64) -> BenchDoc {
        let text = format!(
            r#"{{
              "bench": "workloads",
              "meta": {{"git_commit": "{label}", "quick": true, "config": {{}}}},
              "cases": [
                {{"name": "scenario bursty", "iters": {iters}, "mean_us": {mean_a},
                  "median_us": {mean_a}, "p99_us": {mean_a}, "stddev_us": 0.5, "min_us": 1.0}}
              ],
              "metrics": {{
                "bursty_poisson.ttft_steps_mean": {ttft},
                "bursty_poisson.tokens_per_step": 0.8,
                "bursty_poisson.kv_slots_per_token": 0.96,
                "bursty_poisson.finished": 8
              }},
              "serving_metrics": null
            }}"#
        );
        parse_bench_doc(label, &parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn identical_runs_are_clean() {
        let a = doc("aaa", 100.0, 20, 6.0);
        let b = doc("bbb", 100.0, 20, 6.0);
        let r = compare(&a, &b, &Thresholds::default());
        assert_eq!(r.exit_code(), 0, "breaches: {:?}", r.breaches);
        assert!(r.markdown.contains("| scenario bursty |"));
        assert!(r.markdown.contains("ttft_steps_mean"));
        assert!(r.markdown.contains("kv_slots_per_token"));
        assert!(r.markdown.contains("20→20"), "iters reported");
    }

    #[test]
    fn injected_regression_breaches() {
        let base = doc("aaa", 100.0, 20, 6.0);
        // 3x slower and TTFT up 50%: both past the default thresholds.
        let cur = doc("bbb", 300.0, 20, 9.0);
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 1);
        assert!(r.breaches.iter().any(|b| b.contains("scenario bursty")));
        assert!(r
            .breaches
            .iter()
            .any(|b| b.contains("ttft_steps_mean")));
        assert!(r.markdown.contains("✗ regression"));
    }

    #[test]
    fn improvements_do_not_breach() {
        let base = doc("aaa", 100.0, 20, 6.0);
        let cur = doc("bbb", 50.0, 20, 3.0); // 2x faster, TTFT halved
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 0, "breaches: {:?}", r.breaches);
    }

    #[test]
    fn throughput_direction_is_lower_worse() {
        assert_eq!(
            metric_direction("bursty_poisson.tokens_per_step"),
            Direction::LowerWorse
        );
        assert_eq!(metric_direction("decode_tok_per_s_greedy"), Direction::LowerWorse);
        assert_eq!(
            metric_direction("long_context_ladder.ttft_steps_p99"),
            Direction::HigherWorse
        );
        assert_eq!(
            metric_direction("shared_prefix_tenants.kv_slots_per_token"),
            Direction::HigherWorse
        );
        assert_eq!(metric_direction("steps_greedy"), Direction::Informational);

        // A tokens/step collapse gates.
        let base = doc("aaa", 100.0, 20, 6.0);
        let mut cur = doc("bbb", 100.0, 20, 6.0);
        for (k, v) in cur.metrics.iter_mut() {
            if k.ends_with("tokens_per_step") {
                *v = 0.4; // halved throughput
            }
        }
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 1);
        assert!(r.breaches.iter().any(|b| b.contains("tokens_per_step")));
    }

    #[test]
    fn low_confidence_flags_instead_of_gating() {
        let base = doc("aaa", 100.0, 1, 6.0);
        let cur = doc("bbb", 300.0, 1, 6.0); // 3x "slower" on n=1: noise
        let r = compare(&base, &cur, &Thresholds::default());
        assert!(
            !r.breaches.iter().any(|b| b.contains("scenario bursty")),
            "n=1 deltas must not gate"
        );
        assert!(r.markdown.contains("⚠ low-n"));
        assert!(r.warnings.iter().any(|w| w.contains("low confidence")));
    }

    #[test]
    fn missing_and_new_columns_are_explicit() {
        let base = doc("aaa", 100.0, 20, 6.0);
        let mut cur = doc("bbb", 100.0, 20, 6.0);
        cur.cases[0].0 = "scenario renamed".into();
        cur.metrics.push(("brand_new_metric".into(), 1.0));
        let r = compare(&base, &cur, &Thresholds::default());
        assert!(r.markdown.contains("⚠ missing in current"));
        assert!(r.markdown.contains("⚠ new"));
        assert!(r.warnings.iter().any(|w| w.contains("missing in current")));
        assert_eq!(r.exit_code(), 0, "missing is a warning by default");
        let strict = compare(
            &base,
            &cur,
            &Thresholds {
                fail_on_missing: true,
                ..Thresholds::default()
            },
        );
        assert_eq!(strict.exit_code(), 1, "strict mode gates on missing");
    }

    #[test]
    fn malformed_documents_fail_loudly() {
        let missing_bench = parse(r#"{"meta": {}, "cases": [], "metrics": {}}"#).unwrap();
        assert!(parse_bench_doc("x", &missing_bench).is_err());
        let bad_case = parse(
            r#"{"bench": "b", "meta": {"git_commit": "c", "quick": true},
                "cases": [{"name": "a"}], "metrics": {}}"#,
        )
        .unwrap();
        let err = parse_bench_doc("x", &bad_case).unwrap_err().to_string();
        assert!(err.contains("iters"), "names the missing field: {err}");
        let bad_metric = parse(
            r#"{"bench": "b", "meta": {"git_commit": "c", "quick": true},
                "cases": [], "metrics": {"m": "nope"}}"#,
        )
        .unwrap();
        assert!(parse_bench_doc("x", &bad_metric).is_err());
    }

    #[test]
    fn trajectory_entries_parse_and_render() {
        let e1 = parse_trajectory_entry(
            "0001",
            &parse(
                r#"{"commit": "abc1234", "quick": true,
                    "scenarios": {"bursty_poisson": {"ttft_steps_mean": 6.0, "tokens_per_step": 0.8}}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let e2 = parse_trajectory_entry(
            "0002",
            &parse(
                r#"{"commit": "def5678", "quick": true,
                    "scenarios": {"bursty_poisson": {"ttft_steps_mean": 5.0, "tokens_per_step": 0.9},
                                   "cancel_storm": {"cancelled": 7}}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let md = trajectory_report(&[e1, e2]);
        assert!(md.contains("## bursty_poisson"));
        assert!(md.contains("## cancel_storm"));
        assert!(md.contains("abc1234") && md.contains("def5678"));
        assert!(md.contains("ttft_steps_mean"));
        // Metric absent from the older entry renders as a gap, not a drop.
        assert!(md.contains("— |"));

        let bad = parse(r#"{"commit": "x", "quick": true, "scenarios": []}"#).unwrap();
        assert!(parse_trajectory_entry("bad", &bad).is_err());
    }
}
