//! bench-compare: align `BENCH_*.json` documents and render Markdown
//! regression reports (the `bench_compare` binary's engine).
//!
//! Pure data → data: this module parses bench documents ([`BenchDoc`])
//! and trajectory entries ([`TrajectoryEntry`]) out of
//! [`crate::util::json::Json`] values, aligns cases and metrics *by
//! name*, and produces a [`CompareReport`] — a Markdown table with
//! baseline/current/delta/ratio columns plus the list of threshold
//! breaches.  No file I/O here; the binary loads files and maps
//! `CompareReport::exit_code` onto the process exit status.
//!
//! Alignment policy — **no silent drops**: a case or metric present on
//! only one side gets an explicit ⚠ row (`missing in current` / `new`)
//! and a warning, never omission.  Gating policy: wall-time columns gate
//! on `Thresholds::time_ratio` only when *both* sides have enough
//! samples ([`BenchResult::LOW_CONFIDENCE_ITERS`]; low-n rows are
//! flagged ⚠ and never gate); derived metric columns gate on
//! `Thresholds::metric_ratio` in the direction [`metric_direction`]
//! infers from the name (TTFT/e2e/queue/`kv_slots_per_token`/`*_us`/
//! `waste_fraction`/`*_pad_flops` up = worse, throughput,
//! `effective_gflops*` and `attention_gflops*` down = worse, anything
//! else informational).  The wall-clock-derived `attention_gflops*`
//! family gates on the generous `time_ratio` instead, like case times.
//!
//! When either document embeds compute-ledger counters
//! ([`ComputeSummary`]), the report grows a "Roofline (modeled, H20)"
//! section placing each run's modeled FLOP/byte totals against
//! [`crate::sim::roofline`] — informational, never gated.
//!
//! [`BenchResult::LOW_CONFIDENCE_ITERS`]: super::harness::BenchResult::LOW_CONFIDENCE_ITERS

use crate::util::json::Json;

use super::harness::BenchResult;

/// Regression thresholds (ratios are `worse/better` multipliers).
#[derive(Clone, Copy, Debug)]
pub struct Thresholds {
    /// Max allowed `current/baseline` for case wall times (mean µs).
    /// Generous by default: CI boxes are noisy and the deterministic
    /// step-count metrics are the precise signal.
    pub time_ratio: f64,
    /// Max allowed worsening ratio for derived metrics (TTFT steps,
    /// tokens/step, `kv_slots_per_token`, …).
    pub metric_ratio: f64,
    /// Treat a case/metric that disappeared from the current run as a
    /// breach (new columns are always just ⚠).
    pub fail_on_missing: bool,
}

impl Default for Thresholds {
    fn default() -> Self {
        Thresholds {
            time_ratio: 2.0,
            metric_ratio: 1.10,
            fail_on_missing: false,
        }
    }
}

/// True for metrics whose value is wall-clock-derived (the CPU kernel
/// GFLOP/s family from `benches/attention_cpu.rs` and the workloads
/// bench): these jitter with the box like case times do, so they gate
/// on the generous [`Thresholds::time_ratio`] instead of the tight
/// step-count `metric_ratio`.
fn wall_clock_metric(name: &str) -> bool {
    let base = name.rsplit('.').next().unwrap_or(name);
    base.starts_with("attention_gflops")
}

/// Which direction of change is a regression for a metric column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    HigherWorse,
    LowerWorse,
    /// Reported but never gated (counts, identities).
    Informational,
}

/// Infer gating direction from a metric name.  Scenario prefixes
/// (`bursty_poisson.ttft_steps_mean`) are stripped before matching.
pub fn metric_direction(name: &str) -> Direction {
    let base = name.rsplit('.').next().unwrap_or(name);
    if base.contains("per_s")
        || base.contains("throughput")
        || base.contains("tokens_per_step")
        || base.starts_with("effective_gflops")
        || base.starts_with("attention_gflops")
    {
        Direction::LowerWorse
    } else if base.starts_with("ttft")
        || base.starts_with("e2e")
        || base.starts_with("queue")
        || base == "kv_slots_per_token"
        || base == "waste_fraction"
        || base.ends_with("_pad_flops")
        || base.ends_with("_us")
    {
        Direction::HigherWorse
    } else {
        Direction::Informational
    }
}

/// One case's stats, as read back from `BENCH_*.json`.
#[derive(Clone, Copy, Debug)]
pub struct CaseStats {
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p99_us: f64,
}

impl CaseStats {
    fn low_confidence(&self) -> bool {
        self.iters < BenchResult::LOW_CONFIDENCE_ITERS
    }
}

/// Run-wide compute-ledger totals pulled from the document's embedded
/// `serving_metrics` export (the `flashmla_compute_*` counter family
/// from [`crate::obs::ledger`]).  Feeds the roofline cross-check section
/// of the compare report.
#[derive(Clone, Copy, Debug, Default)]
pub struct ComputeSummary {
    pub useful_flops: f64,
    pub bucket_pad_flops: f64,
    pub chunk_refeed_flops: f64,
    pub spec_rejected_flops: f64,
    pub mask_pad_flops: f64,
    /// Sum of the four modeled-byte counters (mask padding moves none).
    pub bytes_total: f64,
    /// `flashmla_busy_us_total` — the run's engine-busy wall time.
    pub busy_us: f64,
    /// `flashmla_compute_waste_fraction` gauge as exported.
    pub waste_fraction: f64,
}

impl ComputeSummary {
    /// Everything the modeled kernels dispatched, waste included.
    pub fn issued_flops(&self) -> f64 {
        self.useful_flops
            + self.bucket_pad_flops
            + self.chunk_refeed_flops
            + self.spec_rejected_flops
            + self.mask_pad_flops
    }
}

/// Parsed view of one `BENCH_*.json` document.
#[derive(Clone, Debug)]
pub struct BenchDoc {
    /// Where it came from (file stem) — report attribution.
    pub label: String,
    pub bench: String,
    pub commit: String,
    pub quick: bool,
    pub cases: Vec<(String, CaseStats)>,
    pub metrics: Vec<(String, f64)>,
    /// Compute-ledger totals, when the run exported `serving_metrics`
    /// with the ledger counters present (`None` for older documents or
    /// ledger-off runs — lenient by design, roofline rows degrade to ⚠).
    pub compute: Option<ComputeSummary>,
}

/// Parse and schema-check one bench document.  Errors name the missing
/// or mistyped field so a malformed file fails loudly in CI.
pub fn parse_bench_doc(label: &str, doc: &Json) -> anyhow::Result<BenchDoc> {
    let bench = doc
        .get("bench")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing string field `bench`"))?
        .to_string();
    let meta = doc.get("meta");
    let commit = meta
        .get("git_commit")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing `meta.git_commit`"))?
        .to_string();
    let quick = meta
        .get("quick")
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing bool `meta.quick`"))?;
    let cases_json = doc
        .get("cases")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing array `cases`"))?;
    let mut cases = Vec::with_capacity(cases_json.len());
    for (i, c) in cases_json.iter().enumerate() {
        let name = c
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("{label}: cases[{i}] missing `name`"))?
            .to_string();
        let num = |field: &str| -> anyhow::Result<f64> {
            c.get(field)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{label}: case `{name}` missing `{field}`"))
        };
        cases.push((
            name.clone(),
            CaseStats {
                iters: num("iters")? as usize,
                mean_us: num("mean_us")?,
                median_us: num("median_us")?,
                p99_us: num("p99_us")?,
            },
        ));
    }
    let metrics_json = doc
        .get("metrics")
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing object `metrics`"))?;
    let mut metrics = Vec::with_capacity(metrics_json.len());
    for (k, v) in metrics_json {
        let v = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("{label}: metric `{k}` is not a number"))?;
        metrics.push((k.clone(), v));
    }
    let compute = parse_compute_summary(doc);
    Ok(BenchDoc {
        label: label.to_string(),
        bench,
        commit,
        quick,
        cases,
        metrics,
        compute,
    })
}

/// Pull the compute-ledger counter family out of the embedded
/// `serving_metrics` snapshot.  Lenient on purpose: documents written
/// before the ledger existed (or with `serving_metrics: null`) yield
/// `None`, and individual missing siblings default to 0 — but the
/// anchor counter `flashmla_compute_useful_flops_total` must be present
/// for the summary to exist at all.
fn parse_compute_summary(doc: &Json) -> Option<ComputeSummary> {
    let sm = doc.get("serving_metrics");
    let counters = sm.get("counters");
    let counter = |name: &str| counters.get(name).as_f64().unwrap_or(0.0);
    let useful_flops = counters.get("flashmla_compute_useful_flops_total").as_f64()?;
    Some(ComputeSummary {
        useful_flops,
        bucket_pad_flops: counter("flashmla_compute_bucket_pad_flops_total"),
        chunk_refeed_flops: counter("flashmla_compute_chunk_refeed_flops_total"),
        spec_rejected_flops: counter("flashmla_compute_spec_rejected_flops_total"),
        mask_pad_flops: counter("flashmla_compute_mask_pad_flops_total"),
        bytes_total: counter("flashmla_compute_useful_bytes_total")
            + counter("flashmla_compute_bucket_pad_bytes_total")
            + counter("flashmla_compute_chunk_refeed_bytes_total")
            + counter("flashmla_compute_spec_rejected_bytes_total"),
        busy_us: counter("flashmla_busy_us_total"),
        waste_fraction: sm
            .get("gauges")
            .get("flashmla_compute_waste_fraction")
            .as_f64()
            .unwrap_or(0.0),
    })
}

/// The outcome of a comparison: the rendered report plus what gated.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub markdown: String,
    /// Threshold breaches — non-empty makes [`exit_code`](Self::exit_code)
    /// non-zero.
    pub breaches: Vec<String>,
    /// Non-gating anomalies (missing/new/low-confidence columns).
    pub warnings: Vec<String>,
}

impl CompareReport {
    /// Process exit status the binary maps this to: 0 clean, 1 breached.
    pub fn exit_code(&self) -> i32 {
        if self.breaches.is_empty() { 0 } else { 1 }
    }
}

fn fmt(v: f64) -> String {
    if !v.is_finite() {
        return "—".into();
    }
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 1000.0 {
        format!("{v:.0}")
    } else if a >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

fn fmt_ratio(cur: f64, base: f64) -> String {
    if base == 0.0 {
        "—".into()
    } else {
        format!("{:.3}x", cur / base)
    }
}

/// Names from both sides, baseline order first, current-only appended —
/// the no-silent-drops alignment.
fn aligned_names<T>(base: &[(String, T)], cur: &[(String, T)]) -> Vec<String> {
    let mut names: Vec<String> = base.iter().map(|(n, _)| n.clone()).collect();
    for (n, _) in cur {
        if !names.contains(n) {
            names.push(n.clone());
        }
    }
    names
}

fn lookup<'a, T>(list: &'a [(String, T)], name: &str) -> Option<&'a T> {
    list.iter().find(|(n, _)| n == name).map(|(_, v)| v)
}

/// Compare two bench documents and render the Markdown report.
pub fn compare(baseline: &BenchDoc, current: &BenchDoc, th: &Thresholds) -> CompareReport {
    let mut breaches = Vec::new();
    let mut warnings = Vec::new();
    let mut md = String::new();
    md.push_str(&format!("# Bench compare — `{}`\n\n", current.bench));
    if baseline.bench != current.bench {
        warnings.push(format!(
            "comparing different benches: `{}` vs `{}`",
            baseline.bench, current.bench
        ));
        md.push_str(&format!(
            "> ⚠ baseline is a different bench (`{}`)\n\n",
            baseline.bench
        ));
    }
    md.push_str("| | label | commit | quick |\n|---|---|---|---|\n");
    md.push_str(&format!(
        "| baseline | `{}` | `{}` | {} |\n",
        baseline.label, baseline.commit, baseline.quick
    ));
    md.push_str(&format!(
        "| current | `{}` | `{}` | {} |\n\n",
        current.label, current.commit, current.quick
    ));

    // Cases: wall-time columns.
    md.push_str("## Cases (wall time)\n\n");
    md.push_str(
        "| case | baseline mean µs | current mean µs | Δ µs | ratio | n (base→cur) | status |\n\
         |---|---:|---:|---:|---:|---:|---|\n",
    );
    for name in aligned_names(&baseline.cases, &current.cases) {
        let b = lookup(&baseline.cases, &name);
        let c = lookup(&current.cases, &name);
        match (b, c) {
            (Some(b), Some(c)) => {
                let delta = c.mean_us - b.mean_us;
                let low = b.low_confidence() || c.low_confidence();
                let status = if low {
                    warnings.push(format!(
                        "case `{name}`: low confidence (n {} → {}), delta not gated",
                        b.iters, c.iters
                    ));
                    "⚠ low-n".to_string()
                } else if b.mean_us > 0.0 && c.mean_us / b.mean_us > th.time_ratio {
                    let msg = format!(
                        "case `{name}`: mean {} µs → {} µs exceeds {:.2}x time threshold",
                        fmt(b.mean_us),
                        fmt(c.mean_us),
                        th.time_ratio
                    );
                    breaches.push(msg);
                    "✗ regression".to_string()
                } else {
                    "ok".to_string()
                };
                md.push_str(&format!(
                    "| {name} | {} | {} | {:+.2} | {} | {}→{} | {status} |\n",
                    fmt(b.mean_us),
                    fmt(c.mean_us),
                    delta,
                    fmt_ratio(c.mean_us, b.mean_us),
                    b.iters,
                    c.iters
                ));
            }
            (Some(b), None) => {
                let msg = format!("case `{name}` missing in current run");
                if th.fail_on_missing {
                    breaches.push(msg);
                } else {
                    warnings.push(msg);
                }
                md.push_str(&format!(
                    "| {name} | {} | — | — | — | {}→— | ⚠ missing in current |\n",
                    fmt(b.mean_us),
                    b.iters
                ));
            }
            (None, Some(c)) => {
                warnings.push(format!("case `{name}` is new (no baseline)"));
                md.push_str(&format!(
                    "| {name} | — | {} | — | — | —→{} | ⚠ new |\n",
                    fmt(c.mean_us),
                    c.iters
                ));
            }
            (None, None) => unreachable!("aligned name from neither side"),
        }
    }

    // Metrics: derived columns (step counts, ratios, throughputs).
    md.push_str("\n## Metrics\n\n");
    md.push_str(
        "| metric | baseline | current | Δ | ratio | status |\n|---|---:|---:|---:|---:|---|\n",
    );
    for name in aligned_names(&baseline.metrics, &current.metrics) {
        let b = lookup(&baseline.metrics, &name).copied();
        let c = lookup(&current.metrics, &name).copied();
        match (b, c) {
            (Some(b), Some(c)) => {
                let dir = metric_direction(&name);
                let worse_ratio = match dir {
                    Direction::HigherWorse if b != 0.0 => Some(c / b),
                    Direction::LowerWorse if c != 0.0 => Some(b / c),
                    _ => None,
                };
                let limit = if wall_clock_metric(&name) {
                    th.time_ratio
                } else {
                    th.metric_ratio
                };
                let status = match worse_ratio {
                    Some(r) if r > limit => {
                        let msg = format!(
                            "metric `{name}`: {} → {} worsens beyond {:.2}x threshold",
                            fmt(b),
                            fmt(c),
                            limit
                        );
                        breaches.push(msg);
                        "✗ regression".to_string()
                    }
                    Some(_) => "ok".to_string(),
                    None if dir == Direction::Informational => "info".to_string(),
                    None => {
                        warnings.push(format!("metric `{name}`: zero baseline, no ratio"));
                        "⚠ zero".to_string()
                    }
                };
                md.push_str(&format!(
                    "| {name} | {} | {} | {:+.4} | {} | {status} |\n",
                    fmt(b),
                    fmt(c),
                    c - b,
                    fmt_ratio(c, b)
                ));
            }
            (Some(b), None) => {
                let msg = format!("metric `{name}` missing in current run");
                if th.fail_on_missing {
                    breaches.push(msg);
                } else {
                    warnings.push(msg);
                }
                md.push_str(&format!(
                    "| {name} | {} | — | — | — | ⚠ missing in current |\n",
                    fmt(b)
                ));
            }
            (None, Some(c)) => {
                warnings.push(format!("metric `{name}` is new (no baseline)"));
                md.push_str(&format!("| {name} | — | {} | — | — | ⚠ new |\n", fmt(c)));
            }
            (None, None) => unreachable!(),
        }
    }

    // Roofline cross-check: only when at least one side carried ledger
    // counters, so pre-ledger baselines keep rendering byte-identically.
    if baseline.compute.is_some() || current.compute.is_some() {
        push_roofline_section(&mut md, &mut warnings, baseline, current);
    }

    if !breaches.is_empty() {
        md.push_str("\n## Breaches\n\n");
        for b in &breaches {
            md.push_str(&format!("- ✗ {b}\n"));
        }
    }
    if !warnings.is_empty() {
        md.push_str("\n## Warnings\n\n");
        for w in &warnings {
            md.push_str(&format!("- ⚠ {w}\n"));
        }
    }
    CompareReport {
        markdown: md,
        breaches,
        warnings,
    }
}

/// Render the "Roofline (modeled, H20)" section: each run's ledger
/// totals placed against the analytic H20 roofline from
/// [`crate::sim::roofline`].  Informational, never gates — the achieved
/// column divides *modeled* FLOPs by *measured* busy time on whatever
/// backend ran (the reference CPU backend in CI), so the
/// percent-of-attainable figure tracks trend across commits, not
/// silicon utilization.
///
/// When a document also carries an `attention_gflops_measured` metric
/// (emitted by the CPU-kernel sweep in `benches/attention_cpu.rs` and
/// the workloads bench), a `meas/modeled` column reports how the
/// *measured* kernel throughput compares to the run's modeled GFLOP/s —
/// the modeled-vs-measured cross-report.  Blank ("—") for documents
/// predating the kernel subsystem; ⚠-only, never gating.
fn push_roofline_section(
    md: &mut String,
    warnings: &mut Vec<String>,
    baseline: &BenchDoc,
    current: &BenchDoc,
) {
    use crate::hardware::GpuSpec;
    use crate::sim::roofline;

    md.push_str("\n## Roofline (modeled, H20)\n\n");
    md.push_str(
        "Ledger-modeled FLOPs/bytes vs. the analytic H20 roofline.  \
         Achieved TFLOPS = modeled issued FLOPs / measured engine-busy \
         time, so on the reference backend \"of attainable\" tracks \
         trend, not silicon.\n\n",
    );
    md.push_str(
        "| run | intensity FLOP/B | regime | attainable TFLOPS | \
         achieved TFLOPS | of attainable | meas/modeled | waste |\n\
         |---|---:|---|---:|---:|---:|---:|---:|\n",
    );
    let h20 = GpuSpec::h20();
    for (tag, side) in [("baseline", baseline), ("current", current)] {
        // Measured CPU-kernel GFLOP/s, when the run carried the sweep's
        // cross-report metric (scenario-prefixed or bare).
        let measured = side
            .metrics
            .iter()
            .find(|(n, _)| n.rsplit('.').next().unwrap_or(n) == "attention_gflops_measured")
            .map(|(_, v)| *v);
        match side.compute {
            Some(c) if c.issued_flops() > 0.0 && c.bytes_total > 0.0 => {
                let intensity = c.issued_flops() / c.bytes_total;
                let point = roofline::attainable(&h20, intensity, 1.0, 1.0);
                let achieved = if c.busy_us > 0.0 {
                    c.issued_flops() / (c.busy_us * 1e6)
                } else {
                    0.0
                };
                let of_attainable = if achieved > 0.0 {
                    format!("{:.2}%", 100.0 * roofline::efficiency_ratio(achieved, &point))
                } else {
                    "—".to_string()
                };
                // measured GFLOP/s over modeled GFLOP/s (achieved TFLOPS
                // × 1000) — how the real kernel compares to the ledger's
                // busy-time attribution on the same box.
                let meas_ratio = match measured {
                    Some(m) if achieved > 0.0 => format!("{:.2}x", m / (achieved * 1e3)),
                    _ => "—".to_string(),
                };
                let regime = if point.memory_bound { "memory" } else { "compute" };
                md.push_str(&format!(
                    "| {tag} | {} | {regime} | {} | {} | {of_attainable} | {meas_ratio} \
                     | {:.1}% |\n",
                    fmt(intensity),
                    fmt(point.attainable_tflops),
                    fmt(achieved),
                    100.0 * c.waste_fraction,
                ));
            }
            Some(_) => {
                warnings.push(format!(
                    "{tag} `{}`: compute ledger exported but empty; roofline row blank",
                    side.label
                ));
                md.push_str(&format!("| {tag} | — | — | — | — | — | — | — |\n"));
            }
            None => {
                warnings.push(format!(
                    "{tag} `{}` has no compute-ledger counters; roofline row blank",
                    side.label
                ));
                md.push_str(&format!("| {tag} | — | — | — | — | — | — | — |\n"));
            }
        }
    }
}

/// One checked-in trajectory entry (`BENCH_trajectory/*.json`): a small
/// per-commit summary of the quick-mode scenario suite.
#[derive(Clone, Debug)]
pub struct TrajectoryEntry {
    pub label: String,
    pub commit: String,
    pub quick: bool,
    /// scenario → (metric, value), deterministic metrics only.
    pub scenarios: Vec<(String, Vec<(String, f64)>)>,
}

/// Parse and schema-check one trajectory entry.
pub fn parse_trajectory_entry(label: &str, doc: &Json) -> anyhow::Result<TrajectoryEntry> {
    let commit = doc
        .get("commit")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing string field `commit`"))?
        .to_string();
    let quick = doc
        .get("quick")
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing bool `quick`"))?;
    let scen_json = doc
        .get("scenarios")
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("{label}: missing object `scenarios`"))?;
    let mut scenarios = Vec::with_capacity(scen_json.len());
    for (name, entry) in scen_json {
        let obj = entry
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("{label}: scenario `{name}` is not an object"))?;
        let mut metrics = Vec::with_capacity(obj.len());
        for (k, v) in obj {
            let v = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("{label}: scenario `{name}` metric `{k}` is not a number")
            })?;
            metrics.push((k.clone(), v));
        }
        scenarios.push((name.clone(), metrics));
    }
    Ok(TrajectoryEntry {
        label: label.to_string(),
        commit,
        quick,
        scenarios,
    })
}

/// Render the trajectory as one Markdown table per scenario: one row per
/// metric, one column per entry (oldest → newest).  Informational — the
/// trajectory shows drift; gating happens in same-job compares.
pub fn trajectory_report(entries: &[TrajectoryEntry]) -> String {
    let mut md = String::from("# Perf trajectory\n\n");
    if entries.is_empty() {
        md.push_str("(no entries)\n");
        return md;
    }
    md.push_str("Entries (oldest → newest): ");
    md.push_str(
        &entries
            .iter()
            .map(|e| format!("`{}`", e.commit))
            .collect::<Vec<_>>()
            .join(", "),
    );
    md.push_str("\n\n");
    // Union of scenario names across entries, first-seen order.
    let mut scenario_names: Vec<String> = Vec::new();
    for e in entries {
        for (name, _) in &e.scenarios {
            if !scenario_names.contains(name) {
                scenario_names.push(name.clone());
            }
        }
    }
    for sname in &scenario_names {
        md.push_str(&format!("## {sname}\n\n| metric |"));
        for e in entries {
            md.push_str(&format!(" {} |", e.commit));
        }
        md.push_str("\n|---|");
        for _ in entries {
            md.push_str("---:|");
        }
        md.push('\n');
        // Union of metric names for this scenario, first-seen order.
        let mut metric_names: Vec<String> = Vec::new();
        for e in entries {
            if let Some(ms) = lookup(&e.scenarios, sname) {
                for (m, _) in ms {
                    if !metric_names.contains(m) {
                        metric_names.push(m.clone());
                    }
                }
            }
        }
        for m in &metric_names {
            md.push_str(&format!("| {m} |"));
            for e in entries {
                let v = lookup(&e.scenarios, sname).and_then(|ms| lookup(ms, m));
                match v {
                    Some(v) => md.push_str(&format!(" {} |", fmt(*v))),
                    None => md.push_str(" — |"),
                }
            }
            md.push('\n');
        }
        md.push('\n');
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn doc(label: &str, mean_a: f64, iters: usize, ttft: f64) -> BenchDoc {
        let text = format!(
            r#"{{
              "bench": "workloads",
              "meta": {{"git_commit": "{label}", "quick": true, "config": {{}}}},
              "cases": [
                {{"name": "scenario bursty", "iters": {iters}, "mean_us": {mean_a},
                  "median_us": {mean_a}, "p99_us": {mean_a}, "stddev_us": 0.5, "min_us": 1.0}}
              ],
              "metrics": {{
                "bursty_poisson.ttft_steps_mean": {ttft},
                "bursty_poisson.tokens_per_step": 0.8,
                "bursty_poisson.kv_slots_per_token": 0.96,
                "bursty_poisson.finished": 8
              }},
              "serving_metrics": null
            }}"#
        );
        parse_bench_doc(label, &parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn identical_runs_are_clean() {
        let a = doc("aaa", 100.0, 20, 6.0);
        let b = doc("bbb", 100.0, 20, 6.0);
        let r = compare(&a, &b, &Thresholds::default());
        assert_eq!(r.exit_code(), 0, "breaches: {:?}", r.breaches);
        assert!(r.markdown.contains("| scenario bursty |"));
        assert!(r.markdown.contains("ttft_steps_mean"));
        assert!(r.markdown.contains("kv_slots_per_token"));
        assert!(r.markdown.contains("20→20"), "iters reported");
    }

    #[test]
    fn injected_regression_breaches() {
        let base = doc("aaa", 100.0, 20, 6.0);
        // 3x slower and TTFT up 50%: both past the default thresholds.
        let cur = doc("bbb", 300.0, 20, 9.0);
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 1);
        assert!(r.breaches.iter().any(|b| b.contains("scenario bursty")));
        assert!(r
            .breaches
            .iter()
            .any(|b| b.contains("ttft_steps_mean")));
        assert!(r.markdown.contains("✗ regression"));
    }

    #[test]
    fn improvements_do_not_breach() {
        let base = doc("aaa", 100.0, 20, 6.0);
        let cur = doc("bbb", 50.0, 20, 3.0); // 2x faster, TTFT halved
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 0, "breaches: {:?}", r.breaches);
    }

    #[test]
    fn throughput_direction_is_lower_worse() {
        assert_eq!(
            metric_direction("bursty_poisson.tokens_per_step"),
            Direction::LowerWorse
        );
        assert_eq!(metric_direction("decode_tok_per_s_greedy"), Direction::LowerWorse);
        assert_eq!(
            metric_direction("long_context_ladder.ttft_steps_p99"),
            Direction::HigherWorse
        );
        assert_eq!(
            metric_direction("shared_prefix_tenants.kv_slots_per_token"),
            Direction::HigherWorse
        );
        assert_eq!(metric_direction("steps_greedy"), Direction::Informational);

        // A tokens/step collapse gates.
        let base = doc("aaa", 100.0, 20, 6.0);
        let mut cur = doc("bbb", 100.0, 20, 6.0);
        for (k, v) in cur.metrics.iter_mut() {
            if k.ends_with("tokens_per_step") {
                *v = 0.4; // halved throughput
            }
        }
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 1);
        assert!(r.breaches.iter().any(|b| b.contains("tokens_per_step")));
    }

    /// Like `doc`, but with a populated `serving_metrics` snapshot
    /// carrying the compute-ledger counter family (1 GFLOP useful,
    /// 3 GFLOP waste → waste fraction 0.75) and scenario waste metrics.
    fn doc_with_compute(label: &str) -> BenchDoc {
        let text = format!(
            r#"{{
              "bench": "workloads",
              "meta": {{"git_commit": "{label}", "quick": true, "config": {{}}}},
              "cases": [
                {{"name": "scenario bursty", "iters": 20, "mean_us": 100.0,
                  "median_us": 100.0, "p99_us": 100.0, "stddev_us": 0.5, "min_us": 1.0}}
              ],
              "metrics": {{
                "bursty_poisson.ttft_steps_mean": 6.0,
                "bursty_poisson.tokens_per_step": 0.8,
                "bursty_poisson.effective_gflops_per_tick": 0.05,
                "bursty_poisson.waste_fraction": 0.75
              }},
              "serving_metrics": {{
                "counters": {{
                  "flashmla_busy_us_total": 2000.0,
                  "flashmla_compute_useful_flops_total": 1e9,
                  "flashmla_compute_bucket_pad_flops_total": 5e8,
                  "flashmla_compute_chunk_refeed_flops_total": 0.0,
                  "flashmla_compute_spec_rejected_flops_total": 0.0,
                  "flashmla_compute_mask_pad_flops_total": 2.5e9,
                  "flashmla_compute_useful_bytes_total": 4e6,
                  "flashmla_compute_bucket_pad_bytes_total": 2e6,
                  "flashmla_compute_chunk_refeed_bytes_total": 0.0,
                  "flashmla_compute_spec_rejected_bytes_total": 0.0
                }},
                "gauges": {{"flashmla_compute_waste_fraction": 0.75}}
              }}
            }}"#
        );
        parse_bench_doc(label, &parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn waste_and_efficiency_directions() {
        assert_eq!(
            metric_direction("bursty_poisson.waste_fraction"),
            Direction::HigherWorse
        );
        assert_eq!(
            metric_direction("long_context_ladder.bucket_pad_flops"),
            Direction::HigherWorse
        );
        assert_eq!(metric_direction("mask_pad_flops"), Direction::HigherWorse);
        assert_eq!(
            metric_direction("bursty_poisson.effective_gflops_per_tick"),
            Direction::LowerWorse
        );

        // Waste doubling gates…
        let base = doc_with_compute("aaa");
        let mut cur = doc_with_compute("bbb");
        for (k, v) in cur.metrics.iter_mut() {
            if k.ends_with("waste_fraction") {
                *v = 0.9; // 1.2x the baseline 0.75: past the 1.10 default
            }
        }
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 1);
        assert!(r.breaches.iter().any(|b| b.contains("waste_fraction")));

        // …and so does an effective-throughput collapse.
        let mut cur = doc_with_compute("ccc");
        for (k, v) in cur.metrics.iter_mut() {
            if k.ends_with("effective_gflops_per_tick") {
                *v = 0.025; // halved
            }
        }
        let r = compare(&base, &cur, &Thresholds::default());
        assert_eq!(r.exit_code(), 1);
        assert!(r
            .breaches
            .iter()
            .any(|b| b.contains("effective_gflops_per_tick")));
    }

    #[test]
    fn compute_summary_parses_and_roofline_renders() {
        let d = doc_with_compute("aaa");
        let c = d.compute.expect("ledger counters present → Some");
        assert_eq!(c.useful_flops, 1e9);
        assert_eq!(c.issued_flops(), 4e9);
        assert_eq!(c.bytes_total, 6e6);
        assert_eq!(c.busy_us, 2000.0);
        assert_eq!(c.waste_fraction, 0.75);

        let r = compare(&d, &doc_with_compute("bbb"), &Thresholds::default());
        assert_eq!(r.exit_code(), 0, "roofline never gates: {:?}", r.breaches);
        assert!(r.markdown.contains("## Roofline (modeled, H20)"));
        // intensity 4e9/6e6 ≈ 667 F/B → compute-bound on H20 (ridge ≈ 37).
        assert!(r.markdown.contains("| compute |"), "{}", r.markdown);
        // achieved = 4e9 / (2000 µs · 1e6) = 2 TFLOPS.
        assert!(r.markdown.contains("| 2.00 |"), "{}", r.markdown);
        assert!(r.markdown.contains("75.0%"), "{}", r.markdown);
    }

    #[test]
    fn attention_gflops_gates_on_time_ratio() {
        assert_eq!(
            metric_direction("attention_gflops_blocked_n2048"),
            Direction::LowerWorse
        );
        assert_eq!(
            metric_direction("attention_gflops_measured"),
            Direction::LowerWorse
        );
        let base_doc = |v: f64| {
            let mut d = doc("aaa", 100.0, 20, 6.0);
            d.metrics.push(("attention_gflops_blocked_n2048".into(), v));
            d
        };
        // A 1.5x drop is past metric_ratio (1.10) but inside the
        // wall-clock time_ratio (2.0): flagged nowhere, never gates.
        let r = compare(&base_doc(12.0), &base_doc(8.0), &Thresholds::default());
        assert_eq!(r.exit_code(), 0, "breaches: {:?}", r.breaches);
        // A 3x collapse is past even the generous threshold: gates.
        let r = compare(&base_doc(12.0), &base_doc(4.0), &Thresholds::default());
        assert_eq!(r.exit_code(), 1);
        assert!(r
            .breaches
            .iter()
            .any(|b| b.contains("attention_gflops_blocked_n2048")));
    }

    #[test]
    fn roofline_measured_vs_modeled_column() {
        // With the cross-report metric present: achieved is 2 TFLOPS
        // (= 2000 modeled GFLOP/s), measured 1000 GFLOP/s → 0.50x.
        let mut with_measured = doc_with_compute("aaa");
        with_measured
            .metrics
            .push(("attention_gflops_measured".into(), 1000.0));
        let plain = doc_with_compute("bbb");
        let r = compare(&with_measured, &plain, &Thresholds::default());
        assert_eq!(r.exit_code(), 0, "cross-report never gates: {:?}", r.breaches);
        assert!(r.markdown.contains("meas/modeled"), "{}", r.markdown);
        assert!(r.markdown.contains("0.50x"), "{}", r.markdown);
        // The side without the metric renders a blank cell, not a drop —
        // lenient for documents predating the kernel subsystem.
        let cur_row = r
            .markdown
            .lines()
            .find(|l| l.starts_with("| current |") && l.contains("compute"))
            .expect("current roofline row");
        assert!(cur_row.contains("| — |"), "{cur_row}");
    }

    #[test]
    fn docs_without_ledger_have_no_roofline_section() {
        let base = doc("aaa", 100.0, 20, 6.0);
        let cur = doc("bbb", 100.0, 20, 6.0);
        assert!(base.compute.is_none(), "serving_metrics: null → None");
        let r = compare(&base, &cur, &Thresholds::default());
        assert!(!r.markdown.contains("Roofline"));

        // Mixed: one side with ledger data gets a real row, the other a
        // blank ⚠ row — never silent omission.
        let r = compare(&base, &doc_with_compute("ccc"), &Thresholds::default());
        assert!(r.markdown.contains("## Roofline (modeled, H20)"));
        assert!(r.markdown.contains("| baseline | — |"), "{}", r.markdown);
        assert!(r
            .warnings
            .iter()
            .any(|w| w.contains("no compute-ledger counters")));
        assert_eq!(r.exit_code(), 0, "missing ledger warns, never gates");
    }

    #[test]
    fn low_confidence_flags_instead_of_gating() {
        let base = doc("aaa", 100.0, 1, 6.0);
        let cur = doc("bbb", 300.0, 1, 6.0); // 3x "slower" on n=1: noise
        let r = compare(&base, &cur, &Thresholds::default());
        assert!(
            !r.breaches.iter().any(|b| b.contains("scenario bursty")),
            "n=1 deltas must not gate"
        );
        assert!(r.markdown.contains("⚠ low-n"));
        assert!(r.warnings.iter().any(|w| w.contains("low confidence")));
    }

    #[test]
    fn missing_and_new_columns_are_explicit() {
        let base = doc("aaa", 100.0, 20, 6.0);
        let mut cur = doc("bbb", 100.0, 20, 6.0);
        cur.cases[0].0 = "scenario renamed".into();
        cur.metrics.push(("brand_new_metric".into(), 1.0));
        let r = compare(&base, &cur, &Thresholds::default());
        assert!(r.markdown.contains("⚠ missing in current"));
        assert!(r.markdown.contains("⚠ new"));
        assert!(r.warnings.iter().any(|w| w.contains("missing in current")));
        assert_eq!(r.exit_code(), 0, "missing is a warning by default");
        let strict = compare(
            &base,
            &cur,
            &Thresholds {
                fail_on_missing: true,
                ..Thresholds::default()
            },
        );
        assert_eq!(strict.exit_code(), 1, "strict mode gates on missing");
    }

    #[test]
    fn malformed_documents_fail_loudly() {
        let missing_bench = parse(r#"{"meta": {}, "cases": [], "metrics": {}}"#).unwrap();
        assert!(parse_bench_doc("x", &missing_bench).is_err());
        let bad_case = parse(
            r#"{"bench": "b", "meta": {"git_commit": "c", "quick": true},
                "cases": [{"name": "a"}], "metrics": {}}"#,
        )
        .unwrap();
        let err = parse_bench_doc("x", &bad_case).unwrap_err().to_string();
        assert!(err.contains("iters"), "names the missing field: {err}");
        let bad_metric = parse(
            r#"{"bench": "b", "meta": {"git_commit": "c", "quick": true},
                "cases": [], "metrics": {"m": "nope"}}"#,
        )
        .unwrap();
        assert!(parse_bench_doc("x", &bad_metric).is_err());
    }

    #[test]
    fn trajectory_entries_parse_and_render() {
        let e1 = parse_trajectory_entry(
            "0001",
            &parse(
                r#"{"commit": "abc1234", "quick": true,
                    "scenarios": {"bursty_poisson": {"ttft_steps_mean": 6.0, "tokens_per_step": 0.8}}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let e2 = parse_trajectory_entry(
            "0002",
            &parse(
                r#"{"commit": "def5678", "quick": true,
                    "scenarios": {"bursty_poisson": {"ttft_steps_mean": 5.0, "tokens_per_step": 0.9},
                                   "cancel_storm": {"cancelled": 7}}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let md = trajectory_report(&[e1, e2]);
        assert!(md.contains("## bursty_poisson"));
        assert!(md.contains("## cancel_storm"));
        assert!(md.contains("abc1234") && md.contains("def5678"));
        assert!(md.contains("ttft_steps_mean"));
        // Metric absent from the older entry renders as a gap, not a drop.
        assert!(md.contains("— |"));

        let bad = parse(r#"{"commit": "x", "quick": true, "scenarios": []}"#).unwrap();
        assert!(parse_trajectory_entry("bad", &bad).is_err());
    }
}
