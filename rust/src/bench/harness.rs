//! Micro-benchmark runner: warmup, then timed iterations until both a
//! minimum count and a minimum wall budget are met; reports robust stats.
//!
//! Besides the human-readable per-case lines, a harness can emit a
//! machine-readable `BENCH_<name>.json` ([`Bencher::emit_json`]) so the
//! perf trajectory is trackable across PRs: each file carries every case's
//! robust stats plus any scalar metrics the bench recorded
//! ([`Bencher::record_metric`]), and is stamped with run metadata — the
//! git commit, the quick-mode flag, and whatever configuration snapshot
//! the bench recorded via [`Bencher::record_config`] — so a number in one
//! file is attributable to the code and settings that produced it.
//! Output lands in the current directory, or `$FLASHMLA_BENCH_OUT` when
//! set.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::{mean, median, percentile, stddev};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p99_us: f64,
    pub stddev_us: f64,
    pub min_us: f64,
}

impl BenchResult {
    /// Below this many samples, comparing two runs of a case is mostly
    /// noise; `bench_compare` flags (and refuses to gate on) such deltas.
    pub const LOW_CONFIDENCE_ITERS: usize = 5;

    /// Too few samples for a trustworthy delta (`iters` is emitted in the
    /// JSON so the compare layer can re-derive this).
    pub fn low_confidence(&self) -> bool {
        self.iters < Self::LOW_CONFIDENCE_ITERS
    }

    pub fn line(&self) -> String {
        format!(
            "{:<42} {:>10.2} µs/iter (median {:>9.2}, p99 {:>9.2}, σ {:>8.2}, n={})",
            self.name, self.mean_us, self.median_us, self.p99_us, self.stddev_us, self.iters
        )
    }

    /// Throughput helper: items per second given items per iteration.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_us / 1e6)
    }
}

impl BenchResult {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_us", Json::num(self.mean_us)),
            ("median_us", Json::num(self.median_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("stddev_us", Json::num(self.stddev_us)),
            ("min_us", Json::num(self.min_us)),
        ])
    }
}

/// Bench configuration.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    results: Vec<BenchResult>,
    /// Scalar side-channel metrics (e.g. "prefill_steps"), emitted with
    /// the JSON report.
    metrics: Vec<(String, f64)>,
    /// Configuration snapshot (knob → value), emitted under `meta.config`.
    config: Vec<(String, String)>,
    /// Full serving-metrics registry snapshot (same schema as
    /// `ServingMetrics::snapshot_json`), emitted under `serving_metrics`.
    serving_metrics: Option<Json>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Quick mode for CI: `FLASHMLA_BENCH_QUICK=1` (parsed like other
    /// boolean flags — `0`/`false`/`off` disable it, so an explicitly
    /// zeroed variable no longer counts as "set" the way the old
    /// `is_ok()` check made it).
    pub fn quick_mode() -> bool {
        crate::util::logging::env_flag("FLASHMLA_BENCH_QUICK").unwrap_or(false)
    }

    pub fn new() -> Self {
        let quick = Self::quick_mode();
        Bencher {
            warmup: if quick {
                Duration::from_millis(10)
            } else {
                Duration::from_millis(200)
            },
            budget: if quick {
                Duration::from_millis(100)
            } else {
                Duration::from_secs(2)
            },
            min_iters: 5,
            max_iters: 1_000_000,
            results: Vec::new(),
            metrics: Vec::new(),
            config: Vec::new(),
            serving_metrics: None,
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Run one case.  `f` should perform exactly one unit of work; use the
    /// return value to keep the optimizer honest.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed.
        let mut samples_us: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.budget || samples_us.len() < self.min_iters)
            && samples_us.len() < self.max_iters
        {
            let s = Instant::now();
            std::hint::black_box(f());
            samples_us.push(s.elapsed().as_secs_f64() * 1e6);
        }
        // Degenerate sample counts (possible under an aggressive quick
        // budget): with n < 2 a spread statistic is meaningless, so report
        // zero spread and the single observation for every location stat
        // instead of interpolating percentiles off a one-point "curve".
        let (p99_us, stddev_us) = if samples_us.len() < 2 {
            (samples_us.first().copied().unwrap_or(0.0), 0.0)
        } else {
            (percentile(&samples_us, 99.0), stddev(&samples_us))
        };
        let result = BenchResult {
            name: name.to_string(),
            iters: samples_us.len(),
            mean_us: mean(&samples_us),
            median_us: median(&samples_us),
            p99_us,
            stddev_us,
            min_us: if samples_us.is_empty() {
                0.0
            } else {
                samples_us.iter().cloned().fold(f64::INFINITY, f64::min)
            },
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Record a scalar metric alongside the timing cases (workload facts
    /// like "prefill_steps" or derived ratios) for the JSON report.
    /// Names must be unique — the JSON is a map, and silently collapsing
    /// duplicates would corrupt the cross-PR trajectory it exists for.
    pub fn record_metric(&mut self, name: &str, value: f64) {
        assert!(
            !self.metrics.iter().any(|(k, _)| k == name),
            "duplicate bench metric `{name}`"
        );
        self.metrics.push((name.to_string(), value));
    }

    /// Embed the engine's full metrics-registry snapshot (the same JSON
    /// `ServingMetrics::snapshot_json` exports) so every `BENCH_*.json`
    /// carries the serving counters of the workload it timed.  Last call
    /// wins: benches record the final (or merged) engine state.
    pub fn record_serving_metrics(&mut self, m: &crate::coordinator::ServingMetrics) {
        self.serving_metrics = Some(m.snapshot_json());
    }

    /// Record one configuration knob (e.g. "chunk_tokens" → "8") for the
    /// JSON report's `meta.config` snapshot.  Names must be unique, as for
    /// [`record_metric`](Self::record_metric).
    pub fn record_config(&mut self, name: &str, value: impl Into<String>) {
        assert!(
            !self.config.iter().any(|(k, _)| k == name),
            "duplicate bench config `{name}`"
        );
        self.config.push((name.to_string(), value.into()));
    }

    /// Short git commit of the working tree, or "unknown" outside a repo.
    /// Public so benches can stamp trajectory entries with the same id
    /// that `emit_json` records in `meta.git_commit`.
    pub fn git_commit() -> String {
        std::process::Command::new("git")
            .args(["rev-parse", "--short", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".into())
    }

    /// Write `BENCH_<name>.json` with every case's stats, recorded
    /// metrics, and run metadata (git commit, quick flag, config
    /// snapshot).  Target directory: `$FLASHMLA_BENCH_OUT` if set, else
    /// the current directory.  Returns the written path.
    pub fn emit_json(&self, name: &str) -> anyhow::Result<PathBuf> {
        let dir = std::env::var("FLASHMLA_BENCH_OUT").unwrap_or_else(|_| ".".into());
        let path = PathBuf::from(dir).join(format!("BENCH_{name}.json"));
        let doc = Json::obj(vec![
            ("bench", Json::str(name)),
            (
                "meta",
                Json::obj(vec![
                    ("git_commit", Json::str(Self::git_commit())),
                    ("quick", Json::Bool(Self::quick_mode())),
                    (
                        "config",
                        Json::Obj(
                            self.config
                                .iter()
                                .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "cases",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "metrics",
                Json::Obj(
                    self.metrics
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v)))
                        .collect(),
                ),
            ),
            (
                "serving_metrics",
                self.serving_metrics.clone().unwrap_or(Json::Null),
            ),
        ]);
        std::fs::write(&path, doc.dump())
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("FLASHMLA_BENCH_QUICK", "1");
        let mut b = Bencher::new().with_budget(Duration::from_millis(20));
        let r = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.iters >= 5);
        assert!(r.mean_us > 0.0);
        assert!(r.median_us <= r.p99_us + 1e-9);
        assert!(r.min_us <= r.mean_us + 1e-9);
    }

    #[test]
    fn emit_json_round_trips() {
        std::env::set_var("FLASHMLA_BENCH_QUICK", "1");
        let dir = std::env::temp_dir().join(format!(
            "flashmla_bench_json_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("FLASHMLA_BENCH_OUT", &dir);
        let mut b = Bencher::new().with_budget(Duration::from_millis(5));
        b.bench("case_a", || 1 + 1);
        b.record_metric("prefill_steps", 42.0);
        b.record_config("chunk_tokens", "8");
        let path = b.emit_json("harness_selftest").unwrap();
        std::env::remove_var("FLASHMLA_BENCH_OUT");
        assert!(path.ends_with("BENCH_harness_selftest.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").as_str(), Some("harness_selftest"));
        assert_eq!(doc.get("cases").as_arr().map(|a| a.len()), Some(1));
        assert_eq!(
            doc.get("cases").at(0).get("name").as_str(),
            Some("case_a")
        );
        assert!(doc.get("cases").at(0).get("mean_us").as_f64().unwrap() > 0.0);
        assert_eq!(
            doc.get("metrics").get("prefill_steps").as_f64(),
            Some(42.0)
        );
        // Run metadata: git commit (or "unknown"), quick flag, config
        // snapshot — the cross-PR attribution stamp.
        let meta = doc.get("meta");
        let commit = meta.get("git_commit").as_str().unwrap();
        assert!(!commit.is_empty());
        assert_eq!(meta.get("quick").as_bool(), Some(true));
        assert_eq!(meta.get("config").get("chunk_tokens").as_str(), Some("8"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "duplicate bench config")]
    fn duplicate_config_rejected() {
        let mut b = Bencher::new();
        b.record_config("k", "1");
        b.record_config("k", "2");
    }

    #[test]
    fn low_confidence_threshold() {
        let r = BenchResult {
            name: "n1".into(),
            iters: 1,
            mean_us: 5.0,
            median_us: 5.0,
            p99_us: 5.0,
            stddev_us: 0.0,
            min_us: 5.0,
        };
        assert!(r.low_confidence());
        let trusted = BenchResult {
            iters: BenchResult::LOW_CONFIDENCE_ITERS,
            ..r.clone()
        };
        assert!(!trusted.low_confidence());
    }

    #[test]
    fn per_second_inverts_mean() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_us: 1000.0, // 1 ms
            median_us: 0.0,
            p99_us: 0.0,
            stddev_us: 0.0,
            min_us: 0.0,
        };
        assert!((r.per_second(10.0) - 10_000.0).abs() < 1e-9);
    }
}
