//! Micro-benchmark runner: warmup, then timed iterations until both a
//! minimum count and a minimum wall budget are met; reports robust stats.

use std::time::{Duration, Instant};

use crate::util::stats::{mean, median, percentile, stddev};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p99_us: f64,
    pub stddev_us: f64,
    pub min_us: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<42} {:>10.2} µs/iter (median {:>9.2}, p99 {:>9.2}, σ {:>8.2}, n={})",
            self.name, self.mean_us, self.median_us, self.p99_us, self.stddev_us, self.iters
        )
    }

    /// Throughput helper: items per second given items per iteration.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_us / 1e6)
    }
}

/// Bench configuration.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Honor a quick mode for CI: FLASHMLA_BENCH_QUICK=1.
        let quick = std::env::var("FLASHMLA_BENCH_QUICK").is_ok();
        Bencher {
            warmup: if quick {
                Duration::from_millis(10)
            } else {
                Duration::from_millis(200)
            },
            budget: if quick {
                Duration::from_millis(100)
            } else {
                Duration::from_secs(2)
            },
            min_iters: 5,
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Run one case.  `f` should perform exactly one unit of work; use the
    /// return value to keep the optimizer honest.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed.
        let mut samples_us: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.budget || samples_us.len() < self.min_iters)
            && samples_us.len() < self.max_iters
        {
            let s = Instant::now();
            std::hint::black_box(f());
            samples_us.push(s.elapsed().as_secs_f64() * 1e6);
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: samples_us.len(),
            mean_us: mean(&samples_us),
            median_us: median(&samples_us),
            p99_us: percentile(&samples_us, 99.0),
            stddev_us: stddev(&samples_us),
            min_us: samples_us.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("FLASHMLA_BENCH_QUICK", "1");
        let mut b = Bencher::new().with_budget(Duration::from_millis(20));
        let r = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.iters >= 5);
        assert!(r.mean_us > 0.0);
        assert!(r.median_us <= r.p99_us + 1e-9);
        assert!(r.min_us <= r.mean_us + 1e-9);
    }

    #[test]
    fn per_second_inverts_mean() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_us: 1000.0, // 1 ms
            median_us: 0.0,
            p99_us: 0.0,
            stddev_us: 0.0,
            min_us: 0.0,
        };
        assert!((r.per_second(10.0) - 10_000.0).abs() < 1e-9);
    }
}
