//! Aligned-table / CSV output for the paper-reproduction benches.

/// A simple column-aligned table with optional CSV dump.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$} | ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV form (for plotting).
    pub fn csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["seq", "TFLOPS"]);
        t.row(&["512".into(), "12.8".into()]);
        t.row(&["65536".into(), "90.7".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| 65536 |"));
        let csv = t.csv();
        assert!(csv.starts_with("seq,TFLOPS\n"));
        assert!(csv.contains("512,12.8"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
