//! Adaptive draft budget: shrink on consecutive-rejection streaks,
//! recover on acceptance (the ROADMAP "adaptive `max_draft`" follow-on).
//!
//! Rationale: each drafted token costs one verification-chunk position in
//! the step token budget (`prefill::ChunkPlanner` charges verify slots
//! `1 + draft`), so a request whose drafts keep missing burns budget that
//! concurrent prefills could use.  The controller is multiplicative-
//! decrease / additive-increase, mirroring the asymmetry of the costs: a
//! rejection streak is strong evidence the history left the predictable
//! regime (halve quickly), a single acceptance is weak evidence it is
//! back (recover one token at a time up to the configured ceiling).
//!
//! The engine keeps one controller per request (spec-enabled engines with
//! `[engine.spec] adaptive = true` only), clamps each proposed draft to
//! [`budget`](AdaptiveDraft::budget) *before* planning, and feeds every
//! verification outcome back through [`on_verify`](AdaptiveDraft::on_verify).
//! Verifications that carried no draft tokens are ignored — a
//! budget-starved tick says nothing about predictability.
//!
//! The controller only shapes *scheduling*; acceptance stays exact, so
//! outputs remain bit-identical to plain greedy decode either way.

/// Consecutive fully-rejected verifications before the budget halves.
/// Two, not one: a single miss is common at regime boundaries (e.g. the
/// step where a cycle first forms) and halving there would throw away the
/// next tick's likely-good full-length draft.
pub const SHRINK_AFTER: u32 = 2;

/// Per-request adaptive draft-budget controller.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveDraft {
    /// Configured ceiling (`spec.max_draft`).
    ceiling: usize,
    /// Current budget, in `1..=ceiling`.
    cur: usize,
    /// Consecutive fully-rejected verifications seen since the last
    /// acceptance (or shrink).
    streak: u32,
}

impl AdaptiveDraft {
    pub fn new(max_draft: usize) -> Self {
        assert!(max_draft >= 1, "draft ceiling must be ≥ 1");
        AdaptiveDraft {
            ceiling: max_draft,
            cur: max_draft,
            streak: 0,
        }
    }

    /// Tokens the next draft may carry.
    pub fn budget(&self) -> usize {
        self.cur
    }

    /// Feed one verification outcome (`drafted` fed, `accepted` kept).
    pub fn on_verify(&mut self, drafted: usize, accepted: usize) {
        debug_assert!(accepted <= drafted);
        if drafted == 0 {
            return; // budget-starved tick: no evidence either way
        }
        if accepted == 0 {
            self.streak += 1;
            if self.streak >= SHRINK_AFTER {
                self.cur = (self.cur / 2).max(1);
                self.streak = 0;
            }
        } else {
            self.streak = 0;
            self.cur = (self.cur + 1).min(self.ceiling);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_the_ceiling() {
        let a = AdaptiveDraft::new(8);
        assert_eq!(a.budget(), 8);
    }

    #[test]
    fn shrink_schedule_halves_after_streaks_down_to_one() {
        // The satellite's shrink/recover schedule test: 8 → 4 → 2 → 1,
        // one halving per SHRINK_AFTER consecutive full rejections.
        let mut a = AdaptiveDraft::new(8);
        let mut seen = vec![a.budget()];
        for _ in 0..4 * SHRINK_AFTER {
            a.on_verify(a.budget(), 0);
            if *seen.last().unwrap() != a.budget() {
                seen.push(a.budget());
            }
        }
        assert_eq!(seen, vec![8, 4, 2, 1]);
        // Floor: further rejections never reach zero.
        for _ in 0..8 {
            a.on_verify(a.budget(), 0);
            assert_eq!(a.budget(), 1);
        }
    }

    #[test]
    fn single_rejection_does_not_shrink() {
        let mut a = AdaptiveDraft::new(4);
        a.on_verify(4, 0);
        assert_eq!(a.budget(), 4, "one miss is not a streak");
        a.on_verify(4, 2); // acceptance resets the streak
        a.on_verify(4, 0);
        assert_eq!(a.budget(), 4, "streak restarted after the acceptance");
    }

    #[test]
    fn recovery_is_additive_up_to_the_ceiling() {
        let mut a = AdaptiveDraft::new(8);
        for _ in 0..3 * SHRINK_AFTER {
            a.on_verify(a.budget(), 0);
        }
        assert_eq!(a.budget(), 1);
        let mut seen = Vec::new();
        for _ in 0..10 {
            a.on_verify(a.budget(), a.budget()); // full acceptance
            seen.push(a.budget());
        }
        assert_eq!(seen, vec![2, 3, 4, 5, 6, 7, 8, 8, 8, 8]);
    }

    #[test]
    fn partial_acceptance_counts_as_recovery() {
        let mut a = AdaptiveDraft::new(4);
        a.on_verify(4, 0);
        a.on_verify(4, 0);
        assert_eq!(a.budget(), 2);
        a.on_verify(2, 1); // even one accepted token recovers
        assert_eq!(a.budget(), 3);
    }

    #[test]
    fn empty_verifications_carry_no_signal() {
        let mut a = AdaptiveDraft::new(4);
        for _ in 0..10 {
            a.on_verify(0, 0);
        }
        assert_eq!(a.budget(), 4, "budget-starved ticks must not shrink");
    }
}
