//! Speculative decoding: self-drafting via prompt lookup, verified as
//! chunked attention steps.
//!
//! The paper's core observation is that attention cost is shaped by the
//! M-dimension of the GEMM: prefill-shaped work (many query tokens against
//! a long KV context) runs near the roofline knee, while single-token
//! decode is memory-bound (see PAPERS.md, *Hardware-Centric Analysis of
//! DeepSeek's MLA*).  Speculative decoding converts `k` memory-bound
//! decode steps into **one prefill-shaped verification chunk** — exactly
//! the workload `StepRunner::prefill_chunk` was built to execute.
//!
//! The split of responsibilities:
//!
//! * [`PromptLookupDrafter`] (this module) proposes up to `max_draft`
//!   continuation tokens by n-gram matching against the request's own
//!   prompt + generated history.  No draft model is needed, so speculation
//!   runs on the hermetic reference backend, and the drafter is a pure
//!   deterministic function of the token history.
//! * The planner (`crate::prefill::ChunkPlanner`) admits verification
//!   chunks into the tick under the same `step_token_budget` as prefill
//!   chunks, ordered by the `spec_priority` knob.
//! * The backend verifies through
//!   [`StepRunner::verify_chunk`](crate::runtime::StepRunner::verify_chunk):
//!   the chunk `[last_token, d₁ … dₘ]` executes like a prefill chunk, but
//!   the greedy argmax after *every* position comes back.
//! * The engine accepts the longest draft prefix matching those argmaxes,
//!   which guarantees outputs **bit-identical** to plain greedy decode:
//!   token `dᵢ` is only accepted when it equals the token plain decode
//!   would have produced, so every cache row at an accepted position is
//!   (by the write-purity contract) the exact row plain decode would have
//!   written.  Rejected positions are rolled back; see
//!   `docs/speculative-decoding.md` for the full argument.
//!
//! Configured by `[engine.spec]` (`enabled`, `lookback`, `max_draft`);
//! disabled by default so the engine reproduces the non-speculative step
//! sequence byte-for-byte out of the box.

mod adaptive;
mod drafter;

pub use adaptive::{AdaptiveDraft, SHRINK_AFTER};
pub use drafter::{PromptLookupDrafter, MAX_NGRAM};

/// Speculative-decoding knobs, plumbed through `EngineConfig` /
/// `[engine.spec]`.
#[derive(Clone, Copy, Debug)]
pub struct SpecConfig {
    /// Master switch.  Off by default: speculation never changes generated
    /// tokens (greedy verification is exact), but it does change the
    /// engine's step cadence and metrics, so it is opt-in.
    pub enabled: bool,
    /// History window (in tokens) the drafter's ring-buffer n-gram index
    /// covers.  Matches and continuations are only drawn from the last
    /// `lookback` tokens of prompt + generated history.
    pub lookback: usize,
    /// Maximum draft tokens proposed (and therefore verified) per engine
    /// tick per request — the `k` in the k-step-to-one-chunk conversion.
    pub max_draft: usize,
    /// Adapt the per-request draft budget at runtime ([`AdaptiveDraft`]):
    /// halve after [`SHRINK_AFTER`] consecutive fully-rejected
    /// verifications, recover one token per accepting verification up to
    /// `max_draft`.  Off by default so the fixed-budget step cadence (and
    /// every step-count expectation built on it) is reproduced exactly;
    /// outputs are bit-identical either way.
    pub adaptive: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig {
            enabled: false,
            lookback: 256,
            max_draft: 4,
            adaptive: false,
        }
    }
}

impl SpecConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.lookback >= 8, "spec.lookback must be ≥ 8");
        anyhow::ensure!(self.max_draft >= 1, "spec.max_draft must be ≥ 1");
        anyhow::ensure!(
            self.max_draft + MAX_NGRAM <= self.lookback,
            "spec.max_draft {} too large for lookback {} (a match plus its \
             continuation must fit the window)",
            self.max_draft,
            self.lookback
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_off_and_valid() {
        let c = SpecConfig::default();
        assert!(!c.enabled, "speculation must be opt-in");
        c.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(SpecConfig {
            lookback: 4,
            ..SpecConfig::default()
        }
        .validate()
        .is_err());
        assert!(SpecConfig {
            max_draft: 0,
            ..SpecConfig::default()
        }
        .validate()
        .is_err());
        assert!(SpecConfig {
            lookback: 8,
            max_draft: 8,
            ..SpecConfig::default()
        }
        .validate()
        .is_err());
    }
}
