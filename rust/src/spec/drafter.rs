//! Prompt-lookup drafting: n-gram continuation proposals from the
//! request's own history.
//!
//! The idea (prompt-lookup decoding): generated text frequently repeats
//! spans of its own context — templates, code identifiers, quoted input,
//! cycles.  When the last few tokens match an earlier n-gram, the tokens
//! that *followed* that earlier occurrence are a cheap, often-correct
//! guess for what comes next.  The drafter costs no model execution at
//! all, so every accepted token is pure profit.
//!
//! Implementation: a **ring buffer** holds the last `lookback` history
//! tokens, and an incremental index maps every 1-, 2- and 3-gram to its
//! most recent end positions (up to [`OCC_SLOTS`] occurrences, newest
//! first).  Drafting walks the ladder n = 3, 2, 1 (longest suffix match
//! first) and, among the indexed in-window occurrences, prefers the
//! newest one with a full `max_draft` continuation — the most recent
//! match that is *not* butted against the end of history — falling back
//! to the oldest stored occurrence (longest available continuation).
//! This matters for periodic text: the most recent occurrence of the
//! suffix is always one period back, truncating the draft to one period,
//! while a slightly older occurrence yields the full `max_draft` tokens.
//!
//! Properties the engine and the property tests rely on:
//!
//! * **deterministic** — a pure function of the observed history;
//! * **bounded** — never proposes more than `max_draft` tokens;
//! * **grounded** — proposes nothing when no n-gram of the suffix occurs
//!   earlier in the window, and every proposal is the verbatim
//!   continuation of some earlier in-window occurrence;
//! * **windowed** — positions that slid out of the ring are never read
//!   (stale index entries are filtered lazily at draft time).
//!
//! Memory: the ring is `lookback` tokens; the index holds at most
//! `OCC_SLOTS` positions per distinct gram ever observed, i.e. O(history
//! length).  The engine keeps one drafter per active request and drops it
//! when the request finishes.

use std::collections::HashMap;

use super::SpecConfig;

/// Longest suffix n-gram the drafter matches on (the ladder tries
/// `MAX_NGRAM`, then shorter, down to 1).
pub const MAX_NGRAM: usize = 3;

/// Most-recent occurrences remembered per gram.  More slots let the
/// drafter skip past occurrences too close to the end of history to have
/// a full continuation; 4 covers every cycle of period ≤ `MAX_NGRAM`
/// while keeping the index O(1) per observe.
const OCC_SLOTS: usize = 4;

/// Gram key: (n, tokens right-aligned in a fixed array, unused slots -1).
type GramKey = (u8, [i32; MAX_NGRAM]);

/// Deterministic self-drafter over one request's token history.
#[derive(Clone, Debug)]
pub struct PromptLookupDrafter {
    lookback: usize,
    max_draft: usize,
    /// Ring of the last `lookback` tokens; absolute position `p` lives at
    /// `ring[p % lookback]` once `p ≥ observed - lookback`.
    ring: Vec<i32>,
    /// Total tokens observed (absolute position of the next token).
    observed: u64,
    /// Gram → most recent end positions, newest first, ≤ `OCC_SLOTS`.
    index: HashMap<GramKey, Vec<u64>>,
}

impl PromptLookupDrafter {
    pub fn new(cfg: &SpecConfig) -> Self {
        cfg.validate().expect("invalid spec config");
        PromptLookupDrafter {
            lookback: cfg.lookback,
            max_draft: cfg.max_draft,
            ring: Vec::with_capacity(cfg.lookback),
            observed: 0,
            index: HashMap::new(),
        }
    }

    /// Tokens observed so far.  The engine feeds history incrementally and
    /// uses this as the sync cursor (prompt first, then each generated
    /// token as it is accepted).
    pub fn observed(&self) -> u64 {
        self.observed
    }

    fn tok_at(&self, pos: u64) -> i32 {
        debug_assert!(pos + (self.lookback as u64) >= self.observed, "read outside window");
        debug_assert!(pos < self.observed);
        self.ring[(pos % self.lookback as u64) as usize]
    }

    /// Key of the n-gram ending at absolute position `end` (inclusive).
    /// All `n` positions must be inside the window, which holds whenever
    /// `n ≤ MAX_NGRAM ≤ lookback` and `end` is among the newest tokens.
    fn gram_key(&self, end: u64, n: usize) -> GramKey {
        let mut toks = [-1i32; MAX_NGRAM];
        for (i, slot) in toks[MAX_NGRAM - n..].iter_mut().enumerate() {
            *slot = self.tok_at(end + 1 - n as u64 + i as u64);
        }
        (n as u8, toks)
    }

    /// Append one history token and index the grams it completes.
    pub fn observe(&mut self, token: i32) {
        assert!(token >= 0, "negative token id {token}");
        let slot = (self.observed % self.lookback as u64) as usize;
        if self.ring.len() < self.lookback {
            debug_assert_eq!(slot, self.ring.len());
            self.ring.push(token);
        } else {
            self.ring[slot] = token;
        }
        self.observed += 1;
        let end = self.observed - 1;
        for n in 1..=MAX_NGRAM.min(self.observed as usize) {
            let key = self.gram_key(end, n);
            let occs = self.index.entry(key).or_default();
            occs.insert(0, end);
            occs.truncate(OCC_SLOTS);
        }
    }

    pub fn observe_all(&mut self, tokens: &[i32]) {
        for &t in tokens {
            self.observe(t);
        }
    }

    /// Propose up to `max_draft` continuation tokens for the current
    /// history, or an empty vector when no suffix n-gram has occurred
    /// earlier in the window.
    pub fn draft(&self) -> Vec<i32> {
        let l = self.observed;
        if l < 2 {
            return Vec::new();
        }
        let start = l.saturating_sub(self.lookback as u64);
        for n in (1..=MAX_NGRAM.min((l - 1) as usize)).rev() {
            let key = self.gram_key(l - 1, n);
            let Some(occs) = self.index.get(&key) else {
                continue;
            };
            // In-window occurrences strictly before the suffix itself
            // (which is always the newest entry, pushed by `observe`).
            let valid: Vec<u64> = occs
                .iter()
                .copied()
                .filter(|&p| p != l - 1 && p + 1 >= start + n as u64)
                .collect();
            let Some(&newest_full) = valid
                .iter()
                .find(|&&p| l - 1 - p >= self.max_draft as u64)
            else {
                // No occurrence has a full continuation; take the oldest
                // stored one (the longest continuation available), if any.
                let Some(&p) = valid.last() else { continue };
                return self.continuation(p);
            };
            return self.continuation(newest_full);
        }
        Vec::new()
    }

    /// The tokens that followed the occurrence ending at `p`, clipped to
    /// `max_draft` and to recorded history (all within the window: the
    /// continuation starts after an in-window position).
    fn continuation(&self, p: u64) -> Vec<i32> {
        let take = self.max_draft.min((self.observed - 1 - p) as usize);
        (0..take as u64).map(|i| self.tok_at(p + 1 + i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{forall, Config};

    fn drafter(lookback: usize, max_draft: usize) -> PromptLookupDrafter {
        PromptLookupDrafter::new(&SpecConfig {
            enabled: true,
            lookback,
            max_draft,
            ..SpecConfig::default()
        })
    }

    #[test]
    fn empty_and_tiny_histories_draft_nothing() {
        let mut d = drafter(64, 4);
        assert!(d.draft().is_empty());
        d.observe(7);
        assert!(d.draft().is_empty(), "one token has no earlier match");
    }

    #[test]
    fn novel_suffix_drafts_nothing() {
        let mut d = drafter(64, 4);
        d.observe_all(&[1, 2, 3, 4, 5]);
        assert!(d.draft().is_empty(), "all-distinct history has no match");
    }

    #[test]
    fn repeat_continues_the_pattern() {
        // History ...5 4 5 4 5: the 3-gram [4,5,4] ends at an earlier
        // occurrence whose continuation alternates — the draft must too.
        let mut d = drafter(64, 4);
        d.observe_all(&[9, 5, 4, 5, 4, 5, 4, 5, 4, 5]);
        let draft = d.draft();
        assert_eq!(draft, vec![4, 5, 4, 5], "full-length periodic draft");
    }

    #[test]
    fn prefers_occurrence_with_full_continuation() {
        // Periodic tail: the newest previous occurrence of the suffix is
        // one period back (continuation length 2); an older one yields the
        // full draft.  This is the OCC_SLOTS mechanism working.
        let mut d = drafter(64, 3);
        d.observe_all(&[7, 1, 2, 1, 2, 1, 2, 1, 2]);
        assert_eq!(d.draft(), vec![1, 2, 1]);
    }

    #[test]
    fn unigram_fallback_matches_last_token() {
        let mut d = drafter(64, 2);
        d.observe_all(&[3, 9, 9]);
        // Suffix 3-grams/2-grams [9,9] occur only at the end; unigram 9 at
        // position 1 has continuation [9].
        let draft = d.draft();
        assert!(!draft.is_empty());
        assert_eq!(draft[0], 9);
    }

    #[test]
    fn window_eviction_forgets_old_matches() {
        let mut d = drafter(8, 4);
        d.observe_all(&[1, 2, 3]); // will slide out
        d.observe_all(&[4, 5, 6, 7, 8]); // fills the window to 8
        d.observe_all(&[9, 9]); // evicts 1, 2
        // Token 3's earlier occurrence of suffix... suffix is [9]; 9 occurs
        // at the previous position only (in window) → continuation [9].
        assert_eq!(d.draft(), vec![9]);
        // Now a suffix whose only earlier occurrence slid out:
        let mut d = drafter(8, 4);
        d.observe_all(&[7, 1, 2, 3, 4, 5, 6, 8, 9, 7]);
        // `7` at position 0 is out of the 8-token window → nothing.
        assert!(d.draft().is_empty());
    }

    #[test]
    fn deterministic_and_bounded() {
        let hist = [1, 2, 1, 2, 3, 1, 2, 1, 2, 3, 1, 2];
        let mut a = drafter(16, 4);
        let mut b = drafter(16, 4);
        a.observe_all(&hist);
        b.observe_all(&hist);
        assert_eq!(a.draft(), b.draft());
        assert!(a.draft().len() <= 4);
    }

    /// Scan-based soundness check: a non-empty draft must be the verbatim
    /// continuation of an in-window occurrence of some suffix n-gram.
    fn draft_is_grounded(hist: &[i32], lookback: usize, draft: &[i32]) -> bool {
        let l = hist.len();
        let start = l.saturating_sub(lookback);
        let win = &hist[start..];
        for n in (1..=MAX_NGRAM.min(win.len().saturating_sub(1))).rev() {
            let suffix = &win[win.len() - n..];
            for p in 0..win.len() - n {
                // occurrence at win[p..p+n], continuation after it
                if &win[p..p + n] == suffix {
                    let cont = &win[p + n..];
                    if cont.len() >= draft.len() && &cont[..draft.len()] == draft {
                        return true;
                    }
                }
            }
        }
        false
    }

    #[test]
    fn property_drafts_bounded_grounded_deterministic() {
        forall(Config::default().cases(300), |g| {
            let lookback = g.usize(8..64);
            let max_draft = g.usize(1..(lookback - MAX_NGRAM).min(9));
            let vocab = g.usize(2..8) as i32;
            let hist = g.tokens(0..120, vocab);
            let cfg = SpecConfig {
                enabled: true,
                lookback,
                max_draft,
                ..SpecConfig::default()
            };
            let mut a = PromptLookupDrafter::new(&cfg);
            let mut b = PromptLookupDrafter::new(&cfg);
            a.observe_all(&hist);
            b.observe_all(&hist);
            let draft = a.draft();
            prop_assert!(draft == b.draft(), "identical histories must draft identically");
            prop_assert!(draft == a.draft(), "draft() must not mutate state");
            prop_assert!(
                draft.len() <= max_draft,
                "draft {} exceeds max_draft {max_draft}",
                draft.len()
            );
            // No match ⇒ nothing proposed; a proposal ⇒ a real in-window
            // continuation backs it.
            let l = hist.len();
            let start = l.saturating_sub(lookback);
            let last_seen_before = l >= 2 && hist[start..l - 1].contains(&hist[l - 1]);
            if !last_seen_before {
                prop_assert!(
                    draft.is_empty(),
                    "novel last token must draft nothing, got {draft:?}"
                );
            }
            if !draft.is_empty() {
                prop_assert!(
                    draft_is_grounded(&hist, lookback, &draft),
                    "ungrounded draft {draft:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_incremental_equals_batch() {
        forall(Config::default().cases(100), |g| {
            let hist = g.tokens(2..80, 5);
            let cfg = SpecConfig {
                enabled: true,
                lookback: 32,
                max_draft: 4,
                ..SpecConfig::default()
            };
            let mut inc = PromptLookupDrafter::new(&cfg);
            // Draft after every prefix: must equal a fresh drafter fed the
            // same prefix in one shot.
            for i in 0..hist.len() {
                inc.observe(hist[i]);
                let mut batch = PromptLookupDrafter::new(&cfg);
                batch.observe_all(&hist[..=i]);
                prop_assert!(
                    inc.draft() == batch.draft(),
                    "incremental/batch divergence at prefix {}",
                    i + 1
                );
            }
            Ok(())
        });
    }
}
