//! Config system: one typed root config loadable from TOML or JSON, with
//! CLI overrides layered on top.  Used by `main.rs` and the examples.

use std::path::Path;

use crate::coordinator::{ClusterConfig, EngineConfig};
use crate::hardware::GpuSpec;
use crate::kernels::KernelMode;
use crate::prefill::{FairnessPolicy, SpecPriority};
use crate::util::json::Json;
use crate::util::{json, toml};

/// Root configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Artifacts directory (manifest.json, *.hlo.txt, weights).
    pub artifacts_dir: String,
    pub engine: EngineConfig,
    pub cluster: ClusterConfig,
    /// GPU spec name for the simulator ("h20", "h100", …).
    pub gpu: String,
    /// Default RNG seed for workload generation.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: "artifacts".into(),
            engine: EngineConfig::default(),
            cluster: ClusterConfig::default(),
            gpu: "h20".into(),
            seed: 42,
        }
    }
}

impl Config {
    /// Load from a `.toml` or `.json` file.
    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        let tree = match path.extension().and_then(|e| e.to_str()) {
            Some("toml") => toml::parse_file(path)?,
            Some("json") => json::parse_file(path)?,
            other => anyhow::bail!("unsupported config extension {other:?}"),
        };
        Self::from_tree(&tree)
    }

    /// Build from a parsed tree, filling gaps with defaults.
    pub fn from_tree(t: &Json) -> anyhow::Result<Self> {
        let mut c = Config::default();
        if let Some(s) = t.get("artifacts_dir").as_str() {
            c.artifacts_dir = s.to_string();
        }
        if let Some(s) = t.get("gpu").as_str() {
            c.gpu = s.to_string();
            anyhow::ensure!(
                GpuSpec::by_name(&c.gpu).is_some(),
                "unknown gpu `{}`",
                c.gpu
            );
        }
        if let Some(n) = t.get("seed").as_usize() {
            c.seed = n as u64;
        }
        let e = t.get("engine");
        if let Some(s) = e.get("kernel").as_str() {
            anyhow::ensure!(
                s == "etap" || s == "flashmla",
                "engine.kernel must be etap|flashmla, got `{s}`"
            );
            c.engine.kernel = s.to_string();
        }
        if let Some(n) = e.get("max_slots").as_usize() {
            c.engine.max_slots = n;
        }
        if let Some(n) = e.get("kv_blocks").as_usize() {
            c.engine.kv_blocks = n;
        }
        if let Some(n) = e.get("block_size").as_usize() {
            anyhow::ensure!(n >= 1, "block_size must be ≥ 1");
            c.engine.block_size = n;
        }
        if let Some(n) = e.get("eos_token").as_i64() {
            c.engine.eos_token = Some(n as i32);
        }
        if let Some(b) = e.get("prefix_cache").as_bool() {
            c.engine.prefix_cache = b;
        }
        let pf = e.get("prefill");
        if let Some(n) = pf.get("step_token_budget").as_usize() {
            c.engine.prefill.step_token_budget = n;
        }
        if let Some(n) = pf.get("chunk_tokens").as_usize() {
            anyhow::ensure!(n >= 1, "prefill.chunk_tokens must be ≥ 1");
            c.engine.prefill.chunk_tokens = n;
        }
        if let Some(s) = pf.get("fairness").as_str() {
            c.engine.prefill.fairness = match s {
                "fifo" => FairnessPolicy::Fifo,
                "fair" => FairnessPolicy::Fair,
                other => anyhow::bail!(
                    "engine.prefill.fairness must be fifo|fair, got `{other}`"
                ),
            };
        }
        if let Some(s) = pf.get("spec_priority").as_str() {
            c.engine.prefill.spec_priority = match s {
                "spec" => SpecPriority::Spec,
                "prefill" => SpecPriority::Prefill,
                other => anyhow::bail!(
                    "engine.prefill.spec_priority must be spec|prefill, got `{other}`"
                ),
            };
        }
        let sp = e.get("spec");
        if let Some(b) = sp.get("enabled").as_bool() {
            c.engine.spec.enabled = b;
        }
        if let Some(n) = sp.get("lookback").as_usize() {
            c.engine.spec.lookback = n;
        }
        if let Some(n) = sp.get("max_draft").as_usize() {
            c.engine.spec.max_draft = n;
        }
        if let Some(b) = sp.get("adaptive").as_bool() {
            c.engine.spec.adaptive = b;
        }
        c.engine.spec.validate()?;
        let kn = e.get("kernels");
        if let Some(s) = kn.get("mode").as_str() {
            c.engine.kernels.mode = KernelMode::parse(s)?;
        }
        if let Some(n) = kn.get("threads").as_usize() {
            c.engine.kernels.threads = n;
        }
        if let Some(n) = kn.get("block_kv").as_usize() {
            c.engine.kernels.block_kv = n;
        }
        c.engine.kernels.validate()?;
        let cl = t.get("cluster");
        if let Some(n) = cl.get("gpus").as_usize() {
            c.cluster.gpus = n;
        }
        if let Some(n) = cl.get("total_heads").as_usize() {
            c.cluster.total_heads = n;
        }
        if let Some(n) = cl.get("n_layers").as_usize() {
            c.cluster.n_layers = n;
        }
        if let Some(s) = cl.get("kernel").as_str() {
            c.cluster.kernel = s.to_string();
        }
        if let Some(f) = cl.get("other_us_per_req_layer").as_f64() {
            c.cluster.other_us_per_req_layer = f;
        }
        anyhow::ensure!(
            c.cluster.total_heads % c.cluster.gpus == 0,
            "cluster.total_heads must divide evenly across gpus"
        );
        Ok(c)
    }

    pub fn gpu_spec(&self) -> GpuSpec {
        GpuSpec::by_name(&self.gpu).expect("validated at load")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert_eq!(c.engine.kernel, "etap");
        assert_eq!(c.cluster.gpus, 8);
        assert_eq!(c.gpu_spec().name, "H20");
    }

    #[test]
    fn toml_round_trip() {
        let doc = r#"
artifacts_dir = "art"
gpu = "h100"
seed = 7

[engine]
kernel = "flashmla"
max_slots = 8
kv_blocks = 512

[cluster]
gpus = 4
total_heads = 128
kernel = "fa3"
"#;
        let tree = crate::util::toml::parse(doc).unwrap();
        let c = Config::from_tree(&tree).unwrap();
        assert_eq!(c.artifacts_dir, "art");
        assert_eq!(c.gpu, "h100");
        assert_eq!(c.seed, 7);
        assert_eq!(c.engine.kernel, "flashmla");
        assert_eq!(c.engine.max_slots, 8);
        assert_eq!(c.engine.kv_blocks, 512);
        assert_eq!(c.cluster.gpus, 4);
        assert_eq!(c.cluster.kernel, "fa3");
        // Untouched defaults survive.
        assert_eq!(c.engine.block_size, 16);
    }

    #[test]
    fn rejects_bad_values() {
        let bad_kernel = crate::util::toml::parse("[engine]\nkernel = \"x\"").unwrap();
        assert!(Config::from_tree(&bad_kernel).is_err());
        let bad_gpu = crate::util::toml::parse("gpu = \"b200\"").unwrap();
        assert!(Config::from_tree(&bad_gpu).is_err());
        let bad_split =
            crate::util::toml::parse("[cluster]\ngpus = 7\ntotal_heads = 128").unwrap();
        assert!(Config::from_tree(&bad_split).is_err());
    }

    #[test]
    fn json_config_accepted() {
        let tree =
            crate::util::json::parse(r#"{"engine": {"max_slots": 2}, "seed": 9}"#).unwrap();
        let c = Config::from_tree(&tree).unwrap();
        assert_eq!(c.engine.max_slots, 2);
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn prefix_cache_toggle() {
        assert!(Config::default().engine.prefix_cache, "on by default");
        let tree = crate::util::toml::parse("[engine]\nprefix_cache = false").unwrap();
        let c = Config::from_tree(&tree).unwrap();
        assert!(!c.engine.prefix_cache);
    }

    #[test]
    fn prefill_section_parsed() {
        let d = Config::default().engine.prefill;
        assert_eq!(d.step_token_budget, 32, "chunking on by default");
        assert_eq!(d.chunk_tokens, 8);
        assert_eq!(d.fairness, FairnessPolicy::Fair);
        let doc = r#"
[engine.prefill]
step_token_budget = 64
chunk_tokens = 16
fairness = "fifo"
"#;
        let tree = crate::util::toml::parse(doc).unwrap();
        let c = Config::from_tree(&tree).unwrap();
        assert_eq!(c.engine.prefill.step_token_budget, 64);
        assert_eq!(c.engine.prefill.chunk_tokens, 16);
        assert_eq!(c.engine.prefill.fairness, FairnessPolicy::Fifo);
    }

    #[test]
    fn prefill_rejects_bad_values() {
        let bad = crate::util::toml::parse("[engine.prefill]\nchunk_tokens = 0").unwrap();
        assert!(Config::from_tree(&bad).is_err());
        let bad =
            crate::util::toml::parse("[engine.prefill]\nfairness = \"greedy\"").unwrap();
        assert!(Config::from_tree(&bad).is_err());
    }

    #[test]
    fn spec_section_parsed() {
        let d = Config::default().engine.spec;
        assert!(!d.enabled, "speculation off by default");
        assert_eq!(d.lookback, 256);
        assert_eq!(d.max_draft, 4);
        assert!(!d.adaptive, "fixed draft budget by default");
        assert_eq!(
            Config::default().engine.prefill.spec_priority,
            SpecPriority::Spec
        );
        let doc = r#"
[engine.prefill]
spec_priority = "prefill"

[engine.spec]
enabled = true
lookback = 64
max_draft = 6
adaptive = true
"#;
        let tree = crate::util::toml::parse(doc).unwrap();
        let c = Config::from_tree(&tree).unwrap();
        assert!(c.engine.spec.enabled);
        assert_eq!(c.engine.spec.lookback, 64);
        assert_eq!(c.engine.spec.max_draft, 6);
        assert!(c.engine.spec.adaptive);
        assert_eq!(c.engine.prefill.spec_priority, SpecPriority::Prefill);
    }

    #[test]
    fn kernels_section_parsed() {
        let d = Config::default().engine.kernels;
        assert_eq!(d.mode, KernelMode::Naive, "seed path by default");
        assert_eq!(d.threads, 0);
        assert_eq!(d.block_kv, 64);
        let doc = r#"
[engine.kernels]
mode = "blocked_parallel"
threads = 4
block_kv = 128
"#;
        let tree = crate::util::toml::parse(doc).unwrap();
        let c = Config::from_tree(&tree).unwrap();
        assert_eq!(c.engine.kernels.mode, KernelMode::BlockedParallel);
        assert_eq!(c.engine.kernels.threads, 4);
        assert_eq!(c.engine.kernels.block_kv, 128);
    }

    #[test]
    fn kernels_rejects_bad_values() {
        let bad = crate::util::toml::parse("[engine.kernels]\nmode = \"fast\"").unwrap();
        assert!(Config::from_tree(&bad).is_err());
        let bad = crate::util::toml::parse("[engine.kernels]\nblock_kv = 0").unwrap();
        assert!(Config::from_tree(&bad).is_err());
        let bad = crate::util::toml::parse("[engine.kernels]\nthreads = 100").unwrap();
        assert!(Config::from_tree(&bad).is_err());
    }

    #[test]
    fn spec_rejects_bad_values() {
        let bad = crate::util::toml::parse("[engine.spec]\nmax_draft = 0").unwrap();
        assert!(Config::from_tree(&bad).is_err());
        let bad = crate::util::toml::parse("[engine.spec]\nlookback = 2").unwrap();
        assert!(Config::from_tree(&bad).is_err());
        let bad =
            crate::util::toml::parse("[engine.prefill]\nspec_priority = \"draft\"").unwrap();
        assert!(Config::from_tree(&bad).is_err());
    }
}
