//! The decode engine: continuous batching over fixed-shape decode steps.
//!
//! Hot-path design (see also EXPERIMENTS.md §Perf):
//!
//! * While batch composition and buckets are stable, the engine feeds the
//!   decode step its own returned cache literal — zero bookkeeping per
//!   step, the backend writes each request's new latent in place.
//! * On *recomposition* (request finished / admitted / bucket growth) the
//!   engine syncs the survivors' latents from the live cache literal into
//!   the paged latent store, then rebuilds the dense cache for the new
//!   (batch-bucket, kv-bucket) shape by gathering from the store.
//! * Admission control consults the paged store's block budget, so a
//!   request is only admitted when its full context provably fits.
//!
//! The paged store holds one "super-latent" per token — the concatenation
//! of all layers' latent vectors — so request state survives slot moves
//! and bucket changes without any model re-execution (prefix re-use).
//!
//! **Exact KV convention.**  Every position-carrying computation uses
//! [`Request::kv_len`] — the number of tokens actually *fed* to the model,
//! each of whose latents sits at its sequence position — never the token
//! count ([`Request::context_len`]), which runs one ahead once generation
//! starts: the newest generated token is sampled from the previous
//! position's logits and has no latent until it is fed next tick.  The
//! first generated token's latent therefore lands at exactly
//! `prompt.len()`, and every decode step attends over exactly the rows
//! that were written.  (The pre-fix engine used the token count here,
//! permanently skipping position `prompt.len()` and attending one
//! all-zero row per decode step — self-consistent but numerically wrong;
//! a debug-build occupancy ledger now asserts every position below
//! `kv_len` is written exactly once.)
//!
//! **Prefix cache.**  When enabled (default), the engine keeps a radix
//! tree over completed-prefill prompts ([`crate::prefixcache`]):
//!
//! * admission charges a request only for its *unshared* suffix, since the
//!   shared blocks are already resident;
//! * a newly admitted request whose prompt hits the tree adopts the cached
//!   chain copy-on-write and starts its prefill cursor past the shared
//!   prefix — those prefill steps are skipped entirely;
//! * after a request finishes prefilling, its prompt's whole blocks are
//!   inserted back into the tree (deduplicated) so later requests hit;
//! * under block-pool pressure the engine evicts least-recently-used
//!   unreferenced tree leaves before refusing admission.
//!
//! **Chunked prefill.**  Prompts no longer prefill one token per engine
//! tick.  Each tick, a [`ChunkPlanner`] packs a mixed batch — every
//! decoding slot's single token plus multi-token prefill chunks — under
//! `prefill.step_token_budget`, and the whole plan executes in a single
//! [`StepRunner::prefill_chunk`] call (native multi-token on the reference
//! backend, documented per-token fallback on PJRT).  Prefix-cache
//! adoption composes: only the unshared suffix is chunked.  See
//! `docs/chunked-prefill.md`.
//!
//! **Speculative decoding.**  With `[engine.spec]` enabled, every decoding
//! request keeps a [`PromptLookupDrafter`] over its own prompt + generated
//! history.  A non-empty draft turns the slot's tick into a *verification
//! chunk* `[last_token, d₁ … dₘ]`, planned under the same token budget and
//! executed through [`StepRunner::verify_chunk`] — the prefill-shaped
//! workload the paper optimizes for, replacing up to `m` memory-bound
//! decode ticks.  The engine accepts the longest draft prefix matching the
//! per-position greedy argmax, which keeps outputs bit-identical to plain
//! decode; rejected positions only ever exist in the live literal at or
//! past the request's `kv_len()` (overwritten before anything attends to
//! them, per the write-purity contract) and are additionally rolled out
//! of the paged store by truncation.  Disabled (the default), none of
//! this runs and the
//! step sequence is byte-for-byte the non-speculative pipeline.  See
//! `docs/speculative-decoding.md`.
//!
//! **Serving API.**  Clients talk to the engine through handles and
//! events (`docs/serving-api.md`): [`submit`](Engine::submit) takes a
//! [`GenerationRequest`] (prompt, budget, stop tokens, per-request
//! [`SamplingParams`](super::SamplingParams)) and returns a
//! [`RequestHandle`]; every
//! [`step`](Engine::step) appends [`StepEvent`]s (`Admitted` / `Token` /
//! `Finished` / `Rejected`) drained via [`poll_events`](Engine::poll_events);
//! [`take_finished`](Engine::take_finished) hands out terminal results
//! without consuming the engine; [`cancel`](Engine::cancel) stops a
//! queued or running request, freeing its KV blocks through the normal
//! refcounted reap path and re-inserting its completed prompt prefix
//! into the radix tree.  [`run_to_completion`](Engine::run_to_completion)
//! survives as a thin batch-mode shim over the event loop.
//!
//! **Sampling.**  Token selection is engine-side ([`Sampler`]) over the
//! backend's logits row: greedy by default (bit-identical to the
//! pre-sampler pipeline), or seeded temperature/top-k/top-p per request.
//! Sampled requests auto-disable speculation for themselves — greedy
//! verification cannot verify sampled tokens (rejection sampling is the
//! ROADMAP follow-on) — and the engine records why in the metrics
//! (`spec_disabled_sampling`).  A tick that contains any sampled slot
//! additionally suppresses drafting batch-wide (`spec_suppressed_ticks`
//! counts the ticks where a greedy decoding co-resident lost its
//! drafting opportunity): verification ticks return per-position
//! argmaxes, but a sampled slot needs its full logits row.
//!
//! Decode steps execute on one of two backends behind
//! [`StepRunner`]: the PJRT AOT artifacts (production path) or the
//! deterministic pure-Rust reference model (tests, examples, CI).

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::kernels::{KernelConfig, KernelDispatch};
use crate::kvcache::{CacheConfig, PagedLatentCache, SeqId};
use crate::log_info;
use crate::obs::{self, FlightRecorder, RequestTimeline, TickRecord};
use crate::prefill::{ChunkPlanner, PrefillConfig, SlotDemand};
use crate::prefixcache::PrefixTree;
use crate::runtime::{
    DecodeRunner, ReferenceModel, ReferenceModelConfig, Runtime, StepRunner,
};
use crate::spec::{AdaptiveDraft, PromptLookupDrafter, SpecConfig};
use crate::util::stats::Welford;

use super::batcher::{Batcher, BatcherConfig};
use super::events::{FinishedRequest, RejectReason, StepEvent};
use super::metrics::ServingMetrics;
use super::request::{
    FinishReason, GenerationRequest, Request, RequestHandle, RequestId, RequestState,
};
use super::sampler::Sampler;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Attention computation mode: "etap" (default) or "flashmla".
    pub kernel: String,
    /// Concurrent batch slots (≤ largest decode batch bucket).
    pub max_slots: usize,
    /// Paged-store capacity in blocks.
    pub kv_blocks: usize,
    /// Tokens per paged block.
    pub block_size: usize,
    /// EOS token id (None = length-only stopping).
    pub eos_token: Option<i32>,
    /// Enable the cross-request prefix cache.
    pub prefix_cache: bool,
    /// Chunked-prefill knobs (`PrefillConfig::per_token()` restores the
    /// one-token-per-tick pipeline exactly).
    pub prefill: PrefillConfig,
    /// Speculative-decoding knobs (`[engine.spec]`); disabled by default.
    pub spec: SpecConfig,
    /// Fast-path kernel selection (`[engine.kernels]`); the seed-order
    /// `naive` dispatch by default.  Applies to the reference backend;
    /// PJRT executes compiled artifacts and ignores it.
    pub kernels: KernelConfig,
    /// Flight-recorder ring capacity in ticks; 0 (default) disables the
    /// recorder entirely — the hot path then never touches it.
    pub flight_recorder_ticks: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            kernel: "etap".into(),
            max_slots: 4,
            kv_blocks: 256,
            block_size: 16,
            eos_token: None,
            prefix_cache: true,
            prefill: PrefillConfig::default(),
            spec: SpecConfig::default(),
            kernels: KernelConfig::default(),
            flight_recorder_ticks: 0,
        }
    }
}

/// Final report of a serving run.
pub struct EngineReport {
    pub outputs: HashMap<RequestId, Vec<i32>>,
    pub metrics: ServingMetrics,
    pub recompositions: u64,
    pub steps: u64,
}

struct LiveBatch {
    batch_bucket: usize,
    kv_bucket: usize,
    /// RequestId per slot (None = padded slot).
    slots: Vec<Option<RequestId>>,
    cache: xla::Literal,
}

/// Where decode steps execute.
enum EngineBackend {
    /// PJRT over AOT HLO artifacts.
    Pjrt(Runtime),
    /// Deterministic pure-Rust reference model.
    Reference(Arc<ReferenceModel>),
}

/// The serving engine.
pub struct Engine {
    backend: EngineBackend,
    cfg: EngineConfig,
    batcher: Batcher,
    planner: ChunkPlanner,
    store: PagedLatentCache,
    prefix: Option<PrefixTree>,
    seq_of: HashMap<RequestId, SeqId>,
    /// Tokens already synced into the paged store, per request.
    synced: HashMap<RequestId, usize>,
    /// Tick-stamped lifecycle record per request (submitted / admitted /
    /// first token / finished, plus per-pipeline activity).  Kept after
    /// termination so [`timeline`](Self::timeline) answers post-run; the
    /// steps-based TTFT/e2e metrics read their submit stamps from here.
    timelines: HashMap<RequestId, RequestTimeline>,
    /// Requests whose prompt prefix is already in the tree.
    inserted: HashSet<RequestId>,
    // `+ Send` so a whole `Engine` moves across threads — the fleet
    // executor ticks N engines concurrently via `ThreadPool::map`, moving
    // each engine to a worker and back every tick.
    runners: HashMap<(usize, usize), Box<dyn StepRunner + Send>>,
    live: Option<LiveBatch>,
    metrics: ServingMetrics,
    outputs: HashMap<RequestId, Vec<i32>>,
    next_id: RequestId,
    recompositions: u64,
    n_layers: usize,
    latent_dim: usize,
    kv_buckets: Vec<usize>,
    /// Effective speculation config (PJRT degrades to disabled).
    spec: SpecConfig,
    /// One self-drafter per active decoding request (spec enabled only).
    drafters: HashMap<RequestId, PromptLookupDrafter>,
    /// One adaptive draft-budget controller per active decoding request
    /// (`spec.adaptive` only).
    adaptive: HashMap<RequestId, AdaptiveDraft>,
    /// One token sampler per active request, created lazily on its first
    /// emitted token and dropped at reap.  Greedy samplers are stateless;
    /// sampled ones own the request's seeded PRNG stream.
    samplers: HashMap<RequestId, Sampler>,
    /// Step events since the last [`poll_events`](Self::poll_events).
    events: VecDeque<StepEvent>,
    /// Terminal results since the last [`take_finished`](Self::take_finished).
    finished_buf: Vec<FinishedRequest>,
    /// The last executed tick's (demands, plan), moved in after the tick
    /// (no extra allocation) so [`last_plan_summary`](Self::last_plan_summary)
    /// can format on demand — hot ticks never pay for a log string.
    last_demands: Vec<SlotDemand>,
    last_plan: Vec<usize>,
    /// Debug-only exact-occupancy ledger: per active request, how many
    /// times each cache position has been written (adopted prefix
    /// positions start at 1, courtesy of the donor request).  Checked
    /// after every tick by [`debug_check_kv_occupancy`]
    /// (Self::debug_check_kv_occupancy): every position below `kv_len()`
    /// written exactly once — no hole, no double write — in every
    /// pipeline the test suites drive.
    #[cfg(debug_assertions)]
    kv_written: HashMap<RequestId, Vec<u32>>,
    /// Flight recorder (None = disabled): one [`TickRecord`] per executed
    /// tick, capacity-bounded; see `docs/observability.md`.
    recorder: Option<FlightRecorder>,
    /// Fast-path kernel selector handed to reference-backend runners;
    /// owns the slot-parallelism pool in `blocked_parallel` mode.
    kernels: Arc<KernelDispatch>,
    pub sync_cost: Welford,
}

impl Engine {
    /// Build an engine over an artifacts directory (PJRT backend).
    pub fn new(artifacts_dir: &Path, cfg: EngineConfig) -> anyhow::Result<Self> {
        let rt = Runtime::cpu(artifacts_dir)?;
        let model = rt
            .manifest()
            .model
            .clone()
            .ok_or_else(|| anyhow::anyhow!("artifacts have no decode model"))?;
        let buckets = rt.manifest().buckets("decode_step", &cfg.kernel);
        anyhow::ensure!(
            !buckets.is_empty(),
            "no decode artifacts for kernel `{}`",
            cfg.kernel
        );
        let mut batch_buckets: Vec<usize> = buckets.iter().map(|&(b, _)| b).collect();
        batch_buckets.sort();
        batch_buckets.dedup();
        let mut kv_buckets: Vec<usize> = buckets.iter().map(|&(_, n)| n).collect();
        kv_buckets.sort();
        kv_buckets.dedup();
        Self::build(
            EngineBackend::Pjrt(rt),
            model.n_layers,
            model.latent_dim,
            batch_buckets,
            kv_buckets,
            cfg,
        )
    }

    /// Build an engine over the deterministic reference model — no
    /// artifacts or native PJRT needed.  Decode semantics follow the same
    /// step contract as the artifact path.
    pub fn reference(model: ReferenceModelConfig, cfg: EngineConfig) -> anyhow::Result<Self> {
        let batch_buckets = model.batch_buckets.clone();
        let kv_buckets = model.kv_buckets.clone();
        anyhow::ensure!(!batch_buckets.is_empty(), "no batch buckets");
        anyhow::ensure!(!kv_buckets.is_empty(), "no kv buckets");
        let (n_layers, latent_dim) = (model.n_layers, model.latent_dim);
        let model = ReferenceModel::new(model);
        Self::build(
            EngineBackend::Reference(model),
            n_layers,
            latent_dim,
            batch_buckets,
            kv_buckets,
            cfg,
        )
    }

    fn build(
        backend: EngineBackend,
        n_layers: usize,
        latent_dim: usize,
        batch_buckets: Vec<usize>,
        kv_buckets: Vec<usize>,
        cfg: EngineConfig,
    ) -> anyhow::Result<Self> {
        let batcher = Batcher::new(BatcherConfig {
            max_slots: cfg.max_slots.min(*batch_buckets.last().unwrap()),
            batch_buckets,
            kv_buckets: kv_buckets.clone(),
        })?;
        let store = PagedLatentCache::new(CacheConfig {
            block_size: cfg.block_size,
            latent_dim: n_layers * latent_dim,
            num_blocks: cfg.kv_blocks,
        });
        let prefix = cfg
            .prefix_cache
            .then(|| PrefixTree::new(cfg.block_size, None));
        cfg.prefill.validate()?;
        cfg.spec.validate()?;
        let kernels = KernelDispatch::new(cfg.kernels.clone())?;
        // Multi-token scheduling only pays on backends that execute chunks
        // natively.  On PJRT the fallback would emulate a chunk with k
        // step dispatches, so a co-resident *decoding* slot's inter-token
        // wall time would grow ~k× for zero dispatch savings — degrade to
        // per-token planning there until a chunked artifact lands (ROADMAP
        // "chunked PJRT artifact").
        let effective_prefill = match &backend {
            EngineBackend::Reference(_) => cfg.prefill,
            EngineBackend::Pjrt(_) => {
                if cfg.prefill.chunk_tokens > 1 {
                    log_info!(
                        "engine",
                        "PJRT backend has no native chunked step; \
                         using per-token prefill"
                    );
                }
                PrefillConfig::per_token()
            }
        };
        // Same degrade for speculation: the verify fallback would emulate
        // an m-draft verification with m+1 step dispatches, k-multiplying
        // co-resident slots' token latency for zero dispatch savings.
        let effective_spec = match &backend {
            EngineBackend::Reference(_) => cfg.spec,
            EngineBackend::Pjrt(_) => {
                if cfg.spec.enabled {
                    log_info!(
                        "engine",
                        "PJRT backend has no native verify step; \
                         speculative decoding disabled"
                    );
                }
                SpecConfig {
                    enabled: false,
                    ..cfg.spec
                }
            }
        };
        Ok(Engine {
            backend,
            batcher,
            planner: ChunkPlanner::new(effective_prefill),
            store,
            prefix,
            seq_of: HashMap::new(),
            synced: HashMap::new(),
            timelines: HashMap::new(),
            inserted: HashSet::new(),
            runners: HashMap::new(),
            live: None,
            metrics: ServingMetrics::new(),
            outputs: HashMap::new(),
            next_id: 1,
            recompositions: 0,
            n_layers,
            latent_dim,
            kv_buckets,
            spec: effective_spec,
            drafters: HashMap::new(),
            adaptive: HashMap::new(),
            samplers: HashMap::new(),
            events: VecDeque::new(),
            finished_buf: Vec::new(),
            last_demands: Vec::new(),
            last_plan: Vec::new(),
            #[cfg(debug_assertions)]
            kv_written: HashMap::new(),
            recorder: (cfg.flight_recorder_ticks > 0)
                .then(|| FlightRecorder::new(cfg.flight_recorder_ticks)),
            kernels,
            sync_cost: Welford::new(),
            cfg,
        })
    }

    /// Largest admissible context in *tokens* (prompt + generated).  A
    /// request of `C` tokens feeds only `C - 1` of them — the final
    /// generated token is emitted but never fed back — so its latents
    /// occupy positions `0 .. C - 1` exactly and the biggest KV bucket
    /// `N` serves requests of up to `N + 1` tokens.
    pub fn max_context(&self) -> usize {
        self.kv_buckets.last().copied().unwrap_or(1) + 1
    }

    /// Submit a request; returns its handle.  The config-level EOS token
    /// (when set) is folded into the request's stop-token list, and a
    /// sampled request under an effective-spec engine is counted in
    /// `spec_disabled_sampling` — greedy verification cannot verify its
    /// tokens, so it will never carry a draft.
    pub fn submit(&mut self, req: GenerationRequest) -> RequestHandle {
        let id = self.next_id;
        self.next_id += 1;
        let mut r = req.into_request(id);
        if let Some(eos) = self.cfg.eos_token {
            if !r.stop_tokens.contains(&eos) {
                r.stop_tokens.push(eos);
            }
        }
        if self.spec.enabled && !r.sampling.is_greedy() {
            self.metrics.spec_disabled_sampling += 1;
        }
        self.timelines
            .insert(id, RequestTimeline::new(id, self.metrics.steps));
        obs::event_with("engine", "submit", || {
            format!("id={id} prompt={} max_new={}", r.prompt.len(), r.max_new_tokens)
        });
        self.batcher.submit(r);
        RequestHandle::new(id)
    }

    /// Cancel a request by id.  Covers both lifecycles:
    ///
    /// * **queued** — removed immediately: empty output, a
    ///   `Finished { reason: Cancelled }` event, no slot ever held;
    /// * **running** — marked finished in place; the next
    ///   [`step`](Self::step) reaps it exactly like a natural finish,
    ///   freeing its KV blocks through the refcounted `free_seq` path and
    ///   emitting the `Finished` event with its partial output.  If the
    ///   request had completed prefill, its prompt's whole synced blocks
    ///   are re-inserted into the prefix tree first, so the prefill work
    ///   stays sharable after the client walks away.
    ///
    /// Returns `false` when the id is unknown, already finished, or
    /// already cancelled.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(mut r) = self.batcher.remove_queued(id) {
            obs::event_with("engine", "cancel", || format!("id={id} queued"));
            r.finish(FinishReason::Cancelled);
            self.metrics.requests_cancelled += 1;
            self.retire_unstarted(
                r,
                StepEvent::Finished {
                    id,
                    reason: FinishReason::Cancelled,
                },
            );
            return true;
        }
        let Some(r) = self.batcher.find_active_mut(id) else {
            return false;
        };
        if r.is_finished() {
            return false;
        }
        let had_prefilled = r.state == RequestState::Decoding;
        let prompt = r.prompt.clone();
        r.finish(FinishReason::Cancelled);
        obs::event_with("engine", "cancel", || format!("id={id} running"));
        self.metrics.requests_cancelled += 1;
        if had_prefilled {
            self.insert_prompt_prefix(id, &prompt);
        }
        true
    }

    /// Drain the admission queue (shutdown / load-shed path): every queued
    /// request is rejected with a `Rejected { reason: Shutdown }` event
    /// and an empty output; running requests are untouched.  Returns the
    /// number drained.
    pub fn abort_queued(&mut self) -> usize {
        let drained = self.batcher.abort_queued();
        let n = drained.len();
        for mut r in drained {
            r.finish(FinishReason::Aborted);
            self.metrics.requests_rejected += 1;
            let id = r.id;
            self.retire_unstarted(
                r,
                StepEvent::Rejected {
                    id,
                    reason: RejectReason::Shutdown,
                },
            );
        }
        n
    }

    /// Terminal bookkeeping for a request that never held a slot (queue
    /// rejection, queue drain, queued cancellation): latency metrics,
    /// empty output, the event, and the finished buffer.
    fn retire_unstarted(&mut self, r: Request, event: StepEvent) {
        self.metrics.on_finish(&r);
        if let Some(t) = self.timelines.get_mut(&r.id) {
            if t.finished_step.is_none() {
                t.finished_step = Some(self.metrics.steps);
                t.outcome = Some(format!(
                    "{:?}",
                    r.finish_reason.expect("retired request has a reason")
                ));
                self.metrics
                    .on_request_done_steps(self.metrics.steps - t.submitted_step);
            }
        }
        match &event {
            StepEvent::Rejected { id, reason } => {
                let (id, reason) = (*id, *reason);
                obs::event_with("engine", "rejected", || format!("id={id} reason={reason:?}"));
            }
            _ => {
                let id = r.id;
                obs::event_with("engine", "retired", || format!("id={id}"));
            }
        }
        self.events.push_back(event);
        self.finished_buf.push(FinishedRequest {
            id: r.id,
            tokens: Vec::new(),
            reason: r.finish_reason.expect("retired request has a reason"),
        });
        self.outputs.insert(r.id, Vec::new());
    }

    /// Drain the events emitted since the last poll (every
    /// [`step`](Self::step), [`cancel`](Self::cancel) and
    /// [`abort_queued`](Self::abort_queued) appends; see
    /// [`StepEvent`] for the ordering guarantees).
    pub fn poll_events(&mut self) -> Vec<StepEvent> {
        self.events.drain(..).collect()
    }

    /// Drain the terminal results accumulated since the last call —
    /// the non-consuming complement of [`into_report`](Self::into_report):
    /// the engine keeps serving, and each result carries the request's
    /// full token vector and finish reason.
    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        std::mem::take(&mut self.finished_buf)
    }

    /// Run until all submitted work completes; returns the report.
    ///
    /// Batch-mode compatibility shim over the event loop: it drives
    /// [`step`](Self::step) and discards the event stream each tick (the
    /// outputs map carries the same tokens), so pre-event callers migrate
    /// by changing only their submit call sites.
    pub fn run_to_completion(mut self) -> anyhow::Result<EngineReport> {
        while self.has_work() {
            self.step()?;
            self.events.clear();
            self.finished_buf.clear();
        }
        Ok(self.into_report())
    }

    /// Anything queued or active?  Lets callers drive [`step`](Self::step)
    /// manually (e.g. to inspect per-tick plans) instead of
    /// [`run_to_completion`](Self::run_to_completion).
    pub fn has_work(&self) -> bool {
        self.batcher.has_work()
    }

    /// Finish a manually-driven run: consume the engine into its report.
    pub fn into_report(self) -> EngineReport {
        let steps = self.metrics.steps;
        EngineReport {
            outputs: self.outputs,
            metrics: self.metrics,
            recompositions: self.recompositions,
            steps,
        }
    }

    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Summary of the most recent tick's plan (empty before the first
    /// tick), formatted on demand; see [`ChunkPlanner::plan_summary`].
    pub fn last_plan_summary(&self) -> String {
        self.planner.plan_summary(&self.last_demands, &self.last_plan)
    }

    /// Worst-case blocks the active set may still allocate: each request's
    /// peak block count minus what its sequence already holds.  The paged
    /// store allocates lazily (at sync time), so admission must reserve
    /// against this, not against the instantaneous free count.  Peaks are
    /// measured in `max_kv()` — latents actually written — not token
    /// count: the final generated token never gets a cache slot.
    fn committed_future_blocks(&self) -> usize {
        let bs = self.cfg.block_size;
        self.batcher
            .active()
            .iter()
            .map(|r| {
                let peak = r.max_kv().div_ceil(bs);
                let held = self
                    .seq_of
                    .get(&r.id)
                    .map(|s| self.store.blocks_of(*s).len())
                    .unwrap_or(0);
                peak.saturating_sub(held)
            })
            .sum()
    }

    /// One engine step: reap, admit, (maybe) recompose, execute, advance.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        let t0 = Instant::now();
        // Publish the tick this call would execute as (`steps` counts
        // completed ticks; idle polls don't advance it, so an idle poll's
        // records share the number of the next executed tick).
        obs::set_tick(self.metrics.steps + 1);
        let _step_span = obs::span("engine", "step");
        let events_before = self.events.len();

        // 1. Reap finished requests (natural finishes and running
        // cancellations alike — `cancel` only marks; the blocks are freed
        // here, through the same refcounted path as every other exit).
        let finished = self.batcher.reap();
        let mut composition_changed = !finished.is_empty();
        for r in finished {
            self.metrics.on_finish(&r);
            if let Some(seq) = self.seq_of.remove(&r.id) {
                self.store.free_seq(seq);
            }
            self.synced.remove(&r.id);
            if let Some(t) = self.timelines.get_mut(&r.id) {
                if t.finished_step.is_none() {
                    t.finished_step = Some(self.metrics.steps);
                    t.outcome = r.finish_reason.map(|f| format!("{f:?}"));
                    t.tokens = r.generated.len();
                    self.metrics
                        .on_request_done_steps(self.metrics.steps - t.submitted_step);
                }
            }
            self.inserted.remove(&r.id);
            self.drafters.remove(&r.id);
            self.adaptive.remove(&r.id);
            self.samplers.remove(&r.id);
            #[cfg(debug_assertions)]
            self.kv_written.remove(&r.id);
            let reason = r.finish_reason.expect("finished request has a reason");
            obs::event_with("engine", "finished", || {
                format!("id={} reason={reason:?} tokens={}", r.id, r.generated.len())
            });
            self.events.push_back(StepEvent::Finished { id: r.id, reason });
            self.finished_buf.push(FinishedRequest {
                id: r.id,
                tokens: r.generated.clone(),
                reason,
            });
            // `r` is owned and dropped here: move, don't clone again.
            self.outputs.insert(r.id, r.generated);
        }

        // 1b. Abort queued requests that can never fit: a request whose
        // peak block demand exceeds the whole pool is unservable even with
        // every other sequence and tree leaf gone, so leaving it at the
        // head of the queue would spin the serving loop forever (and the
        // pressure path below would pointlessly drain the prefix tree).
        // Sharing cannot rescue it either — its own sequence must hold all
        // `peak` distinct blocks at once.
        while let Some(front) = self.batcher.front() {
            if front.max_kv().div_ceil(self.cfg.block_size) <= self.cfg.kv_blocks {
                break;
            }
            let mut r = self.batcher.reject_front().expect("front exists");
            r.finish(FinishReason::Aborted);
            self.metrics.requests_rejected += 1;
            let id = r.id;
            self.retire_unstarted(
                r,
                StepEvent::Rejected {
                    id,
                    reason: RejectReason::KvCapacity,
                },
            );
        }

        // 2a. Under pool pressure, evict cold prefix-cache leaves so the
        // head-of-queue request can fit (only leaves the tree holds the
        // last reference to — eviction always returns blocks to the pool).
        // Pressure counts blocks already committed to active requests but
        // not yet lazily allocated, not just the instantaneous free count.
        let committed = self.committed_future_blocks();
        let pressure = match (&self.prefix, self.batcher.front()) {
            (Some(tree), Some(front)) => {
                let cap = tree.usable_prefix_len(front.prompt.len());
                let matched = tree.peek_match(&front.prompt[..cap]);
                let needed = committed
                    + (front.max_kv() - matched).div_ceil(self.cfg.block_size);
                let free = self.store.free_blocks();
                if needed > free {
                    Some(needed - free)
                } else {
                    None
                }
            }
            _ => None,
        };
        if let Some(want) = pressure {
            let tree = self.prefix.as_mut().expect("pressure implies a tree");
            tree.evict(want, &mut self.store, true);
        }

        // 2b. Admit from the queue under the block budget, charging prefix
        // hits only for their unshared suffix.  `committed` carries the
        // outstanding worst-case demand of already-running requests plus
        // the ones admitted earlier in this very call, so a sequence of
        // admissions can never over-commit the (lazily allocated) pool.
        // (Eviction above only dropped tree references, so the active
        // set's committed demand from 2a is still exact.)
        let store = &self.store;
        let prefix = self.prefix.as_ref();
        let block_size = self.cfg.block_size;
        let mut committed = committed;
        // Matches peeked here are re-used for bucket selection below: they
        // are taken *after* 2a's eviction, and the tree only grows between
        // here and adoption, so they are safe lower bounds.
        let mut peeked: HashMap<RequestId, usize> = HashMap::new();
        let admitted = self.batcher.admit(|r| {
            let matched = match prefix {
                Some(t) => {
                    let cap = t.usable_prefix_len(r.prompt.len());
                    let m = t.peek_match(&r.prompt[..cap]);
                    peeked.insert(r.id, m);
                    m
                }
                None => 0,
            };
            let blocks_needed = (r.max_kv() - matched).div_ceil(block_size);
            if committed + blocks_needed <= store.free_blocks() {
                committed += blocks_needed;
                true
            } else {
                false
            }
        });
        if admitted > 0 {
            composition_changed = true;
            let active = self.batcher.active();
            let step_now = self.metrics.steps;
            let mut admitted_ids: Vec<RequestId> = Vec::with_capacity(admitted);
            for r in &active[active.len() - admitted..] {
                self.events.push_back(StepEvent::Admitted { id: r.id });
                admitted_ids.push(r.id);
            }
            for id in admitted_ids {
                if let Some(t) = self.timelines.get_mut(&id) {
                    t.admitted_step = Some(step_now);
                }
                obs::event_with("engine", "admitted", || format!("id={id}"));
            }
        }

        if self.batcher.active().is_empty() {
            return Ok(false); // idle (queue blocked on capacity or empty)
        }

        // 2c. Speculation: refresh every decoding slot's draft from its
        // prompt-lookup drafter (created on first decode tick, fed the
        // history incrementally, dropped at reap).  Drafts are recomputed
        // each tick — the drafter is deterministic and cheap, and a
        // rejected draft simply reappears shorter or not at all.  Tokens
        // past the generation budget are never drafted: plain decode could
        // not emit them, so they could never be accepted.
        // A sampled request never drafts (it was counted in
        // `spec_disabled_sampling` at submit), and its mere presence in
        // the batch suppresses drafting for the whole tick: a tick with
        // any draft executes through `verify_chunk`, which returns
        // per-position argmaxes — but a sampled slot needs its full
        // logits row to draw from.  Greedy co-residents resume drafting
        // the tick after the last sampled request leaves.
        let mut spec_suppressed = false;
        if self.spec.enabled {
            let any_sampled = self.batcher.active().iter().any(|r| !r.sampling.is_greedy());
            if any_sampled {
                // Count only ticks where a greedy co-resident actually
                // lost a drafting opportunity — a batch of nothing but
                // sampled/prefilling slots had nothing to suppress.
                let suppressible = self
                    .batcher
                    .active()
                    .iter()
                    .any(|r| r.state == RequestState::Decoding && r.sampling.is_greedy());
                if suppressible {
                    self.metrics.spec_suppressed_ticks += 1;
                    spec_suppressed = true;
                    obs::event("spec", "suppressed");
                }
                for r in self.batcher.active_mut() {
                    r.draft.clear();
                }
            } else {
                let spec_cfg = self.spec;
                for r in self.batcher.active_mut() {
                    if r.state != RequestState::Decoding {
                        continue;
                    }
                    let d = self
                        .drafters
                        .entry(r.id)
                        .or_insert_with(|| PromptLookupDrafter::new(&spec_cfg));
                    while (d.observed() as usize) < r.prompt.len() + r.generated.len() {
                        let i = d.observed() as usize;
                        d.observe(if i < r.prompt.len() {
                            r.prompt[i]
                        } else {
                            r.generated[i - r.prompt.len()]
                        });
                    }
                    let mut draft = d.draft();
                    if spec_cfg.adaptive {
                        let a = self
                            .adaptive
                            .entry(r.id)
                            .or_insert_with(|| AdaptiveDraft::new(spec_cfg.max_draft));
                        draft.truncate(a.budget());
                    }
                    let room = r.max_new_tokens - r.generated.len();
                    draft.truncate(room.saturating_sub(1));
                    r.draft = draft;
                    if !r.draft.is_empty() {
                        obs::event_with("spec", "draft", || {
                            format!("id={} len={}", r.id, r.draft.len())
                        });
                    }
                }
            }
        }

        let plan_span = obs::span("engine", "plan");
        // 3. Determine buckets; recompose if needed.  Bucket choice
        // anticipates both prefix adoption (a newly admitted request may
        // start its write frontier at the cached prefix length rather than
        // zero) and this tick's prefill chunks (a chunk of k tokens writes
        // positions kv .. kv + k - 1, where kv is the request's exact
        // `kv_len()` — every latent written so far, nothing skipped).  The
        // estimate plan below may differ from the final plan — adoption in
        // recompose can shift frontiers — but the final plan is capped by
        // the chosen bucket's headroom, so an off estimate only truncates
        // chunks, never overflows the bucket.
        let batch_bucket = self.batcher.batch_bucket();
        let largest_kv = *self.kv_buckets.last().expect("validated nonempty");
        let mut kv_need = self.batcher.kv_bucket_need();
        {
            let est: Vec<(usize, SlotDemand)> = self
                .batcher
                .active()
                .iter()
                .map(|r| {
                    let adopted = if self.seq_of.contains_key(&r.id) {
                        None
                    } else {
                        peeked.get(&r.id).copied()
                    };
                    let ctx = adopted.unwrap_or_else(|| r.kv_len());
                    let demand = if r.state == RequestState::Prefilling {
                        let consumed = adopted.unwrap_or(r.prefill_pos);
                        let remaining = r.prompt.len().saturating_sub(consumed);
                        let headroom = largest_kv.saturating_sub(ctx).max(1);
                        SlotDemand::prefill(remaining.max(1), ctx, headroom)
                    } else if !r.draft.is_empty() {
                        let headroom = largest_kv.saturating_sub(ctx).max(1);
                        SlotDemand::verify(r.draft.len(), headroom)
                    } else {
                        SlotDemand::decode()
                    };
                    (ctx, demand)
                })
                .collect();
            let demands: Vec<SlotDemand> = est.iter().map(|&(_, d)| d).collect();
            let plan = self.planner.plan(&demands);
            for (&(ctx, _), &k) in est.iter().zip(&plan) {
                kv_need = kv_need.max(ctx + k);
            }
        }
        let kv_bucket = self
            .kv_buckets
            .iter()
            .copied()
            .find(|&n| n >= kv_need)
            .unwrap_or(largest_kv);
        let needs_rebuild = composition_changed
            || match &self.live {
                None => true,
                Some(l) => l.batch_bucket != batch_bucket || l.kv_bucket != kv_bucket,
            };
        if needs_rebuild {
            self.recompose(batch_bucket, kv_bucket)?;
        }

        // 4. Plan this tick's chunks on the post-adoption state and build
        // the mixed-batch inputs: every decoding slot contributes its one
        // token, every prefilling slot a chunk of its unshared prompt
        // suffix, padded slots an empty chunk.
        let live = self.live.as_ref().unwrap();
        let b = live.batch_bucket;
        let kv_bucket = live.kv_bucket;
        let mut by_id: HashMap<RequestId, usize> = HashMap::new();
        for (slot, rid) in live.slots.iter().enumerate() {
            if let Some(rid) = rid {
                by_id.insert(*rid, slot);
            }
        }
        let demands: Vec<SlotDemand> = self
            .batcher
            .active()
            .iter()
            .map(|r| {
                if r.state == RequestState::Prefilling {
                    let remaining = r.prompt.len() - r.prefill_pos;
                    // Positions kv_len .. kv_bucket - 1 are addressable.
                    let headroom = kv_bucket.saturating_sub(r.kv_len()).max(1);
                    SlotDemand::prefill(remaining, r.prefill_pos, headroom)
                } else if !r.draft.is_empty() {
                    let headroom = kv_bucket.saturating_sub(r.kv_len()).max(1);
                    SlotDemand::verify(r.draft.len(), headroom)
                } else {
                    SlotDemand::decode()
                }
            })
            .collect();
        let plan = self.planner.plan(&demands);
        let mut chunks: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut start_pos = vec![0i32; b];
        // Draft tokens fed per active index (verification chunk size - 1).
        let mut fed = vec![0usize; plan.len()];
        for (i, r) in self.batcher.active().iter().enumerate() {
            let slot = by_id[&r.id];
            let k = plan[i];
            // The exact convention: the next latent lands at kv_len() —
            // for the first decode step that is `prompt.len()`, the slot
            // the old `context_len()` convention permanently skipped.
            start_pos[slot] = r.kv_len() as i32;
            chunks[slot] = if r.state == RequestState::Prefilling {
                r.prompt[r.prefill_pos..r.prefill_pos + k].to_vec()
            } else {
                let tok = r.next_input_token().expect("active request has input");
                // The planner may have trimmed the draft (budget or
                // headroom): feed only the prefix it granted.
                fed[i] = k - 1;
                let mut c = Vec::with_capacity(k);
                c.push(tok);
                c.extend_from_slice(&r.draft[..k - 1]);
                c
            };
        }
        // Record this tick's planned writes in the occupancy ledger: slot
        // `i` writes positions `kv_len() .. kv_len() + plan[i]`.
        #[cfg(debug_assertions)]
        for (i, r) in self.batcher.active().iter().enumerate() {
            let (s, k) = (r.kv_len(), plan[i]);
            let w = self.kv_written.entry(r.id).or_default();
            if w.len() < s + k {
                w.resize(s + k, 0);
            }
            for mark in &mut w[s..s + k] {
                *mark += 1;
            }
        }

        drop(plan_span);

        // 5. Execute the whole mixed batch in one multi-token step.  Ticks
        // carrying draft tokens go through `verify_chunk`, whose cache
        // effects are contractually bit-identical to `prefill_chunk` but
        // which also returns the greedy argmax after every consumed token;
        // all other ticks take the non-speculative call unchanged.
        let runner = self
            .runners
            .get(&(b, kv_bucket))
            .expect("runner loaded at recompose");
        let vocab = runner.vocab();
        let spec_tick = fed.iter().any(|&m| m > 0);
        // A spec tick returns per-position argmaxes (all slots are greedy
        // — drafting was suppressed otherwise); a plain tick keeps the
        // raw logits rows so each slot's request samples its own token.
        // The compute ledger observes the dispatch through the
        // `run_*_chunk` wrappers (shape-only, backend-agnostic); draft
        // positions are recorded useful and reclassified below once
        // verification outcomes are known.  All of it is inert behind one
        // relaxed atomic load when no `LedgerGuard` is live.
        obs::ledger::begin_tick();
        let exec_span = obs::span("engine", "execute");
        let (argmaxes, logits, new_cache) = if spec_tick {
            let (am, cache) = crate::runtime::run_verify_chunk(
                runner.as_ref(),
                &chunks,
                &live.cache,
                &start_pos,
                kv_bucket,
            )?;
            (am, Vec::new(), cache)
        } else {
            let (lg, cache) = crate::runtime::run_prefill_chunk(
                runner.as_ref(),
                &chunks,
                &live.cache,
                &start_pos,
                kv_bucket,
            )?;
            (Vec::new(), lg, cache)
        };
        drop(exec_span);

        // 6. Advance request state machines.  Each slot's next token comes
        // from its *last* consumed position: on a spec tick the final
        // greedy argmax, otherwise the slot's own sampler over its logits
        // row (greedy samplers reproduce `argmax_row` bit-for-bit); for a
        // chunk that reaches the end of its prompt it is the first
        // generated token, exactly as in the per-token pipeline.
        // Verification slots accept the longest draft prefix matching the
        // per-position argmaxes.  Every appended token becomes a `Token`
        // event, in generation order.
        let mut new_tokens = 0usize;
        let mut chunk_sizes: Vec<usize> = Vec::new();
        let mut first_tokens: Vec<RequestId> = Vec::new();
        let mut verified: Vec<(RequestId, usize, usize)> = Vec::new();
        let mut rollbacks: Vec<(RequestId, usize)> = Vec::new();
        // Same `batcher.active` order the plan was built from above (no
        // reap/admit between), so `plan[i]` still lines up.
        let advance_span = obs::span("engine", "advance");
        let samplers = &mut self.samplers;
        let events = &mut self.events;
        let timelines = &mut self.timelines;
        for (i, r) in self.batcher.active_mut().iter_mut().enumerate() {
            let slot = by_id[&r.id];
            let k = plan[i];
            let before = r.generated.len();
            let was_prefilling = r.state == RequestState::Prefilling;
            if r.state == RequestState::Prefilling {
                let completes = r.prefill_pos + k == r.prompt.len();
                // The sampler only runs — and only consumes PRNG state —
                // for positions whose token is actually emitted; the
                // argument of a mid-prompt chunk is discarded entirely.
                let sampled = if !completes {
                    0
                } else if spec_tick {
                    *argmaxes[slot].last().expect("active slot has a chunk")
                } else {
                    let row = &logits[slot * vocab..(slot + 1) * vocab];
                    let s = samplers.entry(r.id).or_insert_with(|| Sampler::new(&r.sampling));
                    s.sample(row)
                };
                r.advance_chunk(k, sampled);
                chunk_sizes.push(k);
                if r.state != RequestState::Prefilling {
                    // transition emitted the first generated token
                    new_tokens += 1;
                    first_tokens.push(r.id);
                }
            } else if spec_tick {
                let outcome = r.apply_verification(fed[i], &argmaxes[slot]);
                new_tokens += outcome.emitted;
                if fed[i] > 0 {
                    verified.push((r.id, outcome.drafted, outcome.accepted));
                    rollbacks.push((r.id, r.kv_len()));
                }
            } else {
                debug_assert_eq!(k, 1, "decode slots consume exactly one token");
                let row = &logits[slot * vocab..(slot + 1) * vocab];
                let s = samplers.entry(r.id).or_insert_with(|| Sampler::new(&r.sampling));
                let sampled = s.sample(row);
                r.advance(sampled);
                new_tokens += 1;
            }
            for &t in &r.generated[before..] {
                events.push_back(StepEvent::Token { id: r.id, token: t });
            }
            if let Some(t) = timelines.get_mut(&r.id) {
                if was_prefilling {
                    t.prefill_chunks += 1;
                }
                t.tokens += r.generated.len() - before;
            }
        }
        self.live.as_mut().unwrap().cache = new_cache;

        // 6b. Roll rejected draft positions out of the paged store.  Under
        // the engine's lazy sync this is provably a no-op — latents enter
        // the store only at recompose, which copies positions
        // `synced .. kv_len()`, and `kv_len` counts exactly the validly
        // written positions (never a rejected one) — but the invariant
        // "the store never holds an unverified latent" is enforced here
        // rather than assumed, so a future eager-sync backend (e.g. a
        // chunked PJRT artifact writing through the paged store) cannot
        // silently poison prefix sharing.  Rejected rows in the *live
        // literal* need no cleanup at all: they sit at positions
        // `kv_len()` and beyond and are rewritten by the next correct
        // token before anything attends to them (the write-purity
        // contract; see `docs/speculative-decoding.md`).
        for (rid, ctx) in rollbacks {
            let Some(&seq) = self.seq_of.get(&rid) else {
                continue;
            };
            if self.store.len(seq) > ctx {
                self.store.truncate(seq, ctx);
            }
            if let Some(s) = self.synced.get_mut(&rid) {
                *s = (*s).min(ctx);
            }
        }
        let (mut tick_drafted, mut tick_accepted) = (0usize, 0usize);
        for (rid, drafted, accepted) in verified {
            tick_drafted += drafted;
            tick_accepted += accepted;
            // Ledger reattribution: draft `d` was dispatched as chunk
            // token `d + 1` (after the slot's real next token), attending
            // rows `0 ..= start + d + 1`.  Rejected positions move from
            // `useful` to `spec_rejected`; exact because per-token
            // quantities are integer-valued f64s (see `obs::ledger`).
            if obs::ledger::enabled() {
                let start = start_pos[by_id[&rid]].max(0) as usize;
                for d in accepted..drafted {
                    obs::ledger::reclassify_rejected(start + d + 2, kv_bucket);
                }
            }
            self.metrics.on_verify(drafted, accepted);
            if let Some(t) = self.timelines.get_mut(&rid) {
                t.spec_drafted += drafted;
                t.spec_accepted += accepted;
            }
            obs::event_with("spec", "verified", || {
                format!("id={rid} accepted={accepted}/{drafted}")
            });
            if self.spec.adaptive {
                if let Some(a) = self.adaptive.get_mut(&rid) {
                    a.on_verify(drafted, accepted);
                }
            }
        }
        drop(advance_span);
        #[cfg(debug_assertions)]
        self.debug_check_kv_occupancy();

        // Fold the tick's compute attribution into the run totals (zeros
        // when no ledger guard is live).
        let tick_compute = obs::ledger::take_tick();
        self.metrics.on_compute(&tick_compute);

        let active = self.batcher.active().len();
        self.metrics.on_step(
            t0.elapsed(),
            active,
            self.cfg.max_slots,
            new_tokens,
            &chunk_sizes,
        );
        for id in first_tokens {
            // The timeline survives until the request terminates (its
            // submit stamp also feeds the e2e-steps histogram at reap).
            if let Some(t) = self.timelines.get_mut(&id) {
                if t.first_token_step.is_none() {
                    t.first_token_step = Some(self.metrics.steps);
                    self.metrics.on_first_token_step(self.metrics.steps - t.submitted_step);
                }
            }
            obs::event_with("engine", "first_token", || format!("id={id}"));
        }
        if let Some(tree) = &self.prefix {
            self.metrics.prefix = tree.stats();
            self.metrics.prefix_cached_blocks = tree.cached_blocks() as u64;
        }
        self.last_demands = demands;
        self.last_plan = plan;

        // 7. Flight recorder: one record per executed tick, built from the
        // same state the live accessors report (`last_plan_summary`,
        // batcher composition, pool pressure) so a dumped ring replays the
        // run exactly.  `wall_us` is the only nondeterministic field.
        if self.recorder.is_some() {
            let plan_s = self.last_plan_summary();
            let (mut decode_slots, mut prefill_slots, mut verify_slots) = (0usize, 0usize, 0usize);
            for d in &self.last_demands {
                if d.is_prefill() {
                    prefill_slots += 1;
                } else if d.is_verify() {
                    verify_slots += 1;
                } else {
                    decode_slots += 1;
                }
            }
            let rec = TickRecord {
                tick: self.metrics.steps,
                wall_us: t0.elapsed().as_secs_f64() * 1e6,
                plan: plan_s,
                active,
                queued: self.batcher.queued(),
                decode_slots,
                prefill_slots,
                verify_slots,
                batch_bucket: b,
                kv_bucket,
                budget_used: self.last_plan.iter().sum(),
                budget: self
                    .planner
                    .config()
                    .step_token_budget
                    .max(self.last_demands.len()),
                new_tokens,
                prefill_tokens: chunk_sizes.iter().sum(),
                kv_free_blocks: self.store.free_blocks(),
                kv_total_blocks: self.cfg.kv_blocks,
                prefix_hits: self.metrics.prefix.hits,
                prefix_lookups: self.metrics.prefix.lookups,
                spec_drafted: tick_drafted,
                spec_accepted: tick_accepted,
                spec_suppressed,
                recomposed: needs_rebuild,
                events: self.events.len() - events_before,
                useful_flops: tick_compute.useful_flops,
                bucket_pad_flops: tick_compute.bucket_pad_flops,
                chunk_refeed_flops: tick_compute.chunk_refeed_flops,
                spec_rejected_flops: tick_compute.spec_rejected_flops,
                mask_pad_flops: tick_compute.mask_pad_flops,
                bytes_moved: tick_compute.total_bytes(),
            };
            self.recorder.as_mut().expect("checked above").record(rec);
        }
        Ok(true)
    }

    /// Sync survivors into the paged store, then rebuild the dense cache
    /// for the new bucket shape.
    fn recompose(&mut self, batch_bucket: usize, kv_bucket: usize) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let _span = obs::span("engine", "recompose");
        self.recompositions += 1;

        // (a) Sync: pull the live literal once and append unsynced tokens.
        let kv_sync_span = obs::span("engine", "kv_sync");
        if let Some(live) = self.live.take() {
            let host: Vec<f32> = live
                .cache
                .to_vec()
                .map_err(|e| anyhow::anyhow!("cache to_vec: {e:?}"))?;
            let (l, n, ld) = (self.n_layers, live.kv_bucket, self.latent_dim);
            let b = live.batch_bucket;
            // Sync exactly the positions the backend has written: rows
            // `synced .. kv_len()`.  The newest generated token has no
            // latent yet (it is fed next tick), so syncing up to the token
            // count would copy a garbage row into the store.
            let mut active_len: HashMap<RequestId, usize> = HashMap::new();
            for r in self.batcher.active() {
                active_len.insert(r.id, r.kv_len());
            }
            for (slot, rid) in live.slots.iter().enumerate() {
                let Some(rid) = rid else { continue };
                let Some(&ctx) = active_len.get(rid) else { continue };
                let seq = self.seq_of[rid];
                let synced = self.synced.get(rid).copied().unwrap_or(0);
                let mut latent = vec![0.0f32; l * ld];
                for pos in synced..ctx {
                    for layer in 0..l {
                        let off = ((layer * b + slot) * n + pos) * ld;
                        latent[layer * ld..(layer + 1) * ld]
                            .copy_from_slice(&host[off..off + ld]);
                    }
                    self.store
                        .append(seq, &latent)
                        .map_err(|e| anyhow::anyhow!("store append: {e}"))?;
                }
                self.synced.insert(*rid, ctx);
            }
        }
        drop(kv_sync_span);

        // (a2) Feed completed prefills back into the prefix tree: once a
        // request is decoding, its prompt's whole blocks are synced and
        // immutable, so later requests can share them.  Dedup is the
        // tree's job; `inserted` just avoids rewalking every recompose.
        if self.prefix.is_some() {
            let candidates: Vec<(RequestId, Vec<i32>)> = self
                .batcher
                .active()
                .iter()
                .filter(|r| {
                    r.state == RequestState::Decoding && !self.inserted.contains(&r.id)
                })
                .map(|r| (r.id, r.prompt.clone()))
                .collect();
            for (rid, prompt) in candidates {
                self.insert_prompt_prefix(rid, &prompt);
            }
        }

        // (b) Assign slots (stable order = batcher order) and create
        // sequences for newly admitted requests — adopting cached prefix
        // chains copy-on-write where the tree has them.
        let mut slots: Vec<Option<RequestId>> = vec![None; batch_bucket];
        for (i, r) in self.batcher.active().iter().enumerate() {
            slots[i] = Some(r.id);
        }
        for r in self.batcher.active_mut() {
            if self.seq_of.contains_key(&r.id) {
                continue;
            }
            let seq = match self.prefix.as_mut() {
                Some(tree) => {
                    // Cap at the bucket as well as the prompt: inserts done
                    // in (a2) above may have deepened the match past the
                    // estimate the bucket was chosen with, and an adopted
                    // context must leave room for this step's write slot.
                    let cap = tree.usable_prefix_len(r.prompt.len().min(kv_bucket));
                    let m = tree.match_prefix(&r.prompt[..cap]);
                    if m.tokens > 0 {
                        // Adopt the shared chain: prefill for the matched
                        // tokens is skipped entirely.
                        r.prefill_pos = m.tokens;
                        if let Some(t) = self.timelines.get_mut(&r.id) {
                            t.adopted_prefix_tokens += m.tokens;
                        }
                        obs::event_with("prefix", "adopt", || {
                            format!("id={} tokens={}", r.id, m.tokens)
                        });
                        self.store.adopt_chain(&m.blocks, m.tokens)
                    } else {
                        self.store.new_seq()
                    }
                }
                None => self.store.new_seq(),
            };
            self.synced.insert(r.id, self.store.len(seq));
            self.seq_of.insert(r.id, seq);
            // Adopted prefix positions were written (once) by the donor
            // request; the ledger inherits them as already-occupied.
            #[cfg(debug_assertions)]
            self.kv_written.insert(r.id, vec![1; self.store.len(seq)]);
        }

        // (c) Load (cached) the runner for this bucket pair.
        if !self.runners.contains_key(&(batch_bucket, kv_bucket)) {
            let runner: Box<dyn StepRunner + Send> = match &self.backend {
                EngineBackend::Pjrt(rt) => Box::new(DecodeRunner::best(
                    rt,
                    &self.cfg.kernel,
                    batch_bucket,
                    kv_bucket,
                )?),
                EngineBackend::Reference(model) => Box::new(model.runner_with(
                    batch_bucket,
                    kv_bucket,
                    Arc::clone(&self.kernels),
                )),
            };
            log_info!(
                "engine",
                "loaded decode runner {} for bucket (b{batch_bucket}, n{kv_bucket})",
                runner.name()
            );
            self.runners.insert((batch_bucket, kv_bucket), runner);
        }

        // (d) Rebuild the dense cache from the paged store.
        let (l, ld) = (self.n_layers, self.latent_dim);
        let mut dense = vec![0.0f32; l * batch_bucket * kv_bucket * ld];
        let mut scratch = vec![0.0f32; kv_bucket * l * ld];
        for (slot, rid) in slots.iter().enumerate() {
            let Some(rid) = rid else { continue };
            let seq = self.seq_of[rid];
            let len = self.store.gather_padded(seq, kv_bucket, &mut scratch);
            for pos in 0..len {
                for layer in 0..l {
                    let src = pos * (l * ld) + layer * ld;
                    let dst = ((layer * batch_bucket + slot) * kv_bucket + pos) * ld;
                    dense[dst..dst + ld].copy_from_slice(&scratch[src..src + ld]);
                }
            }
        }
        let dims = [
            l as i64,
            batch_bucket as i64,
            kv_bucket as i64,
            ld as i64,
        ];
        let cache = crate::runtime::client::literal_from_f32(&dense, &dims)?;
        self.live = Some(LiveBatch {
            batch_bucket,
            kv_bucket,
            slots,
            cache,
        });
        self.sync_cost.push(t0.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    /// Insert `prompt`'s whole, already-synced blocks into the prefix tree
    /// on behalf of request `rid` (dedup is the tree's job).  No-op when
    /// the tree is disabled, the prompt spans less than one block, the
    /// blocks are not fully synced into the paged store yet, or this
    /// request's prefix was already inserted.  Called from recompose for
    /// every freshly-decoding request, and from [`cancel`](Self::cancel)
    /// so a cancelled request's prefill work stays sharable.
    fn insert_prompt_prefix(&mut self, rid: RequestId, prompt: &[i32]) {
        if self.inserted.contains(&rid) {
            return;
        }
        let Some(tree) = self.prefix.as_mut() else {
            return;
        };
        let Some(&seq) = self.seq_of.get(&rid) else {
            return;
        };
        let block_size = self.cfg.block_size;
        let aligned = (prompt.len() / block_size) * block_size;
        let synced = self.synced.get(&rid).copied().unwrap_or(0);
        if aligned == 0 || synced < aligned {
            return;
        }
        let chain = self.store.blocks_of(seq)[..aligned / block_size].to_vec();
        tree.insert(&prompt[..aligned], &chain, &mut self.store);
        self.inserted.insert(rid);
    }

    /// KV-occupancy invariant (debug builds, after every tick): every
    /// cache position below a request's `kv_len()` has been written
    /// **exactly once** — a zero would be the old write hole coming back,
    /// a two would be a slot clobbering valid history.  Positions at or
    /// past `kv_len()` are rejected draft rows awaiting their overwrite
    /// under the write-purity contract; their marks are dropped so the
    /// rewrite by the next correct token registers as the real write.
    #[cfg(debug_assertions)]
    fn debug_check_kv_occupancy(&mut self) {
        // Detect first with immutable borrows only (every active request
        // got its ledger entry in the marking pass of section 4, so `get`
        // cannot miss), so a violation can dump the flight recorder before
        // panicking; truncation below happens only on the clean path.
        let mut violation: Option<String> = None;
        for r in self.batcher.active() {
            let kv = r.kv_len();
            let w = self.kv_written.get(&r.id).map(Vec::as_slice).unwrap_or(&[]);
            if w.len() < kv {
                violation = Some(format!(
                    "request {}: write ledger covers {} positions, kv_len is {kv}",
                    r.id,
                    w.len()
                ));
                break;
            }
            if let Some((pos, &n)) = w.iter().take(kv).enumerate().find(|&(_, &n)| n != 1) {
                violation = Some(format!(
                    "request {}: cache position {pos} written {n} times \
                     (kv_len {kv}) — exact-occupancy violated",
                    r.id
                ));
                break;
            }
        }
        if let Some(msg) = violation {
            self.dump_recorder_on_ledger_failure();
            panic!("{msg}");
        }
        for r in self.batcher.active() {
            let kv = r.kv_len();
            if let Some(w) = self.kv_written.get_mut(&r.id) {
                w.truncate(kv);
            }
        }
    }

    /// Best-effort flight-recorder dump when the debug KV ledger trips, so
    /// the panic message comes with the per-tick history that led to it.
    #[cfg(debug_assertions)]
    fn dump_recorder_on_ledger_failure(&self) {
        let Some(rec) = self.recorder.as_ref() else {
            return;
        };
        let path = std::env::temp_dir().join("flashmla-flight-recorder-crash.json");
        match rec.dump(&path) {
            Ok(()) => crate::log_error!(
                "engine",
                "KV ledger violation — flight recorder dumped to {}",
                path.display()
            ),
            Err(e) => crate::log_error!("engine", "flight recorder dump failed: {e}"),
        }
    }

    /// Paged-store utilization (for dashboards/tests).
    pub fn kv_usage(&self) -> f64 {
        self.store.usage()
    }

    /// Free blocks in the paged store (the cancellation-hygiene tests
    /// compare this against the pool size and the tree's pinned blocks).
    pub fn free_kv_blocks(&self) -> usize {
        self.store.free_blocks()
    }

    pub fn recompositions(&self) -> u64 {
        self.recompositions
    }

    /// Blocks currently pinned by the prefix tree (0 when disabled).
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.as_ref().map(|t| t.cached_blocks()).unwrap_or(0)
    }

    /// The flight recorder, when `flight_recorder_ticks > 0`.
    pub fn flight_recorder(&self) -> Option<&FlightRecorder> {
        self.recorder.as_ref()
    }

    /// Dump the flight recorder ring as JSON to `path`.
    pub fn dump_flight_recorder(&self, path: &Path) -> anyhow::Result<()> {
        match &self.recorder {
            Some(rec) => rec.dump(path),
            None => anyhow::bail!("flight recorder disabled (flight_recorder_ticks = 0)"),
        }
    }

    /// Per-request tick-stamped timeline; survives request termination so
    /// post-run queries (TTFT in ticks, spec acceptance, adopted prefix)
    /// still resolve.
    pub fn timeline(&self, h: RequestHandle) -> Option<&RequestTimeline> {
        self.timelines.get(&h.id())
    }

    /// Requests waiting in the admission queue (the fleet executor's
    /// per-engine load gauge and backpressure signal).
    pub fn queue_depth(&self) -> usize {
        self.batcher.queued()
    }

    /// Requests currently holding batch slots.
    pub fn active_requests(&self) -> usize {
        self.batcher.active().len()
    }

    /// Tokens per paged KV block (routing fingerprints and replication
    /// alignment use the same granularity as the tree).
    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    /// Longest block-aligned prefix of `prompt` this engine's tree already
    /// caches, capped the same way admission caps it (at least one prefill
    /// step always remains).  Read-only: no LRU bump, no stats — fleet
    /// admission charges hit-heavy requests only their unshared suffix
    /// without perturbing the engine's own hit accounting.  0 when the
    /// prefix cache is disabled.
    pub fn peek_prefix_tokens(&self, prompt: &[i32]) -> usize {
        match &self.prefix {
            Some(tree) => {
                let cap = tree.usable_prefix_len(prompt.len());
                tree.peek_match(&prompt[..cap])
            }
            None => 0,
        }
    }

    /// Donor side of fleet prefix replication: the block-aligned tokens of
    /// `prompt`'s cached prefix plus the latents backing them, flattened
    /// position by position (`tokens × n_layers·latent_dim` values).
    ///
    /// Block ids are store-local, so replication ships *data*: the chain
    /// is viewed through a temporary refcounted adoption
    /// (`adopt_chain`/`free_seq` — net zero refcounts) and copied out.
    /// Read-only with respect to the tree (no LRU bump, no stats).
    /// `None` when the tree is disabled or holds no prefix of `prompt`.
    pub fn export_prefix_latents(&mut self, prompt: &[i32]) -> Option<(Vec<i32>, Vec<f32>)> {
        let m = {
            let tree = self.prefix.as_ref()?;
            let cap = tree.usable_prefix_len(prompt.len());
            tree.peek_chain(&prompt[..cap])
        };
        if m.tokens == 0 {
            return None;
        }
        let seq = self.store.adopt_chain(&m.blocks, m.tokens);
        let mut latents = Vec::with_capacity(m.tokens * self.n_layers * self.latent_dim);
        for pos in 0..m.tokens {
            latents.extend_from_slice(self.store.token_latent(seq, pos));
        }
        self.store.free_seq(seq);
        Some((prompt[..m.tokens].to_vec(), latents))
    }

    /// Target side of fleet prefix replication: materialize a chain
    /// exported from another engine (`export_prefix_latents`) into this
    /// engine's paged store and radix tree.  Best-effort — returns the
    /// number of blocks newly adopted, 0 when the tree is disabled, the
    /// prefix is already cached, or the pool has no room for the copy
    /// (replication never starves admission).
    pub fn adopt_replicated_prefix(&mut self, tokens: &[i32], latents: &[f32]) -> usize {
        let Some(tree) = self.prefix.as_mut() else {
            return 0;
        };
        crate::prefixcache::replicate_chain(tree, &mut self.store, tokens, latents)
    }
}
