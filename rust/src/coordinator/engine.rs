//! The decode engine: continuous batching over fixed-shape PJRT artifacts.
//!
//! Hot-path design (see also EXPERIMENTS.md §Perf):
//!
//! * While batch composition and buckets are stable, the engine feeds the
//!   decode artifact its own returned cache literal — zero bookkeeping per
//!   step, the artifact writes each request's new latent in place.
//! * On *recomposition* (request finished / admitted / bucket growth) the
//!   engine syncs the survivors' latents from the live cache literal into
//!   the paged latent store, then rebuilds the dense cache for the new
//!   (batch-bucket, kv-bucket) shape by gathering from the store.
//! * Admission control consults the paged store's block budget, so a
//!   request is only admitted when its full context provably fits.
//!
//! The paged store holds one "super-latent" per token — the concatenation
//! of all layers' latent vectors — so request state survives slot moves
//! and bucket changes without any model re-execution (prefix re-use).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::kvcache::{CacheConfig, PagedLatentCache, SeqId};
use crate::log_info;
use crate::runtime::{DecodeRunner, Runtime};
use crate::util::stats::Welford;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::ServingMetrics;
use super::request::{Request, RequestId, RequestState};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Attention computation mode: "etap" (default) or "flashmla".
    pub kernel: String,
    /// Concurrent batch slots (≤ largest decode batch bucket).
    pub max_slots: usize,
    /// Paged-store capacity in blocks.
    pub kv_blocks: usize,
    /// Tokens per paged block.
    pub block_size: usize,
    /// EOS token id (None = length-only stopping).
    pub eos_token: Option<i32>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            kernel: "etap".into(),
            max_slots: 4,
            kv_blocks: 256,
            block_size: 16,
            eos_token: None,
        }
    }
}

/// Final report of a serving run.
pub struct EngineReport {
    pub outputs: HashMap<RequestId, Vec<i32>>,
    pub metrics: ServingMetrics,
    pub recompositions: u64,
    pub steps: u64,
}

struct LiveBatch {
    batch_bucket: usize,
    kv_bucket: usize,
    /// RequestId per slot (None = padded slot).
    slots: Vec<Option<RequestId>>,
    cache: xla::Literal,
}

/// The serving engine.
pub struct Engine {
    rt: Runtime,
    cfg: EngineConfig,
    batcher: Batcher,
    store: PagedLatentCache,
    seq_of: HashMap<RequestId, SeqId>,
    /// Tokens already synced into the paged store, per request.
    synced: HashMap<RequestId, usize>,
    runners: HashMap<(usize, usize), DecodeRunner>,
    live: Option<LiveBatch>,
    metrics: ServingMetrics,
    outputs: HashMap<RequestId, Vec<i32>>,
    next_id: RequestId,
    recompositions: u64,
    n_layers: usize,
    latent_dim: usize,
    pub sync_cost: Welford,
}

impl Engine {
    /// Build an engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path, cfg: EngineConfig) -> anyhow::Result<Self> {
        let rt = Runtime::cpu(artifacts_dir)?;
        let model = rt
            .manifest()
            .model
            .clone()
            .ok_or_else(|| anyhow::anyhow!("artifacts have no decode model"))?;
        let buckets = rt.manifest().buckets("decode_step", &cfg.kernel);
        anyhow::ensure!(
            !buckets.is_empty(),
            "no decode artifacts for kernel `{}`",
            cfg.kernel
        );
        let mut batch_buckets: Vec<usize> = buckets.iter().map(|&(b, _)| b).collect();
        batch_buckets.sort();
        batch_buckets.dedup();
        let mut kv_buckets: Vec<usize> = buckets.iter().map(|&(_, n)| n).collect();
        kv_buckets.sort();
        kv_buckets.dedup();

        let batcher = Batcher::new(BatcherConfig {
            max_slots: cfg.max_slots.min(*batch_buckets.last().unwrap()),
            batch_buckets,
            kv_buckets,
        })?;
        let store = PagedLatentCache::new(CacheConfig {
            block_size: cfg.block_size,
            latent_dim: model.n_layers * model.latent_dim,
            num_blocks: cfg.kv_blocks,
        });
        Ok(Engine {
            rt,
            batcher,
            store,
            seq_of: HashMap::new(),
            synced: HashMap::new(),
            runners: HashMap::new(),
            live: None,
            metrics: ServingMetrics::new(),
            outputs: HashMap::new(),
            next_id: 1,
            recompositions: 0,
            n_layers: model.n_layers,
            latent_dim: model.latent_dim,
            sync_cost: Welford::new(),
            cfg,
        })
    }

    /// Largest admissible context (biggest kv bucket, minus the write slot).
    pub fn max_context(&self) -> usize {
        self.rt
            .manifest()
            .buckets("decode_step", &self.cfg.kernel)
            .iter()
            .map(|&(_, n)| n)
            .max()
            .unwrap_or(0)
            - 1
    }

    /// Submit a request; returns its id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        let mut r = Request::new(id, prompt, max_new_tokens);
        if let Some(eos) = self.cfg.eos_token {
            r = r.with_eos(eos);
        }
        self.batcher.submit(r);
        id
    }

    /// Run until all submitted work completes; returns the report.
    pub fn run_to_completion(mut self) -> anyhow::Result<EngineReport> {
        while self.batcher.has_work() {
            self.step()?;
        }
        let steps = self.metrics.steps;
        Ok(EngineReport {
            outputs: self.outputs,
            metrics: self.metrics,
            recompositions: self.recompositions,
            steps,
        })
    }

    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// One engine step: reap, admit, (maybe) recompose, execute, advance.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        let t0 = Instant::now();

        // 1. Reap finished requests.
        let finished = self.batcher.reap();
        let mut composition_changed = !finished.is_empty();
        for r in finished {
            self.metrics.on_finish(&r);
            if let Some(seq) = self.seq_of.remove(&r.id) {
                self.store.free_seq(seq);
            }
            self.synced.remove(&r.id);
            self.outputs.insert(r.id, r.generated.clone());
        }

        // 2. Admit from the queue under the block budget.
        let store = &self.store;
        let block_size = self.cfg.block_size;
        let admitted = self.batcher.admit(|r| {
            let blocks_needed = r.max_context().div_ceil(block_size);
            blocks_needed <= store.free_blocks()
        });
        if admitted > 0 {
            composition_changed = true;
        }

        if self.batcher.active().is_empty() {
            return Ok(false); // idle (queue blocked on capacity or empty)
        }

        // 3. Determine buckets; recompose if needed.
        let batch_bucket = self.batcher.batch_bucket();
        let kv_bucket = self.batcher.kv_bucket();
        let needs_rebuild = composition_changed
            || match &self.live {
                None => true,
                Some(l) => l.batch_bucket != batch_bucket || l.kv_bucket != kv_bucket,
            };
        if needs_rebuild {
            self.recompose(batch_bucket, kv_bucket)?;
        }

        // 4. Build step inputs.
        let live = self.live.as_ref().unwrap();
        let b = live.batch_bucket;
        let mut tokens = vec![0i32; b];
        let mut lengths = vec![0i32; b];
        let mut by_id: HashMap<RequestId, usize> = HashMap::new();
        for (slot, rid) in live.slots.iter().enumerate() {
            if let Some(rid) = rid {
                by_id.insert(*rid, slot);
            }
        }
        for r in self.batcher.active() {
            let slot = by_id[&r.id];
            tokens[slot] = r.next_input_token().expect("active request has input");
            lengths[slot] = r.context_len() as i32;
        }

        // 5. Execute.
        let runner = self
            .runners
            .get(&(b, kv_bucket))
            .expect("runner loaded at recompose");
        let (logits, new_cache) = runner.step(&tokens, &live.cache, &lengths)?;
        let vocab = runner.vocab();

        // 6. Advance request state machines.
        let mut new_tokens = 0usize;
        let mut prefill_tokens = 0usize;
        for r in self.batcher.active_mut() {
            let slot = by_id[&r.id];
            let sampled = DecodeRunner::argmax_row(&logits, vocab, slot);
            let was_prefill = r.state == RequestState::Prefilling;
            r.advance(sampled);
            if was_prefill {
                prefill_tokens += 1;
                if r.state != RequestState::Prefilling {
                    // transition emitted the first generated token
                    new_tokens += 1;
                }
            } else {
                new_tokens += 1;
            }
        }
        self.live.as_mut().unwrap().cache = new_cache;

        let active = self.batcher.active().len();
        self.metrics.on_step(
            t0.elapsed(),
            active,
            self.cfg.max_slots,
            new_tokens,
            prefill_tokens,
        );
        Ok(true)
    }

    /// Sync survivors into the paged store, then rebuild the dense cache
    /// for the new bucket shape.
    fn recompose(&mut self, batch_bucket: usize, kv_bucket: usize) -> anyhow::Result<()> {
        let t0 = Instant::now();
        self.recompositions += 1;

        // (a) Sync: pull the live literal once and append unsynced tokens.
        if let Some(live) = self.live.take() {
            let host: Vec<f32> = live
                .cache
                .to_vec()
                .map_err(|e| anyhow::anyhow!("cache to_vec: {e:?}"))?;
            let (l, n, ld) = (self.n_layers, live.kv_bucket, self.latent_dim);
            let b = live.batch_bucket;
            let mut active_len: HashMap<RequestId, usize> = HashMap::new();
            for r in self.batcher.active() {
                active_len.insert(r.id, r.context_len());
            }
            for (slot, rid) in live.slots.iter().enumerate() {
                let Some(rid) = rid else { continue };
                let Some(&ctx) = active_len.get(rid) else { continue };
                let seq = self.seq_of[rid];
                let synced = self.synced.get(rid).copied().unwrap_or(0);
                let mut latent = vec![0.0f32; l * ld];
                for pos in synced..ctx {
                    for layer in 0..l {
                        let off = ((layer * b + slot) * n + pos) * ld;
                        latent[layer * ld..(layer + 1) * ld]
                            .copy_from_slice(&host[off..off + ld]);
                    }
                    self.store
                        .append(seq, &latent)
                        .map_err(|e| anyhow::anyhow!("store append: {e}"))?;
                }
                self.synced.insert(*rid, ctx);
            }
        }

        // (b) Assign slots (stable order = batcher order) and create
        // sequences for newly admitted requests.
        let mut slots: Vec<Option<RequestId>> = vec![None; batch_bucket];
        for (i, r) in self.batcher.active().iter().enumerate() {
            slots[i] = Some(r.id);
        }
        let ids: Vec<RequestId> = self.batcher.active().iter().map(|r| r.id).collect();
        for rid in &ids {
            if !self.seq_of.contains_key(rid) {
                let seq = self.store.new_seq();
                self.seq_of.insert(*rid, seq);
                self.synced.insert(*rid, 0);
            }
        }

        // (c) Load (cached) the runner for this bucket pair.
        if !self.runners.contains_key(&(batch_bucket, kv_bucket)) {
            let runner = DecodeRunner::best(&self.rt, &self.cfg.kernel, batch_bucket, kv_bucket)?;
            log_info!(
                "engine",
                "loaded decode runner {} for bucket (b{batch_bucket}, n{kv_bucket})",
                runner.name()
            );
            self.runners.insert((batch_bucket, kv_bucket), runner);
        }

        // (d) Rebuild the dense cache from the paged store.
        let (l, ld) = (self.n_layers, self.latent_dim);
        let mut dense = vec![0.0f32; l * batch_bucket * kv_bucket * ld];
        let mut scratch = vec![0.0f32; kv_bucket * l * ld];
        for (slot, rid) in slots.iter().enumerate() {
            let Some(rid) = rid else { continue };
            let seq = self.seq_of[rid];
            let len = self.store.gather_padded(seq, kv_bucket, &mut scratch);
            for pos in 0..len {
                for layer in 0..l {
                    let src = pos * (l * ld) + layer * ld;
                    let dst = ((layer * batch_bucket + slot) * kv_bucket + pos) * ld;
                    dense[dst..dst + ld].copy_from_slice(&scratch[src..src + ld]);
                }
            }
        }
        let dims = [
            l as i64,
            batch_bucket as i64,
            kv_bucket as i64,
            ld as i64,
        ];
        let cache = crate::runtime::client::literal_from_f32(&dense, &dims)?;
        self.live = Some(LiveBatch {
            batch_bucket,
            kv_bucket,
            slots,
            cache,
        });
        self.sync_cost.push(t0.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    /// Paged-store utilization (for dashboards/tests).
    pub fn kv_usage(&self) -> f64 {
        self.store.usage()
    }

    pub fn recompositions(&self) -> u64 {
        self.recompositions
    }
}
