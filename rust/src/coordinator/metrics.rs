//! Serving metrics: TTFT, per-token latency, throughput, engine step
//! timing, KV utilization.
//!
//! The struct is the hot-path accumulator (plain fields, no lookups per
//! tick); [`ServingMetrics::registry`] enumerates it into the named
//! [`MetricsRegistry`] on demand, which is what the Prometheus and JSON
//! exporters render.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::obs::{ComputeTally, MetricsRegistry, Summary};
use crate::prefixcache::PrefixStats;
use crate::util::json::Json;
use crate::util::stats::{LatencyHistogram, Welford};

use super::request::Request;

/// Export a latency histogram as a summary with approximate quantiles.
fn hist_summary(h: &LatencyHistogram) -> Summary {
    let count = h.count();
    Summary {
        count,
        sum: h.mean_us() * count as f64,
        mean: h.mean_us(),
        p50: Some(h.percentile_us(50.0)),
        p99: Some(h.percentile_us(99.0)),
        min: h.percentile_us(0.0),
        max: h.percentile_us(100.0),
    }
}

/// Export a Welford accumulator as a summary.  Exact moments, no
/// quantiles (Welford keeps no distribution).
fn welford_summary(w: &Welford) -> Summary {
    Summary {
        count: w.count(),
        sum: w.mean() * w.count() as f64,
        mean: w.mean(),
        p50: None,
        p99: None,
        min: w.min(),
        max: w.max(),
    }
}

/// Aggregated serving metrics.
#[derive(Default)]
pub struct ServingMetrics {
    /// Time to first token.
    pub ttft: LatencyHistogram,
    /// Per-output-token latency (decode cadence).
    pub tpot: LatencyHistogram,
    /// End-to-end request latency.
    pub e2e: LatencyHistogram,
    /// Engine step wall time.
    pub step: LatencyHistogram,
    /// Batch occupancy per step (requests in flight / slots).
    pub occupancy: Welford,
    pub requests_finished: u64,
    pub tokens_generated: u64,
    /// Prompt tokens consumed (chunked: a size-k chunk counts k).
    pub prefill_tokens: u64,
    /// Engine steps in which at least one prompt token was consumed — the
    /// denominator of the chunked-prefill win (tokens per prefill step).
    pub prefill_steps: u64,
    /// Prefill chunks executed (decode slots don't count).
    pub prefill_chunks: u64,
    /// Chunk-size histogram: chunk tokens → occurrences.
    pub chunk_hist: BTreeMap<usize, u64>,
    /// Steps from submission to first generated token, per request — the
    /// wall-clock-free TTFT proxy (engine ticks are the scheduler's clock).
    pub ttft_steps: Welford,
    /// Steps from submission to termination, per request — the end-to-end
    /// companion of [`ttft_steps`](Self::ttft_steps), derived from the
    /// same event stream (`Finished`/`Rejected`) the serving API emits.
    pub e2e_steps: Welford,
    /// Requests refused server-side (unservable peak demand, queue drain)
    /// — the formerly silent `reject_front`/`abort_queued` paths.
    pub requests_rejected: u64,
    /// Requests cancelled by the client (`Engine::cancel`), queued or
    /// running.
    pub requests_cancelled: u64,
    pub steps: u64,
    /// Prefix-cache counters (hit rate, shared/evicted blocks); all zero
    /// when the cache is disabled.
    pub prefix: PrefixStats,
    /// Blocks currently pinned by the prefix tree.
    pub prefix_cached_blocks: u64,
    /// Draft tokens fed through speculative verification chunks.
    pub spec_drafted: u64,
    /// Draft tokens accepted (each one is a decode step the request did
    /// not have to wait a tick for — the steps-saved counter).
    pub spec_accepted: u64,
    /// Verification chunks executed (draft non-empty; plain decode slots
    /// in the same tick don't count).
    pub spec_verify_chunks: u64,
    /// Acceptance histogram: accepted-per-verification → occurrences.
    pub accept_hist: BTreeMap<usize, u64>,
    /// Requests whose speculation was auto-disabled because they sample
    /// (temperature > 0): greedy verification cannot verify sampled
    /// tokens, so the engine records *why* a spec-enabled run drafted
    /// nothing for them (rejection sampling is the ROADMAP follow-on).
    pub spec_disabled_sampling: u64,
    /// Engine ticks in which a greedy decoding request lost its drafting
    /// opportunity because a sampled request shared the batch
    /// (verification ticks return per-position argmaxes, but a sampled
    /// slot needs its full logits row).  Ticks with nothing to suppress
    /// (no greedy decoding co-resident) are not counted.
    pub spec_suppressed_ticks: u64,
    /// KV cache positions terminated requests actually occupied at their
    /// peak (`kv_len` at termination; only requests that generated ≥ 1
    /// token count) — the numerator of
    /// [`kv_slots_per_token`](Self::kv_slots_per_token).
    pub kv_slots_committed: u64,
    /// Tokens terminated requests spanned (`context_len` at termination;
    /// same ≥ 1-generated-token filter) — the denominator of
    /// [`kv_slots_per_token`](Self::kv_slots_per_token).
    pub context_tokens: u64,
    /// Accumulated compute-ledger attribution ([`crate::obs::ledger`]):
    /// modeled FLOPs/bytes per waste category across every tick.  All
    /// zero unless a `LedgerGuard` was live during the run.
    pub compute: ComputeTally,
    elapsed: Duration,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one engine step.  `chunk_sizes` holds the prompt-token count
    /// of every prefill chunk consumed this step (one entry per prefilling
    /// slot; decode slots are not listed).
    pub fn on_step(
        &mut self,
        wall: Duration,
        active: usize,
        slots: usize,
        new_tokens: usize,
        chunk_sizes: &[usize],
    ) {
        self.step.record(wall);
        self.occupancy
            .push(active as f64 / slots.max(1) as f64);
        self.tokens_generated += new_tokens as u64;
        let prefill_tokens: usize = chunk_sizes.iter().sum();
        self.prefill_tokens += prefill_tokens as u64;
        if prefill_tokens > 0 {
            self.prefill_steps += 1;
        }
        for &k in chunk_sizes {
            self.prefill_chunks += 1;
            *self.chunk_hist.entry(k).or_insert(0) += 1;
        }
        self.steps += 1;
        self.elapsed += wall;
    }

    /// Record a request's first generated token landing `steps_waited`
    /// engine ticks after submission.
    pub fn on_first_token_step(&mut self, steps_waited: u64) {
        self.ttft_steps.push(steps_waited as f64);
    }

    /// Record a request terminating (finish, cancel, or reject)
    /// `steps_waited` engine ticks after submission.
    pub fn on_request_done_steps(&mut self, steps_waited: u64) {
        self.e2e_steps.push(steps_waited as f64);
    }

    /// Fold one tick's compute-ledger attribution into the run totals.
    /// The engine calls this every tick; the tally is all-zero when no
    /// ledger guard is live, so the disabled cost is nine f64 adds.
    pub fn on_compute(&mut self, tick: &ComputeTally) {
        self.compute.add(tick);
    }

    /// Record one speculative verification: `drafted` tokens were fed,
    /// the longest plain-decode-matching prefix of `accepted` was kept.
    pub fn on_verify(&mut self, drafted: usize, accepted: usize) {
        debug_assert!(accepted <= drafted);
        self.spec_verify_chunks += 1;
        self.spec_drafted += drafted as u64;
        self.spec_accepted += accepted as u64;
        *self.accept_hist.entry(accepted).or_insert(0) += 1;
    }

    /// Fraction of drafted tokens accepted (0 when nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_drafted as f64
    }

    /// Decode engine steps avoided by speculation: every accepted draft
    /// token is a token the request got without waiting another tick.
    pub fn spec_steps_saved(&self) -> u64 {
        self.spec_accepted
    }

    /// Render the acceptance histogram (`accepted×count`, ascending).
    pub fn accept_hist_summary(&self) -> String {
        self.accept_hist
            .iter()
            .map(|(k, n)| format!("{k}×{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Fold another engine's metrics into this one (multi-engine and
    /// cluster-sim aggregation).  Totals add and histograms merge, so
    /// every derived rate recomputes from the merged totals — e.g.
    /// `merged.acceptance_rate()` equals accepted-over-drafted across the
    /// union of both streams, not an average of the two rates.
    /// `prefix_cached_blocks` is a gauge and sums: blocks pinned across
    /// all merged engines.
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
        self.step.merge(&other.step);
        self.occupancy.merge(&other.occupancy);
        self.requests_finished += other.requests_finished;
        self.tokens_generated += other.tokens_generated;
        self.prefill_tokens += other.prefill_tokens;
        self.prefill_steps += other.prefill_steps;
        self.prefill_chunks += other.prefill_chunks;
        for (&k, &n) in &other.chunk_hist {
            *self.chunk_hist.entry(k).or_insert(0) += n;
        }
        self.ttft_steps.merge(&other.ttft_steps);
        self.e2e_steps.merge(&other.e2e_steps);
        self.requests_rejected += other.requests_rejected;
        self.requests_cancelled += other.requests_cancelled;
        self.steps += other.steps;
        self.prefix.lookups += other.prefix.lookups;
        self.prefix.hits += other.prefix.hits;
        self.prefix.hit_tokens += other.prefix.hit_tokens;
        self.prefix.hit_blocks += other.prefix.hit_blocks;
        self.prefix.inserted_blocks += other.prefix.inserted_blocks;
        self.prefix.evicted_blocks += other.prefix.evicted_blocks;
        self.prefix.evictions += other.prefix.evictions;
        self.prefix_cached_blocks += other.prefix_cached_blocks;
        self.spec_drafted += other.spec_drafted;
        self.spec_accepted += other.spec_accepted;
        self.spec_verify_chunks += other.spec_verify_chunks;
        for (&k, &n) in &other.accept_hist {
            *self.accept_hist.entry(k).or_insert(0) += n;
        }
        self.spec_disabled_sampling += other.spec_disabled_sampling;
        self.spec_suppressed_ticks += other.spec_suppressed_ticks;
        self.kv_slots_committed += other.kv_slots_committed;
        self.context_tokens += other.context_tokens;
        self.compute.add(&other.compute);
        self.elapsed += other.elapsed;
    }

    /// Mean prompt tokens consumed per prefill-bearing step (≈ 1.0 on the
    /// per-token pipeline; the chunked pipeline's speedup factor).
    pub fn prefill_tokens_per_step(&self) -> f64 {
        if self.prefill_steps == 0 {
            return 0.0;
        }
        self.prefill_tokens as f64 / self.prefill_steps as f64
    }

    /// Render the chunk-size histogram (`size×count`, ascending sizes).
    pub fn chunk_hist_summary(&self) -> String {
        self.chunk_hist
            .iter()
            .map(|(k, n)| format!("{k}×{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Cache slots consumed per token served, across terminated requests
    /// that generated at least one token.  Under the exact KV convention
    /// this sits strictly below 1.0 — the final generated token of every
    /// counted request is emitted without a cache write — where the old
    /// skip-one convention burned exactly 1.0 (prompt + generated slots
    /// *plus* one garbage slot per request).  Requests that never
    /// generated (queue rejections, prefill-stage cancellations) are
    /// excluded: they have no emitted-but-unwritten final token, so they
    /// would dilute the invariant toward 1.0.  Benches record it so the
    /// reclaimed slot is visible in the perf trajectory.
    pub fn kv_slots_per_token(&self) -> f64 {
        if self.context_tokens == 0 {
            return 0.0;
        }
        self.kv_slots_committed as f64 / self.context_tokens as f64
    }

    pub fn on_finish(&mut self, r: &Request) {
        self.requests_finished += 1;
        if !r.generated.is_empty() {
            self.kv_slots_committed += r.kv_len() as u64;
            self.context_tokens += r.context_len() as u64;
        }
        if let (Some(first), Some(done)) = (r.first_token_at, r.finished_at) {
            self.ttft
                .record(first.duration_since(r.arrived_at));
            self.e2e.record(done.duration_since(r.arrived_at));
            let n = r.generated.len();
            if n > 1 {
                let per = done.duration_since(first).as_secs_f64() / (n - 1) as f64;
                self.tpot.record_us(per * 1e6);
            }
        }
    }

    /// Decode throughput over engine-busy time (tokens/s).
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.tokens_generated as f64 / self.elapsed.as_secs_f64()
    }

    /// Total token throughput (prefill + decode).
    pub fn total_tokens_per_s(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        (self.tokens_generated + self.prefill_tokens) as f64 / self.elapsed.as_secs_f64()
    }

    /// Fraction of prefix-cache lookups that matched at least one block.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix.lookups == 0 {
            return 0.0;
        }
        self.prefix.hits as f64 / self.prefix.lookups as f64
    }

    /// Prefill steps avoided by prefix sharing (one step per reused token).
    pub fn prefill_steps_saved(&self) -> u64 {
        self.prefix.hit_tokens
    }

    /// Enumerate every metric into the named registry.  Counters carry
    /// the mergeable totals (`…_total`); gauges carry the derived rates,
    /// recomputed from totals so a merged registry equals the registry of
    /// the merged metrics.
    pub fn registry(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        // Counters: monotone totals, sum under `merge`.
        r.counter(
            "flashmla_requests_finished_total",
            "Requests that terminated normally.",
            self.requests_finished,
        );
        r.counter(
            "flashmla_requests_rejected_total",
            "Requests refused server-side.",
            self.requests_rejected,
        );
        r.counter(
            "flashmla_requests_cancelled_total",
            "Requests cancelled by the client.",
            self.requests_cancelled,
        );
        r.counter(
            "flashmla_tokens_generated_total",
            "Output tokens produced.",
            self.tokens_generated,
        );
        r.counter(
            "flashmla_prefill_tokens_total",
            "Prompt tokens consumed by prefill chunks.",
            self.prefill_tokens,
        );
        r.counter(
            "flashmla_prefill_steps_total",
            "Engine steps that consumed at least one prompt token.",
            self.prefill_steps,
        );
        r.counter(
            "flashmla_prefill_chunks_total",
            "Prefill chunks executed.",
            self.prefill_chunks,
        );
        r.counter(
            "flashmla_engine_steps_total",
            "Engine ticks executed.",
            self.steps,
        );
        r.counter(
            "flashmla_spec_drafted_total",
            "Draft tokens fed through verification.",
            self.spec_drafted,
        );
        r.counter(
            "flashmla_spec_accepted_total",
            "Draft tokens accepted (decode steps saved).",
            self.spec_accepted,
        );
        r.counter(
            "flashmla_spec_verify_chunks_total",
            "Speculative verification chunks executed.",
            self.spec_verify_chunks,
        );
        r.counter(
            "flashmla_spec_disabled_sampling_total",
            "Requests whose speculation was auto-disabled (sampling).",
            self.spec_disabled_sampling,
        );
        r.counter(
            "flashmla_spec_suppressed_ticks_total",
            "Ticks where a sampled co-resident suppressed drafting.",
            self.spec_suppressed_ticks,
        );
        r.counter(
            "flashmla_kv_slots_committed_total",
            "KV positions occupied at termination (peak).",
            self.kv_slots_committed,
        );
        r.counter(
            "flashmla_context_tokens_total",
            "Tokens terminated requests spanned.",
            self.context_tokens,
        );
        r.counter(
            "flashmla_prefix_lookups_total",
            "Prefix-cache lookups.",
            self.prefix.lookups,
        );
        r.counter(
            "flashmla_prefix_hits_total",
            "Prefix-cache lookups matching at least one block.",
            self.prefix.hits,
        );
        r.counter(
            "flashmla_prefix_hit_tokens_total",
            "Prompt tokens served from the prefix cache.",
            self.prefix.hit_tokens,
        );
        r.counter(
            "flashmla_prefix_hit_blocks_total",
            "KV blocks adopted from the prefix cache.",
            self.prefix.hit_blocks,
        );
        r.counter(
            "flashmla_prefix_inserted_blocks_total",
            "KV blocks inserted into the prefix cache.",
            self.prefix.inserted_blocks,
        );
        r.counter(
            "flashmla_prefix_evicted_blocks_total",
            "KV blocks evicted from the prefix cache.",
            self.prefix.evicted_blocks,
        );
        r.counter(
            "flashmla_prefix_evictions_total",
            "Prefix-cache eviction passes.",
            self.prefix.evictions,
        );
        r.counter_f64(
            "flashmla_busy_us_total",
            "Engine-busy wall time (µs).",
            self.elapsed.as_secs_f64() * 1e6,
        );
        // Compute-ledger counters: modeled FLOPs/bytes per waste
        // category (`obs::ledger`); f64 but integer-valued, sum under
        // `merge` like every other counter.
        r.counter_f64(
            "flashmla_compute_useful_flops_total",
            "Modeled FLOPs over real KV rows of live tokens.",
            self.compute.useful_flops,
        );
        r.counter_f64(
            "flashmla_compute_bucket_pad_flops_total",
            "Modeled FLOPs over KV-bucket rows past kv_len (incl. scratch).",
            self.compute.bucket_pad_flops,
        );
        r.counter_f64(
            "flashmla_compute_chunk_refeed_flops_total",
            "Modeled FLOPs of fallback wavefront re-feeds.",
            self.compute.chunk_refeed_flops,
        );
        r.counter_f64(
            "flashmla_compute_spec_rejected_flops_total",
            "Modeled FLOPs of verified-but-rejected draft positions.",
            self.compute.spec_rejected_flops,
        );
        r.counter_f64(
            "flashmla_compute_mask_pad_flops_total",
            "Modeled M-dimension WGMMA tile-padding FLOPs.",
            self.compute.mask_pad_flops,
        );
        r.counter_f64(
            "flashmla_compute_useful_bytes_total",
            "Modeled HBM bytes moved for useful work.",
            self.compute.useful_bytes,
        );
        r.counter_f64(
            "flashmla_compute_bucket_pad_bytes_total",
            "Modeled HBM bytes moved for bucket padding and scratch.",
            self.compute.bucket_pad_bytes,
        );
        r.counter_f64(
            "flashmla_compute_chunk_refeed_bytes_total",
            "Modeled HBM bytes moved by fallback re-feeds.",
            self.compute.chunk_refeed_bytes,
        );
        r.counter_f64(
            "flashmla_compute_spec_rejected_bytes_total",
            "Modeled HBM bytes moved for rejected draft positions.",
            self.compute.spec_rejected_bytes,
        );
        // Gauges: instantaneous values and rates derived from the totals.
        r.gauge(
            "flashmla_compute_waste_fraction",
            "Wasted share of issued modeled FLOPs, in [0, 1).",
            self.compute.waste_fraction(),
        );
        r.gauge(
            "flashmla_prefix_cached_blocks",
            "Blocks currently pinned by the prefix tree.",
            self.prefix_cached_blocks as f64,
        );
        r.gauge(
            "flashmla_acceptance_rate",
            "Fraction of drafted tokens accepted.",
            self.acceptance_rate(),
        );
        r.gauge(
            "flashmla_prefill_tokens_per_step",
            "Mean prompt tokens per prefill-bearing step.",
            self.prefill_tokens_per_step(),
        );
        r.gauge(
            "flashmla_kv_slots_per_token",
            "Cache slots consumed per token served.",
            self.kv_slots_per_token(),
        );
        r.gauge(
            "flashmla_decode_tokens_per_s",
            "Decode throughput over engine-busy time.",
            self.decode_tokens_per_s(),
        );
        r.gauge(
            "flashmla_total_tokens_per_s",
            "Total token throughput (prefill + decode).",
            self.total_tokens_per_s(),
        );
        r.gauge(
            "flashmla_prefix_hit_rate",
            "Fraction of prefix lookups that matched.",
            self.prefix_hit_rate(),
        );
        r.gauge(
            "flashmla_occupancy_mean",
            "Mean batch occupancy (active / slots).",
            self.occupancy.mean(),
        );
        // Summaries: histogram-backed carry approximate quantiles,
        // Welford-backed carry exact moments only.
        r.summary(
            "flashmla_ttft_us",
            "Time to first token (µs).",
            hist_summary(&self.ttft),
        );
        r.summary(
            "flashmla_tpot_us",
            "Per-output-token latency (µs).",
            hist_summary(&self.tpot),
        );
        r.summary(
            "flashmla_e2e_us",
            "End-to-end request latency (µs).",
            hist_summary(&self.e2e),
        );
        r.summary(
            "flashmla_step_us",
            "Engine step wall time (µs).",
            hist_summary(&self.step),
        );
        r.summary(
            "flashmla_ttft_steps",
            "Engine ticks from submit to first token.",
            welford_summary(&self.ttft_steps),
        );
        r.summary(
            "flashmla_e2e_steps",
            "Engine ticks from submit to termination.",
            welford_summary(&self.e2e_steps),
        );
        r.summary(
            "flashmla_occupancy",
            "Batch occupancy per step.",
            welford_summary(&self.occupancy),
        );
        // Series: the integer-labeled histogram families.
        r.series(
            "flashmla_prefill_chunk_tokens",
            "Prefill chunk size distribution.",
            "tokens",
            &self.chunk_hist,
        );
        r.series(
            "flashmla_spec_accepted_per_verify",
            "Accepted-per-verification distribution.",
            "accepted",
            &self.accept_hist,
        );
        r
    }

    /// [`registry`](Self::registry) plus the process-global span-duration
    /// profile (`flashmla_span_*`, see `obs::profiler`) — the export
    /// shape.  Kept out of `registry()` itself because the profile is
    /// process state, not per-engine state: it would break the
    /// merged-equals-sum-of-parts contract the registry guarantees.
    fn export_registry(&self) -> MetricsRegistry {
        let mut r = self.registry();
        crate::obs::profiler::export_into(&mut r);
        r
    }

    /// Prometheus text exposition of [`registry`](Self::registry), plus
    /// the span-duration profile when `obs::profiler` collected one.
    pub fn to_prometheus(&self) -> String {
        self.export_registry().to_prometheus()
    }

    /// JSON snapshot of [`registry`](Self::registry) — the schema the
    /// bench harness embeds in every `BENCH_*.json` — plus the
    /// span-duration profile when `obs::profiler` collected one.
    pub fn snapshot_json(&self) -> Json {
        self.export_registry().to_json()
    }

    /// Human-readable dump.
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} tokens={} (prefill {}) steps={} | decode {:.1} tok/s, total {:.1} tok/s | \
             ttft p50 {:.1} ms p99 {:.1} ms | tpot p50 {:.2} ms p99 {:.2} ms | \
             e2e p50 {:.1} ms | step mean {:.2} ms | occupancy {:.0}%",
            self.requests_finished,
            self.tokens_generated,
            self.prefill_tokens,
            self.steps,
            self.decode_tokens_per_s(),
            self.total_tokens_per_s(),
            self.ttft.percentile_us(50.0) / 1e3,
            self.ttft.percentile_us(99.0) / 1e3,
            self.tpot.percentile_us(50.0) / 1e3,
            self.tpot.percentile_us(99.0) / 1e3,
            self.e2e.percentile_us(50.0) / 1e3,
            self.step.mean_us() / 1e3,
            self.occupancy.mean() * 100.0,
        );
        if self.prefill_steps > 0 {
            s.push_str(&format!(
                " | prefill {:.1} tok/step over {} steps, ttft {:.1} steps",
                self.prefill_tokens_per_step(),
                self.prefill_steps,
                self.ttft_steps.mean(),
            ));
        }
        if self.e2e_steps.count() > 0 {
            s.push_str(&format!(" | e2e {:.1} steps/req", self.e2e_steps.mean()));
        }
        if self.context_tokens > 0 {
            s.push_str(&format!(" | kv {:.3} slots/token", self.kv_slots_per_token()));
        }
        if self.requests_rejected + self.requests_cancelled > 0 {
            s.push_str(&format!(
                " | rejected {} cancelled {}",
                self.requests_rejected, self.requests_cancelled,
            ));
        }
        if self.prefix.lookups > 0 {
            s.push_str(&format!(
                " | prefix hits {}/{} ({:.0}%), {} prefill steps saved, \
                 {} blocks cached, {} evicted",
                self.prefix.hits,
                self.prefix.lookups,
                self.prefix_hit_rate() * 100.0,
                self.prefix.hit_tokens,
                self.prefix_cached_blocks,
                self.prefix.evicted_blocks,
            ));
        }
        if self.spec_verify_chunks > 0 {
            s.push_str(&format!(
                " | spec {}/{} drafts accepted ({:.0}%) over {} verifications, \
                 {} decode steps saved",
                self.spec_accepted,
                self.spec_drafted,
                self.acceptance_rate() * 100.0,
                self.spec_verify_chunks,
                self.spec_steps_saved(),
            ));
        }
        if self.spec_disabled_sampling > 0 {
            s.push_str(&format!(
                " | spec auto-off for {} sampled requests ({} ticks suppressed)",
                self.spec_disabled_sampling, self.spec_suppressed_ticks,
            ));
        }
        if self.compute.issued_flops() > 0.0 {
            s.push_str(&format!(
                " | compute {:.2}/{:.2} GFLOP useful/issued (waste {:.0}%: \
                 pad {:.2} + refeed {:.2} + spec {:.2} + mask {:.2})",
                self.compute.useful_flops / 1e9,
                self.compute.issued_flops() / 1e9,
                self.compute.waste_fraction() * 100.0,
                self.compute.bucket_pad_flops / 1e9,
                self.compute.chunk_refeed_flops / 1e9,
                self.compute.spec_rejected_flops / 1e9,
                self.compute.mask_pad_flops / 1e9,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_accounting() {
        let mut m = ServingMetrics::new();
        m.on_step(Duration::from_millis(10), 3, 4, 3, &[1]);
        m.on_step(Duration::from_millis(10), 4, 4, 4, &[]);
        assert_eq!(m.steps, 2);
        assert_eq!(m.tokens_generated, 7);
        assert_eq!(m.prefill_tokens, 1);
        assert_eq!(m.prefill_steps, 1);
        let tps = m.decode_tokens_per_s();
        assert!((tps - 350.0).abs() < 1.0, "tps {tps}");
        assert!((m.occupancy.mean() - 0.875).abs() < 1e-12);
    }

    #[test]
    fn chunk_accounting() {
        let mut m = ServingMetrics::new();
        // A mixed step: two chunks (8 and 3 tokens) plus decode slots.
        m.on_step(Duration::from_millis(1), 4, 4, 2, &[8, 3]);
        m.on_step(Duration::from_millis(1), 4, 4, 4, &[]);
        m.on_step(Duration::from_millis(1), 4, 4, 3, &[8]);
        assert_eq!(m.prefill_tokens, 19);
        assert_eq!(m.prefill_steps, 2);
        assert_eq!(m.prefill_chunks, 3);
        assert_eq!(m.chunk_hist.get(&8), Some(&2));
        assert_eq!(m.chunk_hist.get(&3), Some(&1));
        assert!((m.prefill_tokens_per_step() - 9.5).abs() < 1e-12);
        assert_eq!(m.chunk_hist_summary(), "3×1 8×2");
        m.on_first_token_step(4);
        m.on_first_token_step(2);
        assert!((m.ttft_steps.mean() - 3.0).abs() < 1e-12);
        let s = m.report();
        assert!(s.contains("prefill 9.5 tok/step"), "report: {s}");
        assert!(s.contains("ttft 3.0 steps"), "report: {s}");
    }

    #[test]
    fn finish_records_latencies() {
        let mut m = ServingMetrics::new();
        let mut r = Request::new(1, vec![1], 2);
        r.state = super::super::request::RequestState::Prefilling;
        r.advance(5);
        std::thread::sleep(Duration::from_millis(2));
        r.advance(6);
        m.on_finish(&r);
        assert_eq!(m.requests_finished, 1);
        assert!(m.e2e.count() == 1);
        assert!(m.tpot.count() == 1);
        assert!(m.tpot.mean_us() >= 1000.0, "tpot {}", m.tpot.mean_us());
        // Exact KV accounting: 1 prompt + 2 generated tokens, but only 2
        // latents ever written (the final token is never fed).
        assert_eq!(m.kv_slots_committed, 2);
        assert_eq!(m.context_tokens, 3);
        assert!((m.kv_slots_per_token() - 2.0 / 3.0).abs() < 1e-12);
        assert!(m.kv_slots_per_token() < 1.0, "the reclaimed slot shows");
        assert!(m.report().contains("kv 0.667 slots/token"));
    }

    #[test]
    fn report_formats() {
        let m = ServingMetrics::new();
        let s = m.report();
        assert!(s.contains("tok/s"));
        assert!(!s.contains("prefix"), "no prefix section when idle");
    }

    #[test]
    fn spec_accounting_and_report() {
        let mut m = ServingMetrics::new();
        m.on_verify(4, 4);
        m.on_verify(4, 1);
        m.on_verify(2, 0);
        assert_eq!(m.spec_verify_chunks, 3);
        assert_eq!(m.spec_drafted, 10);
        assert_eq!(m.spec_accepted, 5);
        assert_eq!(m.spec_steps_saved(), 5);
        assert!((m.acceptance_rate() - 0.5).abs() < 1e-12);
        assert_eq!(m.accept_hist_summary(), "0×1 1×1 4×1");
        let s = m.report();
        assert!(s.contains("spec 5/10 drafts accepted (50%)"), "report: {s}");
        assert!(s.contains("3 verifications"), "report: {s}");
        let quiet = ServingMetrics::new().report();
        assert!(!quiet.contains("spec"), "no spec section when idle");
    }

    #[test]
    fn merge_rates_equal_recomputed_from_totals() {
        // The satellite contract: merged rates must equal the rates of the
        // concatenated streams, never an average of per-engine rates.
        let mut a = ServingMetrics::new();
        a.on_step(Duration::from_millis(10), 2, 4, 3, &[8, 3]);
        a.on_step(Duration::from_millis(30), 4, 4, 4, &[]);
        a.on_verify(4, 4);
        a.on_verify(4, 2);
        a.on_first_token_step(4);
        a.on_request_done_steps(10);
        a.requests_rejected = 2;
        a.spec_disabled_sampling = 1;
        a.prefix.lookups = 3;
        a.prefix.hits = 1;
        a.kv_slots_committed = 10;
        a.context_tokens = 12;
        let mut b = ServingMetrics::new();
        b.on_step(Duration::from_millis(20), 1, 4, 9, &[5]);
        b.on_verify(2, 0);
        b.on_first_token_step(8);
        b.on_first_token_step(6);
        b.on_request_done_steps(20);
        b.on_request_done_steps(30);
        b.requests_rejected = 1;
        b.requests_cancelled = 3;
        b.spec_suppressed_ticks = 5;
        b.prefix.lookups = 1;
        b.prefix.hits = 1;
        b.prefix_cached_blocks = 7;
        b.kv_slots_committed = 5;
        b.context_tokens = 6;
        a.on_compute(&ComputeTally {
            useful_flops: 100.0,
            bucket_pad_flops: 50.0,
            mask_pad_flops: 25.0,
            useful_bytes: 1000.0,
            bucket_pad_bytes: 500.0,
            ..ComputeTally::ZERO
        });
        b.on_compute(&ComputeTally {
            useful_flops: 40.0,
            chunk_refeed_flops: 10.0,
            spec_rejected_flops: 5.0,
            useful_bytes: 400.0,
            chunk_refeed_bytes: 100.0,
            spec_rejected_bytes: 50.0,
            ..ComputeTally::ZERO
        });

        let mut merged = ServingMetrics::new();
        merged.merge(&a);
        merged.merge(&b);

        // Acceptance: (6 + 0) / (8 + 2), not avg(0.75, 0.0).
        assert!((merged.acceptance_rate() - 6.0 / 10.0).abs() < 1e-12);
        assert_eq!(merged.spec_verify_chunks, 3);
        assert_eq!(merged.spec_steps_saved(), 6);
        assert_eq!(merged.accept_hist_summary(), "0×1 2×1 4×1");
        // Prefill tokens/step: (11 + 5) / (1 + 1).
        assert!((merged.prefill_tokens_per_step() - 8.0).abs() < 1e-12);
        // Throughput over merged busy time: 16 tokens / 60 ms.
        assert!(
            (merged.decode_tokens_per_s() - 16.0 / 0.06).abs() < 1e-6,
            "tps {}",
            merged.decode_tokens_per_s()
        );
        // Prefix hit rate from summed counters: 2/4.
        assert!((merged.prefix_hit_rate() - 0.5).abs() < 1e-12);
        // KV slots/token from summed totals: (10 + 5) / (12 + 6).
        assert!((merged.kv_slots_per_token() - 15.0 / 18.0).abs() < 1e-12);
        assert_eq!(merged.prefix_cached_blocks, 7);
        // Welford-backed stats match pushing every sample into one stream.
        assert_eq!(merged.ttft_steps.count(), 3);
        assert!((merged.ttft_steps.mean() - 6.0).abs() < 1e-12);
        // Event-derived counters: totals add, histograms concatenate.
        assert_eq!(merged.e2e_steps.count(), 3);
        assert!((merged.e2e_steps.mean() - 20.0).abs() < 1e-12);
        assert_eq!(merged.requests_rejected, 3);
        assert_eq!(merged.requests_cancelled, 3);
        assert_eq!(merged.spec_disabled_sampling, 1);
        assert_eq!(merged.spec_suppressed_ticks, 5);
        let occ_mean = (2.0 / 4.0 + 4.0 / 4.0 + 1.0 / 4.0) / 3.0;
        assert!((merged.occupancy.mean() - occ_mean).abs() < 1e-12);
        assert_eq!(merged.steps, 3);
        assert_eq!(merged.chunk_hist_summary(), "3×1 5×1 8×1");
        // Histogram-backed latencies count every step.
        assert_eq!(merged.step.count(), 3);

        // Registry parity, for every registry-backed metric: merged
        // counters are the sums of the per-engine counters, and merged
        // gauges equal the rates recomputed from those summed totals —
        // the registry of the merge is the merge of the registries.
        let (ra, rb, rm) = (a.registry(), b.registry(), merged.registry());
        assert_eq!(ra.entries().len(), rm.entries().len());
        for e in rm.entries() {
            use crate::obs::MetricValue;
            let (va, vb) = (
                ra.get(&e.name).expect("metric in a"),
                rb.get(&e.name).expect("metric in b"),
            );
            match (&e.value, va, vb) {
                (MetricValue::Counter(m), MetricValue::Counter(x), MetricValue::Counter(y)) => {
                    assert!((m - (x + y)).abs() < 1e-6, "{}: {m} != {x} + {y}", e.name);
                }
                (MetricValue::Summary(m), MetricValue::Summary(x), MetricValue::Summary(y)) => {
                    assert_eq!(m.count, x.count + y.count, "{} count", e.name);
                    assert!(
                        (m.sum - (x.sum + y.sum)).abs() < 1e-6 * m.sum.abs().max(1.0),
                        "{} sum", e.name
                    );
                }
                (MetricValue::Series { points: m, .. }, MetricValue::Series { points: x, .. },
                 MetricValue::Series { points: y, .. }) => {
                    let total = |pts: &[(u64, u64)]| pts.iter().map(|&(_, n)| n).sum::<u64>();
                    assert_eq!(total(m), total(x) + total(y), "{} samples", e.name);
                }
                (MetricValue::Gauge(_), _, _) => {
                    // Gauges are derived; checked against recomputation below.
                }
                _ => panic!("metric {} changed kind across merge", e.name),
            }
        }
        let gauge = |name: &str| match rm.get(name) {
            Some(crate::obs::MetricValue::Gauge(v)) => *v,
            other => panic!("{name}: {other:?}"),
        };
        assert!((gauge("flashmla_acceptance_rate") - merged.acceptance_rate()).abs() < 1e-12);
        assert!((gauge("flashmla_prefix_hit_rate") - merged.prefix_hit_rate()).abs() < 1e-12);
        assert!(
            (gauge("flashmla_kv_slots_per_token") - merged.kv_slots_per_token()).abs() < 1e-12
        );
        assert!(
            (gauge("flashmla_prefill_tokens_per_step") - merged.prefill_tokens_per_step()).abs()
                < 1e-12
        );
        assert!((gauge("flashmla_occupancy_mean") - merged.occupancy.mean()).abs() < 1e-12);
        // Compute totals add, and the waste gauge recomputes from the
        // merged totals: (issued − useful) / issued = (230 − 140) / 230.
        assert_eq!(merged.compute.useful_flops, 140.0);
        assert_eq!(merged.compute.issued_flops(), 230.0);
        assert_eq!(merged.compute.total_bytes(), 2050.0);
        assert!(
            (gauge("flashmla_compute_waste_fraction") - merged.compute.waste_fraction()).abs()
                < 1e-12
        );
        assert!((merged.compute.waste_fraction() - 90.0 / 230.0).abs() < 1e-12);

        // Merging an empty stream changes nothing.
        let snapshot = merged.report();
        merged.merge(&ServingMetrics::new());
        assert_eq!(merged.report(), snapshot);
    }

    #[test]
    fn exporters_render_the_registry() {
        let mut m = ServingMetrics::new();
        m.on_step(Duration::from_millis(10), 2, 4, 3, &[8]);
        m.on_verify(4, 2);
        m.on_first_token_step(3);
        let prom = m.to_prometheus();
        assert!(prom.contains("# TYPE flashmla_tokens_generated_total counter"));
        assert!(prom.contains("flashmla_tokens_generated_total 3\n"));
        assert!(prom.contains("flashmla_step_us_count 1\n"));
        assert!(prom.contains("flashmla_prefill_chunk_tokens{tokens=\"8\"} 1\n"));
        let snap =
            crate::util::json::parse(&m.snapshot_json().dump()).expect("snapshot parses");
        assert_eq!(
            snap.get("counters")
                .get("flashmla_spec_accepted_total")
                .as_usize(),
            Some(2)
        );
        assert_eq!(
            snap.get("summaries")
                .get("flashmla_ttft_steps")
                .get("count")
                .as_usize(),
            Some(1)
        );
        // Welford-backed summaries export no quantiles.
        assert_eq!(
            snap.get("summaries").get("flashmla_ttft_steps").get("p50"),
            &Json::Null
        );
        assert_eq!(
            snap.get("series")
                .get("flashmla_spec_accepted_per_verify")
                .get("2")
                .as_usize(),
            Some(1)
        );
    }

    #[test]
    fn compute_counters_export_and_surface_in_report() {
        let mut m = ServingMetrics::new();
        assert!(!m.report().contains("compute"), "quiet with no ledger data");
        m.on_compute(&ComputeTally {
            useful_flops: 1e9,
            bucket_pad_flops: 2e9,
            mask_pad_flops: 1e9,
            useful_bytes: 1e6,
            bucket_pad_bytes: 2e6,
            ..ComputeTally::ZERO
        });
        let s = m.report();
        assert!(s.contains("compute 1.00/4.00 GFLOP useful/issued"), "report: {s}");
        assert!(s.contains("waste 75%"), "report: {s}");
        let snap =
            crate::util::json::parse(&m.snapshot_json().dump()).expect("snapshot parses");
        assert_eq!(
            snap.get("counters")
                .get("flashmla_compute_useful_flops_total")
                .as_f64(),
            Some(1e9)
        );
        assert_eq!(
            snap.get("gauges")
                .get("flashmla_compute_waste_fraction")
                .as_f64(),
            Some(0.75)
        );
        let prom = m.to_prometheus();
        assert!(
            prom.contains("# TYPE flashmla_compute_useful_flops_total counter"),
            "prometheus: {prom}"
        );
    }

    #[test]
    fn lifecycle_counters_surface_in_report() {
        let mut m = ServingMetrics::new();
        assert!(!m.report().contains("rejected"), "quiet when idle");
        assert!(!m.report().contains("steps/req"), "no e2e-steps section yet");
        m.requests_rejected = 2;
        m.requests_cancelled = 1;
        m.on_request_done_steps(6);
        m.on_request_done_steps(10);
        m.spec_disabled_sampling = 3;
        m.spec_suppressed_ticks = 4;
        let s = m.report();
        assert!(s.contains("rejected 2 cancelled 1"), "report: {s}");
        assert!(s.contains("e2e 8.0 steps/req"), "report: {s}");
        assert!(
            s.contains("spec auto-off for 3 sampled requests (4 ticks suppressed)"),
            "report: {s}"
        );
    }

    #[test]
    fn prefix_counters_surface_in_report() {
        let mut m = ServingMetrics::new();
        m.prefix.lookups = 4;
        m.prefix.hits = 3;
        m.prefix.hit_tokens = 96;
        m.prefix_cached_blocks = 6;
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(m.prefill_steps_saved(), 96);
        let s = m.report();
        assert!(s.contains("prefix hits 3/4"), "report: {s}");
        assert!(s.contains("96 prefill steps saved"), "report: {s}");
    }
}
