//! Engine step events: the streaming surface of the serving API.
//!
//! Every [`Engine::step`](super::Engine::step) appends events to an
//! internal buffer; clients drain it with
//! [`Engine::poll_events`](super::Engine::poll_events) after each step
//! (or batch of steps) and correlate by request id.  The event stream is
//! complete: concatenating a request's [`Token`](StepEvent::Token)
//! payloads reproduces its final output exactly, so a streaming client
//! never needs the report.  [`Engine::take_finished`](super::Engine::take_finished)
//! is the non-consuming complement — terminal results with full token
//! vectors, without giving up the engine like `into_report` does.
//!
//! Ordering guarantees, per step:
//!
//! * `Finished`/`Rejected` for requests leaving the engine come first
//!   (reap/reject run at the head of the tick);
//! * `Admitted` precedes any `Token` of the same request;
//! * `Token` events of one request appear in generation order (a
//!   speculative verification emits several in one step);
//! * a request's `Finished` arrives on the step *after* its last token —
//!   the tick that reaps it and frees its KV blocks.

use std::fmt;

use super::request::{FinishReason, RequestId};

/// Why the server refused a queued request (never admitted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Peak KV demand exceeds the whole block pool — unservable even with
    /// every other sequence evicted.
    KvCapacity,
    /// Queue drained server-side (`Engine::abort_queued`).
    Shutdown,
    /// Fleet-level load shedding: admission would exceed the target
    /// engine's bounded queue or the tenant's in-flight token budget.
    /// Overload surfaces here, as an event at submit time, instead of as
    /// unbounded queue growth.
    Backpressure,
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::KvCapacity => write!(f, "kv-capacity"),
            RejectReason::Shutdown => write!(f, "shutdown"),
            RejectReason::Backpressure => write!(f, "backpressure"),
        }
    }
}

/// One engine-loop event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepEvent {
    /// The request left the queue for a batch slot.
    Admitted { id: RequestId },
    /// One generated token (streamed in generation order).
    Token { id: RequestId, token: i32 },
    /// The request completed (budget, stop token, or cancellation) and its
    /// KV blocks were released.
    Finished { id: RequestId, reason: FinishReason },
    /// The server refused the queued request; it never held a slot.
    Rejected { id: RequestId, reason: RejectReason },
}

impl StepEvent {
    /// The request this event belongs to.
    pub fn id(&self) -> RequestId {
        match *self {
            StepEvent::Admitted { id }
            | StepEvent::Token { id, .. }
            | StepEvent::Finished { id, .. }
            | StepEvent::Rejected { id, .. } => id,
        }
    }
}

/// Terminal result handed out by `Engine::take_finished`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FinishedRequest {
    pub id: RequestId,
    /// The full generated sequence (empty for rejected / queued-cancelled
    /// requests that never produced a token).
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
}

/// An engine-stamped event from a multi-engine fleet: the same
/// [`StepEvent`] stream the solo engine emits, tagged with the index of
/// the engine that produced it.  Ids are fleet-level — the
/// `FleetExecutor` translates each engine's local ids before stamping —
/// so one consumer loop can drive any number of engines with the solo
/// `match` arms unchanged (`docs/fleet-serving.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetEvent {
    /// Index of the engine that emitted (or, for door rejections, would
    /// have served) the request; stable for the executor's lifetime.
    pub engine: usize,
    pub event: StepEvent,
}

impl FleetEvent {
    /// The fleet-level request id this event belongs to.
    pub fn id(&self) -> RequestId {
        self.event.id()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_id_extraction() {
        assert_eq!(StepEvent::Admitted { id: 3 }.id(), 3);
        assert_eq!(StepEvent::Token { id: 4, token: 9 }.id(), 4);
        assert_eq!(
            StepEvent::Finished {
                id: 5,
                reason: FinishReason::Length
            }
            .id(),
            5
        );
        assert_eq!(
            StepEvent::Rejected {
                id: 6,
                reason: RejectReason::KvCapacity
            }
            .id(),
            6
        );
    }

    #[test]
    fn reject_reason_renders() {
        assert_eq!(RejectReason::KvCapacity.to_string(), "kv-capacity");
        assert_eq!(RejectReason::Shutdown.to_string(), "shutdown");
        assert_eq!(RejectReason::Backpressure.to_string(), "backpressure");
    }

    #[test]
    fn fleet_event_stamps_engine_and_forwards_id() {
        let ev = FleetEvent {
            engine: 2,
            event: StepEvent::Token { id: 41, token: 7 },
        };
        assert_eq!(ev.engine, 2);
        assert_eq!(ev.id(), 41);
    }
}
