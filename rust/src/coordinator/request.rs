//! Request lifecycle: the state machine every request moves through.

use std::time::Instant;

/// Unique request handle.
pub type RequestId = u64;

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    Length,
    /// Produced the EOS token.
    Eos,
    /// Rejected or evicted by the server.
    Aborted,
}

/// Lifecycle states (monotone forward).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// Admitted, waiting for a batch slot.
    Queued,
    /// In a slot, consuming prompt tokens (prefill-as-decode).
    Prefilling,
    /// In a slot, generating.
    Decoding,
    /// Done (see `finish_reason`).
    Finished,
}

/// What one speculative verification did to a request (per-tick, fed to
/// the serving metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifyOutcome {
    /// Draft tokens fed through the verification chunk.
    pub drafted: usize,
    /// Longest draft prefix that matched plain greedy decode.
    pub accepted: usize,
    /// Tokens appended to `generated` — always `accepted + 1`: the
    /// chunk's first argmax is the plain-decode token and always lands,
    /// and a draft token is only counted accepted if its follow-up argmax
    /// was actually emitted.
    pub emitted: usize,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub eos_token: Option<i32>,
    pub state: RequestState,
    pub generated: Vec<i32>,
    /// Prompt tokens already consumed (prefill cursor).
    pub prefill_pos: usize,
    /// Draft tokens proposed for this tick's speculative verification
    /// (decoding requests only; empty when speculation is off or nothing
    /// matched).  Set by the engine before planning, consumed by
    /// [`apply_verification`](Self::apply_verification) — the field never
    /// carries state across ticks.
    pub draft: Vec<i32>,
    pub finish_reason: Option<FinishReason>,
    pub arrived_at: Instant,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens > 0, "must request at least one token");
        Request {
            id,
            prompt,
            max_new_tokens,
            eos_token: None,
            state: RequestState::Queued,
            generated: Vec::new(),
            prefill_pos: 0,
            draft: Vec::new(),
            finish_reason: None,
            arrived_at: Instant::now(),
            first_token_at: None,
            finished_at: None,
        }
    }

    pub fn with_eos(mut self, eos: i32) -> Self {
        self.eos_token = Some(eos);
        self
    }

    /// Total KV positions this request needs at peak.
    pub fn max_context(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    /// Current KV length (tokens cached so far).
    pub fn context_len(&self) -> usize {
        self.prefill_pos + self.generated.len()
    }

    /// The token to feed the model this step, or None if waiting on state.
    pub fn next_input_token(&self) -> Option<i32> {
        match self.state {
            RequestState::Prefilling => self.prompt.get(self.prefill_pos).copied(),
            RequestState::Decoding => self
                .generated
                .last()
                .copied()
                .or_else(|| self.prompt.last().copied()),
            _ => None,
        }
    }

    /// Advance after one engine step in which this request consumed a slot.
    /// `sampled` is the token sampled from this step's logits.
    pub fn advance(&mut self, sampled: i32) {
        match self.state {
            RequestState::Prefilling => {
                self.prefill_pos += 1;
                if self.prefill_pos == self.prompt.len() {
                    // The logits of the last prompt token ARE the first
                    // generated token (standard decode semantics).
                    self.push_generated(sampled);
                    if self.state != RequestState::Finished {
                        self.state = RequestState::Decoding;
                    }
                }
            }
            RequestState::Decoding => self.push_generated(sampled),
            ref s => panic!("advance() in state {s:?}"),
        }
    }

    /// Advance after consuming a multi-token prefill chunk of `k` prompt
    /// tokens in one engine step (chunked prefill).  `sampled` is the token
    /// sampled from the logits of the chunk's *last* prompt token; it is
    /// only meaningful — and only consumed — when the chunk reaches the end
    /// of the prompt, where those logits are the first generated token
    /// (identical semantics to `advance` with k = 1).
    pub fn advance_chunk(&mut self, k: usize, sampled: i32) {
        assert_eq!(
            self.state,
            RequestState::Prefilling,
            "advance_chunk() outside prefill"
        );
        assert!(k >= 1, "empty chunk");
        assert!(
            self.prefill_pos + k <= self.prompt.len(),
            "chunk of {k} overruns prompt ({} of {})",
            self.prefill_pos,
            self.prompt.len()
        );
        self.prefill_pos += k;
        if self.prefill_pos == self.prompt.len() {
            self.push_generated(sampled);
            if self.state != RequestState::Finished {
                self.state = RequestState::Decoding;
            }
        }
    }

    /// Apply a speculative verification result (greedy acceptance).
    ///
    /// The engine fed this request's chunk `[x₀, d₁ … d_fed]` — the normal
    /// decode input plus the first `fed` tokens of [`draft`](Self::draft)
    /// — and `argmaxes[j]` is the backend's greedy argmax after the j-th
    /// chunk token (`argmaxes[0]` is exactly what plain decode would have
    /// sampled this tick).  Acceptance walks the draft in order: `dᵢ` is
    /// accepted iff it equals `argmaxes[i-1]`, i.e. the token plain decode
    /// would have produced — which inductively makes `argmaxes[i]` the
    /// next plain-decode token, so outputs are bit-identical to the
    /// non-speculative pipeline.  The walk stops at the first mismatch and
    /// whenever the request finishes (EOS or budget), exactly where plain
    /// decode would have stopped.
    ///
    /// Clears the draft; returns the bookkeeping the metrics need.
    pub fn apply_verification(&mut self, fed: usize, argmaxes: &[i32]) -> VerifyOutcome {
        assert_eq!(
            self.state,
            RequestState::Decoding,
            "apply_verification() outside decode"
        );
        assert!(fed <= self.draft.len(), "fed {fed} of {}", self.draft.len());
        assert_eq!(
            argmaxes.len(),
            fed + 1,
            "need one argmax per chunk position"
        );
        let mut accepted = 0usize;
        let mut emitted = 1usize;
        self.push_generated(argmaxes[0]);
        for i in 0..fed {
            if self.is_finished() || self.draft[i] != argmaxes[i] {
                break;
            }
            accepted += 1;
            emitted += 1;
            self.push_generated(argmaxes[i + 1]);
        }
        self.draft.clear();
        VerifyOutcome {
            drafted: fed,
            accepted,
            emitted,
        }
    }

    fn push_generated(&mut self, tok: i32) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
        if Some(tok) == self.eos_token {
            self.finish(FinishReason::Eos);
        } else if self.generated.len() >= self.max_new_tokens {
            self.finish(FinishReason::Length);
        }
    }

    pub fn finish(&mut self, reason: FinishReason) {
        self.state = RequestState::Finished;
        self.finish_reason = Some(reason);
        self.finished_at = Some(Instant::now());
    }

    pub fn is_finished(&self) -> bool {
        self.state == RequestState::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_then_decode_flow() {
        let mut r = Request::new(1, vec![10, 11, 12], 2);
        r.state = RequestState::Prefilling;
        assert_eq!(r.next_input_token(), Some(10));
        r.advance(99);
        assert_eq!(r.state, RequestState::Prefilling);
        assert_eq!(r.next_input_token(), Some(11));
        r.advance(99);
        r.advance(42); // last prompt token → first generated token is 42
        assert_eq!(r.state, RequestState::Decoding);
        assert_eq!(r.generated, vec![42]);
        assert_eq!(r.next_input_token(), Some(42));
        r.advance(43);
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Length));
        assert_eq!(r.generated, vec![42, 43]);
    }

    #[test]
    fn eos_stops_early() {
        let mut r = Request::new(1, vec![5], 10).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance(7);
        assert_eq!(r.state, RequestState::Decoding);
        r.advance(0); // EOS
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Eos));
        assert_eq!(r.generated, vec![7, 0]);
    }

    #[test]
    fn eos_as_first_generated_token() {
        let mut r = Request::new(1, vec![5, 6], 10).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance(99);
        r.advance(0); // first sampled token is EOS
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Eos));
    }

    #[test]
    fn max_context_accounts_prompt_and_budget() {
        let r = Request::new(1, vec![1, 2, 3], 5);
        assert_eq!(r.max_context(), 8);
        assert_eq!(r.context_len(), 0);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 1);
    }

    #[test]
    fn chunked_advance_matches_per_token() {
        // advance_chunk(k) must land in the same state as k advance()s.
        let mut per_tok = Request::new(1, vec![10, 11, 12, 13, 14], 3);
        per_tok.state = RequestState::Prefilling;
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(42); // last prompt token → first generated is 42

        let mut chunked = Request::new(1, vec![10, 11, 12, 13, 14], 3);
        chunked.state = RequestState::Prefilling;
        chunked.advance_chunk(3, 99); // mid-prompt: sampled discarded
        assert_eq!(chunked.state, RequestState::Prefilling);
        assert_eq!(chunked.generated, Vec::<i32>::new());
        chunked.advance_chunk(2, 42); // reaches the end: 42 emitted
        assert_eq!(chunked.state, per_tok.state);
        assert_eq!(chunked.generated, per_tok.generated);
        assert_eq!(chunked.prefill_pos, per_tok.prefill_pos);
        assert_eq!(chunked.context_len(), per_tok.context_len());
    }

    #[test]
    fn whole_prompt_chunk_emits_first_token() {
        let mut r = Request::new(1, vec![5, 6, 7], 1).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance_chunk(3, 8);
        assert!(r.is_finished(), "budget 1 satisfied by the chunk's token");
        assert_eq!(r.generated, vec![8]);
    }

    #[test]
    #[should_panic(expected = "overruns prompt")]
    fn chunk_overrun_rejected() {
        let mut r = Request::new(1, vec![5, 6], 4);
        r.state = RequestState::Prefilling;
        r.advance_chunk(3, 0);
    }

    /// Decode `r` one token at a time with a scripted token stream (the
    /// plain-decode oracle for the verification tests).
    fn plain_decode(mut r: Request, stream: &[i32]) -> Request {
        for &t in stream {
            if r.is_finished() {
                break;
            }
            r.advance(t);
        }
        r
    }

    fn decoding(prompt: usize, budget: usize) -> Request {
        let mut r = Request::new(1, (0..prompt as i32).collect(), budget);
        r.state = RequestState::Prefilling;
        for _ in 0..prompt - 1 {
            r.advance(99);
        }
        r.advance(10); // first generated token
        assert_eq!(r.state, RequestState::Decoding);
        r
    }

    #[test]
    fn verification_full_acceptance_matches_plain_decode() {
        // Plain decode would emit 20, 21, 22 next; the draft guesses all
        // three, so one verification emits all of them plus nothing extra.
        let mut spec = decoding(3, 8);
        spec.draft = vec![20, 21, 22];
        let out = spec.apply_verification(3, &[20, 21, 22, 23]);
        assert_eq!(
            out,
            VerifyOutcome {
                drafted: 3,
                accepted: 3,
                emitted: 4
            }
        );
        let plain = plain_decode(decoding(3, 8), &[20, 21, 22, 23]);
        assert_eq!(spec.generated, plain.generated);
        assert_eq!(spec.context_len(), plain.context_len());
        assert!(spec.draft.is_empty(), "draft consumed");
    }

    #[test]
    fn verification_rejects_at_first_mismatch() {
        let mut spec = decoding(3, 8);
        spec.draft = vec![20, 77, 22]; // 77 is wrong: argmax after 20 is 21
        let out = spec.apply_verification(3, &[20, 21, 22, 23]);
        assert_eq!(out.accepted, 1, "only the prefix before the mismatch");
        assert_eq!(out.emitted, 2);
        // Tokens after the mismatch are discarded even though the backend
        // computed argmaxes for them (they came from a wrong history).
        let plain = plain_decode(decoding(3, 8), &[20, 21]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    fn verification_without_draft_is_plain_advance() {
        let mut spec = decoding(3, 8);
        let out = spec.apply_verification(0, &[42]);
        assert_eq!(
            out,
            VerifyOutcome {
                drafted: 0,
                accepted: 0,
                emitted: 1
            }
        );
        let plain = plain_decode(decoding(3, 8), &[42]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    fn verification_stops_at_eos_mid_chunk() {
        // argmax 0 is EOS: everything after it must be dropped, even
        // matching draft tokens — exactly where plain decode stops.
        let mut spec = decoding(3, 8);
        spec.eos_token = Some(0);
        spec.draft = vec![0, 5];
        let out = spec.apply_verification(2, &[0, 5, 6]);
        assert_eq!(out.accepted, 0);
        assert_eq!(out.emitted, 1);
        assert!(spec.is_finished());
        assert_eq!(spec.finish_reason, Some(FinishReason::Eos));
        let mut plain = decoding(3, 8);
        plain.eos_token = Some(0);
        let plain = plain_decode(plain, &[0, 5, 6]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    fn verification_stops_at_token_budget() {
        // Budget 2 and one token already generated: only one more token
        // may land no matter how much of the draft matches.
        let mut spec = decoding(3, 2);
        spec.draft = vec![20, 21, 22];
        let out = spec.apply_verification(3, &[20, 21, 22, 23]);
        assert_eq!(out.accepted, 0);
        assert_eq!(out.emitted, 1);
        assert!(spec.is_finished());
        assert_eq!(spec.finish_reason, Some(FinishReason::Length));
        let plain = plain_decode(decoding(3, 2), &[20, 21, 22, 23]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    #[should_panic(expected = "outside decode")]
    fn verification_rejected_while_prefilling() {
        let mut r = Request::new(1, vec![1, 2], 4);
        r.state = RequestState::Prefilling;
        r.apply_verification(0, &[7]);
    }

    #[test]
    fn single_token_budget() {
        let mut r = Request::new(1, vec![3], 1);
        r.state = RequestState::Prefilling;
        r.advance(8);
        assert!(r.is_finished());
        assert_eq!(r.generated, vec![8]);
    }
}
