//! Request lifecycle: the state machine every request moves through.

use std::time::Instant;

/// Unique request handle.
pub type RequestId = u64;

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    Length,
    /// Produced the EOS token.
    Eos,
    /// Rejected or evicted by the server.
    Aborted,
}

/// Lifecycle states (monotone forward).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// Admitted, waiting for a batch slot.
    Queued,
    /// In a slot, consuming prompt tokens (prefill-as-decode).
    Prefilling,
    /// In a slot, generating.
    Decoding,
    /// Done (see `finish_reason`).
    Finished,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub eos_token: Option<i32>,
    pub state: RequestState,
    pub generated: Vec<i32>,
    /// Prompt tokens already consumed (prefill cursor).
    pub prefill_pos: usize,
    pub finish_reason: Option<FinishReason>,
    pub arrived_at: Instant,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens > 0, "must request at least one token");
        Request {
            id,
            prompt,
            max_new_tokens,
            eos_token: None,
            state: RequestState::Queued,
            generated: Vec::new(),
            prefill_pos: 0,
            finish_reason: None,
            arrived_at: Instant::now(),
            first_token_at: None,
            finished_at: None,
        }
    }

    pub fn with_eos(mut self, eos: i32) -> Self {
        self.eos_token = Some(eos);
        self
    }

    /// Total KV positions this request needs at peak.
    pub fn max_context(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    /// Current KV length (tokens cached so far).
    pub fn context_len(&self) -> usize {
        self.prefill_pos + self.generated.len()
    }

    /// The token to feed the model this step, or None if waiting on state.
    pub fn next_input_token(&self) -> Option<i32> {
        match self.state {
            RequestState::Prefilling => self.prompt.get(self.prefill_pos).copied(),
            RequestState::Decoding => self
                .generated
                .last()
                .copied()
                .or_else(|| self.prompt.last().copied()),
            _ => None,
        }
    }

    /// Advance after one engine step in which this request consumed a slot.
    /// `sampled` is the token sampled from this step's logits.
    pub fn advance(&mut self, sampled: i32) {
        match self.state {
            RequestState::Prefilling => {
                self.prefill_pos += 1;
                if self.prefill_pos == self.prompt.len() {
                    // The logits of the last prompt token ARE the first
                    // generated token (standard decode semantics).
                    self.push_generated(sampled);
                    if self.state != RequestState::Finished {
                        self.state = RequestState::Decoding;
                    }
                }
            }
            RequestState::Decoding => self.push_generated(sampled),
            ref s => panic!("advance() in state {s:?}"),
        }
    }

    /// Advance after consuming a multi-token prefill chunk of `k` prompt
    /// tokens in one engine step (chunked prefill).  `sampled` is the token
    /// sampled from the logits of the chunk's *last* prompt token; it is
    /// only meaningful — and only consumed — when the chunk reaches the end
    /// of the prompt, where those logits are the first generated token
    /// (identical semantics to `advance` with k = 1).
    pub fn advance_chunk(&mut self, k: usize, sampled: i32) {
        assert_eq!(
            self.state,
            RequestState::Prefilling,
            "advance_chunk() outside prefill"
        );
        assert!(k >= 1, "empty chunk");
        assert!(
            self.prefill_pos + k <= self.prompt.len(),
            "chunk of {k} overruns prompt ({} of {})",
            self.prefill_pos,
            self.prompt.len()
        );
        self.prefill_pos += k;
        if self.prefill_pos == self.prompt.len() {
            self.push_generated(sampled);
            if self.state != RequestState::Finished {
                self.state = RequestState::Decoding;
            }
        }
    }

    fn push_generated(&mut self, tok: i32) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
        if Some(tok) == self.eos_token {
            self.finish(FinishReason::Eos);
        } else if self.generated.len() >= self.max_new_tokens {
            self.finish(FinishReason::Length);
        }
    }

    pub fn finish(&mut self, reason: FinishReason) {
        self.state = RequestState::Finished;
        self.finish_reason = Some(reason);
        self.finished_at = Some(Instant::now());
    }

    pub fn is_finished(&self) -> bool {
        self.state == RequestState::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_then_decode_flow() {
        let mut r = Request::new(1, vec![10, 11, 12], 2);
        r.state = RequestState::Prefilling;
        assert_eq!(r.next_input_token(), Some(10));
        r.advance(99);
        assert_eq!(r.state, RequestState::Prefilling);
        assert_eq!(r.next_input_token(), Some(11));
        r.advance(99);
        r.advance(42); // last prompt token → first generated token is 42
        assert_eq!(r.state, RequestState::Decoding);
        assert_eq!(r.generated, vec![42]);
        assert_eq!(r.next_input_token(), Some(42));
        r.advance(43);
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Length));
        assert_eq!(r.generated, vec![42, 43]);
    }

    #[test]
    fn eos_stops_early() {
        let mut r = Request::new(1, vec![5], 10).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance(7);
        assert_eq!(r.state, RequestState::Decoding);
        r.advance(0); // EOS
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Eos));
        assert_eq!(r.generated, vec![7, 0]);
    }

    #[test]
    fn eos_as_first_generated_token() {
        let mut r = Request::new(1, vec![5, 6], 10).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance(99);
        r.advance(0); // first sampled token is EOS
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Eos));
    }

    #[test]
    fn max_context_accounts_prompt_and_budget() {
        let r = Request::new(1, vec![1, 2, 3], 5);
        assert_eq!(r.max_context(), 8);
        assert_eq!(r.context_len(), 0);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 1);
    }

    #[test]
    fn chunked_advance_matches_per_token() {
        // advance_chunk(k) must land in the same state as k advance()s.
        let mut per_tok = Request::new(1, vec![10, 11, 12, 13, 14], 3);
        per_tok.state = RequestState::Prefilling;
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(42); // last prompt token → first generated is 42

        let mut chunked = Request::new(1, vec![10, 11, 12, 13, 14], 3);
        chunked.state = RequestState::Prefilling;
        chunked.advance_chunk(3, 99); // mid-prompt: sampled discarded
        assert_eq!(chunked.state, RequestState::Prefilling);
        assert_eq!(chunked.generated, Vec::<i32>::new());
        chunked.advance_chunk(2, 42); // reaches the end: 42 emitted
        assert_eq!(chunked.state, per_tok.state);
        assert_eq!(chunked.generated, per_tok.generated);
        assert_eq!(chunked.prefill_pos, per_tok.prefill_pos);
        assert_eq!(chunked.context_len(), per_tok.context_len());
    }

    #[test]
    fn whole_prompt_chunk_emits_first_token() {
        let mut r = Request::new(1, vec![5, 6, 7], 1).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance_chunk(3, 8);
        assert!(r.is_finished(), "budget 1 satisfied by the chunk's token");
        assert_eq!(r.generated, vec![8]);
    }

    #[test]
    #[should_panic(expected = "overruns prompt")]
    fn chunk_overrun_rejected() {
        let mut r = Request::new(1, vec![5, 6], 4);
        r.state = RequestState::Prefilling;
        r.advance_chunk(3, 0);
    }

    #[test]
    fn single_token_budget() {
        let mut r = Request::new(1, vec![3], 1);
        r.state = RequestState::Prefilling;
        r.advance(8);
        assert!(r.is_finished());
        assert_eq!(r.generated, vec![8]);
    }
}
