//! Request lifecycle: the public submission types (builder, handle,
//! sampling parameters) and the state machine every request moves through.

use std::time::Instant;

/// Unique request id (the value inside a [`RequestHandle`]).
pub type RequestId = u64;

/// Opaque handle returned by `Engine::submit`.  Carries the id used to
/// correlate [`StepEvent`](super::StepEvent)s, cancel the request, and
/// look up its output in the final report.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestHandle(RequestId);

impl RequestHandle {
    pub(crate) fn new(id: RequestId) -> Self {
        RequestHandle(id)
    }

    pub fn id(self) -> RequestId {
        self.0
    }
}

/// Per-request sampling parameters (the greedy default reproduces the
/// pre-handle pipeline bit-for-bit).
///
/// Determinism contract: a sampled request draws exactly one PRNG value
/// per emitted token from its own [`crate::util::rng::Rng`] stream seeded
/// by `seed`, and the backend's logits rows depend only on the request's
/// own history (slot isolation) — so equal `(prompt, params)` pairs
/// produce bit-identical outputs regardless of batch composition, engine
/// config, or what else is being served.  That is why `seed` is
/// **mandatory** whenever `temperature > 0`: an unseeded sampled request
/// could never be replayed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature.  `0.0` (the default) means greedy argmax; any
    /// positive value samples from the (top-k/top-p filtered) softmax.
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens before sampling
    /// (`0` = disabled).  `top_k = 1` is exactly greedy.
    pub top_k: usize,
    /// Nucleus cutoff: keep the smallest set of tokens whose cumulative
    /// probability reaches `top_p` (`1.0` = disabled).
    pub top_p: f32,
    /// Per-request PRNG seed; required when `temperature > 0`.
    pub seed: Option<u64>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self::greedy()
    }
}

impl SamplingParams {
    /// Greedy argmax — the bit-identical default.
    pub fn greedy() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: None,
        }
    }

    /// Temperature sampling with the mandatory reproducibility seed.
    pub fn sampled(temperature: f32, seed: u64) -> Self {
        SamplingParams {
            temperature,
            top_k: 0,
            top_p: 1.0,
            seed: Some(seed),
        }
    }

    pub fn with_top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    pub fn with_top_p(mut self, p: f32) -> Self {
        self.top_p = p;
        self
    }

    /// Greedy requests never touch a PRNG (and stay eligible for
    /// speculative verification).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.temperature.is_finite() && self.temperature >= 0.0,
            "temperature must be finite and ≥ 0, got {}",
            self.temperature
        );
        anyhow::ensure!(
            self.top_p > 0.0 && self.top_p <= 1.0,
            "top_p must be in (0, 1], got {}",
            self.top_p
        );
        anyhow::ensure!(
            self.is_greedy() || self.seed.is_some(),
            "sampled requests (temperature > 0) require a seed — \
             unseeded runs could never be replayed bit-identically"
        );
        Ok(())
    }
}

/// Builder for one generation request (the argument of `Engine::submit`).
///
/// ```ignore
/// let h = engine.submit(
///     GenerationRequest::new(prompt, 64)
///         .stop_token(eos)
///         .sampling(SamplingParams::sampled(0.8, 42).with_top_k(40)),
/// );
/// ```
#[derive(Clone, Debug)]
pub struct GenerationRequest {
    prompt: Vec<i32>,
    max_new_tokens: usize,
    stop_tokens: Vec<i32>,
    sampling: SamplingParams,
}

impl GenerationRequest {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens > 0, "must request at least one token");
        GenerationRequest {
            prompt,
            max_new_tokens,
            stop_tokens: Vec::new(),
            sampling: SamplingParams::greedy(),
        }
    }

    /// Add one stop token (generation finishes when any stop token is
    /// emitted; the emitted stop token is kept, EOS-style).
    pub fn stop_token(mut self, token: i32) -> Self {
        if !self.stop_tokens.contains(&token) {
            self.stop_tokens.push(token);
        }
        self
    }

    /// Add several stop tokens at once.
    pub fn stop_tokens(mut self, tokens: &[i32]) -> Self {
        for &t in tokens {
            self = self.stop_token(t);
        }
        self
    }

    /// Set the sampling parameters (validated here, at the earliest
    /// failure point — an invalid request never reaches the queue).
    pub fn sampling(mut self, params: SamplingParams) -> Self {
        params.validate().expect("invalid sampling params");
        self.sampling = params;
        self
    }

    /// The prompt tokens (read-only).  The fleet executor routes and
    /// validates against the prompt before the request ever reaches an
    /// engine, so the builder exposes it.
    pub fn prompt(&self) -> &[i32] {
        &self.prompt
    }

    /// The generation budget (read-only), used for admission charging.
    pub fn max_new_tokens(&self) -> usize {
        self.max_new_tokens
    }

    /// Materialize the engine-internal request.
    pub(crate) fn into_request(self, id: RequestId) -> Request {
        let mut r = Request::new(id, self.prompt, self.max_new_tokens);
        r.stop_tokens = self.stop_tokens;
        r.sampling = self.sampling;
        r
    }
}

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    Length,
    /// Produced a stop token.
    Eos,
    /// Rejected or evicted by the server.
    Aborted,
    /// Cancelled by the client (`Engine::cancel`).
    Cancelled,
}

/// Lifecycle states (monotone forward).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// Admitted, waiting for a batch slot.
    Queued,
    /// In a slot, consuming prompt tokens (prefill-as-decode).
    Prefilling,
    /// In a slot, generating.
    Decoding,
    /// Done (see `finish_reason`).
    Finished,
}

/// What one speculative verification did to a request (per-tick, fed to
/// the serving metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifyOutcome {
    /// Draft tokens fed through the verification chunk.
    pub drafted: usize,
    /// Longest draft prefix that matched plain greedy decode.
    pub accepted: usize,
    /// Tokens appended to `generated` — always `accepted + 1`: the
    /// chunk's first argmax is the plain-decode token and always lands,
    /// and a draft token is only counted accepted if its follow-up argmax
    /// was actually emitted.
    pub emitted: usize,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Generation stops when any of these is emitted (the engine folds
    /// its config-level EOS token in at submit).
    pub stop_tokens: Vec<i32>,
    /// How this request's tokens are drawn from the logits row.
    pub sampling: SamplingParams,
    pub state: RequestState,
    pub generated: Vec<i32>,
    /// Prompt tokens already consumed (prefill cursor).
    pub prefill_pos: usize,
    /// Draft tokens proposed for this tick's speculative verification
    /// (decoding requests only; empty when speculation is off or nothing
    /// matched).  Set by the engine before planning, consumed by
    /// [`apply_verification`](Self::apply_verification) — the field never
    /// carries state across ticks.
    pub draft: Vec<i32>,
    pub finish_reason: Option<FinishReason>,
    pub arrived_at: Instant,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens > 0, "must request at least one token");
        Request {
            id,
            prompt,
            max_new_tokens,
            stop_tokens: Vec::new(),
            sampling: SamplingParams::greedy(),
            state: RequestState::Queued,
            generated: Vec::new(),
            prefill_pos: 0,
            draft: Vec::new(),
            finish_reason: None,
            arrived_at: Instant::now(),
            first_token_at: None,
            finished_at: None,
        }
    }

    /// Add an EOS-style stop token.
    pub fn with_eos(mut self, eos: i32) -> Self {
        if !self.stop_tokens.contains(&eos) {
            self.stop_tokens.push(eos);
        }
        self
    }

    /// Total tokens this request spans at peak (prompt + full budget).
    pub fn max_context(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    /// KV positions this request needs at peak.  One less than
    /// [`max_context`](Self::max_context): the final generated token is
    /// emitted but never fed back, so its latent is never written.
    pub fn max_kv(&self) -> usize {
        self.prompt.len() + self.max_new_tokens - 1
    }

    /// Tokens this request spans so far: prompt consumed + generated.
    /// This is a *token count*, not a cache length — the newest generated
    /// token has been sampled but not yet fed, so its latent does not
    /// exist anywhere.  Use [`kv_len`](Self::kv_len) for anything that
    /// addresses cache positions.
    pub fn context_len(&self) -> usize {
        self.prefill_pos + self.generated.len()
    }

    /// Latents actually written to the KV cache for this request — the
    /// exact convention.  Every *fed* token's latent is written at its
    /// sequence position: prompt token `i` at position `i`, generated
    /// token `j` at position `prompt.len() + j`.  The newest generated
    /// token is sampled from the previous position's logits and is not
    /// fed (and not written) until the next step, so it never counts:
    ///
    /// * prefilling: `prefill_pos` (generated is empty);
    /// * decoding/finished with `g` generated tokens: `prefill_pos + g - 1`.
    ///
    /// The next write for this request always lands at exactly `kv_len()`,
    /// and attention after that write covers exactly `kv_len() + 1` rows —
    /// no skipped slot, no garbage row.
    pub fn kv_len(&self) -> usize {
        self.prefill_pos + self.generated.len().saturating_sub(1)
    }

    /// The token to feed the model this step, or None if waiting on state.
    pub fn next_input_token(&self) -> Option<i32> {
        match self.state {
            RequestState::Prefilling => self.prompt.get(self.prefill_pos).copied(),
            RequestState::Decoding => {
                // The Prefilling→Decoding transition pushes the first
                // generated token, so `generated` is provably non-empty
                // here; a stale-token fallback would silently re-feed
                // `prompt.last()` and corrupt the cache convention.
                debug_assert!(
                    !self.generated.is_empty(),
                    "decoding request {} has no generated token to feed",
                    self.id
                );
                self.generated.last().copied()
            }
            _ => None,
        }
    }

    /// Advance after one engine step in which this request consumed a slot.
    /// `sampled` is the token sampled from this step's logits.
    pub fn advance(&mut self, sampled: i32) {
        match self.state {
            RequestState::Prefilling => {
                self.prefill_pos += 1;
                if self.prefill_pos == self.prompt.len() {
                    // The logits of the last prompt token ARE the first
                    // generated token (standard decode semantics).
                    self.push_generated(sampled);
                    if self.state != RequestState::Finished {
                        self.state = RequestState::Decoding;
                    }
                }
            }
            RequestState::Decoding => self.push_generated(sampled),
            ref s => panic!("advance() in state {s:?}"),
        }
    }

    /// Advance after consuming a multi-token prefill chunk of `k` prompt
    /// tokens in one engine step (chunked prefill).  `sampled` is the token
    /// sampled from the logits of the chunk's *last* prompt token; it is
    /// only meaningful — and only consumed — when the chunk reaches the end
    /// of the prompt, where those logits are the first generated token
    /// (identical semantics to `advance` with k = 1).
    pub fn advance_chunk(&mut self, k: usize, sampled: i32) {
        assert_eq!(
            self.state,
            RequestState::Prefilling,
            "advance_chunk() outside prefill"
        );
        assert!(k >= 1, "empty chunk");
        assert!(
            self.prefill_pos + k <= self.prompt.len(),
            "chunk of {k} overruns prompt ({} of {})",
            self.prefill_pos,
            self.prompt.len()
        );
        self.prefill_pos += k;
        if self.prefill_pos == self.prompt.len() {
            self.push_generated(sampled);
            if self.state != RequestState::Finished {
                self.state = RequestState::Decoding;
            }
        }
    }

    /// Apply a speculative verification result (greedy acceptance).
    ///
    /// The engine fed this request's chunk `[x₀, d₁ … d_fed]` — the normal
    /// decode input plus the first `fed` tokens of [`draft`](Self::draft)
    /// — and `argmaxes[j]` is the backend's greedy argmax after the j-th
    /// chunk token (`argmaxes[0]` is exactly what plain decode would have
    /// sampled this tick).  Acceptance walks the draft in order: `dᵢ` is
    /// accepted iff it equals `argmaxes[i-1]`, i.e. the token plain decode
    /// would have produced — which inductively makes `argmaxes[i]` the
    /// next plain-decode token, so outputs are bit-identical to the
    /// non-speculative pipeline.  The walk stops at the first mismatch and
    /// whenever the request finishes (EOS or budget), exactly where plain
    /// decode would have stopped.
    ///
    /// Clears the draft; returns the bookkeeping the metrics need.
    pub fn apply_verification(&mut self, fed: usize, argmaxes: &[i32]) -> VerifyOutcome {
        assert_eq!(
            self.state,
            RequestState::Decoding,
            "apply_verification() outside decode"
        );
        assert!(fed <= self.draft.len(), "fed {fed} of {}", self.draft.len());
        assert_eq!(
            argmaxes.len(),
            fed + 1,
            "need one argmax per chunk position"
        );
        let mut accepted = 0usize;
        let mut emitted = 1usize;
        self.push_generated(argmaxes[0]);
        for i in 0..fed {
            if self.is_finished() || self.draft[i] != argmaxes[i] {
                break;
            }
            accepted += 1;
            emitted += 1;
            self.push_generated(argmaxes[i + 1]);
        }
        self.draft.clear();
        VerifyOutcome {
            drafted: fed,
            accepted,
            emitted,
        }
    }

    fn push_generated(&mut self, tok: i32) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
        if self.stop_tokens.contains(&tok) {
            self.finish(FinishReason::Eos);
        } else if self.generated.len() >= self.max_new_tokens {
            self.finish(FinishReason::Length);
        }
    }

    pub fn finish(&mut self, reason: FinishReason) {
        self.state = RequestState::Finished;
        self.finish_reason = Some(reason);
        self.finished_at = Some(Instant::now());
    }

    pub fn is_finished(&self) -> bool {
        self.state == RequestState::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_then_decode_flow() {
        let mut r = Request::new(1, vec![10, 11, 12], 2);
        r.state = RequestState::Prefilling;
        assert_eq!(r.next_input_token(), Some(10));
        r.advance(99);
        assert_eq!(r.state, RequestState::Prefilling);
        assert_eq!(r.next_input_token(), Some(11));
        r.advance(99);
        r.advance(42); // last prompt token → first generated token is 42
        assert_eq!(r.state, RequestState::Decoding);
        assert_eq!(r.generated, vec![42]);
        assert_eq!(r.next_input_token(), Some(42));
        r.advance(43);
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Length));
        assert_eq!(r.generated, vec![42, 43]);
    }

    #[test]
    fn eos_stops_early() {
        let mut r = Request::new(1, vec![5], 10).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance(7);
        assert_eq!(r.state, RequestState::Decoding);
        r.advance(0); // EOS
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Eos));
        assert_eq!(r.generated, vec![7, 0]);
    }

    #[test]
    fn eos_as_first_generated_token() {
        let mut r = Request::new(1, vec![5, 6], 10).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance(99);
        r.advance(0); // first sampled token is EOS
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Eos));
    }

    #[test]
    fn any_stop_token_in_the_list_stops() {
        let mut r = Request::new(1, vec![5], 10).with_eos(0).with_eos(3);
        r.state = RequestState::Prefilling;
        r.advance(7);
        r.advance(3); // second stop token fires too
        assert!(r.is_finished());
        assert_eq!(r.finish_reason, Some(FinishReason::Eos));
        assert_eq!(r.generated, vec![7, 3]);
    }

    #[test]
    fn max_context_accounts_prompt_and_budget() {
        let r = Request::new(1, vec![1, 2, 3], 5);
        assert_eq!(r.max_context(), 8);
        assert_eq!(r.context_len(), 0);
        // The final generated token is never fed, so peak KV is one less.
        assert_eq!(r.max_kv(), 7);
    }

    #[test]
    fn kv_len_counts_only_fed_tokens() {
        // The exact-convention walk: kv_len is always the number of tokens
        // fed so far, and the next write position.  context_len (token
        // count) runs exactly one ahead once generation starts.
        let mut r = Request::new(1, vec![10, 11, 12], 4);
        r.state = RequestState::Prefilling;
        assert_eq!(r.kv_len(), 0);
        r.advance(99); // fed prompt[0] → latent at 0
        assert_eq!((r.kv_len(), r.context_len()), (1, 1));
        r.advance(99); // fed prompt[1] → latent at 1
        r.advance(42); // fed prompt[2] → latent at 2, emits g0 (unfed)
        assert_eq!(r.state, RequestState::Decoding);
        assert_eq!((r.kv_len(), r.context_len()), (3, 4));
        r.advance(43); // fed g0 → latent at 3 = prompt.len(), emits g1
        assert_eq!((r.kv_len(), r.context_len()), (4, 5));
        r.advance(44);
        r.advance(45); // budget reached; g3 sampled but never fed
        assert!(r.is_finished());
        assert_eq!(r.kv_len(), 6);
        assert_eq!(r.kv_len(), r.max_kv());
        assert_eq!(r.context_len(), r.max_context());
    }

    #[test]
    fn kv_len_through_chunks_and_verification() {
        // advance_chunk: kv_len is the prefill cursor until the prompt
        // completes, then trails context_len by exactly one.
        let mut r = Request::new(1, vec![1, 2, 3, 4, 5], 8);
        r.state = RequestState::Prefilling;
        r.advance_chunk(3, 0);
        assert_eq!(r.kv_len(), 3);
        r.advance_chunk(2, 42);
        assert_eq!((r.kv_len(), r.context_len()), (5, 6));
        // Verification: emitted tokens advance kv_len by exactly the
        // count of chunk positions whose input was valid (1 + accepted),
        // which is the store's post-rollback length.
        r.draft = vec![20, 77];
        let out = r.apply_verification(2, &[20, 21, 22]);
        assert_eq!(out.emitted, 2);
        assert_eq!((r.kv_len(), r.context_len()), (7, 8));
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 1);
    }

    #[test]
    fn chunked_advance_matches_per_token() {
        // advance_chunk(k) must land in the same state as k advance()s.
        let mut per_tok = Request::new(1, vec![10, 11, 12, 13, 14], 3);
        per_tok.state = RequestState::Prefilling;
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(99);
        per_tok.advance(42); // last prompt token → first generated is 42

        let mut chunked = Request::new(1, vec![10, 11, 12, 13, 14], 3);
        chunked.state = RequestState::Prefilling;
        chunked.advance_chunk(3, 99); // mid-prompt: sampled discarded
        assert_eq!(chunked.state, RequestState::Prefilling);
        assert_eq!(chunked.generated, Vec::<i32>::new());
        chunked.advance_chunk(2, 42); // reaches the end: 42 emitted
        assert_eq!(chunked.state, per_tok.state);
        assert_eq!(chunked.generated, per_tok.generated);
        assert_eq!(chunked.prefill_pos, per_tok.prefill_pos);
        assert_eq!(chunked.context_len(), per_tok.context_len());
    }

    #[test]
    fn whole_prompt_chunk_emits_first_token() {
        let mut r = Request::new(1, vec![5, 6, 7], 1).with_eos(0);
        r.state = RequestState::Prefilling;
        r.advance_chunk(3, 8);
        assert!(r.is_finished(), "budget 1 satisfied by the chunk's token");
        assert_eq!(r.generated, vec![8]);
    }

    #[test]
    #[should_panic(expected = "overruns prompt")]
    fn chunk_overrun_rejected() {
        let mut r = Request::new(1, vec![5, 6], 4);
        r.state = RequestState::Prefilling;
        r.advance_chunk(3, 0);
    }

    /// Decode `r` one token at a time with a scripted token stream (the
    /// plain-decode oracle for the verification tests).
    fn plain_decode(mut r: Request, stream: &[i32]) -> Request {
        for &t in stream {
            if r.is_finished() {
                break;
            }
            r.advance(t);
        }
        r
    }

    fn decoding(prompt: usize, budget: usize) -> Request {
        let mut r = Request::new(1, (0..prompt as i32).collect(), budget);
        r.state = RequestState::Prefilling;
        for _ in 0..prompt - 1 {
            r.advance(99);
        }
        r.advance(10); // first generated token
        assert_eq!(r.state, RequestState::Decoding);
        r
    }

    #[test]
    fn verification_full_acceptance_matches_plain_decode() {
        // Plain decode would emit 20, 21, 22 next; the draft guesses all
        // three, so one verification emits all of them plus nothing extra.
        let mut spec = decoding(3, 8);
        spec.draft = vec![20, 21, 22];
        let out = spec.apply_verification(3, &[20, 21, 22, 23]);
        assert_eq!(
            out,
            VerifyOutcome {
                drafted: 3,
                accepted: 3,
                emitted: 4
            }
        );
        let plain = plain_decode(decoding(3, 8), &[20, 21, 22, 23]);
        assert_eq!(spec.generated, plain.generated);
        assert_eq!(spec.context_len(), plain.context_len());
        assert!(spec.draft.is_empty(), "draft consumed");
    }

    #[test]
    fn verification_rejects_at_first_mismatch() {
        let mut spec = decoding(3, 8);
        spec.draft = vec![20, 77, 22]; // 77 is wrong: argmax after 20 is 21
        let out = spec.apply_verification(3, &[20, 21, 22, 23]);
        assert_eq!(out.accepted, 1, "only the prefix before the mismatch");
        assert_eq!(out.emitted, 2);
        // Tokens after the mismatch are discarded even though the backend
        // computed argmaxes for them (they came from a wrong history).
        let plain = plain_decode(decoding(3, 8), &[20, 21]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    fn verification_without_draft_is_plain_advance() {
        let mut spec = decoding(3, 8);
        let out = spec.apply_verification(0, &[42]);
        assert_eq!(
            out,
            VerifyOutcome {
                drafted: 0,
                accepted: 0,
                emitted: 1
            }
        );
        let plain = plain_decode(decoding(3, 8), &[42]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    fn verification_stops_at_eos_mid_chunk() {
        // argmax 0 is EOS: everything after it must be dropped, even
        // matching draft tokens — exactly where plain decode stops.
        let mut spec = decoding(3, 8);
        spec.stop_tokens = vec![0];
        spec.draft = vec![0, 5];
        let out = spec.apply_verification(2, &[0, 5, 6]);
        assert_eq!(out.accepted, 0);
        assert_eq!(out.emitted, 1);
        assert!(spec.is_finished());
        assert_eq!(spec.finish_reason, Some(FinishReason::Eos));
        let mut plain = decoding(3, 8);
        plain.stop_tokens = vec![0];
        let plain = plain_decode(plain, &[0, 5, 6]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    fn verification_stops_at_token_budget() {
        // Budget 2 and one token already generated: only one more token
        // may land no matter how much of the draft matches.
        let mut spec = decoding(3, 2);
        spec.draft = vec![20, 21, 22];
        let out = spec.apply_verification(3, &[20, 21, 22, 23]);
        assert_eq!(out.accepted, 0);
        assert_eq!(out.emitted, 1);
        assert!(spec.is_finished());
        assert_eq!(spec.finish_reason, Some(FinishReason::Length));
        let plain = plain_decode(decoding(3, 2), &[20, 21, 22, 23]);
        assert_eq!(spec.generated, plain.generated);
    }

    #[test]
    #[should_panic(expected = "outside decode")]
    fn verification_rejected_while_prefilling() {
        let mut r = Request::new(1, vec![1, 2], 4);
        r.state = RequestState::Prefilling;
        r.apply_verification(0, &[7]);
    }

    #[test]
    fn single_token_budget() {
        let mut r = Request::new(1, vec![3], 1);
        r.state = RequestState::Prefilling;
        r.advance(8);
        assert!(r.is_finished());
        assert_eq!(r.generated, vec![8]);
    }

    #[test]
    fn builder_carries_stops_and_sampling() {
        let spec = GenerationRequest::new(vec![1, 2, 3], 5)
            .stop_token(0)
            .stop_tokens(&[7, 0]) // dedup
            .sampling(SamplingParams::sampled(0.8, 42).with_top_k(4).with_top_p(0.9));
        let r = spec.into_request(9);
        assert_eq!(r.id, 9);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.stop_tokens, vec![0, 7]);
        assert_eq!(r.sampling.temperature, 0.8);
        assert_eq!(r.sampling.top_k, 4);
        assert_eq!(r.sampling.top_p, 0.9);
        assert_eq!(r.sampling.seed, Some(42));
        assert!(!r.sampling.is_greedy());
    }

    #[test]
    fn builder_defaults_are_greedy_and_stopless() {
        let r = GenerationRequest::new(vec![4], 2).into_request(1);
        assert!(r.stop_tokens.is_empty());
        assert!(r.sampling.is_greedy());
        assert_eq!(r.sampling, SamplingParams::greedy());
    }

    #[test]
    #[should_panic(expected = "invalid sampling params")]
    fn sampled_without_seed_rejected() {
        GenerationRequest::new(vec![1], 2).sampling(SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            seed: None,
        });
    }

    #[test]
    fn sampling_params_validate() {
        assert!(SamplingParams::greedy().validate().is_ok());
        assert!(SamplingParams::sampled(1.0, 7).validate().is_ok());
        assert!(SamplingParams {
            temperature: -1.0,
            ..SamplingParams::greedy()
        }
        .validate()
        .is_err());
        assert!(SamplingParams {
            top_p: 0.0,
            ..SamplingParams::greedy()
        }
        .validate()
        .is_err());
        assert!(SamplingParams {
            top_p: 1.5,
            ..SamplingParams::greedy()
        }
        .validate()
        .is_err());
        assert!(SamplingParams {
            temperature: f32::NAN,
            seed: Some(1),
            ..SamplingParams::greedy()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn handle_round_trips_its_id() {
        let h = RequestHandle::new(17);
        assert_eq!(h.id(), 17);
    }
}
