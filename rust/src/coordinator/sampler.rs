//! Engine-side token sampling over backend logits rows.
//!
//! One [`Sampler`] exists per active request, built from its
//! [`SamplingParams`].  The greedy path is a pure argmax with the same
//! first-strictly-greater tie-break as `DecodeRunner::argmax_row`, so
//! greedy-default requests reproduce the pre-sampler pipeline
//! bit-for-bit.  The sampled path is deterministic given the mandatory
//! per-request seed:
//!
//! 1. rank the vocabulary by logit, descending (ties by index and NaN
//!    as `-inf`, so the order is total and platform-independent);
//! 2. keep the `top_k` best (when enabled, via an O(V) partition so
//!    only the k survivors pay the sort);
//! 3. softmax the survivors at `temperature` in f64 with the max
//!    subtracted (sequential accumulation — no platform-dependent
//!    reduction order);
//! 4. keep the smallest prefix reaching cumulative probability `top_p`
//!    (when enabled) — the prefix of the *sorted* order, so the nucleus
//!    is well-defined;
//! 5. draw exactly **one** `Rng::f64` value and walk the cumulative
//!    weights.
//!
//! "Exactly one draw per emitted token" is the determinism contract the
//! serving API documents (`docs/serving-api.md`): a request's token
//! stream is a pure function of `(prompt, SamplingParams)`, independent
//! of batch composition, chunk schedule, or co-resident requests.

use crate::runtime::DecodeRunner;
use crate::util::rng::Rng;

use super::request::SamplingParams;

/// Greedy argmax over one logits row — delegates to
/// `DecodeRunner::argmax_row` so the two call paths can never drift
/// apart (the bit-identity contract depends on a single tie-break rule).
pub fn argmax(row: &[f32]) -> i32 {
    DecodeRunner::argmax_row(row, row.len(), 0)
}

/// Total order for ranking logits: descending value, ascending index on
/// ties; NaN (never produced by the reference backend, but a malformed
/// artifact could) sorts as `-inf` so the comparator stays total — a
/// non-total comparator would panic `sort_by` and kill the engine step.
fn rank(row: &[f32], a: usize, b: usize) -> std::cmp::Ordering {
    let va = if row[a].is_nan() { f32::NEG_INFINITY } else { row[a] };
    let vb = if row[b].is_nan() { f32::NEG_INFINITY } else { row[b] };
    vb.partial_cmp(&va)
        .expect("NaN mapped away above")
        .then(a.cmp(&b))
}

/// Stateful per-request sampler (greedy samplers hold no PRNG at all).
#[derive(Clone, Debug)]
pub struct Sampler {
    params: SamplingParams,
    rng: Option<Rng>,
}

impl Sampler {
    pub fn new(params: &SamplingParams) -> Self {
        params.validate().expect("invalid sampling params");
        let rng = if params.is_greedy() {
            None
        } else {
            Some(Rng::new(params.seed.expect("validated: sampled has a seed")))
        };
        Sampler {
            params: *params,
            rng,
        }
    }

    /// Draw the next token from one logits row.
    pub fn sample(&mut self, row: &[f32]) -> i32 {
        debug_assert!(!row.is_empty(), "empty logits row");
        let Some(rng) = self.rng.as_mut() else {
            return argmax(row);
        };
        // 1+2. Rank by the total order (logit descending, index ascending
        // on ties) and keep the top-k.  With top-k enabled, partition to
        // the k best first so only k elements are fully sorted — O(V +
        // k log k) instead of O(V log V) per emitted token; the partition
        // keeps exactly the set a full sort would, so outputs are
        // bit-identical either way.
        let mut idx: Vec<usize> = (0..row.len()).collect();
        let k = self.params.top_k;
        if k > 0 && k < idx.len() {
            idx.select_nth_unstable_by(k - 1, |&a, &b| rank(row, a, b));
            idx.truncate(k);
        }
        idx.sort_by(|&a, &b| rank(row, a, b));
        // 3. Softmax at temperature, f64, max-subtracted (the same
        // NaN→-inf mapping as `rank`, so a poisoned row degrades to
        // weight 0 instead of NaN-ing the whole distribution).
        let val = |i: usize| -> f64 {
            let v = row[i];
            if v.is_nan() {
                f64::NEG_INFINITY
            } else {
                v as f64
            }
        };
        let t = self.params.temperature as f64;
        let m = val(idx[0]);
        let weights: Vec<f64> = idx.iter().map(|&i| ((val(i) - m) / t).exp()).collect();
        let total: f64 = weights.iter().sum();
        // 4. Nucleus cut on the sorted cumulative distribution.
        let mut cut = weights.len();
        if self.params.top_p < 1.0 {
            let mut acc = 0.0f64;
            for (j, w) in weights.iter().enumerate() {
                acc += w / total;
                if acc >= self.params.top_p as f64 {
                    cut = j + 1;
                    break;
                }
            }
        }
        // Zero-weight survivors (deep underflow, NaN→-inf) can never be
        // drawn; trimming them keeps the top-edge f.p. fallback below on
        // a real candidate.
        while cut > 1 && weights[cut - 1] == 0.0 {
            cut -= 1;
        }
        // 5. One PRNG draw, cumulative walk over the survivors.
        let kept_total: f64 = weights[..cut].iter().sum();
        let u = rng.f64() * kept_total;
        let mut acc = 0.0f64;
        for (j, w) in weights[..cut].iter().enumerate() {
            acc += w;
            if u < acc {
                return idx[j] as i32;
            }
        }
        idx[cut - 1] as i32 // f.p. slack: u landed on the upper edge
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Vec<f32> {
        vec![0.1, 2.0, -1.0, 1.9, 0.5, 1.99]
    }

    #[test]
    fn greedy_matches_argmax_row_semantics() {
        let r = row();
        let mut s = Sampler::new(&SamplingParams::greedy());
        assert_eq!(s.sample(&r), 1);
        assert_eq!(s.sample(&r), 1, "greedy is stateless");
        assert_eq!(argmax(&r), 1);
        // Tie-break: first index wins, exactly like argmax_row.
        assert_eq!(argmax(&[3.0, 3.0, 1.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
    }

    #[test]
    fn same_seed_same_stream() {
        let r = row();
        let p = SamplingParams::sampled(1.0, 42);
        let mut a = Sampler::new(&p);
        let mut b = Sampler::new(&p);
        let sa: Vec<i32> = (0..64).map(|_| a.sample(&r)).collect();
        let sb: Vec<i32> = (0..64).map(|_| b.sample(&r)).collect();
        assert_eq!(sa, sb, "equal seeds must replay bit-identically");
    }

    #[test]
    fn different_seeds_diverge() {
        let r = row();
        let mut a = Sampler::new(&SamplingParams::sampled(1.0, 1));
        let mut b = Sampler::new(&SamplingParams::sampled(1.0, 2));
        let sa: Vec<i32> = (0..64).map(|_| a.sample(&r)).collect();
        let sb: Vec<i32> = (0..64).map(|_| b.sample(&r)).collect();
        assert_ne!(sa, sb, "64 draws over a 6-token near-flat row");
    }

    #[test]
    fn top_k_one_is_greedy_for_any_seed() {
        let r = row();
        let mut s = Sampler::new(&SamplingParams::sampled(2.0, 999).with_top_k(1));
        for _ in 0..16 {
            assert_eq!(s.sample(&r), 1);
        }
    }

    #[test]
    fn tiny_top_p_is_greedy_for_any_seed() {
        let r = row();
        // The single best token already exceeds any p ≤ its probability.
        let mut s = Sampler::new(&SamplingParams::sampled(1.0, 7).with_top_p(1e-6));
        for _ in 0..16 {
            assert_eq!(s.sample(&r), 1);
        }
    }

    #[test]
    fn top_k_bounds_the_support() {
        let r = row();
        // k = 3 keeps indices {1, 5, 3} (logits 2.0, 1.99, 1.9).
        let mut s = Sampler::new(&SamplingParams::sampled(3.0, 5).with_top_k(3));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..256 {
            seen.insert(s.sample(&r));
        }
        assert!(seen.iter().all(|t| [1, 3, 5].contains(t)), "{seen:?}");
        assert!(seen.len() > 1, "temperature 3 over 3 near-ties must vary");
    }

    #[test]
    fn flat_row_samples_every_token_eventually() {
        let r = vec![0.0f32; 8];
        let mut s = Sampler::new(&SamplingParams::sampled(1.0, 3));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..512 {
            let t = s.sample(&r);
            assert!((0..8).contains(&t));
            seen.insert(t);
        }
        assert_eq!(seen.len(), 8, "uniform row must reach all 8 tokens");
    }

    #[test]
    fn nan_logits_never_panic_and_never_win() {
        // A malformed row must not panic the sort (total-order violation)
        // nor be sampled: NaN ranks as -inf.
        let r = vec![0.1, f32::NAN, 2.0, f32::NAN, 0.5];
        let mut s = Sampler::new(&SamplingParams::sampled(1.0, 3));
        for _ in 0..128 {
            let t = s.sample(&r);
            assert!(t == 0 || t == 2 || t == 4, "sampled NaN index {t}");
        }
        // Top-2 of [0.1, NaN, 2.0, NaN, 0.5] is {2 (2.0), 4 (0.5)}.
        let mut s = Sampler::new(&SamplingParams::sampled(1.0, 3).with_top_k(2));
        for _ in 0..32 {
            assert!([2, 4].contains(&s.sample(&r)));
        }
        assert_eq!(argmax(&r), 2);
    }

    #[test]
    fn top_k_partition_matches_full_sort_semantics() {
        // The select_nth_unstable fast path must keep exactly the tokens
        // a full sort would: k=3 over near-ties with a duplicate value.
        let r = vec![1.0, 2.0, 2.0, 1.5, 0.0, 2.0];
        // Descending with index tie-break: [1, 2, 5, 3, 0, 4] → top 3 =
        // {1, 2, 5}.
        let mut s = Sampler::new(&SamplingParams::sampled(5.0, 11).with_top_k(3));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..256 {
            seen.insert(s.sample(&r));
        }
        assert!(seen.iter().all(|t| [1, 2, 5].contains(t)), "{seen:?}");
        assert_eq!(seen.len(), 3, "all three near-ties reachable at temp 5");
    }

    #[test]
    fn zero_temperature_never_builds_a_prng() {
        let s = Sampler::new(&SamplingParams::greedy());
        assert!(s.rng.is_none(), "greedy must not consume entropy");
    }

    #[test]
    #[should_panic(expected = "invalid sampling params")]
    fn unseeded_sampling_panics() {
        Sampler::new(&SamplingParams {
            temperature: 0.5,
            top_k: 0,
            top_p: 1.0,
            seed: None,
        });
    }
}
