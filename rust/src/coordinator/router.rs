//! Admission router: validates requests against artifact buckets and cache
//! capacity before they reach the batcher; plus the prefix-affinity
//! placement policy the fleet executor routes through.
//!
//! There is exactly **one** static validation path — [`validate_request`]
//! — shared by the legacy [`Router::admit`] front door and the fleet
//! executor's admission (`fleet::FleetExecutor::submit`), so solo and
//! fleet admission cannot drift apart.  `GenerationRequest`'s builder
//! asserts the same non-empty/positive invariants as a developer-error
//! backstop (panics at the call site); the serving paths report them as
//! [`AdmitError`]s instead.

use std::collections::HashMap;

use super::request::{Request, RequestId};

/// Why a request was rejected at the door.
#[derive(Debug, PartialEq)]
pub enum AdmitError {
    EmptyPrompt,
    ZeroBudget,
    ContextTooLong { needed: usize, limit: usize },
    BadToken { tok: i32, vocab: usize },
    QueueFull { limit: usize },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::EmptyPrompt => write!(f, "prompt is empty"),
            AdmitError::ZeroBudget => write!(f, "max_new_tokens must be ≥ 1"),
            AdmitError::ContextTooLong { needed, limit } => {
                write!(f, "context {needed} exceeds the largest bucket {limit}")
            }
            AdmitError::BadToken { tok, vocab } => {
                write!(f, "token id {tok} outside vocab {vocab}")
            }
            AdmitError::QueueFull { limit } => write!(f, "queue full ({limit} waiting)"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Validate a raw `(prompt, max_new_tokens)` pair against the model's
/// static limits.  Check order (first violation wins): empty prompt,
/// zero budget, oversize context, out-of-vocab token.  Queue capacity is
/// a dynamic property of whichever queue the request is headed for, so
/// the callers ([`Router::admit`], fleet admission) check it after the
/// static checks pass.
pub fn validate_request(
    prompt: &[i32],
    max_new_tokens: usize,
    max_context: usize,
    vocab: usize,
) -> Result<(), AdmitError> {
    if prompt.is_empty() {
        return Err(AdmitError::EmptyPrompt);
    }
    if max_new_tokens == 0 {
        return Err(AdmitError::ZeroBudget);
    }
    let needed = prompt.len() + max_new_tokens;
    if needed > max_context {
        return Err(AdmitError::ContextTooLong {
            needed,
            limit: max_context,
        });
    }
    if let Some(&tok) = prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
        return Err(AdmitError::BadToken { tok, vocab });
    }
    Ok(())
}

/// Stateless admission validator + id allocator.
pub struct Router {
    max_context: usize,
    vocab: usize,
    max_queue: usize,
    next_id: RequestId,
    pub admitted: u64,
    pub rejected: u64,
}

impl Router {
    pub fn new(max_context: usize, vocab: usize, max_queue: usize) -> Self {
        Router {
            max_context,
            vocab,
            max_queue,
            next_id: 1,
            admitted: 0,
            rejected: 0,
        }
    }

    /// Validate and wrap a raw request: the shared [`validate_request`]
    /// checks first, then this queue's capacity.
    pub fn admit(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        queued_now: usize,
    ) -> Result<Request, AdmitError> {
        if let Err(e) = validate_request(&prompt, max_new_tokens, self.max_context, self.vocab) {
            self.rejected += 1;
            return Err(e);
        }
        if queued_now >= self.max_queue {
            self.rejected += 1;
            return Err(AdmitError::QueueFull {
                limit: self.max_queue,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.admitted += 1;
        Ok(Request::new(id, prompt, max_new_tokens))
    }
}

/// Prefix-affinity placement for the fleet path: route a request to the
/// engine instance most likely to already hold its prompt prefix.
///
/// Each engine's prefix cache is local, so cross-instance placement decides
/// whether sharing can happen at all.  The policy keeps, per worker, a
/// bounded set of *block-aligned prefix fingerprints* (rolling hash per
/// block boundary) of the prompts it has served.  `route` scores workers by
/// the longest fingerprint match — the blocks a hit would reuse — and
/// tie-breaks on least outstanding load, so cold prefixes still spread.
///
/// With a spill threshold set ([`with_spill`](Self::with_spill)), affinity
/// stops being absolute: when the affinity winner's outstanding load
/// exceeds the least-loaded worker's by at least the threshold, the
/// request spills to the least-loaded worker instead.  Combined with
/// fleet-level prefix replication (which makes the hot chain matchable on
/// every engine), this is what turns a hot template from a single-engine
/// hotspot into fleet-wide load spreading.
pub struct PrefixAffinityRouter {
    block_size: usize,
    /// Per-worker: fingerprint → (last-use tick, block depth).  Depth is
    /// kept so capacity trimming drops the *deepest* fingerprints of the
    /// oldest prompt first — dropping a leading fingerprint while its
    /// suffixes survive would zero that prompt's affinity score.
    seen: Vec<HashMap<u64, (u64, u32)>>,
    /// Outstanding requests per worker (caller pairs `route`/`finish`).
    load: Vec<usize>,
    /// Fingerprints retained per worker.
    max_tracked: usize,
    /// Load-imbalance spill threshold; `None` = pure affinity.
    spill_threshold: Option<usize>,
    clock: u64,
}

impl PrefixAffinityRouter {
    pub fn new(workers: usize, block_size: usize, max_tracked: usize) -> Self {
        assert!(workers > 0 && block_size > 0 && max_tracked > 0);
        PrefixAffinityRouter {
            block_size,
            seen: vec![HashMap::new(); workers],
            load: vec![0; workers],
            max_tracked,
            spill_threshold: None,
            clock: 0,
        }
    }

    /// Enable load spilling: when the affinity winner carries at least
    /// `threshold` more outstanding requests than the least-loaded
    /// worker, route there instead.  `threshold` must be ≥ 1 (0 would
    /// make affinity a no-op).
    pub fn with_spill(mut self, threshold: usize) -> Self {
        assert!(threshold > 0, "spill threshold must be ≥ 1");
        self.spill_threshold = Some(threshold);
        self
    }

    pub fn workers(&self) -> usize {
        self.seen.len()
    }

    pub fn load(&self, worker: usize) -> usize {
        self.load[worker]
    }

    /// FNV-1a rolling fingerprints at each whole-block boundary.
    fn fingerprints(&self, tokens: &[i32]) -> Vec<u64> {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut out = Vec::with_capacity(tokens.len() / self.block_size);
        for (i, &t) in tokens.iter().enumerate() {
            for byte in t.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
            if (i + 1) % self.block_size == 0 {
                out.push(h);
            }
        }
        out
    }

    /// Pick the worker for a prompt and record its prefix there.  Returns
    /// the worker index; call [`finish`](Self::finish) when the request
    /// completes to release the load it added.
    ///
    /// Fully deterministic: ties on (matched, load) resolve to the lowest
    /// worker index, and the spill target is the lowest least-loaded
    /// index, so a fixed submit order always produces the same placement.
    pub fn route(&mut self, prompt: &[i32]) -> usize {
        self.clock += 1;
        let fps = self.fingerprints(prompt);
        // Score = number of leading block fingerprints the worker has seen.
        let mut best = 0usize;
        let mut best_key = (0usize, usize::MAX); // (matched, load)
        for w in 0..self.seen.len() {
            let matched = fps
                .iter()
                .take_while(|fp| self.seen[w].contains_key(fp))
                .count();
            let key = (matched, self.load[w]);
            if key.0 > best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                best_key = key;
                best = w;
            }
        }
        if let Some(threshold) = self.spill_threshold {
            let least = (0..self.load.len())
                .min_by_key(|&w| self.load[w])
                .expect("workers > 0");
            if self.load[best] >= self.load[least] + threshold {
                best = least;
            }
        }
        self.load[best] += 1;
        let clock = self.clock;
        let seen = &mut self.seen[best];
        for (depth, fp) in fps.into_iter().enumerate() {
            seen.insert(fp, (clock, depth as u32));
        }
        // Bound memory: drop the oldest prompt's deepest fingerprints
        // first (ascending tick, descending depth, fingerprint value as
        // the final total-order tiebreak), so a surviving fingerprint
        // always has its whole leading chain present and the survivor set
        // never depends on hash-map iteration order.
        if seen.len() > self.max_tracked {
            let mut ages: Vec<(u64, std::cmp::Reverse<u32>, u64)> = seen
                .iter()
                .map(|(&f, &(t, d))| (t, std::cmp::Reverse(d), f))
                .collect();
            ages.sort_unstable();
            let drop = seen.len() - self.max_tracked;
            for &(_, _, f) in ages.iter().take(drop) {
                seen.remove(&f);
            }
        }
        best
    }

    /// Release the load recorded by [`route`](Self::route).  Saturates at
    /// zero: a double-finish (or a finish for a request that was rejected
    /// after routing) must not underflow or poison the router — the
    /// worker simply stays at zero outstanding load.
    pub fn finish(&mut self, worker: usize) {
        self.load[worker] = self.load[worker].saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(255, 512, 8)
    }

    #[test]
    fn admits_valid() {
        let mut r = router();
        let req = r.admit(vec![1, 2, 3], 10, 0).unwrap();
        assert_eq!(req.id, 1);
        let req2 = r.admit(vec![4], 1, 0).unwrap();
        assert_eq!(req2.id, 2, "ids increase");
        assert_eq!(r.admitted, 2);
    }

    #[test]
    fn rejects_empty_and_zero() {
        let mut r = router();
        assert_eq!(r.admit(vec![], 5, 0).unwrap_err(), AdmitError::EmptyPrompt);
        assert_eq!(r.admit(vec![1], 0, 0).unwrap_err(), AdmitError::ZeroBudget);
        assert_eq!(r.rejected, 2);
    }

    #[test]
    fn rejects_oversize_context() {
        let mut r = router();
        let err = r.admit(vec![0; 200], 100, 0).unwrap_err();
        assert_eq!(
            err,
            AdmitError::ContextTooLong {
                needed: 300,
                limit: 255
            }
        );
    }

    #[test]
    fn rejects_bad_tokens() {
        let mut r = router();
        assert!(matches!(
            r.admit(vec![1, 512], 1, 0),
            Err(AdmitError::BadToken { tok: 512, .. })
        ));
        assert!(matches!(
            r.admit(vec![-1], 1, 0),
            Err(AdmitError::BadToken { tok: -1, .. })
        ));
    }

    #[test]
    fn rejects_when_queue_full() {
        let mut r = router();
        assert!(matches!(
            r.admit(vec![1], 1, 8),
            Err(AdmitError::QueueFull { limit: 8 })
        ));
        assert!(r.admit(vec![1], 1, 7).is_ok());
    }

    #[test]
    fn validate_matches_legacy_admit() {
        // One validation path: the standalone validator returns exactly
        // the errors (and thus messages) the legacy front door reports.
        let cases: Vec<(Vec<i32>, usize)> =
            vec![(vec![], 5), (vec![1], 0), (vec![0; 200], 100), (vec![1, 512], 1)];
        for (prompt, budget) in cases {
            let mut r = router();
            let legacy = r.admit(prompt.clone(), budget, 0).unwrap_err();
            let shared = validate_request(&prompt, budget, 255, 512).unwrap_err();
            assert_eq!(legacy, shared);
            assert_eq!(legacy.to_string(), shared.to_string());
        }
        assert!(validate_request(&[1, 2], 10, 255, 512).is_ok());
    }

    fn prompt(system: i32, user: i32) -> Vec<i32> {
        let mut p = vec![system; 8];
        p.extend(vec![user; 4]);
        p
    }

    #[test]
    fn affinity_routes_shared_prefixes_together() {
        let mut r = PrefixAffinityRouter::new(4, 4, 64);
        let w_a = r.route(&prompt(1, 10));
        let w_b = r.route(&prompt(2, 20));
        assert_ne!(w_a, w_b, "cold prefixes spread by load");
        // Every later request with system prompt 1 sticks to w_a, 2 to w_b.
        for u in 30..40 {
            assert_eq!(r.route(&prompt(1, u)), w_a);
            assert_eq!(r.route(&prompt(2, u)), w_b);
        }
    }

    #[test]
    fn affinity_spreads_cold_prefixes_by_load() {
        let mut r = PrefixAffinityRouter::new(3, 4, 64);
        let mut counts = [0usize; 3];
        for s in 0..9 {
            counts[r.route(&prompt(100 + s, 0))] += 1;
        }
        assert_eq!(counts, [3, 3, 3], "round-robins under equal affinity");
    }

    #[test]
    fn affinity_finish_releases_load() {
        let mut r = PrefixAffinityRouter::new(2, 4, 64);
        let w = r.route(&prompt(1, 2));
        assert_eq!(r.load(w), 1);
        r.finish(w);
        assert_eq!(r.load(w), 0);
    }

    #[test]
    fn finish_on_idle_worker_saturates() {
        // Regression: double-finish (or finish after a post-route
        // rejection) used to panic on the zero-load assert; it must
        // saturate and leave the router usable.
        let mut r = PrefixAffinityRouter::new(2, 4, 64);
        r.finish(0);
        r.finish(1);
        assert_eq!(r.load(0), 0);
        assert_eq!(r.load(1), 0);
        let w = r.route(&prompt(1, 2));
        r.finish(w);
        r.finish(w); // double-finish
        assert_eq!(r.load(w), 0);
        // The router still routes and accounts normally afterwards.
        let w2 = r.route(&prompt(1, 3));
        assert_eq!(w2, w, "affinity state survived the saturating finishes");
        assert_eq!(r.load(w2), 1);
    }

    #[test]
    fn affinity_prefers_longer_match() {
        let mut r = PrefixAffinityRouter::new(2, 4, 64);
        // Worker 0 has seen [1;8]+[2;4]; worker 1 a disjoint prompt.
        let mut long = vec![1; 8];
        long.extend(vec![2; 4]);
        let mut other = vec![3; 8];
        other.extend(vec![1; 4]);
        let w_long = r.route(&long);
        let w_other = r.route(&other);
        assert_ne!(w_long, w_other);
        // A query extending the 8-token run of 1s matches w_long deeper.
        let mut q = vec![1; 8];
        q.extend(vec![9; 4]);
        assert_eq!(r.route(&q), w_long);
    }

    #[test]
    fn affinity_fingerprint_cap_bounds_memory() {
        let mut r = PrefixAffinityRouter::new(1, 4, 8);
        for s in 0..100 {
            r.route(&vec![s; 16]);
        }
        assert!(r.seen[0].len() <= 8);
    }

    #[test]
    fn fingerprint_eviction_is_deterministic() {
        // Two routers fed the identical route sequence keep the identical
        // fingerprint survivor sets — eviction sorts on the total order
        // (tick, depth desc, fingerprint), never on hash-map iteration
        // order.
        let feed = |r: &mut PrefixAffinityRouter| {
            for s in 0..50 {
                r.route(&vec![s; 16]); // 4 fingerprints each, cap 8
            }
        };
        let mut a = PrefixAffinityRouter::new(1, 4, 8);
        let mut b = PrefixAffinityRouter::new(1, 4, 8);
        feed(&mut a);
        feed(&mut b);
        let mut fa: Vec<u64> = a.seen[0].keys().copied().collect();
        let mut fb: Vec<u64> = b.seen[0].keys().copied().collect();
        fa.sort_unstable();
        fb.sort_unstable();
        assert_eq!(fa, fb);
        assert_eq!(fa.len(), 8, "trimmed exactly to the cap");
        // Survivors are the newest prompts' fingerprints, leading chains
        // intact: the last two prompts (4 fingerprints each).
        let mut expect: Vec<u64> = Vec::new();
        let probe = PrefixAffinityRouter::new(1, 4, 8);
        for s in 48..50 {
            expect.extend(probe.fingerprints(&vec![s; 16]));
        }
        expect.sort_unstable();
        assert_eq!(fa, expect);
    }

    #[test]
    fn spill_overrides_affinity_under_imbalance() {
        let mut r = PrefixAffinityRouter::new(3, 4, 64).with_spill(2);
        let home = r.route(&prompt(1, 0));
        // Same prefix keeps routing home while the imbalance stays under
        // the threshold...
        assert_eq!(r.route(&prompt(1, 1)), home);
        // ...but once home is 2 ahead of an idle worker, the hot template
        // spills to the least-loaded worker instead of hotspotting.
        let spilled = r.route(&prompt(1, 2));
        assert_ne!(spilled, home);
        assert_eq!(spilled, (0..3).find(|&w| w != home).unwrap(), "lowest idle index");
        // The spilled worker recorded the prefix, so with balanced load it
        // now competes on affinity too (replication makes its tree match).
        r.finish(home);
        r.finish(home);
        let next = r.route(&prompt(1, 3));
        assert_eq!(next, home, "equal match, least load wins deterministically");
    }

    #[test]
    fn spill_disabled_by_default_keeps_pure_affinity() {
        let mut r = PrefixAffinityRouter::new(2, 4, 64);
        let home = r.route(&prompt(7, 0));
        for u in 1..20 {
            assert_eq!(r.route(&prompt(7, u)), home, "no spill without opt-in");
        }
    }
}
