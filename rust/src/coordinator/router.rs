//! Admission router: validates requests against artifact buckets and cache
//! capacity before they reach the batcher.

use super::request::{Request, RequestId};

/// Why a request was rejected at the door.
#[derive(Debug, PartialEq, thiserror::Error)]
pub enum AdmitError {
    #[error("prompt is empty")]
    EmptyPrompt,
    #[error("max_new_tokens must be ≥ 1")]
    ZeroBudget,
    #[error("context {needed} exceeds the largest bucket {limit}")]
    ContextTooLong { needed: usize, limit: usize },
    #[error("token id {tok} outside vocab {vocab}")]
    BadToken { tok: i32, vocab: usize },
    #[error("queue full ({limit} waiting)")]
    QueueFull { limit: usize },
}

/// Stateless admission validator + id allocator.
pub struct Router {
    max_context: usize,
    vocab: usize,
    max_queue: usize,
    next_id: RequestId,
    pub admitted: u64,
    pub rejected: u64,
}

impl Router {
    pub fn new(max_context: usize, vocab: usize, max_queue: usize) -> Self {
        Router {
            max_context,
            vocab,
            max_queue,
            next_id: 1,
            admitted: 0,
            rejected: 0,
        }
    }

    /// Validate and wrap a raw request.
    pub fn admit(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        queued_now: usize,
    ) -> Result<Request, AdmitError> {
        let reject = |e: AdmitError, me: &mut Self| {
            me.rejected += 1;
            Err(e)
        };
        if prompt.is_empty() {
            return reject(AdmitError::EmptyPrompt, self);
        }
        if max_new_tokens == 0 {
            return reject(AdmitError::ZeroBudget, self);
        }
        if queued_now >= self.max_queue {
            return reject(AdmitError::QueueFull { limit: self.max_queue }, self);
        }
        let needed = prompt.len() + max_new_tokens;
        if needed > self.max_context {
            return reject(
                AdmitError::ContextTooLong {
                    needed,
                    limit: self.max_context,
                },
                self,
            );
        }
        if let Some(&tok) = prompt.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            return reject(
                AdmitError::BadToken {
                    tok,
                    vocab: self.vocab,
                },
                self,
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.admitted += 1;
        Ok(Request::new(id, prompt, max_new_tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(255, 512, 8)
    }

    #[test]
    fn admits_valid() {
        let mut r = router();
        let req = r.admit(vec![1, 2, 3], 10, 0).unwrap();
        assert_eq!(req.id, 1);
        let req2 = r.admit(vec![4], 1, 0).unwrap();
        assert_eq!(req2.id, 2, "ids increase");
        assert_eq!(r.admitted, 2);
    }

    #[test]
    fn rejects_empty_and_zero() {
        let mut r = router();
        assert_eq!(r.admit(vec![], 5, 0).unwrap_err(), AdmitError::EmptyPrompt);
        assert_eq!(r.admit(vec![1], 0, 0).unwrap_err(), AdmitError::ZeroBudget);
        assert_eq!(r.rejected, 2);
    }

    #[test]
    fn rejects_oversize_context() {
        let mut r = router();
        let err = r.admit(vec![0; 200], 100, 0).unwrap_err();
        assert_eq!(
            err,
            AdmitError::ContextTooLong {
                needed: 300,
                limit: 255
            }
        );
    }

    #[test]
    fn rejects_bad_tokens() {
        let mut r = router();
        assert!(matches!(
            r.admit(vec![1, 512], 1, 0),
            Err(AdmitError::BadToken { tok: 512, .. })
        ));
        assert!(matches!(
            r.admit(vec![-1], 1, 0),
            Err(AdmitError::BadToken { tok: -1, .. })
        ));
    }

    #[test]
    fn rejects_when_queue_full() {
        let mut r = router();
        assert!(matches!(
            r.admit(vec![1], 1, 8),
            Err(AdmitError::QueueFull { limit: 8 })
        ));
        assert!(r.admit(vec![1], 1, 7).is_ok());
    }
}
