//! L3 serving coordinator — the system the paper's kernel lives inside.
//!
//! FlashMLA-ETAP is a decode-attention kernel for *single-instance serving
//! of DeepSeek-R1 on one 8×H20 server* (paper §1).  This module is that
//! server's control plane, in the style of vLLM's engine:
//!
//! * [`request`] — the submission surface ([`GenerationRequest`] builder,
//!   [`RequestHandle`], per-request [`SamplingParams`]) and the lifecycle
//!   state machine;
//! * [`events`] — the streaming surface: [`StepEvent`]s emitted by every
//!   engine step, drained via `Engine::poll_events`;
//! * [`sampler`] — engine-side token selection over logits rows (greedy
//!   argmax by default, seeded temperature/top-k/top-p otherwise);
//! * [`router`] — admission control + validation against artifact buckets
//!   and KV-cache capacity, plus prefix-affinity placement for multi-
//!   instance deployments;
//! * [`batcher`] — continuous batching: slot management, bucket selection;
//! * [`engine`] — the event-driven decode loop over the PJRT artifacts
//!   (chunked prefill, per-request sampling, cancellation, KV bookkeeping
//!   via the paged latent store);
//! * [`cluster`] — the simulated 8-GPU head-split topology driving the
//!   `sim` kernel models at paper scale (64K contexts the CPU cannot run);
//! * [`metrics`] — TTFT/TPOT/throughput accounting.
//!
//! Python never appears here; the engine executes AOT artifacts only.

pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod events;
pub mod metrics;
pub mod request;
pub mod router;
pub mod sampler;

pub use batcher::{Batcher, BatcherConfig};
pub use cluster::{ClusterConfig, ClusterSim, StepBreakdown, TraceReport, TraceRequest};
pub use engine::{Engine, EngineConfig, EngineReport};
pub use events::{FinishedRequest, RejectReason, StepEvent};
pub use metrics::ServingMetrics;
pub use request::{
    FinishReason, GenerationRequest, Request, RequestHandle, RequestId, RequestState,
    SamplingParams, VerifyOutcome,
};
pub use router::{AdmitError, PrefixAffinityRouter, Router};
pub use sampler::Sampler;
