//! L3 serving coordinator — the system the paper's kernel lives inside.
//!
//! FlashMLA-ETAP is a decode-attention kernel for *single-instance serving
//! of DeepSeek-R1 on one 8×H20 server* (paper §1).  This module is that
//! server's control plane, in the style of vLLM's engine:
//!
//! * [`request`] — the submission surface ([`GenerationRequest`] builder,
//!   [`RequestHandle`], per-request [`SamplingParams`]) and the lifecycle
//!   state machine;
//! * [`events`] — the streaming surface: [`StepEvent`]s emitted by every
//!   engine step, drained via `Engine::poll_events`;
//! * [`sampler`] — engine-side token selection over logits rows (greedy
//!   argmax by default, seeded temperature/top-k/top-p otherwise);
//! * [`router`] — admission control + validation against artifact buckets
//!   and KV-cache capacity, plus prefix-affinity placement for multi-
//!   instance deployments;
//! * [`batcher`] — continuous batching: slot management, bucket selection;
//! * [`engine`] — the event-driven decode loop over the PJRT artifacts
//!   (chunked prefill, per-request sampling, cancellation, KV bookkeeping
//!   via the paged latent store);
//! * [`metrics`] — TTFT/TPOT/throughput accounting.
//!
//! The analytical 8-GPU head-split topology (`ClusterSim`) lives in
//! [`crate::sim::cluster`] next to the rest of the step-time math; it is
//! re-exported here for compatibility.  The *real* multi-engine executor
//! is [`crate::fleet::FleetExecutor`].
//!
//! Python never appears here; the engine executes AOT artifacts only.

pub mod batcher;
pub mod engine;
pub mod events;
pub mod metrics;
pub mod request;
pub mod router;
pub mod sampler;

pub use crate::sim::cluster::{ClusterConfig, ClusterSim, StepBreakdown, TraceReport, TraceRequest};
pub use batcher::{Batcher, BatcherConfig};
pub use engine::{Engine, EngineConfig, EngineReport};
pub use events::{FinishedRequest, FleetEvent, RejectReason, StepEvent};
pub use metrics::ServingMetrics;
pub use request::{
    FinishReason, GenerationRequest, Request, RequestHandle, RequestId, RequestState,
    SamplingParams, VerifyOutcome,
};
pub use router::{validate_request, AdmitError, PrefixAffinityRouter, Router};
pub use sampler::Sampler;
