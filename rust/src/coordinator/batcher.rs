//! Continuous batcher: slot management and bucket selection.
//!
//! The engine runs fixed-shape AOT artifacts, so "batch size" is a bucket
//! (1, 2, 4, 8, …) rather than arbitrary.  The batcher:
//!
//! * keeps a FIFO admission queue;
//! * fills free slots from the queue every step (continuous batching —
//!   requests join/leave without draining the batch, the Orca insight);
//! * picks the smallest (batch-bucket, kv-bucket) artifact that covers the
//!   active set, so short-context batches run on cheap artifacts;
//! * never reorders tokens within a request (FIFO per request is the
//!   correctness property tested below).

use std::collections::VecDeque;

use crate::obs;

use super::request::{Request, RequestId, RequestState};

/// Batcher policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Hard cap on concurrent slots (≤ largest batch bucket).
    pub max_slots: usize,
    /// Available batch-size buckets (sorted ascending), from the manifest.
    pub batch_buckets: Vec<usize>,
    /// Available KV-length buckets (sorted ascending).
    pub kv_buckets: Vec<usize>,
}

impl BatcherConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.max_slots >= 1, "need at least one slot");
        anyhow::ensure!(!self.batch_buckets.is_empty(), "no batch buckets");
        anyhow::ensure!(!self.kv_buckets.is_empty(), "no kv buckets");
        anyhow::ensure!(
            self.batch_buckets.windows(2).all(|w| w[0] < w[1]),
            "batch buckets must be sorted ascending"
        );
        anyhow::ensure!(
            self.kv_buckets.windows(2).all(|w| w[0] < w[1]),
            "kv buckets must be sorted ascending"
        );
        anyhow::ensure!(
            self.max_slots <= *self.batch_buckets.last().unwrap(),
            "max_slots exceeds the largest batch bucket"
        );
        Ok(())
    }
}

/// The continuous batcher.
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<Request>,
    /// Active requests, one per occupied slot (order = slot order).
    active: Vec<Request>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        Ok(Batcher {
            cfg,
            queue: VecDeque::new(),
            active: Vec::new(),
        })
    }

    /// Enqueue an admitted request.
    pub fn submit(&mut self, r: Request) {
        obs::event_with("batcher", "queued", || {
            format!("id={} depth={}", r.id, self.queue.len() + 1)
        });
        self.queue.push_back(r);
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Head of the admission queue (next candidate), if any.  Lets the
    /// engine size eviction pressure before running `admit`.
    pub fn front(&self) -> Option<&Request> {
        self.queue.front()
    }

    pub fn active(&self) -> &[Request] {
        &self.active
    }

    pub fn active_mut(&mut self) -> &mut [Request] {
        &mut self.active
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Remove finished requests, returning them.
    pub fn reap(&mut self) -> Vec<Request> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].is_finished() {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        if !done.is_empty() {
            obs::event_with("batcher", "reap", || {
                format!("n={} active={}", done.len(), self.active.len())
            });
        }
        done
    }

    /// Fill free slots from the queue (FIFO).  Returns the number admitted.
    /// `kv_capacity_ok` lets the engine veto admissions that would exceed
    /// the paged-cache budget.
    pub fn admit(&mut self, mut kv_capacity_ok: impl FnMut(&Request) -> bool) -> usize {
        let mut admitted = 0;
        while self.active.len() < self.cfg.max_slots {
            match self.queue.front() {
                Some(front) if kv_capacity_ok(front) => {
                    let mut r = self.queue.pop_front().unwrap();
                    r.state = RequestState::Prefilling;
                    obs::event_with("batcher", "admit", || {
                        format!("id={} slot={}", r.id, self.active.len())
                    });
                    self.active.push(r);
                    admitted += 1;
                }
                _ => break,
            }
        }
        admitted
    }

    /// Smallest batch bucket covering the active set.
    pub fn batch_bucket(&self) -> usize {
        let need = self.active.len().max(1);
        *self
            .cfg
            .batch_buckets
            .iter()
            .find(|&&b| b >= need)
            .unwrap_or(self.cfg.batch_buckets.last().unwrap())
    }

    /// KV positions the active set needs *after* this step: each active
    /// request writes its next latent at exactly `kv_len()`, so the
    /// attention window grows to `kv_len() + 1`.  (`context_len() + 1`
    /// would over-reserve one slot per decoding request — the newest
    /// generated token is counted there before its latent is written —
    /// and could round a request sitting exactly at a bucket boundary up
    /// to the next KV bucket.)  The engine may raise this further for
    /// anticipated prefix-cache adoptions and multi-token chunks before
    /// rounding up to a bucket.
    pub fn kv_bucket_need(&self) -> usize {
        self.active
            .iter()
            .map(|r| r.kv_len() + 1)
            .max()
            .unwrap_or(1)
    }

    /// Smallest KV bucket covering [`kv_bucket_need`](Self::kv_bucket_need).
    pub fn kv_bucket(&self) -> usize {
        let need = self.kv_bucket_need();
        *self
            .cfg
            .kv_buckets
            .iter()
            .find(|&&n| n >= need)
            .unwrap_or(self.cfg.kv_buckets.last().unwrap())
    }

    /// Abort everything still queued (drain shutdown).  The engine turns
    /// each drained request into a `Rejected` event.
    pub fn abort_queued(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }

    /// Remove and return the head of the queue without admitting it (the
    /// engine rejects requests that can never fit the block pool).
    pub fn reject_front(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Remove a queued request by id (client cancellation), preserving the
    /// FIFO order of everything else.  `None` if the id is not queued.
    pub fn remove_queued(&mut self, id: RequestId) -> Option<Request> {
        let pos = self.queue.iter().position(|r| r.id == id)?;
        self.queue.remove(pos)
    }

    /// Mutable access to an active request by id (cancellation of a
    /// running request marks it finished in place; the next reap frees it).
    pub fn find_active_mut(&mut self, id: RequestId) -> Option<&mut Request> {
        self.active.iter_mut().find(|r| r.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{forall, Config};

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            max_slots: 4,
            batch_buckets: vec![1, 2, 4, 8],
            kv_buckets: vec![128, 256],
        }
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(id, (0..prompt_len as i32).collect(), max_new)
    }

    #[test]
    fn admits_fifo_up_to_slots() {
        let mut b = Batcher::new(cfg()).unwrap();
        for i in 0..6 {
            b.submit(req(i, 3, 2));
        }
        assert_eq!(b.admit(|_| true), 4);
        assert_eq!(b.active().len(), 4);
        assert_eq!(b.queued(), 2);
        let ids: Vec<_> = b.active().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "FIFO admission order");
    }

    #[test]
    fn capacity_veto_blocks_head_of_line() {
        let mut b = Batcher::new(cfg()).unwrap();
        b.submit(req(1, 3, 2));
        b.submit(req(2, 3, 2));
        assert_eq!(b.admit(|r| r.id != 1), 0, "HOL blocking is intentional");
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn reap_frees_slots_for_admission() {
        let mut b = Batcher::new(cfg()).unwrap();
        for i in 0..5 {
            b.submit(req(i, 2, 1));
        }
        b.admit(|_| true);
        b.active_mut()[1].finish(super::super::request::FinishReason::Aborted);
        let done = b.reap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(b.admit(|_| true), 1);
        assert_eq!(b.active().len(), 4);
    }

    #[test]
    fn bucket_selection() {
        let mut b = Batcher::new(cfg()).unwrap();
        assert_eq!(b.batch_bucket(), 1); // empty → smallest
        for i in 0..3 {
            b.submit(req(i, 100, 50));
        }
        b.admit(|_| true);
        assert_eq!(b.batch_bucket(), 4); // 3 active → bucket 4
        assert_eq!(b.kv_bucket(), 128); // contexts start at 0
        // Simulate long contexts.
        for r in b.active_mut() {
            r.prefill_pos = 90;
            r.state = RequestState::Prefilling;
        }
        assert_eq!(b.kv_bucket(), 128); // 91 ≤ 128
        b.active_mut()[0].prefill_pos = 100;
        b.active_mut()[0].generated = (0..40).collect();
        b.active_mut()[0].state = RequestState::Decoding;
        assert_eq!(b.kv_bucket(), 256); // kv_len 139, next write needs 140 > 128
    }

    #[test]
    fn kv_bucket_boundary_request_stays_in_its_bucket() {
        // Regression for the demand formula: a decoding request whose next
        // write lands exactly at the bucket boundary must not be rounded
        // up.  kv_len = 100 + 28 - 1 = 127: the next latent is written at
        // position 127 and the window grows to 128 — bucket 128 holds it.
        // The old `context_len() + 1` formula counted the unfed newest
        // token and demanded 129, spilling into bucket 256.
        let mut b = Batcher::new(cfg()).unwrap();
        b.submit(req(0, 100, 50));
        b.admit(|_| true);
        b.active_mut()[0].prefill_pos = 100;
        b.active_mut()[0].generated = (0..28).collect();
        b.active_mut()[0].state = RequestState::Decoding;
        assert_eq!(b.kv_bucket_need(), 128);
        assert_eq!(b.kv_bucket(), 128, "boundary request must not round up");
        // One more generated token crosses the boundary for real.
        b.active_mut()[0].generated.push(99);
        assert_eq!(b.kv_bucket_need(), 129);
        assert_eq!(b.kv_bucket(), 256);
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(Batcher::new(BatcherConfig {
            max_slots: 0,
            batch_buckets: vec![1],
            kv_buckets: vec![128],
        })
        .is_err());
        assert!(Batcher::new(BatcherConfig {
            max_slots: 9,
            batch_buckets: vec![1, 8],
            kv_buckets: vec![128],
        })
        .is_err());
        assert!(Batcher::new(BatcherConfig {
            max_slots: 1,
            batch_buckets: vec![2, 1],
            kv_buckets: vec![128],
        })
        .is_err());
    }

    #[test]
    fn remove_queued_preserves_order_of_the_rest() {
        let mut b = Batcher::new(cfg()).unwrap();
        for i in 0..4 {
            b.submit(req(i, 3, 2));
        }
        assert_eq!(b.remove_queued(2).map(|r| r.id), Some(2));
        assert!(b.remove_queued(2).is_none(), "already gone");
        assert!(b.remove_queued(99).is_none(), "unknown id");
        assert_eq!(b.admit(|_| true), 3);
        let ids: Vec<_> = b.active().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 3], "FIFO order survives the removal");
    }

    #[test]
    fn abort_queued_drains_in_order_and_spares_active() {
        let mut b = Batcher::new(cfg()).unwrap();
        for i in 0..6 {
            b.submit(req(i, 3, 2));
        }
        b.admit(|_| true); // 0..4 active, 4..6 queued
        let drained: Vec<_> = b.abort_queued().iter().map(|r| r.id).collect();
        assert_eq!(drained, vec![4, 5]);
        assert_eq!(b.queued(), 0);
        assert_eq!(b.active().len(), 4, "active set untouched");
    }

    #[test]
    fn find_active_mut_by_id() {
        let mut b = Batcher::new(cfg()).unwrap();
        b.submit(req(7, 3, 2));
        b.admit(|_| true);
        assert!(b.find_active_mut(8).is_none());
        let r = b.find_active_mut(7).expect("active");
        r.finish(super::super::request::FinishReason::Cancelled);
        assert_eq!(b.reap().len(), 1);
    }

    #[test]
    fn property_slots_never_exceed_max_and_fifo_holds() {
        forall(Config::default().cases(150), |g| {
            let max_slots = g.usize(1..8);
            let mut b = Batcher::new(BatcherConfig {
                max_slots,
                batch_buckets: vec![1, 2, 4, 8],
                kv_buckets: vec![64, 128],
            })
            .unwrap();
            let mut next_id = 0u64;
            let mut admitted_order: Vec<u64> = Vec::new();
            for _ in 0..g.usize(1..40) {
                match g.usize(0..3) {
                    0 => {
                        b.submit(req(next_id, 2, 1));
                        next_id += 1;
                    }
                    1 => {
                        let before: Vec<u64> =
                            b.active().iter().map(|r| r.id).collect();
                        b.admit(|_| true);
                        for r in b.active().iter().skip(before.len()) {
                            admitted_order.push(r.id);
                        }
                    }
                    _ => {
                        if !b.active().is_empty() {
                            let idx = g.usize(0..b.active().len());
                            b.active_mut()[idx]
                                .finish(super::super::request::FinishReason::Aborted);
                            b.reap();
                        }
                    }
                }
                prop_assert!(
                    b.active().len() <= max_slots,
                    "{} slots used of {max_slots}",
                    b.active().len()
                );
            }
            // FIFO: admitted ids are strictly increasing.
            prop_assert!(
                admitted_order.windows(2).all(|w| w[0] < w[1]),
                "admission order violated FIFO: {admitted_order:?}"
            );
            Ok(())
        });
    }
}
