//! Chunked-prefill pipeline: token-budget chunk planning for mixed
//! prefill/decode engine steps.
//!
//! The paper's premise is that prefill-shaped work (long KV, many query
//! tokens) should reach the hardware as large well-shaped tiles, not as
//! degenerate one-token slices.  Before this module the serving engine
//! prefilled prompts one token per engine tick (prefill-as-decode); now
//! each tick packs a *mixed batch* — every decoding slot's single token
//! plus multi-token prefill chunks — under a configurable per-step token
//! budget (the Sarathi-style chunked-prefill shape).
//!
//! The split of responsibilities:
//!
//! * [`ChunkPlanner`] (this module) decides, each tick, how many tokens
//!   every active request consumes.  It is pure and deterministic — same
//!   demands in, same plan out — which is what the property tests lean on.
//! * The backend executes the plan through
//!   [`StepRunner::prefill_chunk`](crate::runtime::StepRunner::prefill_chunk),
//!   the multi-token step operation (native on the reference backend,
//!   documented per-token fallback on PJRT until a chunked artifact lands).
//! * The engine (`coordinator::engine`) wires the two together and keeps
//!   the KV-bucket and paged-store bookkeeping honest.
//!
//! Budget semantics (see `docs/chunked-prefill.md`):
//!
//! * Every active slot makes **at least one token of progress per tick**
//!   (the fixed-shape step executes all slots anyway, and holding a slot
//!   would add no throughput).  The budget therefore binds only *above*
//!   the active-slot count: `total planned ≤ max(step_token_budget,
//!   active slots)`.  A budget below the slot count degenerates to the old
//!   per-token pipeline.
//! * Decoding slots always consume exactly 1 token.
//! * The budget surplus (budget minus the mandatory 1-per-slot) is handed
//!   to prefilling slots, each capped by `chunk_tokens`, by its remaining
//!   prompt, and by the KV bucket headroom the engine reports.
//! * Prefix-cache hits are never re-chunked: the planner sees only the
//!   *unshared suffix* (`prompt.len() - prefill_pos`, where adoption has
//!   already advanced `prefill_pos` past the shared blocks).
//! * Speculative **verification chunks** (`crate::spec`) compete for the
//!   same surplus: a decoding slot with a pending draft may consume
//!   `1 + draft` tokens in one tick, capped by its KV headroom.  The
//!   [`SpecPriority`] knob decides whether verification or prefill is
//!   served from the surplus first; within a class the fairness policy
//!   applies unchanged.

mod planner;

pub use planner::{ChunkPlanner, SlotDemand};

/// How the budget surplus is divided among concurrently prefilling slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FairnessPolicy {
    /// Slot order (≈ admission order): the oldest prefilling request takes
    /// as much of the surplus as it can use before younger ones see any.
    /// Minimizes time-to-first-token for the head request; a hot stream of
    /// short prompts can crowd out a long cold one.
    Fifo,
    /// Round-robin the surplus one token at a time, least-prefilled slot
    /// first.  Cold long prompts keep pace with hot short ones; per-request
    /// TTFT is traded for tail fairness.
    Fair,
}

/// Which class of multi-token chunks is served from the budget surplus
/// first when both compete in one tick (speculative verification chunks
/// vs prefill chunks).  Within a class the [`FairnessPolicy`] applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecPriority {
    /// Verification chunks first (default): drafted tokens directly
    /// compress decode latency for running requests, and drafts are small;
    /// prefill takes what remains.  Under tight budgets a stream of
    /// low-acceptance drafts can slow concurrent prefills.
    Spec,
    /// Prefill chunks first: protects TTFT of queued prompts; verification
    /// only speculates on budget prefill leaves behind.
    Prefill,
}

/// Chunked-prefill knobs, plumbed through `EngineConfig` / `[engine.prefill]`.
#[derive(Clone, Copy, Debug)]
pub struct PrefillConfig {
    /// Target total tokens consumed per engine tick across all slots
    /// (decode slots count 1 each).  Binds only above the active-slot
    /// count; see the module docs for the exact semantics.
    pub step_token_budget: usize,
    /// Hard cap on prompt tokens one request may consume in one tick.
    pub chunk_tokens: usize,
    /// Surplus-division policy (the fairness knob).
    pub fairness: FairnessPolicy,
    /// Who gets the surplus first when speculative verification chunks
    /// compete with prefill chunks (`[engine.prefill] spec_priority`).
    pub spec_priority: SpecPriority,
}

impl Default for PrefillConfig {
    fn default() -> Self {
        PrefillConfig {
            step_token_budget: 32,
            chunk_tokens: 8,
            fairness: FairnessPolicy::Fair,
            spec_priority: SpecPriority::Spec,
        }
    }
}

impl PrefillConfig {
    /// The pre-chunking pipeline: one prompt token per request per tick.
    /// Used as the baseline in equivalence tests and benches.
    pub fn per_token() -> Self {
        PrefillConfig {
            step_token_budget: 0,
            chunk_tokens: 1,
            fairness: FairnessPolicy::Fifo,
            spec_priority: SpecPriority::Spec,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.chunk_tokens >= 1, "chunk_tokens must be ≥ 1");
        Ok(())
    }
}
