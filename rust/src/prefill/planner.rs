//! The token-budget chunk planner.
//!
//! Pure function from per-slot demands to per-slot token counts; the
//! engine calls it once per tick (twice, counting the bucket-sizing
//! estimate).  All invariants the engine and the property tests rely on
//! are listed on [`ChunkPlanner::plan`].

use crate::obs;

use super::{FairnessPolicy, PrefillConfig, SpecPriority};

/// What one active slot wants this tick.
#[derive(Clone, Copy, Debug)]
pub struct SlotDemand {
    /// Prompt tokens not yet consumed (0 ⇒ the request is decoding).
    /// Prefix-cache adoption has already been subtracted: this is the
    /// unshared suffix only, so shared prefixes are never re-chunked.
    pub remaining_prefill: usize,
    /// Prompt tokens already consumed (adopted prefixes count).  The
    /// `Fair` policy serves the least-prefilled slot first.
    pub served_prefill: usize,
    /// Draft tokens pending speculative verification (decoding slots
    /// only; 0 ⇒ plain decode).  A verify slot may consume up to
    /// `1 + pending_draft` tokens: its mandatory decode token plus the
    /// draft it verifies in the same step.
    pub pending_draft: usize,
    /// Most tokens this slot can write this tick (KV-bucket headroom:
    /// positions `ctx .. ctx + headroom` are addressable).  The engine
    /// guarantees ≥ 1 for every active slot.
    pub headroom: usize,
}

impl SlotDemand {
    /// A decoding slot: exactly one token, no prefill state.
    pub fn decode() -> Self {
        SlotDemand {
            remaining_prefill: 0,
            served_prefill: 0,
            pending_draft: 0,
            headroom: 1,
        }
    }

    /// A prefilling slot.
    pub fn prefill(remaining: usize, served: usize, headroom: usize) -> Self {
        SlotDemand {
            remaining_prefill: remaining,
            served_prefill: served,
            pending_draft: 0,
            headroom,
        }
    }

    /// A decoding slot with `draft` tokens awaiting verification.
    pub fn verify(draft: usize, headroom: usize) -> Self {
        SlotDemand {
            remaining_prefill: 0,
            served_prefill: 0,
            pending_draft: draft,
            headroom,
        }
    }

    pub fn is_prefill(&self) -> bool {
        self.remaining_prefill > 0
    }

    pub fn is_verify(&self) -> bool {
        self.remaining_prefill == 0 && self.pending_draft > 0
    }
}

/// Plans per-tick token consumption under the budget.
#[derive(Clone, Debug)]
pub struct ChunkPlanner {
    cfg: PrefillConfig,
}

impl ChunkPlanner {
    pub fn new(cfg: PrefillConfig) -> Self {
        ChunkPlanner { cfg }
    }

    pub fn config(&self) -> &PrefillConfig {
        &self.cfg
    }

    /// Per-slot cap on this tick's chunk, before budget division.
    fn cap(&self, d: &SlotDemand) -> usize {
        if d.is_prefill() {
            self.cfg
                .chunk_tokens
                .min(d.remaining_prefill)
                .min(d.headroom)
                .max(1)
        } else if d.is_verify() {
            // The decode token plus its draft; `chunk_tokens` does not cap
            // verification (the draft was already bounded by
            // `spec.max_draft` when proposed).
            (1 + d.pending_draft).min(d.headroom).max(1)
        } else {
            1 // decoding: always exactly one token
        }
    }

    /// Plan one tick.  Returns `plan` aligned with `demands` (slot order).
    ///
    /// Invariants (property-tested in this module):
    ///
    /// 1. `plan[i] == 1` for every plain decoding slot
    ///    (`remaining_prefill == 0`, `pending_draft == 0`);
    /// 2. `1 ≤ plan[i] ≤ min(chunk_tokens, remaining_prefill, headroom)`
    ///    for every prefilling slot;
    /// 3. `1 ≤ plan[i] ≤ min(1 + pending_draft, headroom)` for every
    ///    verify slot;
    /// 4. `Σ plan[i] ≤ max(step_token_budget, demands.len())` — the budget
    ///    binds above the mandatory one-token-per-slot floor;
    /// 5. deterministic: equal inputs produce equal plans.
    ///
    /// The surplus is handed out class-by-class (`spec_priority` decides
    /// whether verify or prefill chunks are served first); within a class
    /// the fairness policy divides it.
    pub fn plan(&self, demands: &[SlotDemand]) -> Vec<usize> {
        let plan = self.plan_inner(demands);
        // Fires twice per engine tick (bucket-sizing estimate + final);
        // both are deterministic, and the pair shows adoption shifts.
        obs::event_with("planner", "plan", || self.plan_summary(demands, &plan));
        plan
    }

    fn plan_inner(&self, demands: &[SlotDemand]) -> Vec<usize> {
        let n = demands.len();
        let mut plan = vec![0usize; n];
        if n == 0 {
            return plan;
        }
        // Mandatory floor: every active slot consumes one token.
        for (i, p) in plan.iter_mut().enumerate() {
            debug_assert!(demands[i].headroom >= 1, "slot {i} has no KV headroom");
            *p = 1;
        }
        let mut surplus = self.cfg.step_token_budget.saturating_sub(n);
        if surplus == 0 {
            return plan;
        }

        // Candidates that can take more than the floor, split by class.
        let verify: Vec<usize> = (0..n)
            .filter(|&i| demands[i].is_verify() && self.cap(&demands[i]) > 1)
            .collect();
        let prefill: Vec<usize> = (0..n)
            .filter(|&i| demands[i].is_prefill() && self.cap(&demands[i]) > 1)
            .collect();
        let classes = match self.cfg.spec_priority {
            SpecPriority::Spec => [verify, prefill],
            SpecPriority::Prefill => [prefill, verify],
        };
        for mut cands in classes {
            if surplus == 0 || cands.is_empty() {
                continue;
            }
            self.distribute(&mut cands, demands, &mut plan, &mut surplus);
        }
        plan
    }

    /// Divide `surplus` among `cands` (indices into `demands`) under the
    /// fairness policy.  `cands` arrive in slot order.
    fn distribute(
        &self,
        cands: &mut Vec<usize>,
        demands: &[SlotDemand],
        plan: &mut [usize],
        surplus: &mut usize,
    ) {
        match self.cfg.fairness {
            FairnessPolicy::Fifo => {
                for &i in cands.iter() {
                    if *surplus == 0 {
                        break;
                    }
                    let take = (self.cap(&demands[i]) - plan[i]).min(*surplus);
                    plan[i] += take;
                    *surplus -= take;
                }
            }
            FairnessPolicy::Fair => {
                // Least-prefilled first; ties broken by slot order so the
                // plan is deterministic.  Verify slots all carry
                // `served_prefill == 0`, so among themselves `Fair` is a
                // plain slot-order round-robin.
                cands.sort_by_key(|&i| (demands[i].served_prefill, i));
                // Round-robin one token at a time until the surplus is gone
                // or every candidate is at its cap.
                let mut progressed = true;
                while *surplus > 0 && progressed {
                    progressed = false;
                    for &i in cands.iter() {
                        if *surplus == 0 {
                            break;
                        }
                        if plan[i] < self.cap(&demands[i]) {
                            plan[i] += 1;
                            *surplus -= 1;
                            progressed = true;
                        }
                    }
                }
            }
        }
    }

    /// Render one tick's plan for logs: per slot `d1` (decode),
    /// `p<k>/<remaining>` (prefill chunk of `k` against the remaining
    /// unshared suffix), or `v1+<m>/<draft>` (decode token plus `m` of the
    /// pending draft), after a `used/budget` header.  Deterministic; the
    /// speculative example and benches print it so mixed
    /// decode+prefill+verify ticks are inspectable without a debugger.
    pub fn plan_summary(&self, demands: &[SlotDemand], plan: &[usize]) -> String {
        debug_assert_eq!(demands.len(), plan.len());
        let used: usize = plan.iter().sum();
        let mut s = format!(
            "plan[used {used}/{}]",
            self.cfg.step_token_budget.max(demands.len())
        );
        for (i, (d, &k)) in demands.iter().zip(plan).enumerate() {
            if d.is_prefill() {
                s.push_str(&format!(" s{i}=p{k}/{}", d.remaining_prefill));
            } else if d.is_verify() {
                s.push_str(&format!(" s{i}=v1+{}/{}", k - 1, d.pending_draft));
            } else {
                s.push_str(&format!(" s{i}=d{k}"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{forall, Config};

    fn planner(budget: usize, chunk: usize, fairness: FairnessPolicy) -> ChunkPlanner {
        ChunkPlanner::new(PrefillConfig {
            step_token_budget: budget,
            chunk_tokens: chunk,
            fairness,
            ..PrefillConfig::default()
        })
    }

    fn planner_prio(budget: usize, prio: SpecPriority) -> ChunkPlanner {
        ChunkPlanner::new(PrefillConfig {
            step_token_budget: budget,
            chunk_tokens: 8,
            fairness: FairnessPolicy::Fair,
            spec_priority: prio,
        })
    }

    #[test]
    fn decode_only_batch_takes_one_each() {
        let p = planner(32, 8, FairnessPolicy::Fair);
        let plan = p.plan(&[SlotDemand::decode(); 4]);
        assert_eq!(plan, vec![1, 1, 1, 1]);
    }

    #[test]
    fn single_prefill_gets_whole_chunk() {
        let p = planner(32, 8, FairnessPolicy::Fair);
        let plan = p.plan(&[SlotDemand::prefill(100, 0, 64)]);
        assert_eq!(plan, vec![8], "capped by chunk_tokens");
        let plan = p.plan(&[SlotDemand::prefill(3, 0, 64)]);
        assert_eq!(plan, vec![3], "capped by remaining prompt");
        let plan = p.plan(&[SlotDemand::prefill(100, 0, 5)]);
        assert_eq!(plan, vec![5], "capped by KV headroom");
    }

    #[test]
    fn verify_slot_takes_its_draft() {
        let p = planner(32, 8, FairnessPolicy::Fair);
        let plan = p.plan(&[SlotDemand::verify(4, 64), SlotDemand::decode()]);
        assert_eq!(plan, vec![5, 1], "decode token + the whole draft");
        let plan = p.plan(&[SlotDemand::verify(4, 3)]);
        assert_eq!(plan, vec![3], "capped by KV headroom");
        // Verification is not capped by chunk_tokens.
        let p = planner(64, 2, FairnessPolicy::Fair);
        let plan = p.plan(&[SlotDemand::verify(9, 64)]);
        assert_eq!(plan, vec![10]);
    }

    #[test]
    fn budget_below_slot_count_degenerates_to_per_token() {
        let p = planner(2, 8, FairnessPolicy::Fair);
        let plan = p.plan(&[
            SlotDemand::prefill(50, 0, 64),
            SlotDemand::decode(),
            SlotDemand::prefill(50, 0, 64),
        ]);
        assert_eq!(plan, vec![1, 1, 1]);
        // Verify slots degrade to plain decode the same way.
        let plan = p.plan(&[SlotDemand::verify(4, 64), SlotDemand::verify(4, 64)]);
        assert_eq!(plan, vec![1, 1]);
    }

    #[test]
    fn decode_traffic_shrinks_prefill_share_but_never_to_zero() {
        let p = planner(8, 8, FairnessPolicy::Fair);
        // 6 decode slots eat 6 of the 8-token budget.
        let mut demands = vec![SlotDemand::decode(); 6];
        demands.push(SlotDemand::prefill(50, 0, 64));
        let plan = p.plan(&demands);
        assert_eq!(&plan[..6], &[1, 1, 1, 1, 1, 1]);
        assert_eq!(plan[6], 2, "floor 1 + the single surplus token");
    }

    #[test]
    fn fair_splits_surplus_evenly() {
        let p = planner(18, 8, FairnessPolicy::Fair);
        let plan = p.plan(&[
            SlotDemand::prefill(100, 0, 64),
            SlotDemand::prefill(100, 0, 64),
        ]);
        assert_eq!(plan, vec![8, 8], "room for both full chunks");
        let p = planner(10, 8, FairnessPolicy::Fair);
        let plan = p.plan(&[
            SlotDemand::prefill(100, 0, 64),
            SlotDemand::prefill(100, 0, 64),
        ]);
        assert_eq!(plan, vec![5, 5], "tight budget split evenly");
    }

    #[test]
    fn fair_prefers_least_served() {
        let p = planner(7, 8, FairnessPolicy::Fair);
        // Slot 0 is far ahead; the cold slot 1 gets the odd extra token.
        let plan = p.plan(&[
            SlotDemand::prefill(100, 90, 64),
            SlotDemand::prefill(100, 2, 64),
        ]);
        assert_eq!(plan.iter().sum::<usize>(), 7);
        assert!(plan[1] > plan[0], "cold slot favored: {plan:?}");
    }

    #[test]
    fn fifo_gives_head_slot_everything() {
        let p = planner(10, 8, FairnessPolicy::Fifo);
        let plan = p.plan(&[
            SlotDemand::prefill(100, 90, 64),
            SlotDemand::prefill(100, 0, 64),
        ]);
        assert_eq!(plan, vec![8, 2], "head takes its full chunk first");
    }

    #[test]
    fn spec_priority_orders_the_classes() {
        // Surplus 4 over the 2-slot floor; both classes want more.
        let demands = [SlotDemand::verify(4, 64), SlotDemand::prefill(50, 0, 64)];
        let plan = planner_prio(6, SpecPriority::Spec).plan(&demands);
        assert_eq!(plan, vec![5, 1], "verify drains the surplus first");
        let plan = planner_prio(6, SpecPriority::Prefill).plan(&demands);
        assert_eq!(plan, vec![1, 5], "prefill drains the surplus first");
        // With room for both, priority does not matter.
        let plan = planner_prio(32, SpecPriority::Prefill).plan(&demands);
        assert_eq!(plan, vec![5, 8], "room for both: full draft and full chunk");
    }

    #[test]
    fn per_token_config_is_exact_old_pipeline() {
        let p = ChunkPlanner::new(PrefillConfig::per_token());
        let plan = p.plan(&[
            SlotDemand::prefill(100, 0, 64),
            SlotDemand::decode(),
            SlotDemand::prefill(2, 1, 64),
        ]);
        assert_eq!(plan, vec![1, 1, 1]);
    }

    #[test]
    fn plan_summary_renders_all_slot_kinds() {
        let p = planner(32, 8, FairnessPolicy::Fair);
        let demands = [
            SlotDemand::decode(),
            SlotDemand::prefill(40, 0, 64),
            SlotDemand::verify(4, 64),
        ];
        let plan = p.plan(&demands);
        let s = p.plan_summary(&demands, &plan);
        assert!(s.starts_with("plan[used "), "summary: {s}");
        assert!(s.contains("s0=d1"), "summary: {s}");
        assert!(s.contains("s1=p8/40"), "summary: {s}");
        assert!(s.contains("s2=v1+4/4"), "summary: {s}");
    }

    #[test]
    fn property_plan_invariants() {
        forall(Config::default().cases(300), |g| {
            let budget = g.usize(0..64);
            let chunk = g.usize(1..17);
            let fairness = if g.bool() {
                FairnessPolicy::Fair
            } else {
                FairnessPolicy::Fifo
            };
            let prio = if g.bool() {
                SpecPriority::Spec
            } else {
                SpecPriority::Prefill
            };
            let p = ChunkPlanner::new(PrefillConfig {
                step_token_budget: budget,
                chunk_tokens: chunk,
                fairness,
                spec_priority: prio,
            });
            let n = g.usize(1..12);
            let demands: Vec<SlotDemand> = (0..n)
                .map(|_| match g.usize(0..3) {
                    0 => SlotDemand::decode(),
                    1 => SlotDemand::prefill(g.usize(1..200), g.usize(0..200), g.usize(1..128)),
                    _ => SlotDemand::verify(g.usize(1..9), g.usize(1..128)),
                })
                .collect();
            let plan = p.plan(&demands);
            let plan2 = p.plan(&demands);
            prop_assert!(plan == plan2, "non-deterministic plan");
            let total: usize = plan.iter().sum();
            prop_assert!(
                total <= budget.max(n),
                "budget violated: {total} > max({budget}, {n})"
            );
            for (i, d) in demands.iter().enumerate() {
                prop_assert!(plan[i] >= 1, "slot {i} starved");
                if d.is_prefill() {
                    prop_assert!(
                        plan[i] <= chunk.min(d.remaining_prefill).min(d.headroom).max(1),
                        "slot {i} over cap: {} (chunk {chunk}, rem {}, head {})",
                        plan[i],
                        d.remaining_prefill,
                        d.headroom
                    );
                } else if d.is_verify() {
                    prop_assert!(
                        plan[i] <= (1 + d.pending_draft).min(d.headroom).max(1),
                        "verify slot {i} over cap: {} (draft {}, head {})",
                        plan[i],
                        d.pending_draft,
                        d.headroom
                    );
                } else {
                    prop_assert!(plan[i] == 1, "decode slot {i} got {}", plan[i]);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_chunks_cover_each_prompt_exactly_once() {
        // Drive a simulated lifecycle: prompts start with a random adopted
        // (shared) prefix; per tick, plan → apply.  Every prompt's unshared
        // suffix must be covered exactly once — no token skipped, none
        // consumed twice, adopted prefixes never re-chunked — and the loop
        // must terminate (liveness: every tick makes progress).
        forall(Config::default().cases(120), |g| {
            let budget = g.usize(0..48);
            let chunk = g.usize(1..12);
            let fairness = if g.bool() {
                FairnessPolicy::Fair
            } else {
                FairnessPolicy::Fifo
            };
            let p = planner(budget, chunk, fairness);
            let n = g.usize(1..8);
            let lens: Vec<usize> = (0..n).map(|_| g.usize(1..60)).collect();
            let adopted: Vec<usize> = lens.iter().map(|&l| g.usize(0..l)).collect();
            let mut pos = adopted.clone();
            let mut ticks = 0usize;
            while pos.iter().zip(&lens).any(|(&p, &l)| p < l) {
                ticks += 1;
                prop_assert!(ticks < 10_000, "planner failed to make progress");
                let demands: Vec<SlotDemand> = pos
                    .iter()
                    .zip(&lens)
                    .map(|(&p, &l)| {
                        if p < l {
                            SlotDemand::prefill(l - p, p, 128)
                        } else {
                            SlotDemand::decode()
                        }
                    })
                    .collect();
                let plan = p.plan(&demands);
                for i in 0..n {
                    if pos[i] < lens[i] {
                        prop_assert!(
                            plan[i] <= lens[i] - pos[i],
                            "slot {i} chunk overruns its prompt"
                        );
                        pos[i] += plan[i];
                    }
                }
            }
            for i in 0..n {
                prop_assert!(
                    pos[i] == lens[i],
                    "slot {i} covered {} of {} (adopted {})",
                    pos[i],
                    lens[i],
                    adopted[i]
                );
            }
            Ok(())
        });
    }
}
