//! Property-testing mini-framework (proptest substitute).

pub mod prop;

pub use prop::{forall, Config, Gen};
