//! Seeded property testing: generators over `util::rng::Rng`, a `forall`
//! runner with shrinking-lite (retry with smaller size parameter), and
//! failure reports that print the reproducing seed.
//!
//! Usage:
//! ```no_run
//! use flashmla_etap::prop_assert;
//! use flashmla_etap::testing::{forall, Config};
//! forall(Config::default().cases(200), |g| {
//!     let xs = g.vec_f64(1..100, -1e3..1e3);
//!     let sum: f64 = xs.iter().sum();
//!     let rev: f64 = xs.iter().rev().sum();
//!     prop_assert!((sum - rev).abs() < 1e-3, "sum order: {sum} vs {rev}");
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Size scaling in [0,1] ramps up over the run (small cases first).
    pub max_size: f64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed override via env for CI reproduction.
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xF1A5_4313);
        Config {
            cases: 100,
            seed,
            max_size: 1.0,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Per-case generator handle: draws values from the case's RNG, scaled by
/// the ramp-up `size` so early cases are small (shrinking-lite).
pub struct Gen {
    rng: Rng,
    size: f64,
    pub case_index: usize,
}

impl Gen {
    /// Integer in `range`, biased toward the low end early in the run.
    pub fn usize(&mut self, range: Range<usize>) -> usize {
        assert!(range.start < range.end);
        let span = range.end - range.start;
        let scaled = ((span as f64 - 1.0) * self.size).floor() as usize + 1;
        range.start + self.rng.below(scaled.max(1))
    }

    pub fn u64(&mut self, range: Range<u64>) -> u64 {
        self.usize(range.start as usize..range.end as usize) as u64
    }

    pub fn f64(&mut self, range: Range<f64>) -> f64 {
        range.start + self.rng.f64() * (range.end - range.start)
    }

    pub fn f32(&mut self, range: Range<f32>) -> f32 {
        self.f64(range.start as f64..range.end as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.rng.normal_f32()
    }

    /// Vector with length drawn from `len` and normal(0,1) f32 entries.
    pub fn normal_vec(&mut self, len: Range<usize>) -> Vec<f32> {
        let n = self.usize(len);
        self.rng.normal_vec(n)
    }

    /// Vector with uniform f64 entries.
    pub fn vec_f64(&mut self, len: Range<usize>, vals: Range<f64>) -> Vec<f64> {
        let n = self.usize(len);
        (0..n).map(|_| self.f64(vals.clone())).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// Token-id vector with length drawn from `len` over a small vocabulary
    /// (`0..vocab`).  Small vocabularies make shared prefixes likely, which
    /// is exactly what prefix-cache and kv-sharing properties need.
    pub fn tokens(&mut self, len: Range<usize>, vocab: i32) -> Vec<i32> {
        assert!(vocab > 0);
        let n = self.usize(len);
        (0..n)
            .map(|_| self.rng.below(vocab as usize) as i32)
            .collect()
    }

    /// Raw RNG access for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `body` over `cfg.cases` generated cases; panics with the seed and
/// case index on the first failure.
pub fn forall<F>(cfg: Config, body: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        // Ramp size: the first ~25% of cases use small inputs, making the
        // first failure likely to be near-minimal (shrinking-lite).
        let ramp = ((case + 1) as f64 / (cfg.cases as f64 * 0.25)).min(1.0);
        let mut gen = Gen {
            rng: root.fork(case as u64),
            size: ramp * cfg.max_size,
            case_index: case,
        };
        if let Err(msg) = body(&mut gen) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}, PROP_SEED={} to reproduce):\n  {msg}",
                cfg.cases, cfg.seed, cfg.seed
            );
        }
    }
}

/// `assert!` for property bodies: returns Err(String) instead of panicking
/// so `forall` can attach the seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Approximate-equality prop assert.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol) = ($a as f64, $b as f64, $tol as f64);
        if (a - b).abs() > tol {
            return Err(format!(
                "{} ≉ {} (|Δ| = {:e} > tol {:e})",
                a, b, (a - b).abs(), tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // Count via a cell captured by the closure.
        let counter = std::cell::Cell::new(0usize);
        forall(Config::default().cases(50), |g| {
            counter.set(counter.get() + 1);
            let v = g.vec_f64(1..20, -1.0..1.0);
            prop_assert!(!v.is_empty());
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(Config::default().cases(50).seed(1), |g| {
            let n = g.usize(1..100);
            prop_assert!(n < 90, "n was {n}");
            Ok(())
        });
    }

    #[test]
    fn sizes_ramp_up() {
        let maxes = std::cell::Cell::new((usize::MAX, 0usize));
        forall(Config::default().cases(100), |g| {
            let n = g.usize(1..1000);
            let (lo, hi) = maxes.get();
            if g.case_index < 5 {
                maxes.set((lo.min(n), hi));
            }
            if g.case_index > 90 {
                maxes.set((lo, hi.max(n)));
            }
            Ok(())
        });
        let (early_min, late_max) = maxes.get();
        assert!(early_min < 200, "early cases should be small: {early_min}");
        assert!(late_max > 200, "late cases should reach larger sizes: {late_max}");
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let v = std::cell::RefCell::new(Vec::new());
            forall(Config::default().cases(10).seed(seed), |g| {
                v.borrow_mut().push(g.usize(0..1000));
                Ok(())
            });
            v.into_inner()
        };
        assert_eq!(collect(5), collect(5));
        assert_ne!(collect(5), collect(6));
    }
}
