//! Software IEEE-754 binary16 (`f16`) and bfloat16 (`bf16`) (half-crate
//! substitute).
//!
//! Conversions use round-to-nearest-even, matching GPU tensor-core and TPU
//! behaviour — this is what makes the Table 1 RMSE experiment meaningful:
//! the FA-3-style kernel model accumulates through repeated f16 roundings
//! while the ETAP model keeps f32 accumulators and rounds once.

#![allow(non_camel_case_types)]

/// IEEE-754 binary16: 1 sign, 5 exponent, 10 mantissa bits.
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct f16(pub u16);

/// bfloat16: 1 sign, 8 exponent, 7 mantissa bits (truncated f32).
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct bf16(pub u16);

impl f16 {
    pub const ZERO: f16 = f16(0);
    pub const ONE: f16 = f16(0x3C00);
    pub const INFINITY: f16 = f16(0x7C00);
    pub const NEG_INFINITY: f16 = f16(0xFC00);
    pub const MAX: f16 = f16(0x7BFF); // 65504
    /// Smallest positive normal (2^-14).
    pub const MIN_POSITIVE: f16 = f16(0x0400);
    /// Machine epsilon (2^-10).
    pub const EPSILON: f16 = f16(0x1400);

    /// Convert from f32 with round-to-nearest-even (IEEE default).
    pub fn from_f32(x: f32) -> f16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x007F_FFFF;

        if exp == 0xFF {
            // Inf / NaN: preserve a quiet NaN payload bit.
            return if man == 0 {
                f16(sign | 0x7C00)
            } else {
                f16(sign | 0x7E00)
            };
        }
        // Rebias: f32 bias 127 → f16 bias 15.
        let unbiased = exp - 127;
        if unbiased >= 16 {
            return f16(sign | 0x7C00); // overflow → inf
        }
        if unbiased >= -14 {
            // Normal range. 23→10 mantissa bits: round off 13 bits RNE.
            let half_exp = ((unbiased + 15) as u32) << 10;
            let half_man = man >> 13;
            let round_bits = man & 0x1FFF;
            let mut h = sign as u32 | half_exp | half_man;
            if round_bits > 0x1000 || (round_bits == 0x1000 && (half_man & 1) == 1) {
                h += 1; // carries correctly into the exponent
            }
            return f16(h as u16);
        }
        if unbiased >= -25 {
            // Subnormal f16.
            let full_man = man | 0x0080_0000; // implicit leading 1
            let shift = (-14 - unbiased + 13) as u32;
            let half_man = full_man >> shift;
            let rem = full_man & ((1u32 << shift) - 1);
            let halfway = 1u32 << (shift - 1);
            let mut h = sign as u32 | half_man;
            if rem > halfway || (rem == halfway && (half_man & 1) == 1) {
                h += 1;
            }
            return f16(h as u16);
        }
        f16(sign) // underflow → signed zero
    }

    /// Convert to f32 (exact).
    pub fn to_f32(self) -> f32 {
        let h = self.0 as u32;
        let sign = (h & 0x8000) << 16;
        let exp = (h >> 10) & 0x1F;
        let man = h & 0x03FF;
        let bits = if exp == 0 {
            if man == 0 {
                sign // signed zero
            } else {
                // Subnormal: normalize.
                let lead = man.leading_zeros() - 22; // zeros within 10-bit field
                let man_norm = (man << (lead + 1)) & 0x03FF;
                let exp32 = 127 - 15 - lead;
                sign | (exp32 << 23) | (man_norm << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (man << 13) // inf / nan
        } else {
            sign | ((exp + 127 - 15) << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

impl bf16 {
    pub const ZERO: bf16 = bf16(0);
    pub const ONE: bf16 = bf16(0x3F80);

    /// Convert from f32 with round-to-nearest-even.
    pub fn from_f32(x: f32) -> bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            return bf16(((bits >> 16) as u16) | 0x0040); // quiet
        }
        let lower = bits & 0xFFFF;
        let upper = bits >> 16;
        let rounded = if lower > 0x8000 || (lower == 0x8000 && (upper & 1) == 1) {
            upper + 1
        } else {
            upper
        };
        bf16(rounded as u16)
    }

    /// Convert to f32 (exact: bf16 is truncated f32).
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F80) == 0x7F80 && (self.0 & 0x007F) != 0
    }
}

/// Round an f32 through f16 precision (the "store to f16 register" op).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16::from_f32(x).to_f32()
}

/// Round an f32 through bf16 precision.
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    bf16::from_f32(x).to_f32()
}

/// f16-precision fused a*b+c as a tensor-core-style MAC: the product is
/// exact in f32, the accumulate result is rounded back to f16 (models
/// WGMMA with an f16 accumulator — the low-precision mode the paper's
/// Table 1 baseline suffers from).
#[inline]
pub fn mac_f16_acc(a: f32, b: f32, c: f32) -> f32 {
    round_f16(round_f16(a) * round_f16(b) + c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_values() {
        assert_eq!(f16::from_f32(0.0).0, 0x0000);
        assert_eq!(f16::from_f32(-0.0).0, 0x8000);
        assert_eq!(f16::from_f32(1.0).0, 0x3C00);
        assert_eq!(f16::from_f32(-2.0).0, 0xC000);
        assert_eq!(f16::from_f32(65504.0).0, 0x7BFF); // f16::MAX
        assert_eq!(f16::from_f32(0.5).0, 0x3800);
        assert_eq!(f16::from_f32(0.099976).0, 0x2E66); // ≈0.1 in f16
    }

    #[test]
    fn f16_round_trip_exact_for_representables() {
        // All 2^16 bit patterns that are finite numbers round-trip exactly.
        let mut checked = 0u32;
        for bits in 0u16..=0xFFFF {
            let h = f16(bits);
            if h.is_nan() {
                assert!(f16::from_f32(h.to_f32()).is_nan());
                continue;
            }
            let back = f16::from_f32(h.to_f32());
            assert_eq!(back.0, h.0, "bits {bits:#06x}");
            checked += 1;
        }
        assert!(checked > 63000);
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert_eq!(f16::from_f32(1e6), f16::INFINITY);
        assert_eq!(f16::from_f32(-1e6), f16::NEG_INFINITY);
        assert_eq!(f16::from_f32(65520.0), f16::INFINITY); // just past MAX+ulp/2
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 2.0f32.powi(-24); // smallest positive subnormal
        assert_eq!(f16::from_f32(tiny).0, 0x0001);
        assert_eq!(f16(0x0001).to_f32(), tiny);
        let below = 2.0f32.powi(-26);
        assert_eq!(f16::from_f32(below).0, 0x0000); // underflow
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 → ties to even (1.0).
        let halfway = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f16::from_f32(halfway).0, f16::ONE.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 → even is 1+2^-9... no:
        // mantissa 1 (odd) vs 2 (even) → rounds up to 2.
        let halfway2 = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f16::from_f32(halfway2).0, 0x3C02);
    }

    #[test]
    fn f16_nan_propagates() {
        assert!(f16::from_f32(f32::NAN).is_nan());
        assert!(f16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn bf16_known_values() {
        assert_eq!(bf16::from_f32(1.0).0, 0x3F80);
        assert_eq!(bf16::from_f32(-1.0).0, 0xBF80);
        assert_eq!(bf16::from_f32(0.0).0, 0x0000);
        // 3.140625 is exactly representable (0x4049).
        assert_eq!(bf16::from_f32(3.140625).0, 0x4049);
    }

    #[test]
    fn bf16_round_trip() {
        for bits in [0x0000u16, 0x3F80, 0xC000, 0x7F00, 0x0080, 0x4049] {
            let b = bf16(bits);
            assert_eq!(bf16::from_f32(b.to_f32()).0, bits);
        }
    }

    #[test]
    fn bf16_rne() {
        // f32 1.0 + 2^-8 is halfway between bf16 1.0 (0x3F80) and 0x3F81 → even.
        let x = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16::from_f32(x).0, 0x3F80);
        let y = f32::from_bits(0x3F81_8000); // halfway, odd → up
        assert_eq!(bf16::from_f32(y).0, 0x3F82);
    }

    #[test]
    fn f16_monotone_on_grid() {
        let mut prev = f32::NEG_INFINITY;
        for i in -1000..1000 {
            let x = i as f32 * 0.37;
            let r = round_f16(x.clamp(-60000.0, 60000.0));
            if x > prev {
                // rounding is monotone
                assert!(r >= round_f16(prev.clamp(-60000.0, 60000.0)));
            }
            prev = x;
        }
    }

    #[test]
    fn mac_f16_loses_small_addends() {
        // 2048 + 1 == 2048 in f16 (ulp at 2048 is 2) — the accumulation
        // pathology Table 1's baseline exhibits.
        assert_eq!(mac_f16_acc(1.0, 1.0, 2048.0), 2048.0);
        // While f32 accumulation keeps it.
        assert_eq!(1.0f32 * 1.0 + 2048.0, 2049.0);
    }
}
