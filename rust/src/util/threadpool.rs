//! Fixed-size worker thread pool (rayon/tokio substitute) used by the
//! coordinator's worker topology and the bench harness.
//!
//! Jobs are `FnOnce` closures dispatched over an MPMC channel built from
//! `std::sync::mpsc` + a mutexed receiver; completion is tracked with a
//! `WaitGroup`-style counter so callers can block on a batch of jobs.
//!
//! ## Panic propagation
//!
//! A panicking job must not hang the caller or kill a worker: each job
//! runs under `catch_unwind`, the pending counter is decremented no
//! matter how the job exits, and a sticky panic flag is re-raised from
//! [`ThreadPool::wait`] on the *caller's* thread.  [`ThreadPool::map`]
//! waits internally, so a panic inside any mapped closure propagates to
//! the `map` caller instead of deadlocking the batch — the contract the
//! engine's parallel tick path relies on.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    panicked: Arc<AtomicBool>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let panicked = Arc::clone(&panicked);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // Catch the panic so the worker survives
                                // and the decrement below always runs —
                                // otherwise `wait()` hangs forever.
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panicked.store(true, Ordering::SeqCst);
                                }
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => return, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
            panicked,
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.  If any job
    /// panicked since the last `wait`, the panic is re-raised here (the
    /// flag is cleared first, so the pool stays usable afterwards).
    pub fn wait(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
        drop(p);
        if self.panicked.swap(false, Ordering::SeqCst) {
            panic!("thread pool job panicked (propagated by ThreadPool::wait)");
        }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Map a function over items in parallel, preserving input order.
    ///
    /// Order is structural, not scheduling-dependent: each job writes
    /// its result into the slot for its *input index*, so however the
    /// workers interleave, `out[i] == f(items[i])`.  A panic in any
    /// `f(item)` propagates to this caller via the internal [`wait`]
    /// (`Self::wait`) rather than hanging the batch.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> = Arc::new(Mutex::new(
            items.iter().map(|_| None).collect(),
        ));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait();
        Arc::try_unwrap(results)
            .ok()
            .expect("all jobs done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job completed"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Atomic counter handy for cross-thread metrics.
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicUsize::new(0))
    }

    pub fn add(&self, n: usize) -> usize {
        self.0.fetch_add(n, Ordering::Relaxed) + n
    }

    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(Counter::new());
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.add(1);
            });
        }
        pool.wait();
        assert_eq!(counter.get(), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn wait_with_no_jobs_returns() {
        let pool = ThreadPool::new(1);
        pool.wait();
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(Counter::new());
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.add(1);
            });
        }
        drop(pool); // must not hang; jobs may or may not all run before close
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| pool.wait()));
        assert!(err.is_err(), "wait() must re-raise the worker panic");
        // The flag is cleared and the workers survived: the pool keeps
        // executing jobs and a clean batch waits cleanly.
        let counter = Arc::new(Counter::new());
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.add(1);
            });
        }
        pool.wait();
        assert_eq!(counter.get(), 8);
    }

    #[test]
    fn map_propagates_worker_panic() {
        let pool = ThreadPool::new(3);
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map((0..10).collect::<Vec<u64>>(), |x| {
                if x == 7 {
                    panic!("poisoned item");
                }
                x
            })
        }));
        assert!(err.is_err(), "map must propagate the item panic");
    }

    #[test]
    fn map_preserves_order_under_contention() {
        // Deterministically jittered job durations force out-of-order
        // completion; results must still land at their input index.
        let pool = ThreadPool::new(4);
        let out = pool.map((0..64).collect::<Vec<u64>>(), |x| {
            std::thread::sleep(std::time::Duration::from_micros((x * 37) % 1100));
            x * 3 + 1
        });
        assert_eq!(out, (0..64).map(|x| x * 3 + 1).collect::<Vec<u64>>());
    }

    #[test]
    fn parallel_speedup_structure() {
        // Not a timing assert — just that concurrent jobs interleave.
        let pool = ThreadPool::new(4);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..8 {
            let order = Arc::clone(&order);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis((8 - i) * 2));
                order.lock().unwrap().push(i);
            });
        }
        pool.wait();
        assert_eq!(order.lock().unwrap().len(), 8);
    }
}
