//! Build-everything substrates: the crates.io closure available offline is
//! limited to the `xla` dependency tree, so the usual ecosystem pieces
//! (rand, half, serde_json, clap, criterion's stats) are implemented here.

pub mod argparse;
pub mod half;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod toml;

pub use half::{bf16, f16};
pub use rng::Rng;
