//! Statistics utilities: summary stats, percentiles, RMSE, and a latency
//! histogram for the serving metrics (criterion/hdrhistogram substitute).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation on the sorted data; `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Root-mean-square error between two equal-length slices.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "rmse: length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    let se: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (se / a.len() as f64).sqrt()
}

/// RMSE for f32 data against an f64 reference.
pub fn rmse_f32_vs_f64(a: &[f32], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let se: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| {
            let d = *x as f64 - y;
            d * d
        })
        .sum();
    (se / a.len() as f64).sqrt()
}

/// Maximum absolute difference.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Fold another accumulator in (the parallel-variance combination):
    /// the result is exactly the accumulator of the concatenated streams,
    /// up to f64 rounding.  Used by `ServingMetrics::merge`.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.n = n;
    }
}

/// Log-bucketed latency histogram: covers 1 µs … ~17 min with ≤ ~4 % bucket
/// relative error, fixed memory, O(1) record.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// 32 sub-buckets per octave over 30 octaves from 1 µs.
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
}

const SUB: usize = 32;
const OCTAVES: usize = 30;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; SUB * OCTAVES],
            total: 0,
            sum_us: 0.0,
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let log = us.log2();
        let octave = log.floor();
        let frac = log - octave;
        let idx = octave as usize * SUB + (frac * SUB as f64) as usize;
        idx.min(SUB * OCTAVES - 1)
    }

    fn bucket_value(idx: usize) -> f64 {
        let octave = (idx / SUB) as f64;
        let frac = (idx % SUB) as f64 / SUB as f64;
        2f64.powf(octave + frac)
    }

    pub fn record_us(&mut self, us: f64) {
        self.counts[Self::bucket_of(us.max(0.0))] += 1;
        self.total += 1;
        self.sum_us += us;
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    /// Approximate percentile in µs.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(SUB * OCTAVES - 1)
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 25.0), 1.75);
    }

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn rmse_length_mismatch_panics() {
        rmse(&[1.0], &[1.0, 2.0]);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.5, -3.0, 7.0, 0.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), -3.0);
        assert_eq!(w.max(), 7.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn welford_merge_equals_single_stream() {
        let xs = [1.0, 2.5, -3.0, 7.0, 0.25, 4.0, -1.5];
        for split in 0..=xs.len() {
            let mut a = Welford::new();
            let mut b = Welford::new();
            for &x in &xs[..split] {
                a.push(x);
            }
            for &x in &xs[split..] {
                b.push(x);
            }
            let mut whole = Welford::new();
            for &x in &xs {
                whole.push(x);
            }
            a.merge(&b);
            assert_eq!(a.count(), whole.count());
            assert!((a.mean() - whole.mean()).abs() < 1e-12, "split {split}");
            assert!((a.stddev() - whole.stddev()).abs() < 1e-12, "split {split}");
            assert_eq!(a.min(), whole.min());
            assert_eq!(a.max(), whole.max());
        }
    }

    #[test]
    fn histogram_percentiles_approximate() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record_us(i as f64);
        }
        let p50 = h.percentile_us(50.0);
        let p99 = h.percentile_us(99.0);
        assert!((p50 / 5000.0 - 1.0).abs() < 0.06, "p50 {p50}");
        assert!((p99 / 9900.0 - 1.0).abs() < 0.06, "p99 {p99}");
        assert!((h.mean_us() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(100.0);
        b.record_us(200.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
